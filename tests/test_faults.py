"""Fault-tolerant serving tests: degraded packages and placement around
holes, the seeded FaultInjector, scripted scenario parsing, executor
failure/recovery semantics (spill, static revive, degraded re-solve),
SolutionCache isolation between intact and degraded fingerprints, and the
ft trainer's shared fault vocabulary + poison-step regression."""
from __future__ import annotations

import jax.numpy as jnp
import pytest

from repro import scope
from repro.core.hw import get_hw, mcm_hetero
from repro.core.regions import flavor_zones, zigzag_order, zigzag_placement
from repro.ft import ResilientTrainer
from repro.multimodel.quota import package_flavors
from repro.serving import (
    FaultEvent,
    FaultInjector,
    InjectedFault,
    Poisson,
    allocate_submeshes,
    parse_faults,
    request_trace,
)


@pytest.fixture(scope="module")
def hetero16():
    return get_hw("mcm16_hetero")       # 8 big + 8 little on a (4, 4) mesh


@pytest.fixture(scope="module")
def served_hetero():
    """A 2-model co-schedule on mcm16_hetero with SLOs, plus its shared
    cache -- the substrate for every executor fault scenario below."""
    cache = scope.SolutionCache()
    prob = scope.problem("alexnet:1:500,resnet18:1:500", "mcm16_hetero",
                         m_samples=16)
    sol = cache.solve(prob)
    assert sol.feasible and sol.multi.mode == "partitioned"
    return sol, cache


def _serve(sol, cache, horizon=4.0, seed=0, **kw):
    return sol.serve(rate_scale=0.75, horizon_s=horizon, seed=seed,
                     cache=cache, **kw)


# ---------------------------------------------------------------------------
# degraded packages: HardwareModel.disable_chips / disable_seam
# ---------------------------------------------------------------------------

class TestDisableChips:
    def test_counts_shrink_and_holes_accumulate(self, hetero16):
        dead = [(0, 0), (2, 1)]        # one big, one little
        hw = hetero16.disable_chips(dead)
        assert hw.chips == 14
        assert dict((t.name, t.chips) for t in hw.region_types) == {
            "big": 7, "little": 7,
        }
        assert hw.dead_chips == ((0, 0), (2, 1))
        # occupied mesh footprint is unchanged: holes stay holes
        assert hw.occupied_coords() == hetero16.occupied_coords()

    def test_chained_disable(self, hetero16):
        hw = hetero16.disable_chips([(0, 0)]).disable_chips([(0, 1)])
        assert hw.chips == 14
        assert hw.dead_chips == ((0, 0), (0, 1))
        assert dict((t.name, t.chips) for t in hw.region_types) == {
            "big": 6, "little": 8,
        }

    def test_whole_flavor_dropped(self, hetero16):
        little = flavor_zones(package_flavors(hetero16),
                              hetero16.mesh_shape)["little"]
        hw = hetero16.disable_chips(little)
        assert [t.name for t in hw.region_types] == ["big"]
        assert hw.chips == 8

    def test_homogeneous(self):
        hw = get_hw("mcm16").disable_chips([(1, 2)])
        assert hw.chips == 15 and hw.region_types == ()

    def test_errors(self, hetero16):
        with pytest.raises(ValueError, match="unoccupied"):
            hetero16.disable_chips([(9, 9)])
        with pytest.raises(ValueError, match="every chip is dead"):
            hetero16.disable_chips(hetero16.occupied_coords())

    def test_fingerprints_isolated(self, served_hetero):
        """Intact and degraded packages never share a cache entry; the
        same degraded package twice is a whole-solution hit."""
        sol, cache = served_hetero
        hw_d = sol.hw.disable_chips([(3, 0)])
        prob_d = scope.Problem(
            package=scope.PackageSpec(hw=hw_d),
            workload=sol.problem.workload,
            options=sol.problem.options,
        )
        misses0 = cache.stats["solution_misses"]
        sol_d = cache.solve(prob_d)
        assert not cache.last_hit
        assert sol_d.feasible
        assert cache.stats["solution_misses"] == misses0 + 1
        cache.solve(prob_d)
        assert cache.last_hit

    def test_disable_seam_overrides(self, hetero16):
        hw = hetero16.disable_seam("big", "little", bw=1.0)
        assert ("big", "little", 1.0) in hw.seam_bw_overrides
        assert hw.seam_link_bw("big", "little") == 1.0
        # repair by re-override replaces, not stacks
        hw2 = hw.disable_seam("little", "big", bw=2.0)
        assert sum(1 for x, y, _ in hw2.seam_bw_overrides
                   if {x, y} == {"big", "little"}) == 1
        with pytest.raises(KeyError):
            hetero16.disable_seam("big", "medium")


# ---------------------------------------------------------------------------
# placement around holes
# ---------------------------------------------------------------------------

class TestDegradedPlacement:
    def test_flavor_zones_minus_holes(self, hetero16):
        pristine = flavor_zones(package_flavors(hetero16),
                                hetero16.mesh_shape)
        dead = {(0, 1), (2, 2), (3, 0)}
        hw = hetero16.disable_chips(dead)
        zones = flavor_zones(package_flavors(hw), hw.mesh_shape,
                             dead=hw.dead_chips)
        for f in ("big", "little"):
            assert zones[f] == [c for c in pristine[f] if c not in dead]

    def test_zigzag_placement_skips_holes(self):
        dead = {(0, 2), (1, 3)}
        regions = zigzag_placement([3, 4], (4, 4), dead=dead)
        walk = [c for c in zigzag_order((4, 4)) if c not in dead]
        assert regions == [walk[:3], walk[3:7]]

    def test_flavored_placement_skips_holes(self, hetero16):
        hw = hetero16.disable_chips([(1, 2), (2, 0)])   # 7 big + 7 little
        counts = package_flavors(hw)
        regions = zigzag_placement(
            [4, 3, 7], hw.mesh_shape,
            region_flavors=["big", "big", "little"],
            flavor_counts=counts, dead=hw.dead_chips,
        )
        flat = [c for reg in regions for c in reg]
        assert len(set(flat)) == 14
        assert not set(flat) & set(hw.dead_chips)
        zones = flavor_zones(counts, hw.mesh_shape, dead=hw.dead_chips)
        assert set(regions[2]) == set(zones["little"])

    def test_spanning_quota_stays_seam_adjacent(self, hetero16):
        """A chip_quota spanning both flavors still gets the seam-facing
        slice of each degraded zone."""
        from repro.core.graph import (
            MM_PARTITIONED,
            ModelAssignment,
            MultiModelSchedule,
            ScopeSchedule,
        )

        hw = hetero16.disable_chips([(1, 0), (2, 0)])   # seam-side holes
        sched = ScopeSchedule(workload="w", chips=0, segments=(),
                              latency=1.0)
        mm = MultiModelSchedule(
            mode=MM_PARTITIONED,
            package=hw.name,
            chips=hw.chips,
            assignments=(
                ModelAssignment(model="span", weight=1.0, chips=4,
                                schedule=sched,
                                chip_quota=(("big", 2), ("little", 2))),
                ModelAssignment(model="solo", weight=1.0, chips=3,
                                schedule=sched, chip_type="little"),
            ),
        )
        out = allocate_submeshes(mm, hw)
        zones = flavor_zones(package_flavors(hw), hw.mesh_shape,
                             dead=hw.dead_chips)
        # spanning model: end of the big zone + front of the little zone
        assert out["span"]["big"] == zones["big"][-2:]
        assert out["span"]["little"] == zones["little"][:2]
        assert out["solo"]["little"] == zones["little"][2:5]

    def test_overcommitted_degraded_zone_raises(self, served_hetero):
        """The pristine co-schedule does NOT fit the degraded package --
        that's exactly why the executor must re-solve."""
        sol, _ = served_hetero
        hw = sol.hw.disable_chips([(2, 0)])
        with pytest.raises(ValueError, match="overcommit|contiguous"):
            allocate_submeshes(sol.multi, hw)


# ---------------------------------------------------------------------------
# FaultInjector + scripted DSL
# ---------------------------------------------------------------------------

class TestFaultInjector:
    def test_parse_faults(self, hetero16):
        events = parse_faults("zone:little@2:6; chip:0,1@3", hetero16)
        assert [(e.t, e.kind, e.target) for e in events] == [
            (2.0, "fail", "zone:little"),
            (3.0, "fail", "chip:0,1"),
            (6.0, "repair", "zone:little"),
        ]
        assert len(events[0].chips) == 8
        assert events[1].chips == ((0, 1),)

    def test_parse_faults_percent_and_seam(self, hetero16):
        events = parse_faults("seam:big+little@25%:75%", hetero16,
                              horizon_s=8.0)
        assert [(e.t, e.kind) for e in events] == [(2.0, "fail"),
                                                   (6.0, "repair")]
        assert events[0].seam == ("big", "little")
        assert events[0].chips == ()

    def test_parse_errors(self, hetero16):
        for bad in ("zone:little", "zone:huge@1", "chip:9,9@1",
                    "zone:little@5:2", "chip:0@1"):
            with pytest.raises(ValueError):
                parse_faults(bad, hetero16)
        with pytest.raises(ValueError, match="horizon"):
            parse_faults("zone:little@50%", hetero16)

    def test_schedule_deterministic_and_alternating(self, hetero16):
        inj = FaultInjector(hetero16, seed=3, zone_mtbf_s=2.0,
                            zone_mttr_s=0.5)
        ev1 = inj.schedule(50.0)
        ev2 = FaultInjector(hetero16, seed=3, zone_mtbf_s=2.0,
                            zone_mttr_s=0.5).schedule(50.0)
        assert ev1 == ev2 and len(ev1) > 4
        for target in ("zone:big", "zone:little"):
            kinds = [e.kind for e in ev1 if e.target == target]
            assert kinds == ["fail", "repair"] * (len(kinds) // 2) + (
                ["fail"] if len(kinds) % 2 else [])

    def test_streams_independent(self, hetero16):
        """Turning chip chaos on must not perturb the zone streams (each
        component draws from its own crc32-keyed rng)."""
        zones_only = FaultInjector(hetero16, seed=1, zone_mtbf_s=3.0)
        both = FaultInjector(hetero16, seed=1, zone_mtbf_s=3.0,
                             chip_mtbf_s=5.0)
        pick = lambda evs: [(e.t, e.kind, e.target) for e in evs
                            if e.target.startswith("zone:")]
        assert pick(zones_only.schedule(30.0)) == pick(both.schedule(30.0))

    def test_scripted_coercion(self, hetero16):
        inj = FaultInjector(
            hetero16,
            scripted=(
                "chip:0,0@1:2",
                ("zone:little", 3.0, 4.0),
                FaultEvent(t=5.0, kind="fail", target="chip:0,1",
                           chips=((0, 1),)),
            ),
        )
        sched = inj.schedule(10.0)
        assert [(e.t, e.kind) for e in sched] == [
            (1.0, "fail"), (2.0, "repair"), (3.0, "fail"),
            (4.0, "repair"), (5.0, "fail"),
        ]
        # past-horizon events are clipped
        assert all(e.t < 3.5 for e in inj.schedule(3.5))


# ---------------------------------------------------------------------------
# executor failure / recovery semantics
# ---------------------------------------------------------------------------

class TestExecutorFaults:
    def test_static_degrade_and_revive(self, served_hetero):
        """No resolver: the killed model's queue stalls until repair, then
        its original server comes back; everything is conserved."""
        sol, cache = served_hetero
        rep = _serve(sol, cache, faults="zone:little@1:2.5",
                     fault_recovery=False)
        assert rep.conserved
        f = rep.faults
        assert f["events"] == 2
        assert [e["kind"] for e in f["log"]] == ["fail", "repair"]
        killed = f["log"][0]["killed"]
        assert killed                      # someone lives on little chips
        assert f["log"][1]["revived"] == killed
        assert f["recoveries"] and not f["recoveries"][0]["resolved"]
        assert f["recoveries"][0]["ttr_s"] == pytest.approx(1.5)
        assert f["unrecovered"] == 0
        # dead time really happened: availability dips below 1
        assert 0.5 < f["availability"] < 1.0
        for m in killed:
            assert f["downtime_s"][m] == pytest.approx(1.5, abs=1e-6)

    def test_resolver_recovers_with_cache_miss_then_hit(self, served_hetero):
        sol, cache = served_hetero
        rep = _serve(sol, cache, faults="zone:little@1:1.8; zone:little@3:3.8")
        assert rep.conserved
        f = rep.faults
        recs = f["recoveries"]
        assert len(recs) == 2 and all(r["resolved"] for r in recs)
        # first degraded solve is a miss, the repeat failure is a hit
        assert recs[0]["cache_hit"] is False
        assert recs[1]["cache_hit"] is True
        # recovery is a redeploy away, orders of magnitude under the MTTR
        assert f["mean_ttr_s"] < 0.1
        assert f["availability"] > 0.99
        assert f["redeploy_dead_s"] > 0
        # repair re-solves land back on the pristine fingerprint
        repairs = [e for e in f["log"] if e["kind"] == "repair"]
        assert all(e["resolve"]["applied"] for e in repairs)
        assert all(e["resolve"]["dead_chips"] == 0 for e in repairs)

    def test_goodput_through_failure_beats_static(self, served_hetero):
        """Identical trace + schedule: the degraded re-solve must carry
        more SLO-gated goodput through the failure window than static
        degradation, and recover to near the pre-fault rate."""
        sol, cache = served_hetero
        kw = dict(horizon=4.0, faults="zone:little@25%:75%")
        auto = _serve(sol, cache, **kw)
        static = _serve(sol, cache, fault_recovery=False, **kw)
        assert auto.conserved and static.conserved
        assert auto.goodput > static.goodput
        fa = auto.faults
        assert fa["goodput_in_failure"] > (
            static.faults["goodput_in_failure"] or 0.0)
        assert fa["goodput_post_recovery"] > 0.9 * fa["goodput_pre_fault"]

    def test_never_repaired_strands_queue(self, served_hetero):
        """A failure with no repair and no resolver: the model's queued
        samples are still conserved (queued_end), not lost."""
        sol, cache = served_hetero
        rep = _serve(sol, cache, horizon=2.0, faults="zone:little@1",
                     fault_recovery=False)
        assert rep.conserved
        assert rep.total_queued_end > 0
        assert rep.faults["unrecovered"] == 1
        killed = rep.faults["log"][0]["killed"]
        assert all(rep.per_model[m].queued_end_samples > 0 for m in killed)

    def test_spilled_batch_is_reserved_not_lost(self, served_hetero):
        """The in-flight batch at failure time spills back and is served
        after recovery -- total completions equal arrivals."""
        sol, cache = served_hetero
        rep = _serve(sol, cache, faults="zone:little@1:1.5")
        spilled = sum(e["spilled_samples"] for e in rep.faults["log"]
                      if e["kind"] == "fail")
        assert spilled > 0
        assert rep.conserved
        assert rep.total_completed == rep.total_arrived

    def test_seam_fault_kills_only_spanning_models(self, served_hetero):
        sol, cache = served_hetero
        spans = {
            a.model for a in sol.multi.assignments
            if len([q for q in (a.chip_quota or ()) if q[1] > 0]) > 1
        }
        rep = _serve(sol, cache, faults="seam:big+little@1:2",
                     fault_recovery=False)
        assert rep.conserved
        assert set(rep.faults["log"][0]["killed"]) == spans

    def test_chip_fault_random_chaos_conserves(self, served_hetero):
        sol, cache = served_hetero
        inj = FaultInjector(sol.hw, seed=11, chip_mtbf_s=1.5,
                            chip_mttr_s=0.3)
        rep = _serve(sol, cache, faults=inj)
        assert rep.faults["events"] > 0
        assert rep.conserved
        assert rep.faults["unrecovered"] == 0

    def test_queue_full_drop_cause_named(self, served_hetero):
        sol, cache = served_hetero
        trace = request_trace({"alexnet": Poisson(4000.0),
                               "resnet18": Poisson(50.0)}, 1.0, seed=0)
        rep = sol.serve(trace=trace, horizon_s=1.0, cache=cache,
                        max_queue=64, faults="chip:3,0@0.4:0.6")
        assert rep.conserved
        drops = rep.per_model["alexnet"].drop_causes
        assert drops.get("queue_full", (0, 0))[1] > 0
        assert rep.total_dropped > 0

    def test_fault_report_serializes(self, served_hetero):
        import json

        sol, cache = served_hetero
        rep = _serve(sol, cache, faults="zone:little@1:2")
        blob = json.loads(json.dumps(rep.to_json()))
        assert blob["conserved"] is True
        assert blob["faults"]["events"] == 2
        assert any("availability" in line for line in rep.describe())


# ---------------------------------------------------------------------------
# ft bridge: shared fault vocabulary + poison-step regression
# ---------------------------------------------------------------------------

def _mini_trainer(tmp_path, **kw):
    def train_step(params, opt, batch):
        loss = jnp.mean((params["w"] - batch["target"]) ** 2)
        params = {
            "w": params["w"] - 0.1 * 2 * (params["w"] - batch["target"])
        }
        return params, opt, {"loss": loss}

    return ResilientTrainer(
        train_step=train_step,
        batch_fn=lambda step: {"target": jnp.ones((4,)) * 2.0},
        ckpt_dir=str(tmp_path), ckpt_every=5, **kw,
    )


class TestFtBridge:
    def test_step_hook_windows(self, hetero16):
        inj = FaultInjector(hetero16, scripted=(("chip:0,0", 3.0, 6.0),))
        hook = inj.step_hook(n_steps=10)
        with pytest.raises(InjectedFault, match="chip:0,0"):
            hook(3)
        # transient semantics: the replay of the same window passes
        for s in range(10):
            hook(s)

    def test_trainer_accepts_injector(self, tmp_path, hetero16):
        inj = FaultInjector(hetero16, scripted=(("zone:little", 7.0, 8.0),))
        tr = _mini_trainer(tmp_path)
        params, _, hist = tr.run({"w": jnp.zeros((4,))}, {}, n_steps=12,
                                 failure_injector=inj)
        steps = [h["step"] for h in hist]
        # failed at 7 -> restored to checkpoint 5 -> replayed to the end
        assert steps.count(7) == 2 and steps[-1] == 12

    def test_transient_faults_on_distinct_steps_not_poison(self, tmp_path):
        """Regression: N transient faults on N different steps must not
        trip the poison-step abort (retries are per step index)."""
        fired = set()

        def injector(step):
            # one transient fault on each of 4 distinct steps -- more
            # total failures than max_retries_per_step
            if step in (6, 7, 8, 9) and step not in fired:
                fired.add(step)
                raise RuntimeError("transient")

        tr = _mini_trainer(tmp_path, max_retries_per_step=3)
        params, _, hist = tr.run({"w": jnp.zeros((4,))}, {}, n_steps=12,
                                 failure_injector=injector)
        assert hist[-1]["step"] == 12

    def test_true_poison_step_still_aborts(self, tmp_path):
        def injector(step):
            if step == 6:
                raise RuntimeError("always fails")

        tr = _mini_trainer(tmp_path, max_retries_per_step=3)
        with pytest.raises(RuntimeError, match="step 6 failed 4x"):
            tr.run({"w": jnp.zeros((4,))}, {}, n_steps=12,
                   failure_injector=injector)
