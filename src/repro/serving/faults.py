"""Seeded fault injection for the serving executor (and the ft trainer).

A :class:`FaultInjector` turns a hardware package into a deterministic
stream of :class:`FaultEvent` failure/repair pairs over three target
kinds:

* ``chip:r,c`` -- one chip at mesh coordinate ``(r, c)``;
* ``zone:<flavor>`` -- a whole flavor zone (``zone:*`` on a homogeneous
  package is every chip);
* ``seam:a+b`` -- the interconnect seam between two adjacent flavor
  zones (chips survive; cross-seam deployments lose service until
  repair).

Random lifetimes are alternating exponential MTBF/MTTR draws, one
independent stream per component keyed exactly like the traffic
generators -- ``numpy.random.default_rng([seed, crc32(name)])``
(:func:`repro.serving.traffic.model_rng`) -- so adding a chip stream
never perturbs another component's schedule, and the trainer and the
serving simulator replay identical chaos from one (seed, hardware) pair.
Scripted scenarios (``"zone:little@2:6;chip:0,1@3"``, parsed by
:func:`parse_faults`) ride the same event type.

The serving executor consumes ``FaultInjector.schedule(horizon)`` (or a
raw event list); the training path (:mod:`repro.ft.runner`) consumes
:meth:`FaultInjector.step_hook`, which maps step indices onto the same
failure windows and raises :class:`InjectedFault` the first time a step
lands inside each window.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from ..core.hw import HardwareModel
from ..core.regions import flavor_zones
from ..multimodel.quota import package_flavors
from .traffic import model_rng

__all__ = [
    "FaultEvent",
    "FaultInjector",
    "InjectedFault",
    "parse_faults",
]


class InjectedFault(RuntimeError):
    """Raised by :meth:`FaultInjector.step_hook` inside a failure window."""


@dataclass(frozen=True, order=True)
class FaultEvent:
    """One state change of the package: ``kind`` is ``"fail"`` or
    ``"repair"``; ``chips`` are the mesh coordinates affected (empty for a
    seam event); ``seam`` is the unordered flavor pair of a seam target."""
    t: float
    kind: str
    target: str
    chips: tuple[tuple[int, int], ...] = ()
    seam: tuple[str, str] | None = None

    def __post_init__(self):
        if self.kind not in ("fail", "repair"):
            raise ValueError(f"fault kind {self.kind!r}")

    def to_json(self) -> dict:
        return {
            "t": self.t, "kind": self.kind, "target": self.target,
            "chips": [list(c) for c in self.chips],
            "seam": list(self.seam) if self.seam else None,
        }


def resolve_target(
    target: str, hw: HardwareModel
) -> tuple[tuple[tuple[int, int], ...], tuple[str, str] | None]:
    """Map a target string onto ``(chip coords, seam pair)`` for ``hw``.

    Zones resolve against the *pristine* flavor zones (the package as
    built); chip coordinates must be occupied.
    """
    kind, _, rest = target.partition(":")
    if kind == "chip":
        try:
            r, c = rest.split(",")
            coord = (int(r), int(c))
        except ValueError:
            raise ValueError(f"chip target {target!r}: want chip:r,c") from None
        if coord not in hw.occupied_coords():
            raise ValueError(
                f"{target!r}: coordinate outside the occupied mesh "
                f"{hw.mesh_shape}"
            )
        return (coord,), None
    if kind == "zone":
        flavor = None if rest in ("", "*") else rest
        zones = flavor_zones(package_flavors(hw), hw.mesh_shape,
                             dead=hw.dead_chips)
        if flavor not in zones:
            raise ValueError(
                f"{target!r}: package flavors are "
                f"{sorted(str(f) for f in zones)}"
            )
        return tuple(zones[flavor]), None
    if kind == "seam":
        parts = rest.split("+")
        if len(parts) != 2:
            raise ValueError(f"seam target {target!r}: want seam:a+b")
        a, b = parts
        for n in (a, b):
            hw.chip_type(n)       # raises on unknown flavors
        return (), (a, b)
    raise ValueError(
        f"fault target {target!r}: want chip:r,c | zone:flavor | seam:a+b"
    )


def _parse_time(tok: str, horizon_s: float | None) -> float:
    if tok.endswith("%"):
        if horizon_s is None:
            raise ValueError(
                f"relative fault time {tok!r} needs a horizon"
            )
        return float(tok[:-1]) / 100.0 * horizon_s
    return float(tok)


def parse_faults(
    spec: str, hw: HardwareModel, horizon_s: float | None = None
) -> list[FaultEvent]:
    """Parse a scripted scenario DSL into sorted events.

    ``spec`` is ``;``-separated items ``target@t_fail[:t_repair]`` (chip
    targets contain a comma, hence the semicolon separator).  Times are
    seconds, or percentages of ``horizon_s`` (``zone:little@25%:75%``).  A
    missing ``t_repair`` means the component never comes back.
    """
    events: list[FaultEvent] = []
    for item in spec.split(";"):
        item = item.strip()
        if not item:
            continue
        target, at, times = item.rpartition("@")
        if not at:
            raise ValueError(f"fault item {item!r}: want target@t0[:t1]")
        chips, seam = resolve_target(target, hw)
        toks = times.split(":")
        if len(toks) not in (1, 2):
            raise ValueError(f"fault item {item!r}: want target@t0[:t1]")
        t0 = _parse_time(toks[0], horizon_s)
        events.append(FaultEvent(t=t0, kind="fail", target=target,
                                 chips=chips, seam=seam))
        if len(toks) == 2:
            t1 = _parse_time(toks[1], horizon_s)
            if t1 <= t0:
                raise ValueError(
                    f"fault item {item!r}: repair {t1} <= failure {t0}"
                )
            events.append(FaultEvent(t=t1, kind="repair", target=target,
                                     chips=chips, seam=seam))
    events.sort(key=lambda e: (e.t, e.target, e.kind))
    return events


@dataclass
class FaultInjector:
    """Deterministic failure/repair schedule generator for one package.

    Random streams turn on per component class when its MTBF is set:
    every chip (``chip_mtbf_s``), every flavor zone (``zone_mtbf_s``) and
    every adjacent flavor seam (``seam_mtbf_s``) draws alternating
    Exponential(MTBF) up-times and Exponential(MTTR) down-times from its
    own ``model_rng(seed, component_name)`` stream.  ``scripted`` events
    (FaultEvents, ``(target, t0, t1)`` tuples, or DSL strings) merge into
    the same timeline.
    """
    hw: HardwareModel
    seed: int = 0
    chip_mtbf_s: float | None = None
    chip_mttr_s: float = 1.0
    zone_mtbf_s: float | None = None
    zone_mttr_s: float = 2.0
    seam_mtbf_s: float | None = None
    seam_mttr_s: float = 2.0
    scripted: tuple = ()
    horizon_hint_s: float | None = None   # resolves % times in scripted items
    _scripted_events: list[FaultEvent] = field(init=False, repr=False)

    def __post_init__(self):
        for label, v in (("chip_mtbf_s", self.chip_mtbf_s),
                         ("zone_mtbf_s", self.zone_mtbf_s),
                         ("seam_mtbf_s", self.seam_mtbf_s),
                         ("chip_mttr_s", self.chip_mttr_s),
                         ("zone_mttr_s", self.zone_mttr_s),
                         ("seam_mttr_s", self.seam_mttr_s)):
            if v is not None and v <= 0:
                raise ValueError(f"{label} {v} <= 0")
        events: list[FaultEvent] = []
        for item in self.scripted:
            if isinstance(item, FaultEvent):
                events.append(item)
            elif isinstance(item, str):
                events.extend(parse_faults(item, self.hw,
                                           self.horizon_hint_s))
            else:
                target, t0, t1 = item
                events.extend(parse_faults(
                    f"{target}@{t0}" + (f":{t1}" if t1 is not None else ""),
                    self.hw, self.horizon_hint_s,
                ))
        self._scripted_events = events

    # ------------------------------------------------------------- streams
    def _random_components(self) -> list[tuple[str, float, float]]:
        """(component name, mtbf, mttr) of every enabled random stream."""
        out: list[tuple[str, float, float]] = []
        if self.chip_mtbf_s is not None:
            for r, c in self.hw.occupied_coords():
                out.append((f"chip:{r},{c}",
                            self.chip_mtbf_s, self.chip_mttr_s))
        counts = package_flavors(self.hw)
        if self.zone_mtbf_s is not None:
            for f, _ in counts:
                out.append((f"zone:{f if f is not None else '*'}",
                            self.zone_mtbf_s, self.zone_mttr_s))
        if self.seam_mtbf_s is not None:
            for (a, _), (b, _) in zip(counts, counts[1:]):
                if a is not None and b is not None:
                    out.append((f"seam:{a}+{b}",
                                self.seam_mtbf_s, self.seam_mttr_s))
        return out

    def schedule(self, horizon_s: float) -> list[FaultEvent]:
        """All events with ``t < horizon_s``, time-sorted, deterministic.

        A failure whose repair would land past the horizon stays down for
        the rest of the run (no repair event is emitted).
        """
        events = [e for e in self._scripted_events if e.t < horizon_s]
        for name, mtbf, mttr in self._random_components():
            rng = model_rng(self.seed, name)
            chips, seam = resolve_target(name, self.hw)
            t = 0.0
            while True:
                t += rng.exponential(mtbf)
                if t >= horizon_s:
                    break
                events.append(FaultEvent(t=t, kind="fail", target=name,
                                         chips=chips, seam=seam))
                t += rng.exponential(mttr)
                if t >= horizon_s:
                    break
                events.append(FaultEvent(t=t, kind="repair", target=name,
                                         chips=chips, seam=seam))
        events.sort(key=lambda e: (e.t, e.target, e.kind))
        return events

    # ---------------------------------------------------------- ft bridge
    def step_hook(self, step_time_s: float = 1.0, n_steps: int = 1000):
        """A ``failure_injector(step)`` callable for
        :class:`repro.ft.ResilientTrainer`: maps ``step * step_time_s``
        onto this injector's failure windows and raises
        :class:`InjectedFault` the *first* time a step lands inside each
        window (transient-fault semantics: after checkpoint restore the
        replay of the same step passes, matching a node that was replaced).
        """
        events = self.schedule(n_steps * step_time_s)
        down_since: dict[str, float] = {}
        windows: list[tuple[float, float, str]] = []
        for e in events:
            if e.kind == "fail":
                down_since.setdefault(e.target, e.t)
            elif e.target in down_since:
                windows.append((down_since.pop(e.target), e.t, e.target))
        for target, t0 in down_since.items():
            windows.append((t0, n_steps * step_time_s, target))
        windows.sort()
        fired: set[int] = set()

        def hook(step: int) -> None:
            t = step * step_time_s
            for i, (t0, t1, target) in enumerate(windows):
                if i not in fired and t0 <= t < t1:
                    fired.add(i)
                    raise InjectedFault(
                        f"{target} down at t={t:g}s (step {step})"
                    )

        return hook
