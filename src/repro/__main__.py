"""The ``repro`` CLI: one front door over the Scope solver facade.

    PYTHONPATH=src python -m repro solve --mix resnet50:2,alexnet:1 --hw mcm64
    PYTHONPATH=src python -m repro solve --mix resnet50 --hw mcm64_hetero --json
    PYTHONPATH=src python -m repro serve --mix resnet50:1,alexnet:1 --hw mcm16 \
        --requests 1000 --baselines --json
    PYTHONPATH=src python -m repro strategies

``solve`` accepts any preset from ``repro.core.hw`` (``--hw``) and a
``net[:weight[:slo_ms]]`` mix (``--mix``); a single-entry mix is a
single-model DSE (strategy auto-selection picks ``scope`` /
``scope-mixed`` / ``coschedule`` by problem shape -- override with
``--strategy``).  ``serve`` solves and then *runs* the deployment under
synthetic traffic (:mod:`repro.serving`): seeded open-loop arrivals,
per-model batching queues, quota/slice enforcement, and a serving report
(goodput, latency percentiles, SLO attainment); ``--baselines`` replays
the exact same trace against the equal-split and time-mux deployments.
"""
from __future__ import annotations

import argparse
import json
import sys

from .api import SearchOptions, available_strategies, problem, solve


def _build_solve_parser(sub) -> argparse.ArgumentParser:
    ap = sub.add_parser(
        "solve", help="run the declarative Scope DSE (Problem -> Solution)",
        description="Solve a workload x package DSE through repro.scope.",
    )
    ap.add_argument("--mix", "--workload", dest="mix", required=True,
                    help="comma list of net[:weight], e.g. resnet50:2,alexnet:1 "
                         "(a single entry is a single-model DSE)")
    ap.add_argument("--hw", default="mcm64", help="hardware preset name")
    ap.add_argument("--strategy", default="auto",
                    help=f"one of {', '.join(available_strategies())} "
                         "(default: auto-select by problem shape)")
    ap.add_argument("--mode", default="free", choices=("free", "uniform"),
                    help="region allocation mode (uniform = TPU SPMD)")
    ap.add_argument("--m-samples", type=int, default=16)
    ap.add_argument("--engine", default="fast", choices=("fast", "reference"))
    ap.add_argument("--paper-strict", action="store_true",
                    help="literal Algorithm 1 rebalance semantics")
    ap.add_argument("--step", type=int, default=1,
                    help="quota grid step (1 = exhaustive)")
    ap.add_argument("--refine", action="store_true",
                    help="coarse-to-fine curves (1D and mixed 2D): re-sample "
                         "at step 1 around each coarse argmax")
    ap.add_argument("--no-mixed", action="store_true",
                    help="disable mixed-flavor (spanning) quotas / "
                         "per-cluster flavors on heterogeneous packages")
    ap.add_argument("--mixed-step", type=int, default=None,
                    help="budget grid step of the mixed-flavor curves "
                         "(default: quarter of the smaller flavor)")
    ap.add_argument("--switch-cost", action="store_true",
                    help="charge time-mux slices for per-slice weight "
                         "re-deployment")
    ap.add_argument("--switch-period-s", type=float, default=1.0)
    ap.add_argument("--samples", type=int, default=10_000,
                    help="sample count for --strategy random")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--baselines", action="store_true",
                    help="also report the equal-split and time-mux baselines")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON of the solve "
                         "(open in Perfetto / chrome://tracing; .jsonl for "
                         "one event per line)")
    ap.add_argument("--dashboard", default=None, metavar="PATH",
                    help="write a self-contained HTML dashboard: per-stage "
                         "cost attribution tables plus the solve timeline "
                         "(no external assets)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit a machine-readable JSON summary")
    return ap


def _build_serve_parser(sub) -> argparse.ArgumentParser:
    ap = sub.add_parser(
        "serve",
        help="solve, then run the deployment under synthetic traffic",
        description="Solve a workload x package DSE and simulate serving "
                    "it (repro.serving).",
    )
    ap.add_argument("--mix", "--workload", dest="mix", default=None,
                    help="comma list of net[:weight[:slo_ms]]")
    ap.add_argument("--llm", default=None, metavar="ARCHS",
                    help="token-level LLM mix: comma list of arch[:weight] "
                         "from the LM registry (e.g. gemma2-9b:2,"
                         "granite-3-8b:1); solves with strategy llm-phase "
                         "and runs the TokenExecutor (exclusive with --mix)")
    ap.add_argument("--llm-smoke", action="store_true",
                    help="use the reduced smoke configs for --llm archs")
    ap.add_argument("--seq-len", type=int, default=128,
                    help="prompt length the LLM phase DSE plans for")
    ap.add_argument("--output-tokens", type=float, default=64.0,
                    help="expected decode tokens per request (LLM DSE)")
    ap.add_argument("--phase-mode", default="auto",
                    choices=("auto", "disaggregated", "colocated"),
                    help="LLM phase deployment mode to search")
    ap.add_argument("--ttft-slo-ms", type=float, default=None,
                    help="time-to-first-token SLO (gates token goodput)")
    ap.add_argument("--tpot-slo-ms", type=float, default=None,
                    help="time-per-output-token SLO (gates token goodput)")
    ap.add_argument("--queue-policy", default="fifo",
                    choices=("fifo", "edf"),
                    help="LLM prefill queue order / coloc arbitration")
    ap.add_argument("--hw", default="mcm64", help="hardware preset name")
    ap.add_argument("--strategy", default="auto",
                    help="solver strategy (default: auto-select)")
    ap.add_argument("--m-samples", type=int, default=16)
    ap.add_argument("--step", type=int, default=1)
    ap.add_argument("--switch-cost", action="store_true",
                    help="charge time-mux slices for weight re-deployment")
    ap.add_argument("--requests", type=int, default=1000,
                    help="approximate number of simulated requests")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rate-scale", type=float, default=0.8,
                    help="offered load as a fraction of solved capacity")
    ap.add_argument("--max-batch", type=int, default=None,
                    help="batcher size cap (default: the DSE batch)")
    ap.add_argument("--max-delay-ms", type=float, default=2.0,
                    help="batcher queue-delay cap")
    ap.add_argument("--autoscale", action="store_true",
                    help="enable the online re-solve hook")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="scripted fault scenario: ';'-separated "
                         "target@t0[:t1] with chip:R,C / zone:FLAVOR / "
                         "seam:A+B targets; times in seconds or %% of the "
                         "horizon (e.g. 'zone:little@35%%:65%%')")
    ap.add_argument("--fault-seed", type=int, default=None, metavar="N",
                    help="random chaos: seed a FaultInjector on top of any "
                         "--faults script (uses --chip-mtbf etc.)")
    ap.add_argument("--chip-mtbf", type=float, default=None, metavar="S",
                    help="per-chip mean time between failures (random chaos)")
    ap.add_argument("--chip-mttr", type=float, default=1.0, metavar="S")
    ap.add_argument("--zone-mtbf", type=float, default=None, metavar="S")
    ap.add_argument("--zone-mttr", type=float, default=2.0, metavar="S")
    ap.add_argument("--fault-static", action="store_true",
                    help="disable the degraded re-solve: down servers stay "
                         "down until repair (the static-degraded baseline)")
    ap.add_argument("--baselines", action="store_true",
                    help="replay the same trace on equal-split and time-mux "
                         "(--mix) or the static whole-request deployments "
                         "(--llm)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON of the whole run "
                         "(solver spans + server lanes + queue/fault "
                         "timeline; open in Perfetto)")
    ap.add_argument("--dashboard", default=None, metavar="PATH",
                    help="write a self-contained HTML dashboard: cost "
                         "attribution + latency waterfall tables, the run "
                         "timeline with fault/recovery windows, and "
                         "queue/KV counter sparklines")
    ap.add_argument("--json", action="store_true", dest="as_json")
    return ap


def _cmd_serve(args) -> None:
    if args.mix and args.llm:
        raise SystemExit("pass --mix or --llm, not both")
    if args.llm:
        _cmd_serve_llm(args)
        return
    if not args.mix:
        raise SystemExit("serve needs --mix or --llm")
    # one Tracer spans the whole command: the primary solve's spans, every
    # baseline solve, the executor's sim-time lanes, and any mid-run
    # re-solves all land on one timeline
    obs_tracer = None
    if args.trace or args.dashboard:
        from .obs import Tracer

        obs_tracer = Tracer()
    options = SearchOptions(
        strategy=args.strategy, m_samples=args.m_samples, step=args.step,
        switch_cost=args.switch_cost, trace=obs_tracer,
    )
    prob = problem(args.mix, args.hw, options=options)
    # One SolutionCache for the primary solve, the baselines and any
    # autoscale re-solves: every DSE shares one evaluation-engine memo.
    from .api import SolutionCache

    cache = SolutionCache()
    sol = cache.solve(prob)
    if not sol.feasible:
        raise SystemExit(f"no feasible solution for {args.mix} on {args.hw}")
    # One trace for every deployment: the offered load is fixed by the
    # primary solution's capacity, so --baselines replays are like-for-like.
    from .serving import request_trace

    traffic, horizon = sol.offered_traffic(args.rate_scale, args.requests)
    trace = request_trace(traffic, horizon, seed=args.seed)
    serve_kw = dict(
        trace=trace, horizon_s=horizon, seed=args.seed,
        max_delay_s=args.max_delay_ms / 1e3, max_batch=args.max_batch,
    )
    faults = None
    if args.faults or args.fault_seed is not None:
        # scripted specs may use %-of-horizon times, so build the schedule
        # here where the horizon is known
        from .serving import FaultInjector, parse_faults

        scripted = (parse_faults(args.faults, sol.hw, horizon)
                    if args.faults else ())
        if args.fault_seed is not None:
            faults = FaultInjector(
                sol.hw, seed=args.fault_seed,
                chip_mtbf_s=args.chip_mtbf, chip_mttr_s=args.chip_mttr,
                zone_mtbf_s=args.zone_mtbf, zone_mttr_s=args.zone_mttr,
                scripted=scripted, horizon_hint_s=horizon,
            )
        else:
            faults = scripted
    report = sol.serve(autoscale=args.autoscale, cache=cache,
                       faults=faults,
                       fault_recovery=not args.fault_static,
                       tracer=obs_tracer, **serve_kw)
    out = {"solution": sol.to_json(), "serving": report.to_json()}
    if args.baselines:
        out["baselines"] = {}
        for name in ("equal-split", "time-mux"):
            b = cache.solve(prob.with_options(strategy=name))
            if not b.feasible:
                out["baselines"][name] = None
                continue
            out["baselines"][name] = b.serve(**serve_kw).to_json()
    if obs_tracer is not None and args.trace:
        obs_tracer.write(args.trace)
    if args.dashboard:
        from .obs import write_dashboard

        write_dashboard(
            args.dashboard, title=f"Scope Lens: serve {args.mix}",
            solution_explain=sol.explain(),
            serving_explain=report.explain(),
            tracer=obs_tracer,
            meta={"hw": args.hw, "strategy": sol.strategy,
                  "requests": report.total_arrived,
                  "faults": args.faults or "-"},
        )
        print(f"dashboard written to {args.dashboard}", file=sys.stderr)
    if args.as_json:
        print(json.dumps(out, indent=1))
        return
    for line in sol.describe():
        print(line)
    print()
    for line in report.describe():
        print(line)
    if obs_tracer is not None:
        print()
        print(obs_tracer.summary())
        if args.trace:
            print(f"trace written to {args.trace} (open in Perfetto)")
    for name, rep in out.get("baselines", {}).items():
        if rep is None:
            print(f"{name}: infeasible")
        else:
            print(f"{name}: goodput {rep['goodput']:.1f}/s "
                  f"(vs {report.goodput:.1f}), p95 "
                  f"{rep['latency_p95_s'] * 1e3:.2f}ms "
                  f"(vs {report.latency_p95_s * 1e3:.2f})")


def _cmd_serve_llm(args) -> None:
    """Token-level serving: llm-phase DSE + TokenExecutor replay, with the
    static whole-request deployments as --baselines on the same trace."""
    from .api import SolutionCache, WorkloadSpec
    from .configs import get_config, get_smoke_config
    from .serving import TokenLengths, request_trace

    obs_tracer = None
    if args.trace or args.dashboard:
        from .obs import Tracer

        obs_tracer = Tracer()
    names, weights = [], []
    for entry in args.llm.split(","):
        parts = entry.strip().split(":")
        names.append(parts[0])
        weights.append(float(parts[1]) if len(parts) > 1 else 1.0)
    get = get_smoke_config if args.llm_smoke else get_config
    wl = WorkloadSpec.lm([get(n) for n in names], args.seq_len, weights)
    options = SearchOptions(
        strategy="llm-phase", m_samples=args.m_samples, step=args.step,
        output_tokens=args.output_tokens, phase_mode=args.phase_mode,
        trace=obs_tracer,
    )
    prob = problem(wl, args.hw, options=options)
    cache = SolutionCache()
    sol = cache.solve(prob)
    if not sol.feasible:
        raise SystemExit(f"no feasible LLM plan for {args.llm} on {args.hw}")
    # one token trace (arrivals + prompt/output lengths) shared by the
    # chosen deployment and every --baselines replay
    traffic, horizon = sol.offered_traffic(args.rate_scale, args.requests)
    lengths = TokenLengths(prompt_mean=float(args.seq_len),
                           output_mean=float(args.output_tokens))
    trace = request_trace(traffic, horizon, seed=args.seed, lengths=lengths)
    ttft = args.ttft_slo_ms / 1e3 if args.ttft_slo_ms is not None else None
    tpot = args.tpot_slo_ms / 1e3 if args.tpot_slo_ms is not None else None
    serve_kw = dict(trace=trace, horizon_s=horizon, seed=args.seed,
                    max_delay_s=args.max_delay_ms / 1e3,
                    max_batch=args.max_batch,
                    queue_policy=args.queue_policy,
                    ttft_slo=ttft, tpot_slo=tpot)
    report = sol.serve(tracer=obs_tracer, **serve_kw)
    out = {"solution": sol.to_json(), "serving": report.to_json()}
    if args.baselines:
        out["baselines"] = {}
        for mode, alt in sol.diagnostics.get("plans", {}).items():
            if alt is None:
                out["baselines"][f"{mode}-static"] = None
                continue
            b = sol.serve(plan=alt, static_batching=True, **serve_kw)
            out["baselines"][f"{mode}-static"] = b.to_json()
    if obs_tracer is not None and args.trace:
        obs_tracer.write(args.trace)
    if args.dashboard:
        from .obs import write_dashboard

        write_dashboard(
            args.dashboard, title=f"Scope Lens: serve --llm {args.llm}",
            solution_explain=sol.explain(),
            serving_explain=report.explain(),
            serving_title="Token-level latency waterfalls",
            tracer=obs_tracer,
            meta={"hw": args.hw, "mode": report.mode,
                  "requests": report.total_arrived},
        )
        print(f"dashboard written to {args.dashboard}", file=sys.stderr)
    if args.as_json:
        print(json.dumps(out, indent=1))
        return
    for line in sol.describe():
        print(line)
    print()
    for line in report.describe():
        print(line)
    if obs_tracer is not None:
        print()
        print(obs_tracer.summary())
        if args.trace:
            print(f"trace written to {args.trace} (open in Perfetto)")
    for name, rep in out.get("baselines", {}).items():
        if rep is None:
            print(f"{name}: infeasible")
        else:
            ratio = (report.token_goodput / rep["token_goodput"]
                     if rep["token_goodput"] else float("inf"))
            print(f"{name}: token goodput {rep['token_goodput']:.1f} tok/s "
                  f"({ratio:.2f}x vs solution), TTFT p95 "
                  f"{rep['ttft_p95_s'] * 1e3:.2f}ms")


def _cmd_solve(args) -> None:
    trace_arg = args.trace
    if args.dashboard and trace_arg is None:
        # the dashboard wants a timeline even when no trace file was asked for
        from .obs import Tracer

        trace_arg = Tracer()
    options = SearchOptions(
        strategy=args.strategy,
        mode=args.mode,
        m_samples=args.m_samples,
        engine=args.engine,
        paper_strict=args.paper_strict,
        step=args.step,
        refine=args.refine,
        mixed=not args.no_mixed,
        mixed_step=args.mixed_step,
        switch_cost=args.switch_cost,
        switch_period_s=args.switch_period_s,
        samples=args.samples,
        seed=args.seed,
        trace=trace_arg,
    )
    prob = problem(args.mix, args.hw, options=options)
    sol = solve(prob)
    if not sol.feasible and sol.strategy != "random":
        if args.as_json:
            print(json.dumps(sol.to_json(), indent=1))
        raise SystemExit(
            f"no feasible {sol.strategy} solution for {args.mix} on {args.hw}"
        )
    if args.dashboard:
        from .obs import write_dashboard

        write_dashboard(
            args.dashboard, title=f"Scope Lens: solve {args.mix}",
            solution_explain=sol.explain(),
            tracer=sol.diagnostics.get("trace"),
            meta={"hw": args.hw, "strategy": sol.strategy,
                  "mode": args.mode},
        )
        print(f"dashboard written to {args.dashboard}", file=sys.stderr)

    if args.as_json:
        out = sol.to_json()
        if args.baselines:
            out["baselines"] = _baseline_rates(prob, sol)
        print(json.dumps(out, indent=1))
        return

    for line in sol.describe():
        print(line)
    tr = sol.diagnostics.get("trace")
    if tr is not None:
        print()
        print(tr.summary())
        if args.trace:
            print(f"trace written to {args.trace} (open in Perfetto)")
    if args.baselines:
        for name, tp in _baseline_rates(prob, sol).items():
            if tp is None:
                print(f"{name}: infeasible")
            else:
                ratio = (sol.weighted_throughput / tp) if tp else float("inf")
                print(f"{name}: weighted throughput {tp:.1f} samples/s "
                      f"({ratio:.2f}x vs solution)")


def _baseline_rates(prob, sol) -> dict:
    """Weighted throughput of the static baselines, through the facade
    (sharing nothing with the solution's engine so numbers stay honest)."""
    out = {}
    for name in ("equal-split", "time-mux"):
        b = solve(prob.with_options(strategy=name))
        out[name] = b.weighted_throughput if b.feasible else None
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="repro", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = ap.add_subparsers(dest="command")
    _build_solve_parser(sub)
    _build_serve_parser(sub)
    sub.add_parser("strategies", help="list registered solver strategies")
    args = ap.parse_args(argv)
    if args.command == "solve":
        _cmd_solve(args)
    elif args.command == "serve":
        _cmd_serve(args)
    elif args.command == "strategies":
        for name in available_strategies():
            print(name)
    else:
        ap.print_help()
        sys.exit(2)


if __name__ == "__main__":
    main()
