"""Seeded open-loop request generators for the serving executor.

Each model of a mix gets an arrival process producing ``Request`` records
``(t_arrive, model, samples)``; the executor replays the merged trace.  All
generators are *open-loop* (arrivals do not react to service) and fully
deterministic under a seed: every model draws from its own
``numpy.random.Generator`` seeded by ``(seed, crc32(model_name))``, so
adding or removing one model never perturbs another model's arrivals.

Three arrival processes, the usual serving-simulator trio:

* :class:`Poisson` -- homogeneous Poisson at ``rate`` requests/s;
* :class:`MMPP` -- a 2-state Markov-modulated Poisson process (bursty
  traffic: exponential dwell in a low-rate and a high-rate state);
* :class:`Diurnal` -- non-homogeneous Poisson with a raised-cosine rate
  ramp between ``rate_trough`` and ``rate_peak`` (one ``period_s`` =
  one simulated "day"), sampled by thinning.

:func:`request_trace` merges per-model streams into one time-sorted trace;
:func:`phased_trace` concatenates traffic phases (the autoscale benchmark's
mix-flip scenario).
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Sequence

import numpy as np

__all__ = [
    "Diurnal",
    "MMPP",
    "Poisson",
    "Request",
    "TokenLengths",
    "model_rng",
    "phased_trace",
    "request_trace",
]


@dataclass(frozen=True, order=True)
class Request:
    """One admitted unit of work: ``samples`` inputs for ``model``.

    Token-level serving stamps each request with its prompt and output
    lengths (seeded draws from a :class:`TokenLengths` distribution); the
    whole-request executor ignores both fields.
    """
    t_arrive: float
    model: str
    samples: int = 1
    seq: int = 0          # global arrival index (deterministic tie-break)
    prompt_tokens: int = 0
    output_tokens: int = 0


def model_rng(seed: int, model: str) -> np.random.Generator:
    """Per-(seed, model) generator: streams are independent and stable."""
    return np.random.default_rng([seed, zlib.crc32(model.encode())])


# ---------------------------------------------------------------------------
# Arrival processes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Poisson:
    """Homogeneous Poisson arrivals at ``rate`` requests/s."""
    rate: float
    batch_hint: int = 1            # samples per request

    @property
    def mean_rate(self) -> float:
        return self.rate

    def arrival_times(self, rng: np.random.Generator,
                      horizon_s: float) -> list[float]:
        if self.rate <= 0:
            return []
        out, t = [], 0.0
        while True:
            t += rng.exponential(1.0 / self.rate)
            if t >= horizon_s:
                return out
            out.append(t)


@dataclass(frozen=True)
class MMPP:
    """2-state Markov-modulated Poisson process (bursty traffic).

    The process dwells exponentially (means ``mean_low_s`` /
    ``mean_high_s``) in a low-rate and a high-rate state; within a state
    arrivals are Poisson at that state's rate.
    """
    rate_low: float
    rate_high: float
    mean_low_s: float = 1.0
    mean_high_s: float = 0.25
    batch_hint: int = 1

    @property
    def mean_rate(self) -> float:
        return (self.rate_low * self.mean_low_s
                + self.rate_high * self.mean_high_s) / (
            self.mean_low_s + self.mean_high_s)

    def arrival_times(self, rng: np.random.Generator,
                      horizon_s: float) -> list[float]:
        out: list[float] = []
        t, high = 0.0, False
        while t < horizon_s:
            dwell = rng.exponential(self.mean_high_s if high else self.mean_low_s)
            end = min(t + dwell, horizon_s)
            rate = self.rate_high if high else self.rate_low
            if rate > 0:
                at = t
                while True:
                    at += rng.exponential(1.0 / rate)
                    if at >= end:
                        break
                    out.append(at)
            t, high = end, not high
        return out


@dataclass(frozen=True)
class Diurnal:
    """Non-homogeneous Poisson ramp: raised-cosine rate between trough and
    peak over ``period_s`` (thinning / Lewis-Shedler sampling)."""
    rate_peak: float
    rate_trough: float = 0.0
    period_s: float = 60.0
    phase_s: float = 0.0
    batch_hint: int = 1

    @property
    def mean_rate(self) -> float:
        return 0.5 * (self.rate_peak + self.rate_trough)

    def rate_at(self, t: float) -> float:
        x = 2.0 * np.pi * (t + self.phase_s) / self.period_s
        return self.rate_trough + (self.rate_peak - self.rate_trough) * (
            0.5 * (1.0 - np.cos(x))
        )

    def arrival_times(self, rng: np.random.Generator,
                      horizon_s: float) -> list[float]:
        if self.rate_peak <= 0:
            return []
        out, t = [], 0.0
        while True:
            t += rng.exponential(1.0 / self.rate_peak)
            if t >= horizon_s:
                return out
            if rng.random() * self.rate_peak < self.rate_at(t):
                out.append(t)


@dataclass(frozen=True)
class TokenLengths:
    """Seeded per-request (prompt, output) token-length distribution.

    Lengths are lognormal with the given means and coefficients of
    variation (the long right tail is what makes static whole-request
    batching waste decode slots), rounded to ints and clamped to
    ``[1, *_max]``.  Draws come from a dedicated ``(seed, model)`` stream
    so stamping lengths never perturbs the arrival process.
    """
    prompt_mean: float = 512.0
    output_mean: float = 128.0
    prompt_cv: float = 0.5
    output_cv: float = 0.5
    prompt_max: int | None = None
    output_max: int | None = None

    @staticmethod
    def _draw(rng: np.random.Generator, n: int, mean: float, cv: float,
              cap: int | None) -> np.ndarray:
        if cv <= 0:
            out = np.full(n, mean)
        else:
            sigma2 = np.log1p(cv * cv)
            mu = np.log(mean) - 0.5 * sigma2
            out = rng.lognormal(mu, np.sqrt(sigma2), size=n)
        out = np.maximum(1, np.rint(out)).astype(int)
        return np.minimum(out, cap) if cap is not None else out

    def sample(self, rng: np.random.Generator,
               n: int) -> tuple[np.ndarray, np.ndarray]:
        return (
            self._draw(rng, n, self.prompt_mean, self.prompt_cv,
                       self.prompt_max),
            self._draw(rng, n, self.output_mean, self.output_cv,
                       self.output_max),
        )


def _coerce(model: str, spec) -> object:
    if isinstance(spec, (int, float)):
        return Poisson(rate=float(spec))
    if hasattr(spec, "arrival_times"):
        return spec
    raise TypeError(f"{model}: cannot interpret traffic spec {spec!r}")


# ---------------------------------------------------------------------------
# Trace assembly
# ---------------------------------------------------------------------------

def request_trace(
    traffic: dict[str, object],
    horizon_s: float,
    seed: int = 0,
    t0: float = 0.0,
    seq0: int = 0,
    lengths: "TokenLengths | dict[str, TokenLengths] | None" = None,
) -> list[Request]:
    """Merge per-model arrival streams into one sorted request trace.

    ``traffic`` maps model name -> arrival process (or a bare number,
    taken as a Poisson rate in requests/s).  Ties are broken by model name
    then per-model order, so the trace is bytewise deterministic.

    ``lengths`` (one :class:`TokenLengths` for all models, or a per-model
    dict) stamps each request with seeded prompt/output token counts for
    the token-level executor; length draws use a separate per-model stream
    (``model_rng(seed, model + "/tokens")``), so the same arrivals are
    produced with or without lengths.
    """
    merged: list[tuple[float, str, int]] = []
    for model in sorted(traffic):
        proc = _coerce(model, traffic[model])
        rng = model_rng(seed, model)
        hint = max(1, int(getattr(proc, "batch_hint", 1)))
        merged.extend((t, model, hint)
                      for t in proc.arrival_times(rng, horizon_s))
    merged.sort(key=lambda e: (e[0], e[1]))
    toks: dict[str, tuple] = {}
    if lengths is not None:
        counts: dict[str, int] = {}
        for _, m, _ in merged:
            counts[m] = counts.get(m, 0) + 1
        for model, n in sorted(counts.items()):
            dist = lengths.get(model) if isinstance(lengths, dict) else lengths
            if dist is None:
                continue
            prompts, outs = dist.sample(
                model_rng(seed, model + "/tokens"), n)
            toks[model] = (iter(prompts), iter(outs))
    out = []
    for i, (t, m, s) in enumerate(merged):
        p = o = 0
        if m in toks:
            p, o = int(next(toks[m][0])), int(next(toks[m][1]))
        out.append(Request(t_arrive=t0 + t, model=m, samples=s, seq=seq0 + i,
                           prompt_tokens=p, output_tokens=o))
    return out


def phased_trace(
    phases: Sequence[tuple[dict[str, object], float]],
    seed: int = 0,
) -> list[Request]:
    """Concatenate traffic phases: ``[(traffic_dict, duration_s), ...]``.

    Each phase is generated independently (sub-seeded by its index) and
    shifted onto the global timeline -- the autoscale drift scenario flips
    the mix between phases.
    """
    out: list[Request] = []
    t0 = 0.0
    for i, (traffic, dur) in enumerate(phases):
        reqs = request_trace(traffic, dur, seed=seed * 1_000_003 + i,
                             t0=t0, seq0=len(out))
        out.extend(reqs)
        t0 += dur
    return out


def offered_load(trace: Sequence[Request]) -> dict[str, int]:
    """Samples offered per model (the conservation test's left-hand side)."""
    out: dict[str, int] = {}
    for r in trace:
        out[r.model] = out.get(r.model, 0) + r.samples
    return out
