"""Model specs for co-scheduling: a LayerGraph plus its traffic weight."""
from __future__ import annotations

from dataclasses import dataclass

from ..core.graph import LayerGraph
from ..core.workloads import get_cnn


@dataclass(frozen=True)
class ModelSpec:
    """One tenant of a co-scheduled package.

    ``weight`` is the relative request rate of this model in the traffic
    mix (weights only matter relative to each other): the co-scheduler
    maximizes the sustainable rate of the weighted mix unit.
    """
    graph: LayerGraph
    weight: float = 1.0

    @property
    def name(self) -> str:
        return self.graph.name

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"{self.graph.name}: weight must be > 0")


def parse_mix(mix: str) -> list[ModelSpec]:
    """``"resnet50:2,alexnet:1"`` -> ModelSpecs (weight defaults to 1).

    Names resolve through the CNN workload registry; duplicate names get a
    ``#k`` suffix so per-model results stay distinguishable.
    """
    specs: list[ModelSpec] = []
    seen: dict[str, int] = {}
    for part in mix.split(","):
        part = part.strip()
        if not part:
            continue
        name, _, w = part.partition(":")
        graph = get_cnn(name)
        count = seen.get(name, 0)
        seen[name] = count + 1
        if count:
            graph = LayerGraph(f"{name}#{count + 1}", graph.layers)
        specs.append(ModelSpec(graph, float(w) if w else 1.0))
    if not specs:
        raise ValueError(f"empty mix: {mix!r}")
    return specs
