"""Model configuration: one dataclass describes every assigned architecture.

``block_pattern`` is a repeating unit of block kinds (scanned ``n_layers /
len(pattern)`` times), which covers all assigned families:

* dense decoder            -> ("attn",)
* gemma2 local/global      -> ("local", "attn")
* jamba 1:7 attn:mamba     -> ("attn", "mamba", ...7 mambas) with MoE every 2
* rwkv6                    -> ("rwkv",)

The same config also exports a Scope layer graph (``workloads/lm.py``) so the
paper's DSE can schedule the model.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    every: int = 1            # MoE FFN on every ``every``-th block (jamba: 2)
    capacity_factor: float = 1.25
    d_ff: int | None = None   # expert hidden dim if != dense d_ff
    dispatch_groups: int = 512  # local-dispatch groups (>= mesh shards so the
                                # group axis shards; capacity is per group)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                       # 0 -> d_model // n_heads
    block_pattern: tuple[str, ...] = ("attn",)
    moe: MoEConfig | None = None
    rope_theta: float = 10_000.0
    logit_softcap: float = 0.0            # gemma2: 30.0 final / 50.0 attn
    attn_softcap: float = 0.0
    window: int = 0                       # sliding window for "local" blocks
    norm_eps: float = 1e-6
    ffn_gated: bool = True                # SwiGLU (3 mats) vs classic MLP (2)
    tie_embeddings: bool = False
    frontend: str = "none"                # none | audio_stub | vision_stub
    frontend_tokens: int = 0              # stub positions (e.g. 256 patches)
    # mamba sub-config (jamba)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # rwkv sub-config
    rwkv_head_dim: int = 64
    # numerics / memory knobs (hillclimb levers, see EXPERIMENTS.md SSPerf)
    param_dtype: str = "bfloat16"
    remat: bool = True
    scan_unroll: int = 1       # lax.scan unroll; pattern_repeats => trip=1 so
                               # cost_analysis counts every layer (dry-run mode)
    optimizer: str = "adamw"              # adamw | adafactor (huge MoE)
    accum_steps: int = 1
    meta: dict = field(default_factory=dict, hash=False, compare=False)

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so the embedding shards over any mesh
        axis (production practice; labels stay < vocab)."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def expanded_pattern(self) -> tuple[str, ...]:
        """Pattern expanded so MoE periodicity aligns with pattern positions
        (keeps stacked-scan param pytrees homogeneous across repeats)."""
        import math

        P = len(self.block_pattern)
        if self.moe is None:
            return self.block_pattern
        l = math.lcm(P, self.moe.every)
        return self.block_pattern * (l // P)

    @property
    def pattern_repeats(self) -> int:
        P = len(self.expanded_pattern)
        assert self.n_layers % P == 0, (
            f"{self.name}: n_layers {self.n_layers} not divisible by "
            f"expanded pattern of length {P}"
        )
        return self.n_layers // P

    def block_kinds(self) -> list[str]:
        return list(self.expanded_pattern) * self.pattern_repeats

    def is_moe_block(self, layer_idx: int) -> bool:
        return self.moe is not None and (layer_idx % self.moe.every == self.moe.every - 1)

    @property
    def n_params(self) -> float:
        """Total parameter count (embeddings included once)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim
        fmats = 3.0 if self.ffn_gated else 2.0
        total = float(v) * d * (1 if self.tie_embeddings else 2)
        for i, kind in enumerate(self.block_kinds()):
            if kind in ("attn", "local"):
                total += d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            elif kind == "mamba":
                di = self.mamba_expand * d
                total += 2 * d * di + di * (self.mamba_d_conv + 2 * self.mamba_d_state + 2) + di * d
            elif kind == "rwkv":
                total += 5 * d * d   # r/k/v/g token-mix + output proj
            # FFN / channel-mix
            if kind == "rwkv":
                total += 2.0 * d * ff + d * d   # k->ff, ff->d + receptance
            elif self.is_moe_block(i):
                eff_ff = self.moe.d_ff or ff
                total += fmats * d * eff_ff * self.moe.n_experts + d * self.moe.n_experts
            else:
                total += fmats * d * ff
        return total

    @property
    def n_active_params(self) -> float:
        """Active params per token (MoE counts top_k experts only)."""
        if self.moe is None:
            return self.n_params
        dense = self.n_params
        eff_ff = self.moe.d_ff or self.d_ff
        fmats = 3.0 if self.ffn_gated else 2.0
        n_moe_blocks = sum(1 for i in range(self.n_layers) if self.is_moe_block(i))
        expert_params = fmats * self.d_model * eff_ff * n_moe_blocks
        dense -= expert_params * self.moe.n_experts
        dense += expert_params * self.moe.top_k
        return dense
