"""Multi-model co-scheduling walkthrough: mixed traffic on one MCM package.

Schedules a 3-model mix (weighted traffic) onto a 64-chiplet package with
the co-scheduler, compares it against the two static baselines, then shows
the same subsystem on a heterogeneous big/little package.

    PYTHONPATH=src python examples/multimodel_serve.py
"""
from repro.core.fastcost import FastCostModel
from repro.core.hw import mcm_hetero, mcm_table_iii
from repro.multimodel import (
    co_schedule,
    describe,
    equal_split,
    parse_mix,
    time_multiplexed,
)

# Traffic mix: resnet50 gets 2x the request rate of the small models.
MIX = "resnet50:2,resnet18:1,alexnet:1"

specs = parse_mix(MIX)
hw = mcm_table_iii(64)
cost = FastCostModel(hw, m_samples=16)   # one shared memo for everything

print(f"mix {MIX} on {hw.name}\n")
co = co_schedule(specs, hw, cost=cost)
for line in describe(co):
    print(line)
print(f"  modes searched: { {k: round(v) for k, v in co.meta['mode_rates'].items()} }")
print(f"  engine stats:   {co.meta['engine_stats']}")

print("\nstatic baselines:")
for name, fn in (("equal_split", equal_split), ("time_mux", time_multiplexed)):
    b = fn(specs, cost)
    print(f"  {name:12s} {b.weighted_throughput:9.1f} samples/s "
          f"({co.weighted_throughput / b.weighted_throughput:.2f}x behind)")

# --- heterogeneous package: quotas are drawn per chip flavor -------------
hw2 = mcm_hetero(64)    # 32 big + 32 little (half the FLOPs, 3/4 the NoP)
specs2 = parse_mix("resnet50:1,resnet18:1")
print(f"\nmix resnet50:1,resnet18:1 on {hw2.name} "
      f"({', '.join(f'{t.chips}x{t.name}' for t in hw2.region_types)})")
co2 = co_schedule(specs2, hw2)
for line in describe(co2):
    print(line)
