"""Optimizers built from scratch (no optax): AdamW and Adafactor.

AdamW keeps fp32 first/second moments per parameter (8 bytes/param) -- the
default.  Adafactor factors the second moment of matrices into row/col
statistics (the production choice for the 400B MoE config, where AdamW state
cannot fit the single-pod HBM budget -- see EXPERIMENTS.md SSPerf).

Both are pure functions over pytrees so GSPMD shards the update math exactly
like the states are sharded (ZeRO-style placement comes from the sharding
rules, not from the optimizer).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    m: Any          # AdamW: fp32 moments. Adafactor: row stats pytree.
    v: Any          # AdamW: fp32 moments. Adafactor: col stats pytree.


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(1, warmup)
        t = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


# --------------------------------------------------------------------- AdamW

def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def adamw_update(
    params, grads, state: OptState, lr,
    b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
):
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step=step, m=new_m, v=new_v)


# ----------------------------------------------------------------- Adafactor

def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params) -> OptState:
    def rows(p):
        if _factored(p.shape):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def cols(p):
        if _factored(p.shape):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((1,), jnp.float32)

    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(rows, params),
        v=jax.tree.map(cols, params),
    )


def adafactor_update(
    params, grads, state: OptState, lr,
    decay=0.8, eps=1e-30, clip_threshold=1.0, weight_decay=0.0,
):
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr
    beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** -decay

    def upd(p, g, r, c):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored(p.shape):
            r = beta * r + (1 - beta) * jnp.mean(g2, axis=-1)
            c = beta * c + (1 - beta) * jnp.mean(g2, axis=-2)
            rc = r / jnp.maximum(jnp.mean(r, axis=-1, keepdims=True), eps)
            vhat = rc[..., None] * c[..., None, :]
        else:
            r = beta * r + (1 - beta) * g2
            vhat = r
        u = g * jax.lax.rsqrt(jnp.maximum(vhat, eps))
        # update clipping (RMS)
        rms = jnp.sqrt(jnp.mean(u * u))
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        delta = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), r, c

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_r = treedef.flatten_up_to(state.m)
    flat_c = treedef.flatten_up_to(state.v)
    out = [upd(p, g, r, c) for p, g, r, c in zip(flat_p, flat_g, flat_r, flat_c)]
    return (
        treedef.unflatten([o[0] for o in out]),
        OptState(step=step,
                 m=treedef.unflatten([o[1] for o in out]),
                 v=treedef.unflatten([o[2] for o in out])),
    )


def make_optimizer(kind: str):
    if kind == "adamw":
        return adamw_init, adamw_update
    if kind == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(kind)
