"""musicgen-medium [audio]: decoder-only transformer over EnCodec tokens.

48L d_model=1536 24H (GQA kv=24 == MHA) d_ff=6144 vocab=2048
[arXiv:2306.05284; hf].  The EnCodec frontend is a stub: ``input_specs``
provides precomputed frame embeddings (already codebook-summed to d_model);
the backbone predicts the next frame's codebook-0 token ids.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    ffn_gated=False,            # classic transformer MLP (GELU)
    frontend="audio_stub",
    rope_theta=10_000.0,
)
