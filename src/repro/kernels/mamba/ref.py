"""Sequential jnp oracle for the selective scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba_scan_ref(dt, x, A, Bc, Cc, D):
    """Same contract as mamba_scan_kernel."""
    B, S, di = x.shape
    N = A.shape[1]
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A)          # [B,S,di,N]
    bx = (dt * x).astype(jnp.float32)[..., None] * Bc.astype(jnp.float32)[:, :, None, :]

    def step(h, t):
        h = a[:, t] * h + bx[:, t]
        y = jnp.einsum("bdn,bn->bd", h, Cc[:, t].astype(jnp.float32))
        return h, y

    h0 = jnp.zeros((B, di, N), jnp.float32)
    h_last, ys = jax.lax.scan(step, h0, jnp.arange(S))
    y = jnp.moveaxis(ys, 0, 1) + D * x.astype(jnp.float32)
    return y, h_last
