import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax-importing module: jax locks the
# device count at first init, and the production dry-run needs 512 host
# placeholder devices to build the 16x16 and 2x16x16 meshes.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell.

For each cell this proves, without hardware:
  * the sharding plan is coherent (GSPMD partitions every op),
  * the program fits (memory_analysis),
  * and it yields the roofline inputs (cost_analysis + HLO collective bytes).

Usage:
  python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k
  python -m repro.launch.dryrun --all                  # single-pod 16x16
  python -m repro.launch.dryrun --all --multi-pod      # 2 pods, 512 chips
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_config
from repro.configs.registry import cells
from repro.launch.hlo_analysis import collective_stats, roofline_terms
from repro.launch.mesh import make_production_mesh
from repro.models import init_kv_cache, init_params
from repro.optim import make_optimizer
from repro.runtime.planner import plan_for_cell
from repro.runtime.serve import build_decode_step, build_prefill_step
from repro.runtime.train import build_train_step

I32 = jnp.int32
BF16 = jnp.bfloat16


def input_specs(arch: str, shape: str):
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    cfg = get_config(arch)
    S, B, kind = SHAPES[shape]
    sds = jax.ShapeDtypeStruct
    if kind in ("train", "prefill"):
        batch = {}
        if cfg.frontend == "audio_stub":
            batch["frontend_embeds"] = sds((B, S, cfg.d_model), BF16)
        elif cfg.frontend == "vision_stub":
            batch["tokens"] = sds((B, S - cfg.frontend_tokens), I32)
            batch["frontend_embeds"] = sds((B, cfg.frontend_tokens, cfg.d_model), BF16)
        else:
            batch["tokens"] = sds((B, S), I32)
        if kind == "train":
            batch["labels"] = sds((B, S), I32)
        return batch
    # decode: one token against an S-long cache
    caches = jax.eval_shape(lambda: init_kv_cache(cfg, B, S, BF16))
    return {
        "token": sds((B, 1), I32),
        "position": sds((B,), I32),
        "caches": caches,
    }


def _lower_cell(cfg, arch, shape, mesh, plan, S, B, kind, params_s, specs):
    if kind == "train":
        step, _ = build_train_step(cfg, mesh, plan)
        init_fn, _u = make_optimizer(cfg.optimizer)
        opt_s = jax.eval_shape(init_fn, params_s)
        return step.lower(params_s, opt_s, specs)
    if kind == "prefill":
        step, _ = build_prefill_step(cfg, mesh, plan)
        if cfg.frontend == "audio_stub":
            return step.lower(params_s, specs["frontend_embeds"])
        if cfg.frontend == "vision_stub":
            return step.lower(params_s, specs["tokens"], specs["frontend_embeds"])
        return step.lower(params_s, specs["tokens"])
    step, _ = build_decode_step(cfg, mesh, plan, batch=B, max_len=S)
    return step.lower(params_s, specs["token"], specs["position"], specs["caches"])


def run_cell(arch: str, shape: str, multi_pod: bool, use_dse: bool = True,
             plan_override=None, scan_correct: bool = True,
             force_accum1: bool = True) -> dict:
    cfg = get_config(arch)
    if force_accum1 and cfg.accum_steps != 1:
        # The grad-accumulation lax.scan body is also trip-counted once by
        # cost_analysis; lower with accum=1 so roofline terms are per full
        # batch (accum is purely a temp-memory knob -- see SSPerf).
        import dataclasses as _dc
        cfg = _dc.replace(cfg, accum_steps=1)
    S, B, kind = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = tuple(mesh.axis_names)
    chips = mesh.size
    plan = plan_override or plan_for_cell(
        cfg, S, B, axes, model_axis=mesh.shape["model"], kind=kind,
        use_dse=use_dse,
    )
    dp_size = 1
    for a in axes:
        if a in ("pod", "data"):
            dp_size *= mesh.shape[a]
    if B % dp_size != 0:
        import dataclasses
        plan = dataclasses.replace(plan, use_dp=False)
    specs = input_specs(arch, shape)
    params_s = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))

    t0 = time.time()
    lowered = _lower_cell(cfg, arch, shape, mesh, plan, S, B, kind, params_s, specs)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # noqa: BLE001
        mem_d = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        cost_d = {k: float(v) for k, v in cost.items()
                  if isinstance(v, (int, float))} if cost else {}
    except Exception as e:  # noqa: BLE001
        cost_d = {"error": str(e)}
    hlo = compiled.as_text()
    coll = collective_stats(hlo)

    flops = cost_d.get("flops", 0.0)
    bytes_ = cost_d.get("bytes accessed", 0.0)
    coll_bytes = coll.total_bytes
    scan_info = {"corrected": False}
    R = cfg.pattern_repeats
    if scan_correct and R > 1:
        # XLA cost_analysis counts a while-loop body ONCE regardless of trip
        # count.  Re-lower with scan unroll=2 (each scan body duplicated once,
        # compile stays cheap) and extrapolate:
        #   true ~ u1 + (R - n_scans)/n_scans * (u2 - u1)
        # where n_scans is 1 (single zone) or 2 (WSP->ISP split).
        import dataclasses as _dc
        cfg2 = _dc.replace(cfg, scan_unroll=2)
        low2 = _lower_cell(cfg2, arch, shape, mesh, plan, S, B, kind, params_s, specs)
        comp2 = low2.compile()
        cost2 = comp2.cost_analysis() or {}
        coll2 = collective_stats(comp2.as_text())
        n_scans = 2 if plan.transition_repeat not in (None, 0, R) else 1
        scale = (R - n_scans) / n_scans
        d_fl = max(0.0, float(cost2.get("flops", 0.0)) - flops)
        d_by = max(0.0, float(cost2.get("bytes accessed", 0.0)) - bytes_)
        d_co = max(0.0, coll2.total_bytes - coll_bytes)
        scan_info = {
            "corrected": True, "n_scans": n_scans,
            "u1_flops": flops, "body_flops": d_fl,
        }
        flops = flops + scale * d_fl
        bytes_ = bytes_ + scale * d_by
        coll_bytes = coll_bytes + scale * d_co
    # NOTE: the partitioned HLO is per-device, so flops/bytes/collective
    # byte counts are already per chip.
    terms = roofline_terms(flops, bytes_, coll_bytes, chips)
    result = {
        "arch": arch, "shape": shape, "kind": kind,
        "mesh": {"axes": list(axes), "shape": [mesh.shape[a] for a in axes],
                 "chips": chips},
        "plan": {"p1": plan.p1, "p2": plan.p2,
                 "transition_repeat": plan.transition_repeat,
                 "dse_meta": {k: v for k, v in plan.meta.items()}},
        "lower_s": t_lower, "compile_s": t_compile,
        "memory_analysis": mem_d,
        "cost_analysis": {k: cost_d.get(k) for k in
                          ("flops", "bytes accessed", "optimal_seconds")
                          if k in cost_d},
        "corrected": {"flops": flops, "bytes": bytes_,
                      "collective_bytes": coll_bytes, **scan_info},
        "collectives": coll.to_dict(),
        "roofline": terms,
        "hlo_bytes": len(hlo),
    }
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-dse", action="store_true")
    ap.add_argument("--out-dir", default="benchmarks/results/dryrun")
    args = ap.parse_args()

    if args.all:
        todo = cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]

    os.makedirs(args.out_dir, exist_ok=True)
    mesh_tag = "pod2x16x16" if args.multi_pod else "pod16x16"
    n_devices = len(jax.devices())
    assert n_devices >= (512 if args.multi_pod else 256), n_devices

    failures = []
    for arch, shape in todo:
        tag = f"{arch}__{shape}__{mesh_tag}"
        out_path = os.path.join(args.out_dir, tag + ".json")
        print(f"=== {tag}", flush=True)
        try:
            res = run_cell(arch, shape, args.multi_pod, use_dse=not args.no_dse)
            with open(out_path, "w") as f:
                json.dump(res, f, indent=1)
            r = res["roofline"]
            print(
                f"    ok: compile={res['compile_s']:.1f}s "
                f"flops={res['cost_analysis'].get('flops', 0):.3e} "
                f"coll={res['collectives']['total_bytes']:.3e}B "
                f"dominant={r['dominant']}",
                flush=True,
            )
        except Exception as e:  # noqa: BLE001
            failures.append((tag, str(e)))
            traceback.print_exc()
    if failures:
        print(f"FAILED {len(failures)} cells:")
        for tag, err in failures:
            print(f"  {tag}: {err[:200]}")
        raise SystemExit(1)
    print("all cells passed")


if __name__ == "__main__":
    main()
