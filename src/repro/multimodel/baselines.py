"""Static multi-model baselines the co-scheduler is measured against.

* ``equal_split``: the package is divided into equal per-model quotas up
  front, ignoring the models' sizes and traffic weights (the static spatial
  baseline of the multi-chiplet multi-tenancy literature).
* ``time_multiplexed``: every model gets the whole package for an optimal
  fraction of time.  By default zero switching cost is charged for the
  per-slice weight re-deployment, which makes this a *generous* baseline --
  real packages pay a segment re-load per switch; ``switch_cost=True``
  charges that re-load (model weights through shared DRAM once per
  scheduling period) and keeps the default off so historical numbers stay
  reproducible.

Both produce :class:`MultiModelSchedule` objects with the same figure of
merit as the co-scheduler, so fig11 compares like with like.
"""
from __future__ import annotations

from ..core.costmodel import INF, CostModel
from ..core.graph import (
    MM_PARTITIONED,
    MM_TIME_MUX,
    ModelAssignment,
    MultiModelSchedule,
    mix_rate,
)
from ..core.search import search
from .quota import package_flavors


def _searched_assignment(spec, cost, ctype, chips, **kw):
    sched = search(spec.graph, cost, chips, chip_type=ctype)
    if sched is None or sched.latency == INF:
        return None
    sched.meta["m_samples"] = cost.m
    return ModelAssignment(
        model=spec.name, weight=spec.weight, chips=chips,
        schedule=sched, chip_type=ctype, **kw,
    )


def equal_split(specs, cost: CostModel) -> MultiModelSchedule | None:
    """Equal per-model quotas; models round-robin across flavors (hetero)."""
    hw = cost.hw
    flavors = package_flavors(hw)
    n = len(specs)
    # Round-robin models over flavors, then split each flavor equally among
    # the models it hosts (remainder chips go to the first models).
    groups: dict[int, list[int]] = {}
    for i in range(n):
        groups.setdefault(i % len(flavors), []).append(i)
    quota: dict[int, tuple[str | None, int]] = {}
    for t, members in groups.items():
        ctype, cap = flavors[t]
        if cap < len(members):
            return None
        base, rem = divmod(cap, len(members))
        for j, i in enumerate(members):
            quota[i] = (ctype, base + (1 if j < rem else 0))
    assignments = []
    for i, spec in enumerate(specs):
        ctype, chips = quota[i]
        a = _searched_assignment(spec, cost, ctype, chips)
        if a is None:
            return None
        assignments.append(a)
    assignments = tuple(assignments)
    lam = mix_rate(assignments)
    return MultiModelSchedule(
        package=hw.name, chips=hw.chips, mode=MM_PARTITIONED,
        assignments=assignments, mix_rate=lam,
        weighted_throughput=lam * sum(s.weight for s in specs),
        meta={"baseline": "equal_split"},
    )


def time_multiplexed(specs, cost: CostModel,
                     curves=None,
                     switch_cost: bool = False,
                     switch_period_s: float = 1.0) -> MultiModelSchedule | None:
    """Whole-package time slicing with optimal per-model time fractions.

    With full-package throughput ``tp_i`` and weights ``w_i``, the optimal
    slice of model i is ``share_i = (w_i / tp_i) / sum_j (w_j / tp_j)``,
    giving mix rate ``lambda = 1 / sum_j (w_j / tp_j)``.  On a heterogeneous
    package a Scope schedule is single-flavored, so each slice runs on the
    best single flavor for that model (the other flavors idle).

    ``switch_cost=True`` stops pretending slice switches are free: entering
    a model's slice re-deploys its weights through shared DRAM, charging
    ``r_i = weight_bytes_i / dram_bw_total`` per scheduling period of
    ``switch_period_s`` seconds.  The optimum then serves
    ``lambda = (1 - sum_i r_i / T) / sum_i (w_i / tp_i)`` with gross share
    ``share_i = lambda * w_i / tp_i + r_i / T``; assignments carry the
    *useful* fraction in ``time_share`` (gross shares in the meta), so the
    reported throughputs stay consistent.  Default False reproduces the
    historical zero-cost baseline numbers.

    ``curves`` (the quota search's per-(model, flavor) tables) lets
    co_schedule reuse the already-computed full-capacity points instead of
    re-running the most expensive search per model.
    """
    hw = cost.hw
    flavors = package_flavors(hw)
    picks = []
    for spec in specs:
        best = None
        for ctype, cap in flavors:
            pt = None
            if curves is not None:
                pt = curves[(spec.name, ctype)].envelope(cap)[cap]
            if pt is not None:
                tp, sched, used = pt.throughput, pt.schedule, pt.chips
            else:
                sched = search(spec.graph, cost, cap, chip_type=ctype)
                if sched is None or sched.latency == INF:
                    continue
                tp, used = cost.m / sched.latency, cap
            if best is None or tp > best[2]:
                best = (ctype, used, tp, sched)
        if best is None:
            return None
        picks.append(best)
    denom = sum(
        spec.weight / tp for spec, (_, _, tp, _) in zip(specs, picks)
    )
    meta = {"baseline": "time_multiplexed", "switch_cost": switch_cost}
    if switch_cost:
        T = switch_period_s
        reloads = [
            spec.graph.total_weight_bytes / hw.dram_bw_total for spec in specs
        ]
        overhead = sum(reloads) / T
        if overhead >= 1.0:
            return None   # the period is all switching, no useful time left
        lam = (1.0 - overhead) / denom
        meta.update(
            switch_period_s=T,
            reload_s=reloads,
            gross_shares=[
                lam * spec.weight / tp + r / T
                for spec, (_, _, tp, _), r in zip(specs, picks, reloads)
            ],
        )
    else:
        lam = 1.0 / denom
    assignments = []
    for spec, (ctype, cap, tp, sched) in zip(specs, picks):
        sched.meta["m_samples"] = cost.m
        assignments.append(ModelAssignment(
            model=spec.name, weight=spec.weight, chips=cap,
            schedule=sched, chip_type=ctype,
            time_share=lam * spec.weight / tp,   # useful (post-reload) fraction
        ))
    assignments = tuple(assignments)
    return MultiModelSchedule(
        package=hw.name, chips=hw.chips, mode=MM_TIME_MUX,
        assignments=assignments, mix_rate=mix_rate(assignments),
        weighted_throughput=mix_rate(assignments) * sum(s.weight for s in specs),
        meta=meta,
    )
