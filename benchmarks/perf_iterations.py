import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# must precede any jax import (see repro.launch.dryrun)

"""SSPerf hillclimb driver: hypothesis -> change -> re-lower -> measure.

For a chosen (arch x shape) cell, evaluates the baseline plan plus a set of
candidate changes (each one knob), re-runs the dry-run, and reports the three
roofline terms per variant.  Results feed EXPERIMENTS.md SSPerf.

  PYTHONPATH=src python -m benchmarks.perf_iterations --cell granite-3-8b:train_4k
"""
import argparse
import dataclasses
import json

from repro.configs import SHAPES, registry
from repro.launch.dryrun import run_cell
from repro.runtime.planner import plan_for_cell
from repro.runtime.sharding import ShardPlan

OUT_DIR = os.path.join(os.path.dirname(__file__), "results", "perf")


def variant_plan(base: ShardPlan, **kw) -> ShardPlan:
    return dataclasses.replace(base, **kw)


def run_variant(arch, shape, label, hypothesis, plan=None, cfg_patch=None,
                multi_pod=False):
    """Run one variant; cfg_patch temporarily replaces the registry config."""
    cfg0 = registry.ARCHS[arch]
    accum = (cfg_patch or {}).get("accum_steps", 1)
    try:
        if cfg_patch:
            registry.ARCHS[arch] = dataclasses.replace(cfg0, **cfg_patch)
        res = run_cell(arch, shape, multi_pod, plan_override=plan,
                       force_accum1=(accum == 1))
    finally:
        registry.ARCHS[arch] = cfg0
    if accum > 1:
        # the accumulation scan body is counted once; one body = one
        # microbatch => scale the whole-batch terms by A (temp memory is the
        # real per-microbatch footprint, which is accum's point)
        for k in ("flops", "bytes", "collective_bytes"):
            res["corrected"][k] *= accum
        for k in ("compute_s", "memory_s", "collective_s", "bound_s"):
            res["roofline"][k] *= accum
    r = res["roofline"]
    mem = res["memory_analysis"]
    dse_meta = res["plan"].get("dse_meta", {})
    row = {
        "label": label,
        "hypothesis": hypothesis,
        "plan": res["plan"],
        # DSE cost of producing this plan (FastCostModel; the memoized
        # engine from fastcost.py -- see BENCH_search_time.json for the
        # before/after sweep comparison).
        "dse_s": dse_meta.get("dse_s"),
        "dse_engine": dse_meta.get("dse_engine"),
        "compute_s": r["compute_s"],
        "memory_s": r["memory_s"],
        "collective_s": r["collective_s"],
        "bound_s": r["bound_s"],
        "dominant": r["dominant"],
        "temp_bytes": mem.get("temp_size_bytes"),
        "arg_bytes": mem.get("argument_size_bytes"),
        "flops": res["corrected"]["flops"],
        "hlo_bytes_accessed": res["corrected"]["bytes"],
        "collective_bytes": res["corrected"]["collective_bytes"],
    }
    print(f"  {label:34s} comp={r['compute_s']:.3f}s mem={r['memory_s']:.3f}s "
          f"coll={r['collective_s']:.3f}s bound={r['bound_s']:.3f}s "
          f"[{r['dominant']}] temp={(mem.get('temp_size_bytes') or 0)/2**30:.1f}GiB",
          flush=True)
    return row


def variants_for(arch: str, shape: str, axes, model_axis: int):
    """The enumerated candidate changes with their napkin-math hypotheses."""
    cfg = registry.ARCHS[arch]
    S, B, kind = SHAPES[shape]
    base = plan_for_cell(cfg, S, B, axes, model_axis=model_axis, kind=kind)
    R = cfg.pattern_repeats
    out = [("baseline(dse)", "paper-faithful DSE plan", base, None)]
    if kind != "decode":
        for t, tag in [(0, "all-ISP"), (R, "all-WSP"), (R // 2, "half")]:
            p1 = "WSP" if t > 0 else "ISP"
            p2 = "ISP" if t < R else "WSP"
            tr = None if t in (0, R) else t
            if (p1, p2, tr) == (base.p1, base.p2, base.transition_repeat):
                continue
            out.append((
                f"transition={tag}",
                "move WSP->ISP point: trades weight-gather traffic (WSP) "
                "against activation all-reduce (ISP)",
                variant_plan(base, p1=p1, p2=p2, transition_repeat=tr),
                None,
            ))
        out.append((
            "remat=off",
            "recompute costs ~1/3 extra flops + bytes; off => compute/memory "
            "terms drop, temp memory grows",
            base, {"remat": False},
        ))
        acc = cfg.accum_steps
        out.append((
            f"accum={max(2, acc * 2)}",
            "more microbatches: temp activation memory shrinks ~2x, "
            "roofline terms unchanged (same math)",
            base, {"accum_steps": max(2, acc * 2)},
        ))
        if cfg.moe is not None:
            out.append((
                "ep=off",
                "replicated experts: kills the EP all-to-all but multiplies "
                "weight memory by n_experts/model_axis",
                variant_plan(base, ep=False), None,
            ))
        out.append((
            "zero=off",
            "optimizer state replicated over data: argument bytes grow, "
            "removes the ZeRO gather collectives",
            variant_plan(base, zero=False), None,
        ))
    else:
        out.append((
            "cache_time_shard=off",
            "cache replicated over model: no gather at attention, but "
            "argument bytes x model_axis",
            variant_plan(base, shard_kv_cache_time=False), None,
        ))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--only", default=None, help="comma list of variant labels")
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    axes = ("pod", "data", "model") if args.multi_pod else ("data", "model")

    print(f"== perf iterations for {arch} x {shape} ==", flush=True)
    rows = []
    for label, hyp, plan, patch in variants_for(arch, shape, axes, 16):
        if args.only and label not in args.only.split(","):
            continue
        try:
            rows.append(run_variant(arch, shape, label, hyp, plan, patch,
                                    args.multi_pod))
        except Exception as e:  # noqa: BLE001
            print(f"  {label:34s} FAILED: {str(e)[:160]}", flush=True)
            rows.append({"label": label, "hypothesis": hyp, "error": str(e)[:400]})

    os.makedirs(OUT_DIR, exist_ok=True)
    out_path = os.path.join(OUT_DIR, f"{arch}__{shape}.json")
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
