"""Minimal deterministic stand-in for ``hypothesis`` when it isn't installed.

The test suite's property tests use a small surface: ``@given`` with
positional/keyword strategies, ``@settings(max_examples=..., deadline=...)``,
``assume``, and the ``integers`` / ``floats`` / ``booleans`` / ``sampled_from``
/ ``lists`` / ``just`` / ``tuples`` strategies.  ``tests/conftest.py`` installs
this module under the ``hypothesis`` name *only* when the real package is
missing (the container image cannot pip-install), so property tests still run
as deterministic randomized sweeps instead of ERRORing at collection.

This is not a shrinker and makes no coverage claims -- it exists so the suite
degrades to seeded random testing rather than losing the modules entirely.
"""
from __future__ import annotations

import functools
import random


class _Unsatisfied(Exception):
    """Raised by assume() to skip an example."""


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


class HealthCheck:
    all = staticmethod(lambda: [])
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"
    data_too_large = "data_too_large"


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng: random.Random):
        return self._draw(rng)

    # combinators used via st.X(...).map/filter in some suites
    def map(self, f):
        return SearchStrategy(lambda rng: f(self._draw(rng)))

    def filter(self, pred, tries: int = 100):
        def draw(rng):
            for _ in range(tries):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise _Unsatisfied()
        return SearchStrategy(draw)


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_settings = {"max_examples": max_examples}
        return fn
    return deco


settings.register_profile = lambda *a, **k: None
settings.load_profile = lambda *a, **k: None


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            conf = getattr(wrapper, "_fallback_settings", None) or getattr(
                fn, "_fallback_settings", {}
            )
            max_examples = conf.get("max_examples", 20)
            for i in range(max_examples):
                rng = random.Random(0x5C09E + 7919 * i)
                try:
                    pos = [s.example_from(rng) for s in arg_strategies]
                    kws = {k: s.example_from(rng) for k, s in kw_strategies.items()}
                    fn(*args, *pos, **kws, **kwargs)
                except _Unsatisfied:
                    continue
        # pytest follows __wrapped__ to the original signature and would
        # treat the strategy parameters as fixtures; hide it.
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        return wrapper
    return deco


# --------------------------------------------------------------- strategies

def integers(min_value: int = 0, max_value: int = 1 << 16) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value))


def floats(
    min_value: float = 0.0,
    max_value: float = 1.0,
    allow_nan: bool = False,
    allow_infinity: bool = False,
    **_ignored,
) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.uniform(min_value, max_value))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5)


def sampled_from(seq) -> SearchStrategy:
    options = list(seq)
    return SearchStrategy(lambda rng: options[rng.randrange(len(options))])


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def lists(elements: SearchStrategy, min_size: int = 0, max_size: int = 10,
          unique: bool = False, **_ignored) -> SearchStrategy:
    def draw(rng):
        size = rng.randint(min_size, max_size)
        if not unique:
            return [elements.example_from(rng) for _ in range(size)]
        out, seen = [], set()
        for _ in range(50 * max(1, size)):
            if len(out) >= size:
                break
            v = elements.example_from(rng)
            if v not in seen:
                seen.add(v)
                out.append(v)
        return out
    return SearchStrategy(draw)


def tuples(*strategies) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: tuple(s.example_from(rng) for s in strategies)
    )
