"""Merged interleaving: fuse several models into one shared merged pipeline.

Spatial partitioning wastes chips when a small model cannot use even its
minimal quota efficiently.  The alternative the merged-pipeline dimension
opens up: concatenate the models' LayerGraphs into one chain, scale each
model's layers by a per-model batch weighting (``LayerNode.scaled``), and
run a single Scope DSE over the whole package.  One pipeline beat then
produces ``scale_i`` samples of model ``i``; every region serves exactly one
model's layers (clusters never straddle models more than the CMT merge
allows -- straddling is legal and simply means two small adjacent models
share a region, which is the point of merging).

Boundary semantics: consecutive models exchange no activations -- model
outputs leave via DRAM (out/halo sanitized to 0, like any network output)
and the next model's inputs arrive from DRAM.  Each model-initial layer is
marked ``meta["dram_input"]`` and the cost model's segment-level load term
charges its staging wherever the boundary lands (mid-segment entry layers
included, see ``segment_time``) -- partition-independent, so the DSE cannot
dodge the charge by picking a particular boundary partition pair.
"""
from __future__ import annotations

from dataclasses import replace

from ..core.costmodel import INF, CostModel
from ..core.graph import (
    MM_MERGED,
    LayerGraph,
    ModelAssignment,
    MultiModelSchedule,
    mix_rate,
)
from ..core.search import search


def batch_scales(specs, max_scale: int = 8) -> list[int]:
    """Integer samples-per-beat per model, approximately proportional to the
    traffic weights (capped at ``max_scale`` to keep merged graphs small).
    The achieved mix rate is computed from the *actual* scales, so the
    integer rounding never over-reports throughput."""
    w_min = min(s.weight for s in specs)
    return [
        max(1, min(max_scale, round(s.weight / w_min))) for s in specs
    ]


def merged_graph(specs, scales=None) -> tuple[LayerGraph, list[int]]:
    """Concatenate the specs' graphs with per-model batch weighting."""
    scales = scales or batch_scales(specs)
    layers = []
    for m, (spec, scale) in enumerate(zip(specs, scales)):
        for i, node in enumerate(spec.graph.layers):
            node = node.scaled(scale)
            if i == len(spec.graph) - 1:
                node = replace(node, out_bytes=0.0, halo_bytes=0.0)
            if i == 0 and m > 0:
                node = replace(
                    node, meta={**node.meta, "dram_input": True}
                )
            layers.append(replace(node, name=f"{spec.name}.{node.name}"))
    name = "+".join(
        f"{s.name}x{k}" if k > 1 else s.name for s, k in zip(specs, scales)
    )
    return LayerGraph(name, tuple(layers)), list(scales)


def search_merged(
    specs,
    cost: CostModel,
    chip_type: str | None = None,
    chips: int | None = None,
    paper_strict: bool = False,
) -> MultiModelSchedule | None:
    """One Scope DSE over the merged graph on the whole package.

    On a heterogeneous package the merged pipeline must live on a single
    flavor (a Scope schedule is single-typed); callers pick the flavor via
    ``chip_type``/``chips`` -- co_schedule tries each.
    """
    hw = cost.hw
    if chips is None:
        chips = hw.chips if not hw.region_types else hw.chip_type(chip_type).chips
    graph, scales = merged_graph(specs)
    sched = search(graph, cost, chips, chip_type=chip_type,
                   paper_strict=paper_strict)
    if sched is None or sched.latency == INF:
        return None
    sched.meta["m_samples"] = cost.m
    sched.meta["batch_scales"] = list(scales)
    assignments = tuple(
        ModelAssignment(
            model=spec.name,
            weight=spec.weight,
            chips=chips,
            schedule=sched,
            chip_type=chip_type,
            samples_per_beat=float(scale),
        )
        for spec, scale in zip(specs, scales)
    )
    lam = mix_rate(assignments)
    return MultiModelSchedule(
        package=hw.name,
        chips=hw.chips,
        mode=MM_MERGED,
        assignments=assignments,
        mix_rate=lam,
        weighted_throughput=lam * sum(s.weight for s in specs),
        meta={"merged_graph": graph.name, "batch_scales": list(scales)},
    )
