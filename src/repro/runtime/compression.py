"""Gradient compression: symmetric int8 quantization with error feedback.

Two uses:
* ``compress_decompress`` -- stateless quantize->dequantize, applied before
  the (GSPMD-inserted) data-parallel reduction to bound accumulation traffic.
* ``ef_compress`` -- error-feedback variant carrying a residual buffer,
  used by the shard_map pipeline runtime where the DP all-reduce is explicit
  (``jax.lax.psum`` over int8 payloads).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(g: jax.Array) -> jax.Array:
    if g.dtype == jnp.int32 or g.size <= 1:
        return g
    q, s = quantize_int8(g)
    return dequantize_int8(q, s).astype(g.dtype)


def ef_compress(g: jax.Array, residual: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback compression: returns (q, scale, new_residual)."""
    corrected = g.astype(jnp.float32) + residual
    q, s = quantize_int8(corrected)
    new_res = corrected - dequantize_int8(q, s)
    return q, s, new_res


def psum_compressed(g: jax.Array, residual: jax.Array, axis_name: str):
    """Compressed DP all-reduce with error feedback (shard_map path)."""
    q, s, new_res = ef_compress(g, residual)
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)  # int32 wire format
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    mean = summed.astype(jnp.float32) * s / n
    return mean.astype(g.dtype), new_res
