"""Batched serving example: prefill + KV-cache greedy decoding.

Loads a reduced gemma2 (local/global alternating attention + softcaps),
prefills a batch of prompts, then streams tokens with the jitted serve_step
-- the same step the decode_32k dry-run cells lower at scale.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.launch.mesh import single_device_mesh
from repro.models import init_kv_cache, init_params
from repro.runtime.planner import plan_for_cell
from repro.runtime.serve import build_decode_step, greedy_generate

BATCH, PROMPT, NEW = 8, 24, 48

cfg = get_smoke_config("gemma2-9b")
mesh = single_device_mesh()
max_len = PROMPT + NEW
plan = plan_for_cell(cfg, max_len, BATCH, ("data", "model"), 1, kind="decode")
params = init_params(cfg, jax.random.PRNGKey(0))
dstep, _ = build_decode_step(cfg, mesh, plan, batch=BATCH, max_len=max_len)
caches = init_kv_cache(cfg, BATCH, max_len, jnp.float32)

prompts = jax.random.randint(jax.random.PRNGKey(1), (BATCH, PROMPT), 0, cfg.vocab)
t0 = time.time()
logits = None
for t in range(PROMPT):
    pos = jnp.full((BATCH,), t, jnp.int32)
    logits, caches = dstep(params, prompts[:, t:t + 1], pos, caches)
print(f"prefill ({BATCH}x{PROMPT}) in {time.time() - t0:.2f}s")

first = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
t0 = time.time()
out, _ = greedy_generate(cfg, params, dstep, caches, first, PROMPT, NEW)
dt = time.time() - t0
print(f"decoded {BATCH}x{NEW} tokens in {dt:.2f}s "
      f"({BATCH * NEW / dt:.0f} tok/s on 1 CPU core)")
print("greedy continuations are deterministic:",
      bool((out[:1] == out[:1]).all()))
print("sample:", out[0, :12].tolist())
