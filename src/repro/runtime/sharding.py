"""Scope schedule -> GSPMD sharding rules (the paper's ISP/WSP on a TPU mesh).

Storage rule (paper SSIII-B, distributed weight buffering): parameters are
ALWAYS stored sharded over the ``model`` axis on their heavy dimension.
* ISP-zone layers compute directly on the shards (Megatron-style tensor
  parallelism) -- activations stay replicated over ``model``.
* WSP-zone layers keep activations *sequence-sharded* over ``model``; GSPMD
  then all-gathers the (sharded-stored) weights at use -- which is exactly
  the paper's "chiplets exchange weight tiles in the preparation phase".

The WSP->ISP transition point from the Scope DSE maps to ``transition_repeat``
on the scanned layer stack; zone 1 runs under the WSP constraints, zone 2
under ISP (models/model.py executes the two scan segments).

Table II correspondence (verified in tests/test_runtime_sharding.py by
counting HLO collectives):
* WSP->WSP boundary: halo only        -> no collective on the residual
  (attention K/V gathers play the halo role),
* WSP->ISP transition: all-gather of the sequence-sharded activations,
* ISP->ISP: all-reduce after row-parallel matmuls.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig
from ..optim import OptState

WSP, ISP = "WSP", "ISP"


@dataclass(frozen=True)
class ShardPlan:
    """Execution plan for one (arch x shape x mesh) cell."""
    mesh_axes: tuple[str, ...]            # ("pod","data","model") | ("data","model")
    p1: str = ISP                         # zone-1 partition
    p2: str = ISP                         # zone-2 partition
    transition_repeat: int | None = None  # None -> single zone (p1)
    ep: bool = True                       # expert parallelism for MoE weights
    zero: bool = True                     # optimizer state sharded over data too
    shard_kv_cache_time: bool = True      # decode cache sharded over T
    use_dp: bool = True                   # False when batch < dp size (long_500k)
    # Pipeline stages of the Scope schedule behind this plan, as
    # (layer_lo, layer_hi, chip_type, region_chips) tuples.  On mixed-flavor
    # packages consecutive stages may carry different chip types; the
    # serving executor maps each stage onto its flavor's sub-mesh.  Empty
    # for plans not derived from a cluster-level schedule.
    stage_chip_types: tuple[tuple[int, int, str | None, int], ...] = ()
    meta: dict = field(default_factory=dict, hash=False, compare=False)

    @property
    def dp(self):
        """Batch data-parallel axes."""
        if not self.use_dp:
            return ()
        return tuple(a for a in self.mesh_axes if a in ("pod", "data"))

    def zone_partition(self, zone: int) -> str:
        return self.p1 if zone == 1 else self.p2


# ------------------------------------------------------------- param specs

def _attn_specs(cfg: ModelConfig, model_div_kv: bool) -> dict:
    kv_spec = P(None, None, "model") if model_div_kv else P(None, None, None)
    return {
        "wq": P(None, None, "model"),
        "wk": kv_spec,
        "wv": kv_spec,
        "wo": P(None, "model", None),
    }


def _ffn_specs() -> dict:
    return {"w1": P(None, None, "model"), "w2": P(None, "model", None),
            "w3": P(None, None, "model")}


def _moe_specs(ep: bool) -> dict:
    if ep:
        # experts over 'model' + FSDP-style 'data' shard on the hidden dim:
        # a 772 GB expert bank over 16 chips alone is 48 GB/chip (> HBM);
        # GSPMD all-gathers the tile at use (paper SSIII-B semantics).
        e = P(None, "model", "data", None)
        return {"router": P(None, None, None), "w1": e, "w2": e, "w3": e}
    return {
        "router": P(None, None, None),
        "w1": P(None, None, None, "model"),
        "w2": P(None, None, "model", None),
        "w3": P(None, None, None, "model"),
    }


def _mamba_specs() -> dict:
    return {
        "in_proj": P(None, None, "model"),
        "conv_w": P(None, None, "model"),
        "conv_b": P(None, "model"),
        "x_proj": P(None, "model", None),
        "dt_proj": P(None, None, "model"),
        "dt_bias": P(None, "model"),
        "A_log": P(None, "model", None),
        "D": P(None, "model"),
        "out_proj": P(None, "model", None),
    }


def _rwkv_specs() -> dict:
    return {
        "mu": P(None, None, None),
        "wr": P(None, None, "model"),
        "wk": P(None, None, "model"),
        "wv": P(None, None, "model"),
        "wg": P(None, None, "model"),
        "wo": P(None, "model", None),
        "w0": P(None, None),
        "w_lora_a": P(None, None, None),
        "w_lora_b": P(None, None, "model"),
        "u": P(None, "model", None),
        "ln_x": P(None, None),
        "cm_r": P(None, None, "model"),
        "cm_k": P(None, None, "model"),
        "cm_v": P(None, "model", None),
    }


def param_pspecs(cfg: ModelConfig, plan: ShardPlan, mesh: Mesh) -> dict:
    """PartitionSpec pytree matching init_params' structure."""
    model_size = mesh.shape["model"]
    model_div_kv = cfg.n_kv_heads % model_size == 0 or model_size % cfg.n_kv_heads == 0
    blocks = []
    for pi, kind in enumerate(cfg.expanded_pattern):
        spec = {"ln1": P(None, None), "ln2": P(None, None)}
        if kind in ("attn", "local"):
            spec["attn"] = _attn_specs(cfg, model_div_kv)
        elif kind == "mamba":
            spec["mamba"] = _mamba_specs()
        elif kind == "rwkv":
            spec["rwkv"] = _rwkv_specs()
        if kind == "rwkv":
            pass
        elif cfg.is_moe_block(pi):
            spec["moe"] = _moe_specs(plan.ep)
            if not cfg.ffn_gated:
                spec["moe"].pop("w3")
        else:
            spec["ffn"] = _ffn_specs()
            if not cfg.ffn_gated:
                spec["ffn"].pop("w3")
        blocks.append(spec)
    out = {
        "embed": P("model", None),          # vocab-sharded
        "blocks": tuple(blocks),
        "final_ln": P(None),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = P(None, "model")
    return out


def opt_pspecs(cfg: ModelConfig, plan: ShardPlan, mesh: Mesh, param_specs, optimizer: str):
    """Optimizer-state specs.  ZeRO mode adds a 'data' shard on the repeat
    axis of stacked block params (paper SSIII-B applied to optimizer state)."""
    def zero_ify(spec: P) -> P:
        if not plan.zero or len(spec) == 0:
            return spec
        if spec[0] is None and "data" in plan.mesh_axes:
            return P("data", *spec[1:])
        return spec

    def map_spec(s):
        return zero_ify(s) if isinstance(s, P) else s

    moment_specs = jax.tree.map(map_spec, param_specs,
                                is_leaf=lambda x: isinstance(x, P))
    if optimizer == "adamw":
        return OptState(step=P(), m=moment_specs, v=moment_specs)
    # adafactor: row stats drop the last dim, col stats drop the 2nd-to-last
    def rows(s):
        if not isinstance(s, P):
            return s
        return zero_ify(P(*s[:-1])) if len(s) >= 2 else s

    def cols(s):
        if not isinstance(s, P):
            return s
        if len(s) >= 2:
            return zero_ify(P(*s[:-2], s[-1]))
        return P(None)

    return OptState(
        step=P(),
        m=jax.tree.map(rows, param_specs, is_leaf=lambda x: isinstance(x, P)),
        v=jax.tree.map(cols, param_specs, is_leaf=lambda x: isinstance(x, P)),
    )


# -------------------------------------------------------------- activations

def make_constrain(mesh: Mesh, plan: ShardPlan, zone: int):
    """Activation-constraint callback for models.forward/decode_step."""
    dp = plan.dp
    partition = plan.zone_partition(zone)

    def constrain(x, tag: str):
        if tag == "moe:groups":
            # token groups [G, Tg, d]: G shards over every mesh axis
            spec = P(tuple([*dp, "model"]), *([None] * (x.ndim - 1)))
        elif tag == "moe:buffers":
            # expert buffers [E, G*Cg, d]: shard experts (EP) or capacity
            # rows -- NEVER replicate (the biggest MoE activation tensor).
            if plan.ep:
                spec = P("model", tuple(dp), *([None] * (x.ndim - 2)))
            else:
                spec = P(None, tuple([*dp, "model"]), *([None] * (x.ndim - 2)))
        elif tag == "logits":
            spec = P(dp, None, "model")
        elif partition == WSP and x.ndim >= 3 and x.shape[1] > 1:
            spec = P(dp, "model", *([None] * (x.ndim - 2)))
        else:
            spec = P(dp, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain


# ------------------------------------------------------------------- caches

def cache_pspecs(cfg: ModelConfig, plan: ShardPlan) -> tuple:
    dp = plan.dp
    t_ax = "model" if plan.shard_kv_cache_time else None
    specs = []
    for kind in cfg.expanded_pattern:
        if kind in ("attn", "local"):
            specs.append({
                "k": P(None, dp, t_ax, None, None),
                "v": P(None, dp, t_ax, None, None),
            })
        elif kind == "mamba":
            specs.append({
                "h": P(None, dp, "model", None),
                "conv": P(None, dp, None, "model"),
            })
        elif kind == "rwkv":
            specs.append({
                "S": P(None, dp, "model", None, None),
                "shift": P(None, dp, None, None),
                "shift_ffn": P(None, dp, None, None),
            })
    return tuple(specs)


def batch_pspecs(cfg: ModelConfig, plan: ShardPlan, with_labels: bool = True):
    dp = plan.dp
    tok = P(dp, None)
    spec = {}
    if cfg.frontend != "audio_stub":      # audio stub has no token input
        spec["tokens"] = tok
    if with_labels:
        spec["labels"] = tok
    if cfg.frontend != "none":
        spec["frontend_embeds"] = P(dp, None, None)
    return spec


def to_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _axes_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def zero_shard(spec_tree, shape_tree, mesh: Mesh, axis: str = "data"):
    """Shape-aware ZeRO placement: for each optimizer-moment leaf, put the
    ``data`` axis on the first unsharded dim whose size it divides (the
    naive dim-0 choice dies on the divisibility sanitizer for most layer
    counts -- 40, 42, 52 repeats vs a 16-way axis)."""
    if axis not in mesh.shape:
        return spec_tree
    n = mesh.shape[axis]

    def fix(spec, shaped):
        if not isinstance(spec, P):
            return spec
        entries = list(spec) + [None] * (len(shaped.shape) - len(spec))
        if axis in entries:
            return spec
        for i, (e, dim) in enumerate(zip(entries, shaped.shape)):
            if e is None and dim % n == 0 and dim >= n:
                entries[i] = axis
                return P(*entries)
        return spec

    return jax.tree.map(fix, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, P))


def sanitize_pspecs(spec_tree, shape_tree, mesh: Mesh):
    """Drop shard axes whose size does not divide the array dim.

    jit in_shardings/out_shardings require exact divisibility (unlike
    with_sharding_constraint); non-divisible cases (40 rwkv heads over a
    16-way model axis, 21 gemma2 repeats over a 16-way ZeRO axis, ...) fall
    back to replication on that dim.
    """
    def fix(spec, shaped):
        if not isinstance(spec, P):
            return spec
        shape = shaped.shape
        out = []
        for i, entry in enumerate(spec):
            if i >= len(shape) or shape[i] % _axes_size(mesh, entry) != 0:
                out.append(None)
            else:
                out.append(entry)
        return P(*out)

    return jax.tree.map(fix, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, P))
