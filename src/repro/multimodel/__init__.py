"""Multi-model co-scheduling: N LayerGraphs on one (optionally hetero) MCM.

Production MCM packages serve mixed traffic (Odema et al., SCAR); this
subsystem schedules a set of ``(LayerGraph, traffic_weight)`` models onto a
single package by searching jointly over

* package partitioning into per-model chip quotas (``quota.py``), drawing
  each quota from one flavor of a heterogeneous package -- or *spanning*
  two flavors (``search_partitioned_mixed``), where the model's pipeline
  itself crosses the flavor seam (``repro.core.search.search_mixed``),
* per-model Scope schedules via the existing ``search()`` -- one shared
  :class:`~repro.core.fastcost.FastCostModel` memo makes the repeated
  ``(graph, chips, chip_type)`` sub-searches across quota candidates
  near-free,
* a merged interleaving mode (``interleave.py``) that concatenates small
  models into one shared merged pipeline with per-model batch weighting.

The figure of merit is weighted throughput at the traffic mix: the largest
``lambda`` such that model ``i`` sustains ``lambda * weight_i`` samples/s,
times the total weight (see :class:`repro.core.graph.MultiModelSchedule`).
``co_schedule`` returns the best of the searched modes and is compared in
``benchmarks/fig11_multimodel.py`` against the two static baselines
(equal-split and whole-package time-multiplexing, ``baselines.py``).
"""
from ..core.graph import (  # noqa: F401
    MM_MERGED,
    MM_PARTITIONED,
    MM_TIME_MUX,
    ModelAssignment,
    MultiModelSchedule,
    validate_multimodel,
)
from .spec import ModelSpec, parse_mix  # noqa: F401
from .curves import (  # noqa: F401
    MixedCurve,
    ThroughputCurve,
    build_curves,
    mixed_throughput_curve,
)
from .quota import (  # noqa: F401
    brute_force_partitioned,
    search_partitioned,
    search_partitioned_mixed,
)
from .interleave import (  # noqa: F401
    merged_graph,
    search_merged,
    search_merged_groups,
)
from .baselines import equal_split, time_multiplexed  # noqa: F401
from .coschedule import co_schedule, describe  # noqa: F401
