"""The paper's three baseline scheduling families (SSI / SSV-A).

* fully sequential ([6] Simba, [7] NN-Baton, [21]): every layer runs on the
  whole package, one layer at a time; weights streamed from DRAM per layer,
  amortized over the batch.
* fully pipelined ([15] DNNBuilder, [16] TGPA): one segment, one layer per
  cluster across the package; invalid when L > C or weights overflow.
* segmented pipeline ([17] Tangram, [18] DeepBurning-SEG, [19] Gemini), the
  SOTA Scope compares against: segments of single-layer clusters -- i.e.
  Scope with the cluster-merge dimension disabled.  Shares segment division
  and the region/partition search with Scope so that measured gains isolate
  the merge contribution (paper SSV-A).
"""
from __future__ import annotations

from .costmodel import INF, CostModel
from .graph import LayerGraph, ScopeSchedule, SegmentSchedule
from .partition import enumerate_transition_points
from .regions import RegionMode
from .search import SegmentResult, search, search_segment
from .segments import candidate_segment_counts, divide_segments


def schedule_sequential(graph: LayerGraph, cost: CostModel, chips: int) -> ScopeSchedule:
    """Layer-at-a-time on all C chips; batch of m streams through each layer.

    Per layer: weights loaded once from DRAM (not resident across layers),
    then m samples each pay max(T_comm, T_comp) (Eq. 7 overlap still applies);
    inter-layer traffic is an on-package redistribution (Case1 with n = C).
    """
    hw, m = cost.hw, cost.m
    total = 0.0
    for i, layer in enumerate(graph.layers):
        best = INF
        nxt = graph.layers[i + 1] if i + 1 < len(graph.layers) else None
        for p in ("WSP", "ISP"):
            for p_next in (("WSP", "ISP") if nxt is not None else (None,)):
                t = cost.layer_time(layer, p, chips, p_next, chips, same_region=True)
                beat = t.total if cost.overlap else t.unoverlapped
                cand = layer.weight_bytes / hw.dram_bw_total + m * beat
                best = min(best, cand)
        total += best
    # single "segment" covering everything on the full package, no pipelining
    return ScopeSchedule(
        workload=graph.name, chips=chips,
        segments=(), latency=total, meta={"method": "sequential"},
    )


def schedule_full_pipeline(graph: LayerGraph, cost: CostModel, chips: int) -> ScopeSchedule | None:
    """One segment, every layer its own cluster, pipelined across the package."""
    L = len(graph)
    if L > chips:
        return None
    fixed = tuple((i, i + 1) for i in range(L))
    res = search_segment(
        cost, graph, 0, L, chips, mode=RegionMode.FREE, fixed_clustering=fixed
    )
    if res is None or res.latency == INF:
        return None
    return ScopeSchedule(
        workload=graph.name, chips=chips,
        segments=(SegmentSchedule(res.clusters, res.latency, res.cluster_times),),
        latency=res.latency, meta={"method": "full_pipeline"},
    )


def schedule_segmented(
    graph: LayerGraph, cost: CostModel, chips: int,
    segment_counts: list[int] | None = None,
) -> ScopeSchedule | None:
    """Segmented pipeline: Scope minus the merge dimension (1 layer/cluster)."""
    hw = cost.hw
    counts = segment_counts or candidate_segment_counts(graph, hw, chips)
    best = None
    for n_seg in counts:
        split = divide_segments(graph, hw, chips, n_seg)
        if split is None:
            continue
        segs, total, ok = [], 0.0, True
        for lo, hi in split:
            if hi - lo > chips:       # can't give every layer its own region
                ok = False
                break
            fixed = tuple((i, i + 1) for i in range(hi - lo))
            res = search_segment(
                cost, graph, lo, hi, chips, mode=RegionMode.FREE,
                fixed_clustering=fixed,
            )
            if res is None or res.latency == INF:
                ok = False
                break
            segs.append(SegmentSchedule(res.clusters, res.latency, res.cluster_times))
            total += res.latency
        if not ok:
            continue
        if best is None or total < best.latency:
            best = ScopeSchedule(
                workload=graph.name, chips=chips, segments=tuple(segs),
                latency=total,
                meta={"method": "segmented", "n_segments": n_seg},
            )
    return best


def schedule_scope(
    graph: LayerGraph, cost: CostModel, chips: int,
    mode: RegionMode = RegionMode.FREE, ep_for_moe: bool = False,
    segment_counts: list[int] | None = None,
) -> ScopeSchedule | None:
    sched = search(
        graph, cost, chips, mode=mode, ep_for_moe=ep_for_moe,
        segment_counts=segment_counts,
    )
    if sched is not None:
        sched.meta["method"] = "scope"
    return sched


ALL_METHODS = {
    "sequential": schedule_sequential,
    "full_pipeline": schedule_full_pipeline,
    "segmented": schedule_segmented,
    "scope": schedule_scope,
}
