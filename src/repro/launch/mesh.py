"""Production mesh construction.

A TPU v5e pod is a 16x16 chip torus (256 chips); the multi-pod deployment
adds a leading ``pod`` axis over the (slower) DCN/pod-interconnect domain.
``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state (device count is locked at first use).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly Auto
    AxisType = None


def _make_mesh_compat(shape: tuple[int, ...], axes: tuple[str, ...]):
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:  # make_mesh predates the axis_types kwarg
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh_compat(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return _make_mesh_compat(shape, axes)


def make_pipeline_mesh(n_stages: int, n_data: int):
    """Mesh for the shard_map merged-pipeline runtime."""
    return make_mesh((n_stages, n_data), ("stage", "data"))


def single_device_mesh(axes: tuple[str, ...] = ("data", "model")):
    return make_mesh((1,) * len(axes), axes)
