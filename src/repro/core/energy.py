"""Per-sample energy breakdown (paper Fig. 10b).

Components: MAC (compute), SRAM (activation + weight buffer traffic), NoP
(inter-chiplet), DRAM (weight loads amortized over the batch + segment
boundary activation spills).  Constants live on the HardwareModel; the
paper's synthesized numbers are Table III (0.2 pJ/8-bit MAC, 1.3 pJ/bit NoP),
the rest are documented estimates -- Fig. 10b is reported normalized, so the
breakdown *structure* is what is reproduced.
"""
from __future__ import annotations

from dataclasses import dataclass

from .costmodel import CostModel
from .graph import LayerGraph, ScopeSchedule


@dataclass(frozen=True)
class EnergyBreakdown:
    mac: float
    sram: float
    nop: float
    dram: float

    @property
    def total(self) -> float:
        return self.mac + self.sram + self.nop + self.dram

    def normalized(self, base: float | None = None):
        b = base or self.total
        return {
            "mac": self.mac / b,
            "sram": self.sram / b,
            "nop": self.nop / b,
            "dram": self.dram / b,
        }


def schedule_energy(cost: CostModel, graph: LayerGraph, sched: ScopeSchedule) -> EnergyBreakdown:
    hw, m = cost.hw, cost.m
    mac = sram = nop = dram = 0.0
    for seg_idx, seg in enumerate(sched.segments):
        clusters = seg.clusters
        for j, cl in enumerate(clusters):
            placement = cost.place_weights(graph, cl)
            n = cl.region_chips
            layers = graph.layers[cl.layer_lo : cl.layer_hi]
            for k, (layer, p) in enumerate(zip(layers, cl.partitions)):
                mac += layer.flops * hw.e_flop
                # activation + one weight sweep through on-chip SRAM per beat
                sram += (2.0 * (layer.in_bytes + layer.out_bytes) + layer.weight_bytes) * hw.e_sram_byte
                last_layer = k == len(layers) - 1
                if not last_layer:
                    nxt_p, nxt_n, same = cl.partitions[k + 1], n, True
                elif j + 1 < len(clusters):
                    nc = clusters[j + 1]
                    nxt_p, nxt_n, same = nc.partitions[0], nc.region_chips, False
                else:
                    nxt_p, nxt_n, same = None, None, False
                nop += cost.comm_volume(layer, p, n, nxt_p, nxt_n, same) * hw.e_nop_byte
                nop += placement.gather_bytes[k] * n * hw.e_nop_byte
                dram += layer.weight_bytes / m * hw.e_dram_byte  # amortized load
                if last_layer and j == len(clusters) - 1 and seg_idx + 1 < len(sched.segments):
                    dram += layer.out_bytes * hw.e_dram_byte     # spill
                    dram += layer.out_bytes * hw.e_dram_byte     # refill next seg
    return EnergyBreakdown(mac=mac, sram=sram, nop=nop, dram=dram)


def sequential_energy(cost: CostModel, graph: LayerGraph) -> EnergyBreakdown:
    """Energy of the fully-sequential baseline (whole package per layer)."""
    hw, m, chips = cost.hw, cost.m, cost.hw.chips
    mac = sram = nop = dram = 0.0
    for i, layer in enumerate(graph.layers):
        mac += layer.flops * hw.e_flop
        sram += (2.0 * (layer.in_bytes + layer.out_bytes) + layer.weight_bytes) * hw.e_sram_byte
        nxt = "WSP" if i + 1 < len(graph.layers) else None
        nop += cost.comm_volume(layer, "WSP", chips, nxt, chips, True) * hw.e_nop_byte
        dram += layer.weight_bytes / m * hw.e_dram_byte
    return EnergyBreakdown(mac=mac, sram=sram, nop=nop, dram=dram)
