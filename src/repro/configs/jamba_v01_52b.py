"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, Mamba:attention 7:1 interleave, MoE 16 experts top-2 on every
2nd layer [arXiv:2403.19887; hf].

Block pattern: 8 layers with attention at position 4 (jamba's published
layout), scanned 4 times.  Sub-quadratic state => runs the long_500k cell.
"""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    block_pattern=(
        "mamba", "mamba", "mamba", "mamba",
        "attn", "mamba", "mamba", "mamba",
    ),
    moe=MoEConfig(n_experts=16, top_k=2, every=2, capacity_factor=1.25),
    ffn_gated=True,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    rope_theta=10_000.0,
)
