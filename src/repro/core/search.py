"""Scope DSE: paper Algorithm 1, plus exhaustive/random search for validation.

Per segment, three nested dimensions are explored:
  * WSP->ISP transition index (linear, L+1 candidates)       [partition.py]
  * N_cluster via the cluster merge table (linear, L rows)   [cmt.py]
  * region allocation: proportional seed + chip-rebalance    [regions.py]

On heterogeneous packages a fourth dimension opens up (``search_mixed`` /
``search_segment_mixed``): contiguous *runs* of clusters are assigned to
chip flavors under per-flavor chip budgets, so one pipeline can start on
big chips and finish on little ones (SCAR / Odema et al.).  Flavors occupy
contiguous areas of the mesh, so a pipeline crosses at most one seam per
flavor change; the cost model charges those boundary hand-offs through
``HardwareModel.seam_link_bw``.  Run boundaries are pruned to a window
around the compute-proportional cut (per-flavor proportional seeds), and
the rebalance walk only moves chips within a flavor pool.

The pseudocode's inner ``while tmpLatency < minLatency`` only rebalances while
beating the global best; we run the (strictly stronger) local-improvement
rebalance and track the global best across it -- this can only find better
schedules and keeps the same asymptotics.

System level: sweep segment counts from the minimal feasible value
(segments.py) and run Algorithm 1 independently per segment (paper SSV-A uses
an identical segment allocation for Scope and the segmented baseline).
"""
from __future__ import annotations

import bisect
import itertools
import math
import random
from dataclasses import dataclass

from ..obs import current_tracer
from .cmt import Clustering, gen_cmt
from .costmodel import INF, CostModel, _flavor_tuple
from .graph import (
    ClusterAssignment,
    LayerGraph,
    ScopeSchedule,
    SegmentSchedule,
)
from .partition import (
    apply_ep,
    enumerate_exhaustive,
    enumerate_transition_points,
    transition_partitions,
)
from .regions import (
    RegionMode,
    proportional_allocate,
    rebalance,
    uniform_allocate,
)
from .segments import candidate_segment_counts, divide_segments


def build_clusters(
    seg_lo: int,
    clustering: Clustering,
    partitions: tuple[str, ...],
    regions: list[int],
    chip_type=None,
) -> tuple[ClusterAssignment, ...]:
    """Assemble ClusterAssignments from segment-relative pieces.

    ``chip_type`` is one flavor name for every cluster, or a per-cluster
    flavor sequence (mixed-flavor pipelines).
    """
    types = _flavor_tuple(chip_type, len(clustering))
    out = []
    for (lo, hi), chips, ctype in zip(clustering, regions, types):
        out.append(
            ClusterAssignment(
                layer_lo=seg_lo + lo,
                layer_hi=seg_lo + hi,
                region_chips=chips,
                partitions=partitions[lo:hi],
                chip_type=ctype,
            )
        )
    return tuple(out)


def evaluate_segment(
    cost: CostModel,
    graph: LayerGraph,
    seg_lo: int,
    clustering: Clustering,
    partitions: tuple[str, ...],
    regions: list[int],
    chip_type=None,
) -> tuple[float, list[float]]:
    clusters = build_clusters(seg_lo, clustering, partitions, regions, chip_type)
    lat, times = cost.segment_time(graph, clusters)
    return lat, times


@dataclass
class SegmentResult:
    clusters: tuple[ClusterAssignment, ...]
    latency: float
    cluster_times: tuple[float, ...]


def _partition_sets(
    graph: LayerGraph, seg_lo: int, L: int, ep_for_moe: bool
) -> dict[tuple[str, ...], tuple[int, bool]]:
    """Candidate partition sets, each with a (transition_idx, ep) hint that
    lets FastCostModel key its memo by small int tuples (see fastcost.py)."""
    partition_sets: dict[tuple[str, ...], tuple[int, bool]] = {}
    for idx in range(L + 1):
        partition_sets[transition_partitions(L, idx)] = (idx, False)
    if ep_for_moe:
        for idx in range(L + 1):
            p = transition_partitions(L, idx)
            pe = apply_ep(graph, p, lo=seg_lo)
            if pe != p and pe not in partition_sets:  # dedupe, keep order
                partition_sets[pe] = (idx, True)
    return partition_sets


def search_segment(
    cost: CostModel,
    graph: LayerGraph,
    seg_lo: int,
    seg_hi: int,
    chips: int,
    mode: RegionMode = RegionMode.FREE,
    ep_for_moe: bool = False,
    max_clusters: int | None = None,
    fixed_clustering: Clustering | None = None,
    chip_type: str | None = None,
    paper_strict: bool = False,
) -> SegmentResult | None:
    """Algorithm 1 over one segment.

    ``fixed_clustering`` short-circuits the CMT (used by the segmented-pipeline
    baseline, where every layer is its own cluster).  ``chip_type`` runs the
    whole segment on one flavor of a heterogeneous package; ``paper_strict``
    replicates the pseudocode's rebalance exactly (regions.rebalance).
    """
    sub = graph.slice(seg_lo, seg_hi)
    L = len(sub)
    cmt = {len(fixed_clustering): fixed_clustering} if fixed_clustering else gen_cmt(sub)
    best: SegmentResult | None = None
    partition_sets = _partition_sets(graph, seg_lo, L, ep_for_moe)

    # Seed allocations depend only on the clustering (not on partitions), so
    # compute them once per CMT row instead of once per (partitions x row).
    seeds: dict[int, list[int] | None] = {}
    for n_cluster, clustering in cmt.items():
        if max_clusters is not None and n_cluster > max_clusters:
            continue
        if n_cluster > chips:
            continue
        if mode is RegionMode.UNIFORM:
            seeds[n_cluster] = uniform_allocate(n_cluster, chips)
        else:
            seeds[n_cluster] = proportional_allocate(
                [sum(graph.layers[seg_lo + i].flops for i in range(lo, hi))
                 for lo, hi in clustering],
                chips,
            )

    # Clustering-outer, partitions-inner: one sweeper per CMT row carries the
    # allocation-independent precomputation through the whole transition
    # sweep (FastCostModel updates it incrementally per transition step).
    for n_cluster, clustering in cmt.items():
        seed = seeds.get(n_cluster)
        if seed is None:
            continue
        sweeper = cost.segment_sweeper(graph, seg_lo, clustering, chip_type)
        # Seed-phase batch fill (fastcost 2D (k x layer) vectorization): every
        # transition slice's body at the seed allocation in one array pass.
        prefill = getattr(sweeper, "prefill", None)
        if prefill is not None:
            prefill(seed)
        # Batched transition sweep (fastcost.sweep_transitions): every
        # candidate's seed score as one gather over per-slot value tables,
        # instead of K x n_cl scalar probes.  Each candidate's rebalance
        # walk then starts from its batch row (times0) without re-evaluating
        # the seed allocation.
        sweep_batch = getattr(sweeper, "sweep_transitions", None)
        seed_lats = seed_times = heads = None
        if sweep_batch is not None:
            if mode is RegionMode.FREE and not paper_strict:
                # Also batch the first rebalance iteration: most walks end
                # right there (both donors fail), and the rest resume the
                # scalar walk from their post-move state.
                seed_lats, seed_times, heads = sweep_batch(
                    seed, list(partition_sets.values()), first_moves=True
                )
            else:
                seed_lats, seed_times = sweep_batch(
                    seed, list(partition_sets.values())
                )
        for r, (partitions, hint) in enumerate(partition_sets.items()):

            if mode is RegionMode.UNIFORM:
                if seed_lats is not None:
                    lat, times = float(seed_lats[r]), seed_times[r]
                else:
                    lat, times = sweeper(partitions, transition=hint)(seed)
                alloc = seed
            else:
                head = None if heads is None else heads[r]
                if head is not None and head[0] == "done":
                    # Batched first iteration proved no donor move improves:
                    # the walk terminates at the seed without configuring.
                    alloc, lat, times = seed, float(seed_lats[r]), seed_times[r]
                    if lat < (best.latency if best else INF):
                        best = SegmentResult(
                            clusters=build_clusters(
                                seg_lo, clustering, partitions, alloc, chip_type
                            ),
                            latency=lat,
                            cluster_times=tuple(times),
                        )
                    continue
                # One evaluator per (clustering, partitions): FastCostModel
                # memoizes cluster costs, so the rebalance walk below only
                # ever computes the clusters a chip move actually changed.
                eval_fn = sweeper(partitions, transition=hint)
                if head is not None:
                    # ("cont", alloc2, lat2, times2): resume after the one
                    # accepted move (max_iters=255: iteration 1 is spent).
                    alloc, lat, times = rebalance(
                        head[1], eval_fn, max_iters=255,
                        paper_strict=paper_strict,
                        times0=(head[2], head[3]),
                    )
                else:
                    t0 = (
                        None if seed_lats is None
                        else (float(seed_lats[r]), seed_times[r])
                    )
                    alloc, lat, times = rebalance(seed, eval_fn,
                                                  paper_strict=paper_strict,
                                                  times0=t0)
            if lat < (best.latency if best else INF):
                best = SegmentResult(
                    clusters=build_clusters(
                        seg_lo, clustering, partitions, alloc, chip_type
                    ),
                    latency=lat,
                    cluster_times=tuple(times),
                )
    return best


# ---------------------------------------------------------------------------
# Mixed-flavor pipelines: chip_type as a per-cluster search dimension
# ---------------------------------------------------------------------------

def _flavor_sequences(n_flavors: int, max_runs: int):
    """Ordered tuples of distinct flavor indices: the flavor each contiguous
    cluster run lands on, in pipeline order.  Flavors occupy contiguous mesh
    areas, so revisiting a flavor would tear a region apart -- runs use each
    flavor at most once, in either direction."""
    for r in range(1, min(n_flavors, max_runs) + 1):
        yield from itertools.permutations(range(n_flavors), r)


def _run_cut_candidates(
    loads: list[float], capacities: list[float], window: int
) -> list[tuple[int, ...]]:
    """Candidate cut index tuples splitting ``len(loads)`` clusters into
    ``len(capacities)`` contiguous non-empty runs.

    Small segments are cut exhaustively.  Larger ones are pruned to a
    ``window`` around the compute-proportional cuts (run r's cumulative
    cluster load tracks its cumulative effective capacity) -- the same
    proportionality the region seed allocation uses, applied one level up.
    """
    n = len(loads)
    R = len(capacities)
    if R == 1:
        return [()]
    if n < R:
        return []
    exhaustive = math.comb(n - 1, R - 1)
    if exhaustive <= (2 * window + 1) ** (R - 1):
        return list(itertools.combinations(range(1, n), R - 1))
    prefix = [0.0]
    for l in loads:
        prefix.append(prefix[-1] + l)
    total_load = prefix[-1] or 1.0
    total_cap = sum(capacities) or 1.0
    targets, acc = [], 0.0
    for c in capacities[:-1]:
        acc += c
        s = bisect.bisect_left(prefix, (acc / total_cap) * total_load, 1, n)
        targets.append(min(max(s, 1), n - 1))
    ranges = [
        range(max(1, t - window), min(n - 1, t + window) + 1) for t in targets
    ]
    return [
        cut for cut in itertools.product(*ranges)
        if all(a < b for a, b in zip(cut, cut[1:]))
    ]


def search_segment_mixed(
    cost: CostModel,
    graph: LayerGraph,
    seg_lo: int,
    seg_hi: int,
    flavor_budgets: list[tuple[str | None, int]],
    mode: RegionMode = RegionMode.FREE,
    ep_for_moe: bool = False,
    max_clusters: int | None = None,
    fixed_clustering: Clustering | None = None,
    paper_strict: bool = False,
    cut_window: int = 2,
) -> SegmentResult | None:
    """Algorithm 1 over one segment with per-cluster chip flavors.

    On top of the three paper dimensions, a flavor-run assignment layer
    maps contiguous runs of clusters onto package flavors under the
    per-flavor chip budgets in ``flavor_budgets`` (``[(chip_type, chips)]``).
    Seeds are proportional *within* each run's budget and the rebalance
    walk is constrained to within-flavor chip moves (a chip physically
    belongs to one flavor).  Single-run assignments are included, so the
    result is never worse than running the whole segment on the best
    single flavor at these budgets.
    """
    sub = graph.slice(seg_lo, seg_hi)
    L = len(sub)
    cmt = {len(fixed_clustering): fixed_clustering} if fixed_clustering else gen_cmt(sub)
    partition_sets = _partition_sets(graph, seg_lo, L, ep_for_moe)
    hw = cost.hw
    scales = [
        1.0 if t is None else hw.chip_type(t).flops_scale
        for t, _ in flavor_budgets
    ]
    best: SegmentResult | None = None

    for n_cluster, clustering in cmt.items():
        if max_clusters is not None and n_cluster > max_clusters:
            continue
        loads = [
            sum(graph.layers[seg_lo + i].flops for i in range(lo, hi))
            for lo, hi in clustering
        ]
        for seq in _flavor_sequences(len(flavor_budgets), n_cluster):
            eff_caps = [flavor_budgets[f][1] * scales[f] for f in seq]
            # Materialize every feasible cut of this flavor assignment first,
            # so the whole candidate set can be scored as one population:
            # each cut re-seeds the same cluster spans at different region
            # sizes, and FastCostModel.prefill_spans batch-fills all those
            # bodies in one matrix pass per span before the per-cut sweeps.
            cut_plans = []
            for cuts in _run_cut_candidates(loads, eff_caps, cut_window):
                bounds = (0, *cuts, n_cluster)
                runs = list(zip(bounds[:-1], bounds[1:]))
                if any(
                    hi - lo > flavor_budgets[f][1]
                    for (lo, hi), f in zip(runs, seq)
                ):
                    continue   # a run needs >= 1 chip per cluster
                ctypes, groups, seed = [], [], []
                feasible = True
                for r, ((lo, hi), f) in enumerate(zip(runs, seq)):
                    budget = flavor_budgets[f][1]
                    ctypes += [flavor_budgets[f][0]] * (hi - lo)
                    groups += [r] * (hi - lo)
                    if mode is RegionMode.UNIFORM:
                        alloc_r = uniform_allocate(hi - lo, budget)
                        if alloc_r is None:
                            feasible = False
                            break
                        seed += alloc_r
                    else:
                        seed += proportional_allocate(loads[lo:hi], budget)
                if feasible:
                    cut_plans.append((tuple(ctypes), groups, seed))
            if not cut_plans:
                continue
            prefill_spans = getattr(cost, "prefill_spans", None)
            if prefill_spans is not None and len(cut_plans) > 1:
                span_ns: dict[tuple, set] = {}
                for ctypes, _g, seed in cut_plans:
                    for j, (lo, hi) in enumerate(clustering):
                        key = (seg_lo + lo, seg_lo + hi, ctypes[j])
                        span_ns.setdefault(key, set()).add(seed[j])
                prefill_spans(graph, [
                    (lo, hi, sorted(ns), ct)
                    for (lo, hi, ct), ns in span_ns.items()
                ])
            for ctypes, groups, seed in cut_plans:
                sweeper = cost.segment_sweeper(graph, seg_lo, clustering, ctypes)
                prefill = getattr(sweeper, "prefill", None)
                if prefill is not None:
                    prefill(seed)
                sweep_batch = getattr(sweeper, "sweep_transitions", None)
                seed_lats = seed_times = None
                if sweep_batch is not None:
                    seed_lats, seed_times = sweep_batch(
                        seed, list(partition_sets.values())
                    )
                for r, (partitions, hint) in enumerate(partition_sets.items()):
                    if mode is RegionMode.UNIFORM:
                        if seed_lats is not None:
                            lat, times = float(seed_lats[r]), seed_times[r]
                        else:
                            lat, times = sweeper(partitions, transition=hint)(seed)
                        alloc = seed
                    else:
                        eval_fn = sweeper(partitions, transition=hint)
                        t0 = (
                            None if seed_lats is None
                            else (float(seed_lats[r]), seed_times[r])
                        )
                        alloc, lat, times = rebalance(
                            seed, eval_fn, paper_strict=paper_strict,
                            groups=groups, times0=t0,
                        )
                    if lat < (best.latency if best else INF):
                        best = SegmentResult(
                            clusters=build_clusters(
                                seg_lo, clustering, partitions, alloc, ctypes
                            ),
                            latency=lat,
                            cluster_times=tuple(times),
                        )
    return best


def search_mixed(
    graph: LayerGraph,
    cost: CostModel,
    flavor_budgets: list[tuple[str | None, int]] | None = None,
    mode: RegionMode = RegionMode.FREE,
    ep_for_moe: bool = False,
    segment_counts: list[int] | None = None,
    max_clusters: int | None = None,
    paper_strict: bool = False,
    cut_window: int = 2,
    include_single_flavor: bool = True,
) -> ScopeSchedule | None:
    """Full Scope DSE with ``chip_type`` as a per-cluster dimension.

    ``flavor_budgets`` caps how many chips of each flavor the schedule may
    use (default: every chip of every flavor of ``cost.hw``); the multimodel
    quota search passes partial budgets so one model can span flavors while
    others keep the rest.  The result is the best of (a) the plain
    single-flavor DSE per flavor at its budget and (b) the mixed sweep, so
    mixed search never returns worse than the best single-flavor schedule.
    """
    hw = cost.hw
    if flavor_budgets is None:
        if hw.region_types:
            flavor_budgets = [(t.name, t.chips) for t in hw.region_types]
        else:
            flavor_budgets = [(None, hw.chips)]
    flavor_budgets = [(t, b) for t, b in flavor_budgets if b > 0]
    if not flavor_budgets:
        return None

    best_sched: ScopeSchedule | None = None
    if include_single_flavor or len(flavor_budgets) == 1:
        for t, b in flavor_budgets:
            s = search(
                graph, cost, b, mode=mode, ep_for_moe=ep_for_moe,
                segment_counts=segment_counts, max_clusters=max_clusters,
                chip_type=t, paper_strict=paper_strict,
            )
            if s is not None and (
                best_sched is None or s.latency < best_sched.latency
            ):
                best_sched = s
    if len(flavor_budgets) == 1:
        return best_sched

    total = sum(b for _, b in flavor_budgets)
    counts = segment_counts or candidate_segment_counts(graph, hw, total)
    tr = current_tracer()
    with tr.span("search:mixed", graph=graph.name, chips=total,
                 flavors=len(flavor_budgets)):
        best_sched = _search_mixed_sweep(
            graph, cost, hw, flavor_budgets, counts, best_sched, mode,
            ep_for_moe, max_clusters, paper_strict, cut_window, tr,
        )
    return best_sched


def _search_mixed_sweep(graph, cost, hw, flavor_budgets, counts, best_sched,
                        mode, ep_for_moe, max_clusters, paper_strict,
                        cut_window, tr):
    total = sum(b for _, b in flavor_budgets)
    for n_seg in counts:
        split = divide_segments(graph, hw, total, n_seg)
        if split is None:
            continue
        segs: list[SegmentSchedule] = []
        total_lat = 0.0
        ok = True
        for lo, hi in split:
            with tr.span("segment:mixed", n_seg=n_seg, lo=lo, hi=hi):
                res = search_segment_mixed(
                    cost, graph, lo, hi, flavor_budgets, mode=mode,
                    ep_for_moe=ep_for_moe, max_clusters=max_clusters,
                    paper_strict=paper_strict, cut_window=cut_window,
                )
            if res is None or res.latency == INF:
                ok = False
                break
            segs.append(
                SegmentSchedule(res.clusters, res.latency, res.cluster_times)
            )
            total_lat += res.latency
        if not ok:
            continue
        if best_sched is None or total_lat < best_sched.latency:
            best_sched = ScopeSchedule(
                workload=graph.name,
                chips=total,
                segments=tuple(segs),
                latency=total_lat,
                meta={
                    "n_segments": n_seg,
                    "mode": mode.value,
                    "mixed_flavors": [[t, b] for t, b in flavor_budgets],
                },
            )
    return best_sched


def search(
    graph: LayerGraph,
    cost: CostModel,
    chips: int,
    mode: RegionMode = RegionMode.FREE,
    ep_for_moe: bool = False,
    segment_counts: list[int] | None = None,
    max_clusters: int | None = None,
    chip_type: str | None = None,
    paper_strict: bool = False,
) -> ScopeSchedule | None:
    """Full Scope DSE: segment sweep x Algorithm 1 per segment (Eq. 1).

    ``chip_type`` schedules onto ``chips`` chips of that flavor of a
    heterogeneous package (multimodel quota search); segment feasibility
    uses package-level weight capacity, which is flavor-independent.
    """
    hw = cost.hw
    counts = segment_counts or candidate_segment_counts(graph, hw, chips)
    best_sched: ScopeSchedule | None = None
    tr = current_tracer()
    with tr.span("search", graph=graph.name, chips=chips,
                 flavor=chip_type or "base") as sp:
        for n_seg in counts:
            split = divide_segments(graph, hw, chips, n_seg)
            if split is None:
                continue
            segs: list[SegmentSchedule] = []
            total = 0.0
            ok = True
            for lo, hi in split:
                with tr.span("segment", n_seg=n_seg, lo=lo, hi=hi):
                    res = search_segment(
                        cost, graph, lo, hi, chips, mode=mode,
                        ep_for_moe=ep_for_moe, max_clusters=max_clusters,
                        chip_type=chip_type, paper_strict=paper_strict,
                    )
                if res is None or res.latency == INF:
                    ok = False
                    break
                segs.append(
                    SegmentSchedule(res.clusters, res.latency, res.cluster_times)
                )
                total += res.latency
            if not ok:
                continue
            if best_sched is None or total < best_sched.latency:
                meta = {"n_segments": n_seg, "mode": mode.value}
                if chip_type:
                    meta["chip_type"] = chip_type
                best_sched = ScopeSchedule(
                    workload=graph.name,
                    chips=chips,
                    segments=tuple(segs),
                    latency=total,
                    meta=meta,
                )
        if best_sched is not None:
            sp.set(latency=best_sched.latency,
                   n_segments=best_sched.meta.get("n_segments"))
    return best_sched


# ---------------------------------------------------------------------------
# Validation searches (paper SSV-B(1), Fig. 8)
# ---------------------------------------------------------------------------

def compositions(total: int, parts: int):
    """All ways to write ``total`` as ``parts`` positive integers (ordered)."""
    for cuts in itertools.combinations(range(1, total), parts - 1):
        prev, out = 0, []
        for c in cuts:
            out.append(c - prev)
            prev = c
        out.append(total - prev)
        yield out


def enumerate_clusterings(L: int):
    for n_cluster in range(1, L + 1):
        for sizes in compositions(L, n_cluster):
            bounds, cursor = [], 0
            for s in sizes:
                bounds.append((cursor, cursor + s))
                cursor += s
            yield tuple(bounds)


def exhaustive_search(
    cost: CostModel, graph: LayerGraph, chips: int, yield_all: bool = False
):
    """Brute force over (clustering x regions x 2^L partitions) for one segment.

    Only tractable for tiny L/C (the paper uses AlexNet x 16 chiplets).
    Yields (latency, clustering, regions, partitions) for every valid config
    when ``yield_all``; otherwise returns the best tuple.
    """
    L = len(graph)
    best = (INF, None, None, None)
    for clustering in enumerate_clusterings(L):
        n_cluster = len(clustering)
        if n_cluster > chips:
            continue
        for regions in compositions(chips, n_cluster):
            for partitions in enumerate_exhaustive(L):
                lat, _ = evaluate_segment(cost, graph, 0, clustering, partitions, list(regions))
                if yield_all and lat < INF:
                    yield lat, clustering, tuple(regions), partitions
                if lat < best[0]:
                    best = (lat, clustering, tuple(regions), partitions)
    if not yield_all:
        yield best


def random_search(
    cost: CostModel,
    graph: LayerGraph,
    chips: int,
    samples: int,
    seed: int = 0,
):
    """Uniform random samples of the full space -- builds Fig. 8's histogram."""
    rng = random.Random(seed)
    L = len(graph)
    out = []
    for _ in range(samples):
        n_cluster = rng.randint(1, min(L, chips))
        cuts = sorted(rng.sample(range(1, L), n_cluster - 1)) if n_cluster > 1 else []
        bounds, cursor = [], 0
        for c in cuts + [L]:
            bounds.append((cursor, c))
            cursor = c
        rcuts = sorted(rng.sample(range(1, chips), n_cluster - 1)) if n_cluster > 1 else []
        regions, prev = [], 0
        for c in rcuts + [chips]:
            regions.append(c - prev)
            prev = c
        partitions = tuple(rng.choice(("WSP", "ISP")) for _ in range(L))
        lat, _ = evaluate_segment(cost, graph, 0, tuple(bounds), partitions, regions)
        if lat < INF:
            out.append(lat)
    return out
