"""Fig. 8 + SSV-B(1): search-quality validation on AlexNet x 16 chiplets.

The paper compares Algorithm 1's result against the full design space
(exhaustive at the smallest scale) and reports a top-0.05% rank.  We build
the processing-time histogram from uniform random samples of the space and
rank Algorithm 1's schedule in it; a small exact exhaustive case checks
near-optimality directly.
"""
from __future__ import annotations

import time

from repro.core.fastcost import FastCostModel
from repro.core.graph import chain
from repro.core.hw import mcm_table_iii
from repro.core.search import exhaustive_search, random_search, search_segment
from repro.core.workloads import get_cnn

from .common import M_SAMPLES, cached


def run(refresh: bool = False, samples: int = 50_000):
    def _go():
        g = get_cnn("alexnet")
        hw = mcm_table_iii(16)
        cost = FastCostModel(hw, m_samples=M_SAMPLES)
        t0 = time.time()
        res = search_segment(cost, g, 0, len(g), 16)
        alg1_s = time.time() - t0
        t0 = time.time()
        pop = random_search(cost, g, 16, samples=samples, seed=0)
        sample_s = time.time() - t0
        beaten = sum(1 for s in pop if s < res.latency)
        # exact exhaustive check on a reduced case
        sub = chain("alexnet[:4]", g.layers[:4])
        best = next(exhaustive_search(cost, sub, 6))
        res_sub = search_segment(cost, sub, 0, 4, 6)
        # histogram (20 bins) of the sampled space
        lo, hi = min(pop), max(pop)
        bins = [0] * 20
        for s in pop:
            bins[min(19, int((s - lo) / (hi - lo + 1e-30) * 20))] += 1
        return {
            "alg1_latency_s": res.latency,
            "alg1_search_s": alg1_s,
            "samples": samples,
            "sample_s": sample_s,
            "rank_fraction": beaten / samples,
            "histogram": {"lo": lo, "hi": hi, "bins": bins},
            "exhaustive_small": {
                "optimum_s": best[0],
                "alg1_s": res_sub.latency,
                "ratio": res_sub.latency / best[0],
            },
        }

    return cached("fig8_search_quality", _go, refresh)


def report(r) -> list[str]:
    return [
        "metric,value",
        f"alg1_rank_in_space,{r['rank_fraction']:.5f}",
        f"paper_claim_top_fraction,0.0005",
        f"small_exhaustive_ratio,{r['exhaustive_small']['ratio']:.4f}",
        f"alg1_search_seconds,{r['alg1_search_s']:.3f}",
        f"# alg1 ranks in top {100 * r['rank_fraction']:.3f}% of {r['samples']} uniform samples"
        f" (paper: top 0.05%)",
    ]
