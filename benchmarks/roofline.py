"""SSRoofline: aggregate the dry-run artifacts into the roofline table.

Reads benchmarks/results/dryrun/*.json (produced by repro.launch.dryrun) and
reports, per (arch x shape x mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS = 6ND (train) / 2ND (forward-only) with N_active for
MoE, and the usefulness ratio MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import ARCHS, SHAPES

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def model_flops(arch: str, shape: str) -> float:
    cfg = ARCHS[arch]
    S, B, kind = SHAPES[shape]
    n = cfg.n_active_params
    if kind == "train":
        return 6.0 * n * S * B
    if kind == "prefill":
        return 2.0 * n * S * B
    return 2.0 * n * 1 * B          # decode: one token per sequence


def load_rows(mesh_tag: str = "pod16x16"):
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh_tag}.json"))):
        with open(path) as f:
            r = json.load(f)
        chips = r["mesh"]["chips"]
        corrected = r.get("corrected", {})
        flops = corrected.get("flops") or r["cost_analysis"].get("flops") or 0.0
        mf = model_flops(r["arch"], r["shape"])
        useful = mf / (flops * chips) if flops else float("nan")
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": mesh_tag,
            "plan": f"{r['plan']['p1']}->{r['plan']['p2']}@{r['plan']['transition_repeat']}",
            "compute_s": r["roofline"]["compute_s"],
            "memory_s": r["roofline"]["memory_s"],
            "collective_s": r["roofline"]["collective_s"],
            "dominant": r["roofline"]["dominant"],
            "model_flops": mf,
            "useful_ratio": useful,
            "compile_s": r["compile_s"],
        })
    return rows


def report(rows) -> list[str]:
    lines = [
        "arch,shape,plan,compute_s,memory_s,collective_s,dominant,useful_ratio"
    ]
    for r in rows:
        lines.append(
            f"{r['arch']},{r['shape']},{r['plan']},"
            f"{r['compute_s']:.4e},{r['memory_s']:.4e},{r['collective_s']:.4e},"
            f"{r['dominant']},{r['useful_ratio']:.3f}"
        )
    if not rows:
        lines.append("# no dry-run artifacts found -- run repro.launch.dryrun --all first")
    return lines
