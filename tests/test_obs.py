"""Scope Observatory (repro.obs): tracer, metrics, export, and determinism.

Covers the tentpole contracts:

* the disabled path is near-zero overhead (micro-benched bound on the
  no-op singletons),
* wall-clock spans nest by construction and export valid Chrome trace
  JSON (property-tested against :func:`validate_chrome_trace`),
* executor traces on the simulated clock are bytewise identical across
  two same-seed runs (faults included),
* the metrics registry's time-weighted series reproduce the serving
  report's queue statistics, and
* both evaluation engines report one counter schema.
"""
from __future__ import annotations

import json
import time

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import scope
from repro.api import problem_fingerprint
from repro.obs import (
    NULL_METRICS,
    NULL_TRACER,
    Histogram,
    MetricsRegistry,
    TimeSeries,
    Tracer,
    current_tracer,
    traced,
    use_tracer,
    validate_chrome_trace,
)

M = 16          # m_samples everywhere: small and fast


# ---------------------------------------------------------------------------
# Metrics primitives
# ---------------------------------------------------------------------------

class TestTimeSeries:
    def test_mean_is_time_weighted(self):
        ts = TimeSeries()
        ts.extend([(1.0, 2), (3.0, 4)])
        # [0,1): 0, [1,3): 2, [3,5): 4 over t_end=5 -> (0+4+8)/5
        assert ts.mean(5.0) == pytest.approx((0 * 1 + 2 * 2 + 4 * 2) / 5.0)

    def test_implicit_zero_before_first_point(self):
        ts = TimeSeries()
        ts.record(4.0, 10)
        assert ts.mean(5.0) == pytest.approx(10 * 1.0 / 5.0)
        assert ts.percentile(50, 5.0) == 0.0        # zero holds 80% of time

    def test_percentile_bounds_and_max(self):
        ts = TimeSeries()
        ts.extend([(0.0, 1), (1.0, 5), (1.5, 2)])
        t_end = 2.0
        p95 = ts.percentile(95, t_end)
        assert 0 <= ts.percentile(5, t_end) <= p95 <= ts.max == 5

    def test_same_timestamp_dedups_to_last_value(self):
        ts = TimeSeries()
        ts.record(1.0, 3)
        ts.record(1.0, 7)
        assert ts.points == [(1.0, 7)]

    def test_empty_series(self):
        ts = TimeSeries()
        assert ts.mean(10.0) == 0.0
        assert ts.percentile(95, 10.0) == 0.0
        assert ts.max == 0

    @given(st.lists(st.tuples(st.floats(min_value=0.0, max_value=9.0),
                              st.integers(min_value=0, max_value=50)),
                    min_size=1, max_size=20))
    @settings(max_examples=50, deadline=None)
    def test_mean_never_exceeds_peak(self, pairs):
        pairs = sorted(pairs)
        ts = TimeSeries()
        ts.extend(pairs)
        t_end = 10.0
        assert 0.0 <= ts.mean(t_end) <= ts.max + 1e-12
        assert 0 <= ts.percentile(95, t_end) <= ts.max

    def test_queue_stats_parity_with_serving_report(self):
        """report.metrics time-weighted queue series == ModelMetrics scalars."""
        sol = scope.solve(scope.problem("alexnet", "mcm16", m_samples=M))
        rep = sol.serve(n_requests=600, seed=0)
        for m, mm in rep.per_model.items():
            series = rep.metrics.series[f"queue_depth/{m}"]
            assert mm.queue_mean == pytest.approx(series.mean(rep.makespan_s))
            assert mm.queue_max == series.max
            assert mm.queue_p95 == series.percentile(95, rep.makespan_s)
            assert 0 <= mm.queue_p95 <= mm.queue_max


class TestRegistry:
    def test_instruments_create_on_first_use(self):
        reg = MetricsRegistry()
        reg.counter("a").inc(3)
        reg.counter("a").inc()
        reg.gauge("g").set(2.5)
        reg.histogram("h").observe(1.0)
        reg.timeseries("s").record(0.0, 1)
        snap = reg.snapshot(t_end=2.0)
        assert snap["counters"] == {"a": 4}
        assert snap["gauges"] == {"g": 2.5}
        assert snap["histograms"]["h"]["count"] == 1
        assert snap["series"]["s"]["mean"] == pytest.approx(1.0)

    def test_update_counters_snapshots_numeric_values(self):
        reg = MetricsRegistry()
        reg.update_counters({"x": 3, "y": 1.5, "skip": "str"}, prefix="e.")
        assert reg.snapshot()["counters"] == {"e.x": 3, "e.y": 1.5}

    def test_histogram_nearest_rank(self):
        h = Histogram()
        for v in range(1, 101):
            h.observe(float(v))
        assert h.percentile(50) == 50.0
        assert h.percentile(95) == 95.0
        assert h.snapshot()["p99"] == 99.0


# ---------------------------------------------------------------------------
# Disabled-path overhead (the zero-overhead contract, micro-benched)
# ---------------------------------------------------------------------------

class TestNullOverhead:
    N = 100_000
    BUDGET_S_PER_CALL = 5e-6        # 5us: ~100x a no-op call, CI-safe

    def test_null_tracer_span_overhead(self):
        tr = NULL_TRACER
        t0 = time.perf_counter()
        for _ in range(self.N):
            with tr.span("x"):
                pass
        dt = time.perf_counter() - t0
        assert not tr.events
        assert dt / self.N < self.BUDGET_S_PER_CALL, (
            f"disabled span costs {dt / self.N * 1e6:.2f}us/call")

    def test_null_metrics_overhead(self):
        reg = NULL_METRICS
        t0 = time.perf_counter()
        for _ in range(self.N):
            reg.counter("x").inc()
        dt = time.perf_counter() - t0
        assert reg.snapshot() == {}
        assert dt / self.N < self.BUDGET_S_PER_CALL

    def test_ambient_default_is_null_and_falsy(self):
        tr = current_tracer()
        assert tr is NULL_TRACER and not tr
        tr.instant("nothing")
        tr.counter("c", 0.0, 1)
        tr.complete("x", 0.0, 1.0)
        assert tr.summary() == "(tracing disabled)"

    def test_use_tracer_stacks_and_restores(self):
        assert current_tracer() is NULL_TRACER
        tr = Tracer()
        with use_tracer(tr):
            assert current_tracer() is tr
            with use_tracer(None):
                assert current_tracer() is NULL_TRACER
            assert current_tracer() is tr
        assert current_tracer() is NULL_TRACER

    def test_traced_decorator_uses_ambient_tracer(self):
        @traced("unit", group="dse", lane="solver")
        def f(x):
            return x + 1

        assert f(1) == 2                 # disabled: plain call
        tr = Tracer()
        with use_tracer(tr):
            assert f(2) == 3
        assert [e[1] for e in tr.events] == ["unit"]


# ---------------------------------------------------------------------------
# Tracer spans + Chrome export
# ---------------------------------------------------------------------------

def _counting_clock():
    """Deterministic clock: advances 1s per call."""
    state = {"t": 0.0}

    def clock():
        state["t"] += 1.0
        return state["t"]

    return clock


class TestTracer:
    def test_spans_nest_and_export_valid_chrome(self):
        tr = Tracer(clock=_counting_clock())
        with tr.span("outer", alpha=1):
            with tr.span("inner"):
                pass
            tr.instant("mark")
        tr.counter("depth", 0.5, 3, group="serving")
        payload = tr.to_chrome()
        assert validate_chrome_trace(payload, expect_groups=["dse", "serving"]) == []
        phases = sorted(ev["ph"] for ev in payload["traceEvents"])
        assert "C" in phases and "X" in phases and "i" in phases and "M" in phases

    def test_span_records_error_arg_on_exception(self):
        tr = Tracer(clock=_counting_clock())
        with pytest.raises(ValueError):
            with tr.span("bad"):
                raise ValueError("boom")
        (ev,) = tr.events
        assert ev[6]["error"] == "ValueError"

    def test_sim_complete_events_ignore_wall_clock(self):
        tr = Tracer()
        tr.complete("batch", 1.0, 2.0, group="serving", lane="alexnet", n=4)
        tr.instant("fault:fail", t=1.5, group="serving", lane="faults")
        (x, i) = tr.to_chrome()["traceEvents"][-2:]
        assert (x["ts"], x["dur"]) == (1_000_000, 1_000_000)
        assert i["ts"] == 1_500_000 and i["s"] == "t"

    def test_jsonl_export_one_event_per_line(self, tmp_path):
        tr = Tracer(clock=_counting_clock())
        with tr.span("s"):
            pass
        path = tr.write(str(tmp_path / "t.jsonl"))
        lines = [json.loads(l) for l in open(path)]
        assert [e["ph"] for e in lines] == ["M", "M", "X"]

    def test_summary_reports_self_time_and_metrics(self):
        tr = Tracer(clock=_counting_clock())
        with tr.span("outer"):          # clock ticks 1s per now() call:
            with tr.span("inner"):      # outer [1,4], inner [2,3]
                pass
        tr.metrics.counter("hits").inc(7)
        s = tr.summary()
        assert "dse/inner" in s and "dse/outer" in s and "hits" in s
        inner = next(l for l in s.splitlines() if "dse/inner" in l)
        outer = next(l for l in s.splitlines() if "dse/outer" in l)
        assert float(inner.split()[0]) == pytest.approx(1.0)
        assert float(outer.split()[0]) == pytest.approx(2.0)   # child removed

    @given(st.lists(st.booleans(), min_size=1, max_size=40))
    @settings(max_examples=50, deadline=None)
    def test_random_span_trees_always_validate(self, ops):
        """Spans produced by the context-manager API nest by construction:
        any open/close sequence exports with zero nesting violations."""
        tr = Tracer(clock=_counting_clock())
        with tr.span("root"):           # never empty, whatever ops drew
            pass
        open_spans = []
        for op in ops:
            if op and len(open_spans) < 5:
                sp = tr.span(f"s{len(open_spans)}")
                sp.__enter__()
                open_spans.append(sp)
            elif open_spans:
                open_spans.pop().__exit__(None, None, None)
        while open_spans:
            open_spans.pop().__exit__(None, None, None)
        assert validate_chrome_trace(tr.to_chrome()) == []

    def test_validator_flags_overlap_and_bad_counter(self):
        tr = Tracer()
        # two overlapping (non-nested) spans on one lane
        tr.complete("a", 0.0, 2.0, group="serving", lane="m")
        tr.complete("b", 1.0, 3.0, group="serving", lane="m")
        # counter going back in time
        tr.counter("q", 2.0, 1, group="serving")
        tr.counter("q", 1.0, 2, group="serving")
        problems = validate_chrome_trace(tr.to_chrome())
        assert any("overlaps" in p for p in problems)
        assert any("non-monotone" in p for p in problems)

    def test_validator_rejects_malformed_payloads(self):
        assert validate_chrome_trace([]) != []
        assert validate_chrome_trace({"traceEvents": [{"ph": "X"}]}) != []
        probs = validate_chrome_trace(
            {"traceEvents": [{"ph": "M", "name": "process_name", "pid": 1,
                              "tid": 0, "ts": 0, "args": {"name": "dse"}}]},
            expect_fault_events=True, expect_groups=["serving"])
        assert any("fault" in p for p in probs)
        assert any("serving" in p for p in probs)


# ---------------------------------------------------------------------------
# Engine counter schema (satellite: one stats schema for both engines)
# ---------------------------------------------------------------------------

class TestEngineStatsSchema:
    def test_reference_and_fast_share_one_schema(self):
        opts = scope.SearchOptions(m_samples=M)
        hw = scope.PackageSpec.of("mcm16").resolve()
        fast = opts.make_cost(hw)
        ref = scope.SearchOptions(m_samples=M, engine="reference").make_cost(hw)
        f_sol = scope.solve(scope.problem("alexnet", "mcm16", m_samples=M,
                                          cost=fast))
        r_sol = scope.solve(scope.problem("alexnet", "mcm16", m_samples=M,
                                          cost=ref))
        assert f_sol.latency == pytest.approx(r_sol.latency, rel=1e-9)
        fs, rs = fast.stats, ref.stats
        assert set(fs) == set(rs)
        # reference: no memo, every probe is a compute
        assert rs["memo_hits"] == 0 and rs["memo_cells"] == 0
        assert rs["cluster_probes"] == rs["cluster_computes"] > 0
        # fast: memo answers the probes it doesn't compute
        assert fs["memo_hits"] == fs["cluster_probes"] - fs["cluster_computes"]
        assert fs["memo_hits"] > 0
        # both runs routed their stats into solve()'s diagnostics
        assert f_sol.diagnostics["engine_stats"] == fs


# ---------------------------------------------------------------------------
# Front doors: solve(trace=...) / serve(tracer=...)
# ---------------------------------------------------------------------------

class TestFrontDoors:
    def test_trace_option_is_not_part_of_problem_identity(self):
        plain = scope.problem("alexnet", "mcm16", m_samples=M)
        traced_p = plain.with_options(trace="somewhere.json")
        assert problem_fingerprint(plain) == problem_fingerprint(traced_p)

    def test_solve_trace_true_attaches_tracer(self):
        sol = scope.solve(scope.problem("alexnet", "mcm16", m_samples=M,
                                        trace=True))
        tr = sol.diagnostics["trace"]
        assert isinstance(tr, Tracer)
        names = {e[1] for e in tr.events}
        assert "solve:scope" in names and "search" in names
        assert "segment" in names
        snap = tr.metrics.snapshot()["counters"]
        assert snap["solve.calls"] == 1
        assert snap["engine.segment_evals"] > 0
        assert validate_chrome_trace(tr.to_chrome(),
                                     expect_groups=["dse"]) == []

    def test_solve_trace_path_writes_file(self, tmp_path):
        path = str(tmp_path / "solve.json")
        sol = scope.solve(scope.problem("alexnet", "mcm16", m_samples=M,
                                        trace=path))
        assert sol.feasible
        payload = json.load(open(path))
        assert validate_chrome_trace(payload, expect_groups=["dse"]) == []

    def test_solve_without_trace_records_nothing(self):
        sol = scope.solve(scope.problem("alexnet", "mcm16", m_samples=M))
        assert "trace" not in sol.diagnostics
        assert "engine_stats" in sol.diagnostics       # stats stay regardless

    def test_serve_tracer_builds_gantt(self, tmp_path):
        path = str(tmp_path / "serve.json")
        sol = scope.solve(scope.problem("alexnet:1:500,resnet18:1:500",
                                        "mcm16_hetero", m_samples=M))
        rep = sol.serve(n_requests=1500, rate_scale=0.75, seed=0,
                        faults="zone:little@35%:65%", tracer=path)
        assert rep.conserved
        tr = rep.tracer
        assert rep.meta["trace_path"] == path
        payload = json.load(open(path))
        assert validate_chrome_trace(payload, expect_fault_events=True,
                                     expect_groups=["serving"]) == []
        names = {e[1] for e in tr.events}
        assert "batch" in names and "fault:fail" in names
        assert "fault:re-solve" in names and "recovered" in names
        assert "redeploy" in names
        assert any(e[0] == "C" and e[1].startswith("queue:")
                   for e in tr.events)
        # mid-run degraded re-solves land on the same timeline (dse group)
        groups = {e[2] for e in tr.events}
        assert "serving" in groups and "dse" in groups
        counters = tr.metrics.snapshot()["counters"]
        assert counters["serving.faults"] >= 1
        assert counters["serving.batches"] > 0


# ---------------------------------------------------------------------------
# Determinism: sim-clock traces are bytewise stable across same-seed runs
# ---------------------------------------------------------------------------

class TestTraceDeterminism:
    def test_same_seed_serving_trace_is_bytewise_identical(self, tmp_path):
        # fault_recovery=False keeps the run free of wall-clock solver
        # spans: every event is on the simulated clock.
        sol = scope.solve(scope.problem("alexnet", "mcm16", m_samples=M))

        def run(path):
            rep = sol.serve(n_requests=1200, seed=7,
                            faults="chip:0,0@30%:60%",
                            fault_recovery=False, tracer=str(path))
            assert rep.conserved
            return path.read_bytes()

        a = run(tmp_path / "a.json")
        b = run(tmp_path / "b.json")
        assert a == b
        payload = json.loads(a)
        assert validate_chrome_trace(payload, expect_fault_events=True,
                                     expect_groups=["serving"]) == []

    def test_different_seed_changes_the_trace(self, tmp_path):
        sol = scope.solve(scope.problem("alexnet", "mcm16", m_samples=M))
        reps = [sol.serve(n_requests=400, seed=s, tracer=True)
                for s in (0, 1)]
        streams = [r.tracer.to_chrome()["traceEvents"] for r in reps]
        assert streams[0] != streams[1]
