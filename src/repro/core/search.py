"""Scope DSE: paper Algorithm 1, plus exhaustive/random search for validation.

Per segment, three nested dimensions are explored:
  * WSP->ISP transition index (linear, L+1 candidates)       [partition.py]
  * N_cluster via the cluster merge table (linear, L rows)   [cmt.py]
  * region allocation: proportional seed + chip-rebalance    [regions.py]

The pseudocode's inner ``while tmpLatency < minLatency`` only rebalances while
beating the global best; we run the (strictly stronger) local-improvement
rebalance and track the global best across it -- this can only find better
schedules and keeps the same asymptotics.

System level: sweep segment counts from the minimal feasible value
(segments.py) and run Algorithm 1 independently per segment (paper SSV-A uses
an identical segment allocation for Scope and the segmented baseline).
"""
from __future__ import annotations

import itertools
import random
from dataclasses import dataclass

from .cmt import Clustering, gen_cmt
from .costmodel import INF, CostModel
from .graph import (
    ClusterAssignment,
    LayerGraph,
    ScopeSchedule,
    SegmentSchedule,
)
from .partition import (
    apply_ep,
    enumerate_exhaustive,
    enumerate_transition_points,
    transition_partitions,
)
from .regions import (
    RegionMode,
    proportional_allocate,
    rebalance,
    uniform_allocate,
)
from .segments import candidate_segment_counts, divide_segments


def build_clusters(
    seg_lo: int,
    clustering: Clustering,
    partitions: tuple[str, ...],
    regions: list[int],
    chip_type: str | None = None,
) -> tuple[ClusterAssignment, ...]:
    """Assemble ClusterAssignments from segment-relative pieces."""
    out = []
    for (lo, hi), chips in zip(clustering, regions):
        out.append(
            ClusterAssignment(
                layer_lo=seg_lo + lo,
                layer_hi=seg_lo + hi,
                region_chips=chips,
                partitions=partitions[lo:hi],
                chip_type=chip_type,
            )
        )
    return tuple(out)


def evaluate_segment(
    cost: CostModel,
    graph: LayerGraph,
    seg_lo: int,
    clustering: Clustering,
    partitions: tuple[str, ...],
    regions: list[int],
    chip_type: str | None = None,
) -> tuple[float, list[float]]:
    clusters = build_clusters(seg_lo, clustering, partitions, regions, chip_type)
    lat, times = cost.segment_time(graph, clusters)
    return lat, times


@dataclass
class SegmentResult:
    clusters: tuple[ClusterAssignment, ...]
    latency: float
    cluster_times: tuple[float, ...]


def search_segment(
    cost: CostModel,
    graph: LayerGraph,
    seg_lo: int,
    seg_hi: int,
    chips: int,
    mode: RegionMode = RegionMode.FREE,
    ep_for_moe: bool = False,
    max_clusters: int | None = None,
    fixed_clustering: Clustering | None = None,
    chip_type: str | None = None,
    paper_strict: bool = False,
) -> SegmentResult | None:
    """Algorithm 1 over one segment.

    ``fixed_clustering`` short-circuits the CMT (used by the segmented-pipeline
    baseline, where every layer is its own cluster).  ``chip_type`` runs the
    whole segment on one flavor of a heterogeneous package; ``paper_strict``
    replicates the pseudocode's rebalance exactly (regions.rebalance).
    """
    sub = graph.slice(seg_lo, seg_hi)
    L = len(sub)
    cmt = {len(fixed_clustering): fixed_clustering} if fixed_clustering else gen_cmt(sub)
    best: SegmentResult | None = None

    # Candidate partition sets, each with a (transition_idx, ep) hint that
    # lets FastCostModel key its memo by small int tuples (see fastcost.py).
    partition_sets: dict[tuple[str, ...], tuple[int, bool]] = {}
    for idx in range(L + 1):
        partition_sets[transition_partitions(L, idx)] = (idx, False)
    if ep_for_moe:
        for idx in range(L + 1):
            p = transition_partitions(L, idx)
            pe = apply_ep(graph, p, lo=seg_lo)
            if pe != p and pe not in partition_sets:  # dedupe, keep order
                partition_sets[pe] = (idx, True)

    # Seed allocations depend only on the clustering (not on partitions), so
    # compute them once per CMT row instead of once per (partitions x row).
    seeds: dict[int, list[int] | None] = {}
    for n_cluster, clustering in cmt.items():
        if max_clusters is not None and n_cluster > max_clusters:
            continue
        if n_cluster > chips:
            continue
        if mode is RegionMode.UNIFORM:
            seeds[n_cluster] = uniform_allocate(n_cluster, chips)
        else:
            seeds[n_cluster] = proportional_allocate(
                [sum(graph.layers[seg_lo + i].flops for i in range(lo, hi))
                 for lo, hi in clustering],
                chips,
            )

    # Clustering-outer, partitions-inner: one sweeper per CMT row carries the
    # allocation-independent precomputation through the whole transition
    # sweep (FastCostModel updates it incrementally per transition step).
    for n_cluster, clustering in cmt.items():
        seed = seeds.get(n_cluster)
        if seed is None:
            continue
        sweeper = cost.segment_sweeper(graph, seg_lo, clustering, chip_type)
        # Seed-phase batch fill (fastcost 2D (k x layer) vectorization): every
        # transition slice's body at the seed allocation in one array pass.
        prefill = getattr(sweeper, "prefill", None)
        if prefill is not None:
            prefill(seed)
        for partitions, hint in partition_sets.items():

            # One evaluator per (clustering, partitions): FastCostModel
            # memoizes cluster costs, so the rebalance walk below only ever
            # computes the clusters a chip move actually changed.
            eval_fn = sweeper(partitions, transition=hint)

            if mode is RegionMode.UNIFORM:
                lat, times = eval_fn(seed)
                alloc = seed
            else:
                alloc, lat, times = rebalance(seed, eval_fn,
                                              paper_strict=paper_strict)
            if lat < (best.latency if best else INF):
                best = SegmentResult(
                    clusters=build_clusters(
                        seg_lo, clustering, partitions, alloc, chip_type
                    ),
                    latency=lat,
                    cluster_times=tuple(times),
                )
    return best


def search(
    graph: LayerGraph,
    cost: CostModel,
    chips: int,
    mode: RegionMode = RegionMode.FREE,
    ep_for_moe: bool = False,
    segment_counts: list[int] | None = None,
    max_clusters: int | None = None,
    chip_type: str | None = None,
    paper_strict: bool = False,
) -> ScopeSchedule | None:
    """Full Scope DSE: segment sweep x Algorithm 1 per segment (Eq. 1).

    ``chip_type`` schedules onto ``chips`` chips of that flavor of a
    heterogeneous package (multimodel quota search); segment feasibility
    uses package-level weight capacity, which is flavor-independent.
    """
    hw = cost.hw
    counts = segment_counts or candidate_segment_counts(graph, hw, chips)
    best_sched: ScopeSchedule | None = None
    for n_seg in counts:
        split = divide_segments(graph, hw, chips, n_seg)
        if split is None:
            continue
        segs: list[SegmentSchedule] = []
        total = 0.0
        ok = True
        for lo, hi in split:
            res = search_segment(
                cost, graph, lo, hi, chips, mode=mode,
                ep_for_moe=ep_for_moe, max_clusters=max_clusters,
                chip_type=chip_type, paper_strict=paper_strict,
            )
            if res is None or res.latency == INF:
                ok = False
                break
            segs.append(
                SegmentSchedule(res.clusters, res.latency, res.cluster_times)
            )
            total += res.latency
        if not ok:
            continue
        if best_sched is None or total < best_sched.latency:
            meta = {"n_segments": n_seg, "mode": mode.value}
            if chip_type:
                meta["chip_type"] = chip_type
            best_sched = ScopeSchedule(
                workload=graph.name,
                chips=chips,
                segments=tuple(segs),
                latency=total,
                meta=meta,
            )
    return best_sched


# ---------------------------------------------------------------------------
# Validation searches (paper SSV-B(1), Fig. 8)
# ---------------------------------------------------------------------------

def compositions(total: int, parts: int):
    """All ways to write ``total`` as ``parts`` positive integers (ordered)."""
    for cuts in itertools.combinations(range(1, total), parts - 1):
        prev, out = 0, []
        for c in cuts:
            out.append(c - prev)
            prev = c
        out.append(total - prev)
        yield out


def enumerate_clusterings(L: int):
    for n_cluster in range(1, L + 1):
        for sizes in compositions(L, n_cluster):
            bounds, cursor = [], 0
            for s in sizes:
                bounds.append((cursor, cursor + s))
                cursor += s
            yield tuple(bounds)


def exhaustive_search(
    cost: CostModel, graph: LayerGraph, chips: int, yield_all: bool = False
):
    """Brute force over (clustering x regions x 2^L partitions) for one segment.

    Only tractable for tiny L/C (the paper uses AlexNet x 16 chiplets).
    Yields (latency, clustering, regions, partitions) for every valid config
    when ``yield_all``; otherwise returns the best tuple.
    """
    L = len(graph)
    best = (INF, None, None, None)
    for clustering in enumerate_clusterings(L):
        n_cluster = len(clustering)
        if n_cluster > chips:
            continue
        for regions in compositions(chips, n_cluster):
            for partitions in enumerate_exhaustive(L):
                lat, _ = evaluate_segment(cost, graph, 0, clustering, partitions, list(regions))
                if yield_all and lat < INF:
                    yield lat, clustering, tuple(regions), partitions
                if lat < best[0]:
                    best = (lat, clustering, tuple(regions), partitions)
    if not yield_all:
        yield best


def random_search(
    cost: CostModel,
    graph: LayerGraph,
    chips: int,
    samples: int,
    seed: int = 0,
):
    """Uniform random samples of the full space -- builds Fig. 8's histogram."""
    rng = random.Random(seed)
    L = len(graph)
    out = []
    for _ in range(samples):
        n_cluster = rng.randint(1, min(L, chips))
        cuts = sorted(rng.sample(range(1, L), n_cluster - 1)) if n_cluster > 1 else []
        bounds, cursor = [], 0
        for c in cuts + [L]:
            bounds.append((cursor, c))
            cursor = c
        rcuts = sorted(rng.sample(range(1, chips), n_cluster - 1)) if n_cluster > 1 else []
        regions, prev = [], 0
        for c in rcuts + [chips]:
            regions.append(c - prev)
            prev = c
        partitions = tuple(rng.choice(("WSP", "ISP")) for _ in range(L))
        lat, _ = evaluate_segment(cost, graph, 0, tuple(bounds), partitions, regions)
        if lat < INF:
            out.append(lat)
    return out
