"""Search-algorithm tests: CMT, regions, segments, Algorithm 1, baselines.

Includes hypothesis property tests on the scheduler's invariants.
"""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.cmt import gen_cmt, validate_clustering
from repro.core.costmodel import INF, CostModel
from repro.core.graph import LayerNode, chain, validate_schedule
from repro.core.hw import mcm_table_iii
from repro.core.baselines import (
    schedule_full_pipeline,
    schedule_scope,
    schedule_segmented,
    schedule_sequential,
)
from repro.core.regions import proportional_allocate, rebalance, zigzag_placement
from repro.core.search import exhaustive_search, random_search, search_segment
from repro.core.segments import divide_segments, min_segments
from repro.core.workloads import get_cnn


def mk_graph(flops_list, parallel=None):
    layers = []
    for i, f in enumerate(flops_list):
        p = parallel[i] if parallel else 28.0
        layers.append(
            LayerNode(
                name=f"l{i}", kind="conv", flops=float(f), weight_bytes=64e3,
                in_bytes=32e3, out_bytes=32e3, halo_bytes=512.0,
                wsp_parallel=p, isp_parallel=128.0,
            )
        )
    return chain("synthetic", layers)


# ------------------------------------------------------------------- CMT

class TestCMT:
    def test_rows_cover_all_counts(self):
        g = mk_graph([1e9] * 10)
        cmt = gen_cmt(g)
        assert set(cmt.keys()) == set(range(1, 11))

    def test_every_row_is_valid_contiguous_cover(self):
        g = get_cnn("alexnet")
        cmt = gen_cmt(g)
        for n, clustering in cmt.items():
            assert len(clustering) == n
            assert validate_clustering(clustering, len(g))

    def test_merges_most_similar_parallelism_first(self):
        # layers: parallel 28, 28, 7 -> first merge must join the two 28s
        g = mk_graph([1e9] * 3, parallel=[28.0, 28.0, 7.0])
        cmt = gen_cmt(g)
        assert cmt[2] == ((0, 2), (2, 3))

    @given(st.lists(st.floats(min_value=1.0, max_value=1e4), min_size=2, max_size=24))
    @settings(max_examples=50, deadline=None)
    def test_property_valid_for_any_parallelism(self, parallels):
        g = mk_graph([1e9] * len(parallels), parallel=parallels)
        cmt = gen_cmt(g)
        assert set(cmt.keys()) == set(range(1, len(parallels) + 1))
        for n, clustering in cmt.items():
            assert validate_clustering(clustering, len(parallels))


# ---------------------------------------------------------------- regions

class TestRegions:
    def test_proportional_sums_and_minimum(self):
        alloc = proportional_allocate([1.0, 3.0, 8.0, 4.0], 16)
        assert sum(alloc) == 16
        assert all(a >= 1 for a in alloc)
        assert alloc[2] == max(alloc)

    @given(
        st.lists(st.floats(min_value=0.01, max_value=100.0), min_size=1, max_size=12),
        st.integers(min_value=12, max_value=64),
    )
    @settings(max_examples=80, deadline=None)
    def test_property_proportional(self, loads, chips):
        alloc = proportional_allocate(loads, chips)
        assert sum(alloc) == chips
        assert all(a >= 1 for a in alloc)

    def test_rebalance_improves_or_keeps(self):
        # loads 1:3, seed [2,2]: mover should shift a chip to the heavy one.
        def eval_fn(alloc):
            times = [1.0 / alloc[0], 3.0 / alloc[1]]
            return max(times), times

        alloc, lat, _ = rebalance([2, 2], eval_fn)
        assert lat <= 1.5
        assert alloc == [1, 3]

    def test_zigzag_contiguous_and_disjoint(self):
        regions = zigzag_placement([5, 7, 4], (4, 4))
        flat = [c for r in regions for c in r]
        assert len(flat) == len(set(flat)) == 16
        assert [len(r) for r in regions] == [5, 7, 4]


# --------------------------------------------------------------- segments

class TestSegments:
    def test_divide_covers_and_balances(self):
        g = get_cnn("resnet18")
        hw = mcm_table_iii(64)
        split = divide_segments(g, hw, 64, 3)
        assert split is not None
        assert split[0][0] == 0 and split[-1][1] == len(g)
        for (a, b), (c, d) in zip(split, split[1:]):
            assert b == c

    def test_min_segments_capacity(self):
        g = get_cnn("resnet152")       # 58 MB of weights
        hw = mcm_table_iii(16)         # 16 MiB package capacity
        s = min_segments(g, hw, 16)
        assert s is not None and s >= 4  # needs >= ceil(58/16.8) segments


# ------------------------------------------------------------ Algorithm 1

class TestAlgorithm1:
    def test_beats_or_matches_exhaustive_within_2pct(self):
        g = chain("sub", get_cnn("alexnet").layers[:4])
        hw = mcm_table_iii(6)
        cost = CostModel(hw, m_samples=16)
        best = next(exhaustive_search(cost, g, 6))
        res = search_segment(cost, g, 0, 4, 6)
        assert res.latency <= best[0] * 1.02

    def test_top_fraction_of_random_space(self):
        """Paper SSV-B(1): search result ranks in the top 0.05% of the space."""
        g = get_cnn("alexnet")
        hw = mcm_table_iii(16)
        cost = CostModel(hw, m_samples=16)
        res = search_segment(cost, g, 0, len(g), 16)
        samples = random_search(cost, g, 16, samples=4000, seed=7)
        beaten = sum(1 for s in samples if s < res.latency)
        assert beaten / len(samples) <= 0.0005 * 10  # generous CI at 4k samples

    def test_uniform_mode_regions_equal(self):
        from repro.core.regions import RegionMode

        g = get_cnn("alexnet")
        hw = mcm_table_iii(16)
        cost = CostModel(hw, m_samples=16)
        res = search_segment(cost, g, 0, len(g), 16, mode=RegionMode.UNIFORM)
        sizes = {c.region_chips for c in res.clusters}
        assert len(sizes) == 1


# ---------------------------------------------------------------- system

class TestSystemSchedules:
    @pytest.mark.parametrize("net", ["alexnet", "darknet19", "resnet18"])
    def test_scope_schedule_valid(self, net):
        g = get_cnn(net)
        hw = mcm_table_iii(64)
        cost = CostModel(hw, m_samples=16)
        s = schedule_scope(g, cost, 64)
        assert s is not None and s.latency < INF
        validate_schedule(g, s, 64)

    def test_scope_never_loses_to_segmented(self):
        """Merged pipeline generalizes segmented (paper SSI-A) -- given the
        same segment counts, Scope's space contains segmented's schedules."""
        g = get_cnn("resnet18")
        hw = mcm_table_iii(64)
        cost = CostModel(hw, m_samples=16)
        seg = schedule_segmented(g, cost, 64)
        sc = schedule_scope(g, cost, 64)
        assert sc.latency <= seg.latency * 1.0 + 1e-12

    def test_full_pipeline_invalid_when_layers_exceed_chips(self):
        g = get_cnn("resnet18")  # 17 layers
        hw = mcm_table_iii(16)
        cost = CostModel(hw, m_samples=16)
        assert schedule_full_pipeline(g, cost, 16) is None

    def test_sequential_degrades_at_scale(self):
        """Paper Fig. 9: sequential throughput saturates with chip count."""
        g = get_cnn("alexnet")
        tps = []
        for chips in (16, 256):
            hw = mcm_table_iii(chips)
            cost = CostModel(hw, m_samples=16)
            s = schedule_sequential(g, cost, chips)
            tps.append(cost.throughput(g, s.latency))
        assert tps[1] < tps[0] * 16 * 0.5  # far from linear scaling

    def test_scope_beats_sequential_at_scale(self):
        g = get_cnn("resnet50")
        hw = mcm_table_iii(256)
        cost = CostModel(hw, m_samples=16)
        seq = schedule_sequential(g, cost, 256)
        sc = schedule_scope(g, cost, 256)
        assert sc.latency < seq.latency
