"""Architecture registry: the 10 assigned configs + input-shape set.

Every entry is importable as ``repro.configs.<module>.CONFIG`` and selectable
as ``--arch <id>`` in the launchers.  ``get_smoke_config`` returns the
family-preserving reduced config used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import replace

from ..models.config import ModelConfig, MoEConfig
from . import (  # noqa: F401  (imported for registration side effect below)
    musicgen_medium,
    starcoder2_15b,
    granite_3_8b,
    gemma2_9b,
    granite_20b,
    llama4_maverick_400b,
    granite_moe_1b,
    jamba_v01_52b,
    rwkv6_3b,
    paligemma_3b,
)

ARCHS: dict[str, ModelConfig] = {
    "musicgen-medium": musicgen_medium.CONFIG,
    "starcoder2-15b": starcoder2_15b.CONFIG,
    "granite-3-8b": granite_3_8b.CONFIG,
    "gemma2-9b": gemma2_9b.CONFIG,
    "granite-20b": granite_20b.CONFIG,
    "llama4-maverick-400b-a17b": llama4_maverick_400b.CONFIG,
    "granite-moe-1b-a400m": granite_moe_1b.CONFIG,
    "jamba-v0.1-52b": jamba_v01_52b.CONFIG,
    "rwkv6-3b": rwkv6_3b.CONFIG,
    "paligemma-3b": paligemma_3b.CONFIG,
}

# (seq_len, global_batch, kind); kind decides which step the cell lowers.
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

# Sub-quadratic state is required for long_500k (DESIGN.md SS5): only the
# SSM/hybrid archs qualify; gemma2's alternating stack still contains global
# full-attention layers, so it is skipped too.
LONG_CONTEXT_ARCHS = {"rwkv6-3b", "jamba-v0.1-52b"}


def get_config(name: str) -> ModelConfig:
    return ARCHS[name]


def get_shape(name: str) -> tuple[int, int, str]:
    return SHAPES[name]


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells, honoring the long_500k skip rule."""
    out = []
    for arch in ARCHS:
        for shape in SHAPES:
            skipped = shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS
            if skipped and not include_skipped:
                continue
            out.append((arch, shape))
    return out


def get_smoke_config(name: str) -> ModelConfig:
    """Family-preserving reduction: tiny dims, same block pattern/features."""
    cfg = ARCHS[name]
    kw = dict(
        name=f"{cfg.name}-smoke",
        n_layers=2 * len(cfg.block_pattern),
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, 4 * cfg.n_kv_heads // cfg.n_heads),
        d_head=16,
        d_ff=128,
        vocab=128,
        window=8 if cfg.window else 0,
        frontend_tokens=4 if cfg.frontend != "none" else 0,
        rwkv_head_dim=16,
        mamba_d_state=4,
        accum_steps=1,
        param_dtype="float32",       # CPU smoke tests prefer exactness
    )
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(
            n_experts=4,
            top_k=min(cfg.moe.top_k, 2),
            every=cfg.moe.every,
            capacity_factor=2.0,
            d_ff=64 if cfg.moe.d_ff else None,
        )
    return replace(cfg, **kw)
