"""Pallas kernel validation: interpret=True vs pure-jnp oracles.

Shape/dtype sweeps per kernel + hypothesis property tests (assignment SSc).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba.ops import mamba_scan
from repro.kernels.mamba.ref import mamba_scan_ref
from repro.kernels.qmatmul.ops import qmatmul
from repro.kernels.qmatmul.ref import qmatmul_ref, quantize_cols, quantize_rows
from repro.kernels.rwkv6.ops import wkv6
from repro.kernels.rwkv6.ref import wkv6_ref

KEY = jax.random.PRNGKey(42)


# ----------------------------------------------------------- flash attention

FA_CASES = [
    # (B, H, KV, S, hd, causal, window, softcap, dtype)
    (2, 4, 2, 256, 64, True, 0, 0.0, jnp.float32),
    (1, 4, 1, 256, 128, True, 0, 50.0, jnp.float32),
    (2, 2, 2, 384, 64, True, 128, 0.0, jnp.float32),
    (1, 8, 4, 512, 64, False, 0, 0.0, jnp.float32),
    (1, 2, 2, 256, 64, True, 0, 0.0, jnp.bfloat16),
    (1, 16, 2, 128, 128, True, 64, 30.0, jnp.float32),
]


@pytest.mark.parametrize("case", FA_CASES)
def test_flash_attention_matches_oracle(case):
    B, H, KV, S, hd, causal, window, cap, dtype = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                          interpret=True)
    ref = attention_ref(q, k, v, causal, window, cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


@given(
    bq=st.sampled_from([64, 128]),
    bk=st.sampled_from([64, 128]),
    s_mult=st.integers(min_value=2, max_value=4),
)
@settings(max_examples=6, deadline=None)
def test_flash_attention_block_shape_invariance(bq, bk, s_mult):
    """Output must not depend on the BlockSpec tiling."""
    S = 128 * s_mult
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, S, 64))
    k = jax.random.normal(ks[1], (1, 2, S, 64))
    v = jax.random.normal(ks[2], (1, 2, S, 64))
    out = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------- wkv6

WKV_CASES = [(2, 2, 64, 16, 16), (1, 4, 128, 64, 32), (2, 1, 96, 32, 32), (1, 2, 256, 64, 64)]


@pytest.mark.parametrize("case", WKV_CASES)
def test_wkv6_matches_oracle(case):
    B, H, S, hd, chunk = case
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, H, S, hd))
    v = jax.random.normal(ks[2], (B, H, S, hd))
    w = jax.random.uniform(ks[3], (B, H, S, hd), minval=0.7, maxval=0.999)
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    out, s_last = wkv6(r, k, v, jnp.log(w), u, chunk=chunk, interpret=True)
    ro, rs = wkv6_ref(r, k, v, jnp.log(w), u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ro), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_last), np.asarray(rs), rtol=2e-4, atol=2e-4)


@given(chunk=st.sampled_from([8, 16, 32, 64]))
@settings(max_examples=4, deadline=None)
def test_wkv6_chunk_invariance(chunk):
    """State handoff must make the result chunk-size independent."""
    ks = jax.random.split(KEY, 5)
    B, H, S, hd = 1, 2, 64, 16
    r = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, H, S, hd))
    v = jax.random.normal(ks[2], (B, H, S, hd))
    w = jax.random.uniform(ks[3], (B, H, S, hd), minval=0.75, maxval=0.995)
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    out, _ = wkv6(r, k, v, jnp.log(w), u, chunk=chunk, interpret=True)
    ref, _ = wkv6_ref(r, k, v, jnp.log(w), u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


# -------------------------------------------------------------------- mamba

MAMBA_CASES = [(2, 64, 128, 8, 64, 32), (1, 128, 256, 16, 128, 64), (1, 96, 64, 4, 64, 32)]


@pytest.mark.parametrize("case", MAMBA_CASES)
def test_mamba_scan_matches_oracle(case):
    B, S, di, N, bd, chunk = case
    ks = jax.random.split(KEY, 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, di)))
    x = jax.random.normal(ks[1], (B, S, di))
    A = -jnp.exp(jax.random.normal(ks[2], (di, N)) * 0.5)
    Bc = jax.random.normal(ks[3], (B, S, N))
    Cc = jax.random.normal(ks[4], (B, S, N))
    D = jnp.ones((di,))
    y, h = mamba_scan(dt, x, A, Bc, Cc, D, block_d=bd, chunk=chunk, interpret=True)
    yr, hr = mamba_scan_ref(dt, x, A, Bc, Cc, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------ qmatmul

@pytest.mark.parametrize("mnk", [(128, 128, 128), (256, 128, 384), (128, 256, 256)])
def test_qmatmul_exact_int_arithmetic(mnk):
    """int8 x int8 -> int32 must be bit-exact vs the oracle."""
    M, N, K = mnk
    ks = jax.random.split(KEY, 2)
    xq = jax.random.randint(ks[0], (M, K), -127, 128, jnp.int8)
    wq = jax.random.randint(ks[1], (K, N), -127, 128, jnp.int8)
    xs = jnp.ones((M,), jnp.float32)
    ws = jnp.ones((N,), jnp.float32)
    out = qmatmul(xq, wq, xs, ws, interpret=True)
    ref = qmatmul_ref(xq, wq, xs, ws)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_qmatmul_quantized_close_to_fp():
    """End-to-end: quantize fp32 operands, int8 matmul ~ fp32 matmul."""
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (128, 256))
    w = jax.random.normal(ks[1], (256, 128)) * 0.1
    xq, xs = quantize_rows(x)
    wq, ws = quantize_cols(w)
    out = qmatmul(xq, wq, xs, ws, interpret=True)
    ref = x @ w
    rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
    assert rel < 0.02, rel


@given(
    m=st.sampled_from([128, 256]),
    k_steps=st.integers(min_value=1, max_value=3),
)
@settings(max_examples=4, deadline=None)
def test_qmatmul_k_accumulation_property(m, k_steps):
    """Accumulating over K blocks must equal the single-block result."""
    K = 128 * k_steps
    ks = jax.random.split(KEY, 2)
    xq = jax.random.randint(ks[0], (m, K), -5, 6, jnp.int8)
    wq = jax.random.randint(ks[1], (K, 128), -5, 6, jnp.int8)
    s1 = jnp.ones((m,), jnp.float32)
    s2 = jnp.ones((128,), jnp.float32)
    out = qmatmul(xq, wq, s1, s2, block_k=128, interpret=True)
    ref = qmatmul_ref(xq, wq, s1, s2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
