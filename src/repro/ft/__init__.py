from .runner import ResilientTrainer, StragglerMonitor  # noqa: F401
