"""Fig. 9: throughput scalability vs chiplet count at a fixed workload.

Paper claims reproduced: Scope scales best; segmented grows slower; the
fully-sequential method saturates (NoP-bound) and can even degrade; the
fully-pipelined method lacks valid solutions at low chip counts.
"""
from __future__ import annotations

from .common import cached, run_method

CHIPS = [16, 32, 64, 128, 256]
# 512/1024-chip rows, affordable since the fast engine (ROADMAP open item);
# run on the flagship net so the big-package regime is actually exercised.
LARGE_CHIPS = [512, 1024]
LARGE_NET = "resnet152"
METHODS = ["sequential", "full_pipeline", "segmented", "scope"]
NET = "resnet50"


def run(refresh: bool = False, net: str = NET, chips_list=None):
    rows = []
    for chips in chips_list or CHIPS:
        def _one(chips=chips):
            return [run_method(net, chips, m) for m in METHODS]
        rows.extend(cached(f"fig9_{net}_{chips}", _one, refresh))
    return rows


def run_large(refresh: bool = False, net: str = LARGE_NET):
    """The beyond-256 scalability study (512 and 1024 chips)."""
    return run(refresh, net=net, chips_list=LARGE_CHIPS)


def report(rows) -> list[str]:
    by = {}
    chips_seen = []
    for r in rows:
        by.setdefault(r["method"], {})[r["chips"]] = r
        if r["chips"] not in chips_seen:
            chips_seen.append(r["chips"])
    chips = sorted(chips_seen)
    base_c = chips[0]
    lines = ["method," + ",".join(f"x{c}" for c in chips)
             + f"  (normalized to {base_c} chips)"]
    for m in METHODS:
        base = by.get(m, {}).get(base_c, {})
        base_tp = base.get("throughput") if base.get("valid") else None
        cells = []
        for c in chips:
            r = by.get(m, {}).get(c, {})
            if not r.get("valid"):
                cells.append("invalid")
            elif base_tp:
                cells.append(f"{r['throughput'] / base_tp:.2f}")
            else:
                cells.append(f"abs:{r['throughput']:.0f}")
        lines.append(f"{m}," + ",".join(cells))
    lines.append("method," + ",".join(f"x{c}" for c in chips)
                 + "  (absolute samples/s)")
    for m in METHODS:
        cells = []
        for c in chips:
            r = by.get(m, {}).get(c, {})
            cells.append(f"{r['throughput']:.0f}" if r.get("valid") else "invalid")
        lines.append(f"{m}," + ",".join(cells))
    best = all(
        by["scope"][c]["throughput"] >= by["segmented"][c]["throughput"]
        for c in chips
        if by["scope"].get(c, {}).get("valid")
        and by["segmented"].get(c, {}).get("valid")
    )
    lines.append(f"# scope >= segmented at every scale: {best} "
                 "(paper Fig 9: Scope exhibits the best scalability)")
    return lines
