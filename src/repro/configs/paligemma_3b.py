"""paligemma-3b [vlm]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=257216, SigLIP vision frontend + gemma decoder [arXiv:2407.07726; hf].

The SigLIP tower is a stub per the assignment: ``input_specs`` provides 256
precomputed patch embeddings, concatenated ahead of the text tokens.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_head=256,
    d_ff=16384,
    vocab=257216,
    tie_embeddings=True,
    ffn_gated=True,
    frontend="vision_stub",
    frontend_tokens=256,
    rope_theta=10_000.0,
)
