"""Pallas-TPU version shims shared by the kernels."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 spells this TPUCompilerParams; keep one name for both.
CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)
