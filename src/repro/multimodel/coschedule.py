"""The co-scheduler: best of {partitioned quotas, merged pipeline, time-mux}.

``co_schedule`` is the subsystem's entry point.  It searches the three
co-scheduling families over one shared FastCostModel (the cluster-cost memo
is what makes the joint sweep affordable -- engine stats land in the result
meta) and returns the best :class:`MultiModelSchedule` by weighted
throughput.  Time multiplexing is itself a legal co-schedule, so the result
is by construction at least as good as either fig11 baseline.
"""
from __future__ import annotations

import time
import warnings

from ..core.costmodel import CostModel
from ..core.fastcost import FastCostModel
from ..core.graph import MultiModelSchedule, validate_multimodel
from ..core.hw import HardwareModel, validate_region_types
from ..obs import current_tracer
from .baselines import time_multiplexed
from .curves import build_curves
from .interleave import merged_graph, search_merged
from .quota import package_flavors, search_partitioned, search_partitioned_mixed
from .spec import ModelSpec


def co_schedule(
    specs: list[ModelSpec],
    hw: HardwareModel,
    m_samples: int = 16,
    step: int = 1,
    include_merged: bool = True,
    include_time_mux: bool = True,
    include_mixed: bool = True,
    paper_strict: bool = False,
    cost: CostModel | None = None,
    validate: bool = True,
    curve_refine: bool = False,
    mixed_step: int | None = None,
    switch_cost: bool = False,
    switch_period_s: float = 1.0,
) -> MultiModelSchedule | None:
    """Jointly schedule ``specs`` onto one package.

    ``step`` coarsens the quota grid (1 = exhaustive; ``curve_refine``
    re-samples the coarse curves -- 1D *and* mixed 2D -- around each
    argmax); ``cost`` lets callers supply a pre-warmed engine (its memo
    then carries over between calls).  On two-flavor heterogeneous packages
    ``include_mixed`` also searches quotas that span flavors (one model's
    pipeline on big *and* little chips); packages with 3+ flavors fall
    back to single-flavor quotas with a warning and
    ``meta["mixed_fallback"]``.  ``switch_cost`` charges the time-mux mode
    for per-slice weight re-deployment (see ``baselines.time_multiplexed``).
    """
    validate_region_types(hw)
    names = [s.name for s in specs]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate model names in mix: {names}")
    if cost is None:
        cost = FastCostModel(hw, m_samples=m_samples)
    t0 = time.time()
    tr = current_tracer()
    flavors = package_flavors(hw)
    with tr.span("coschedule:curves", models=len(specs),
                 flavors=len(flavors)):
        curves = build_curves(specs, cost, flavors, step, paper_strict,
                              refine=curve_refine)

    candidates: list[tuple[str, MultiModelSchedule]] = []
    mixed_fallback = None
    with tr.span("coschedule:partitioned"):
        part = search_partitioned(specs, cost, step, paper_strict,
                                  curves=curves)
    if part is not None:
        candidates.append((part.mode, part))
    if include_mixed and len(flavors) == 2:
        with tr.span("coschedule:partitioned-mixed"):
            mixed = search_partitioned_mixed(
                specs, cost, step, paper_strict, curves=curves,
                mixed_step=mixed_step, mixed_refine=curve_refine,
            )
        if mixed is not None:
            candidates.append(("partitioned:mixed", mixed))
    elif include_mixed and len(flavors) > 2:
        # Spanning quotas cover exactly the big/little pair today; don't let
        # a 3+-flavor package silently degrade to single-flavor quotas.
        mixed_fallback = {
            "n_flavors": len(flavors),
            "flavors": [t for t, _ in flavors],
            "reason": "spanning quotas support exactly two flavors; "
                      "falling back to single-flavor quotas",
        }
        warnings.warn(
            f"{hw.name}: {len(flavors)}-flavor package -- "
            f"{mixed_fallback['reason']} (the per-cluster mixed DSE itself "
            "handles any flavor count; only the quota enumeration is 2-flavor)",
            stacklevel=2,
        )
    if include_merged and len(specs) > 1:
        with tr.span("coschedule:merged", flavors=len(flavors)):
            for ctype, _cap in flavors:
                merged = search_merged(specs, cost, chip_type=ctype,
                                       paper_strict=paper_strict)
                if merged is not None:
                    label = f"{merged.mode}:{ctype}" if ctype else merged.mode
                    candidates.append((label, merged))
    if include_time_mux:
        with tr.span("coschedule:time-mux"):
            tm = time_multiplexed(specs, cost, curves=curves,
                                  switch_cost=switch_cost,
                                  switch_period_s=switch_period_s)
        if tm is not None:
            candidates.append((tm.mode, tm))
    if not candidates:
        return None

    best = max(candidates, key=lambda c: c[1].weighted_throughput)[1]
    best.meta.update({
        "dse_s": time.time() - t0,
        "engine_stats": dict(cost.stats),
        "mode_rates": {
            label: c.weighted_throughput for label, c in candidates
        },
    })
    if mixed_fallback is not None:
        best.meta["mixed_fallback"] = mixed_fallback
    if validate:
        graphs = {s.name: s.graph for s in specs}
        if best.mode == "merged":
            mg, _ = merged_graph(specs)
            graphs[mg.name] = mg
        type_capacity = dict(flavors)
        validate_multimodel(best, graphs, type_capacity)
    return best


def describe(sched: MultiModelSchedule) -> list[str]:
    """Human-readable co-schedule summary (CLI / examples)."""
    lines = [
        f"{sched.package}: {sched.n_models} models, mode={sched.mode}, "
        f"mix rate {sched.mix_rate:.1f}/s, "
        f"weighted throughput {sched.weighted_throughput:.1f} samples/s"
    ]
    for a in sched.assignments:
        extras = []
        if a.chip_type:
            extras.append(f"type={a.chip_type}")
        if a.chip_quota:
            extras.append(
                "quota=" + "+".join(f"{c}x{t}" for t, c in a.chip_quota if c)
            )
        if a.samples_per_beat != 1.0:
            extras.append(f"{a.samples_per_beat:g} samples/beat")
        if a.time_share != 1.0:
            extras.append(f"{a.time_share * 100:.0f}% of time")
        lines.append(
            f"  {a.model:12s} w={a.weight:g}  {a.chips:4d} chips  "
            f"{a.throughput:9.1f} samples/s  {' '.join(extras)}"
        )
    return lines
