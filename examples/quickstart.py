"""Quickstart: schedule a network with Scope and inspect the result.

Runs the paper's full DSE (Algorithm 1) for ResNet-50 on a 64-chiplet MCM,
compares it against the three baseline schedulers, and prints the chosen
segments / clusters / regions / partitions -- the paper's Table I variables.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import FastCostModel, mcm_table_iii
from repro.core.baselines import ALL_METHODS
from repro.core.workloads import get_cnn

NET, CHIPS = "resnet50", 64

graph = get_cnn(NET)
hw = mcm_table_iii(CHIPS)
cost = FastCostModel(hw, m_samples=16)

print(f"{NET}: {len(graph)} layers, {graph.total_flops / 1e9:.1f} GFLOPs, "
      f"{graph.total_weight_bytes / 1e6:.1f} MB weights on {CHIPS} chiplets\n")

results = {}
for name, fn in ALL_METHODS.items():
    sched = fn(graph, cost, CHIPS)
    ok = sched is not None and sched.latency != float("inf")
    results[name] = sched if ok else None
    tp = cost.throughput(graph, sched.latency) if ok else 0.0
    print(f"{name:14s} {'%8.3f ms' % (sched.latency * 1e3) if ok else '  invalid'}"
          f"   {tp:8.1f} samples/s")

scope = results["scope"]
print(f"\nScope schedule ({scope.meta['n_segments']} segments):")
for i, seg in enumerate(scope.segments):
    print(f"  segment {i}: {seg.n_clusters} clusters")
    for cl, t in zip(seg.clusters, seg.cluster_times):
        kinds = {p for p in cl.partitions}
        print(f"    layers[{cl.layer_lo:3d}:{cl.layer_hi:3d}] "
              f"region={cl.region_chips:3d} chips  P={'/'.join(sorted(kinds))}"
              f"  beat={t * 1e6:7.1f} us")

speedup = results["segmented"].latency / scope.latency
print(f"\nScope vs segmented pipeline: {speedup:.2f}x")
