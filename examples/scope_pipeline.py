"""Merged-pipeline execution demo (the paper's mechanism, on a JAX mesh).

Spawns 8 virtual devices, builds a (stage=4, data=2) mesh, and runs the
shard_map GPipe pipeline where each stage executes a Scope *cluster* of
merged layers.  Verifies the pipelined forward matches the plain forward
and shows the Eq. 2 beat structure (m + N_cluster - 1).

NOTE: must run as its own process (device count is locked at jax init):
    PYTHONPATH=src python examples/scope_pipeline.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.mesh import make_pipeline_mesh
from repro.models import forward, init_params
from repro.runtime.pipeline import pipeline_forward

N_STAGES, N_DATA, N_MICRO, MB, S = 4, 2, 8, 4, 32

cfg = dataclasses.replace(get_smoke_config("granite-3-8b"),
                          n_layers=8, remat=False)   # 8 repeats / 4 stages
mesh = make_pipeline_mesh(N_STAGES, N_DATA)
params = init_params(cfg, jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (N_MICRO, MB, S), 0, cfg.vocab)

print(f"mesh: {dict(mesh.shape)} -- each stage owns a merged cluster of "
      f"{cfg.pattern_repeats // N_STAGES} blocks")
print(f"GPipe beats = n_micro + n_stages - 1 = {N_MICRO + N_STAGES - 1} "
      f"(paper Eq. 2: m + N_cluster - 1)")

t0 = time.time()
piped = pipeline_forward(params, cfg, toks, mesh, n_stages=N_STAGES)
piped.block_until_ready()
print(f"pipelined forward: {time.time() - t0:.2f}s, logits {piped.shape}")

ref = jnp.stack([forward(params, cfg, toks[i])[0] for i in range(N_MICRO)])
err = float(jnp.max(jnp.abs(piped - ref)))
print(f"max |pipelined - plain| = {err:.2e}")
np.testing.assert_allclose(np.asarray(piped), np.asarray(ref), rtol=2e-3, atol=2e-3)
print("OK: merged pipeline reproduces the plain forward exactly")
