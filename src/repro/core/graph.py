"""Layer-graph IR consumed by the Scope scheduler.

The paper treats an NN as a sequence of layers (Table I indexes
``Layer(i,j,k)`` by segment / cluster / position).  We linearize every
workload (CNN or LM) into a chain of :class:`LayerNode`.  Residual adds,
norms and other cheap glue are folded into the node they feed.

Each node carries the quantities the cost model (paper Eqs. 4-7, Table II)
needs:

* ``flops``          total forward FLOPs (2 x MACs) for one sample
* ``weight_bytes``   parameter bytes (at the deployment precision)
* ``in_bytes`` / ``out_bytes``  activation volumes for one sample
* ``halo_bytes``     WSP boundary-exchange volume for one sample: conv kernel
                     overlap for CNNs, KV/state handoff for attention/SSM
* ``wsp_parallel``   max useful split degree of the activation dim
                     (output pixels / tokens) -- WSP's parallelism
* ``isp_parallel``   max useful split degree of the weight-output dim
                     (output channels / heads / ffn width) -- ISP's parallelism
* ``parallel_metric``  scalar used by GenCMT's similarity merge
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Sequence


@dataclass(frozen=True)
class LayerNode:
    name: str
    kind: str                      # conv | fc | attention | ffn | moe_ffn | mamba | rwkv | embed
    flops: float
    weight_bytes: float
    in_bytes: float
    out_bytes: float
    halo_bytes: float = 0.0
    wsp_parallel: float = 1.0
    isp_parallel: float = 1.0
    parallel_metric: float = 0.0   # defaults to wsp_parallel in __post_init__
    # Optional extras used by extensions (kept out of the paper-faithful path).
    n_experts: int = 0             # >0 marks a MoE layer -> EP partition legal
    active_experts: int = 0
    meta: dict = field(default_factory=dict, hash=False, compare=False)

    def __post_init__(self):
        if self.parallel_metric == 0.0:
            object.__setattr__(self, "parallel_metric", float(self.wsp_parallel))

    def scaled(self, batch: int) -> "LayerNode":
        """Per-sample -> per-microbatch scaling (weights are batch invariant)."""
        return replace(
            self,
            flops=self.flops * batch,
            in_bytes=self.in_bytes * batch,
            out_bytes=self.out_bytes * batch,
            halo_bytes=self.halo_bytes * batch,
            wsp_parallel=self.wsp_parallel * batch,
        )


@dataclass(frozen=True)
class LayerGraph:
    """A linearized network: an ordered chain of layers."""
    name: str
    layers: tuple[LayerNode, ...]

    def __len__(self) -> int:
        return len(self.layers)

    def __iter__(self):
        return iter(self.layers)

    def __getitem__(self, idx):
        if isinstance(idx, slice):
            return LayerGraph(self.name, tuple(self.layers[idx]))
        return self.layers[idx]

    @property
    def total_flops(self) -> float:
        return sum(l.flops for l in self.layers)

    @property
    def total_weight_bytes(self) -> float:
        return sum(l.weight_bytes for l in self.layers)

    def slice(self, lo: int, hi: int) -> "LayerGraph":
        return LayerGraph(f"{self.name}[{lo}:{hi}]", tuple(self.layers[lo:hi]))


def chain(name: str, layers: Sequence[LayerNode]) -> LayerGraph:
    return LayerGraph(name, tuple(layers))


# ---------------------------------------------------------------------------
# Cluster / schedule containers (Table I of the paper).
# ---------------------------------------------------------------------------

PARTITION_WSP = "WSP"
PARTITION_ISP = "ISP"
PARTITION_EP = "EP"            # beyond-paper: expert parallelism for MoE FFNs


@dataclass(frozen=True)
class ClusterAssignment:
    """``Cluster(i, j)`` with its region and per-layer partitions."""
    layer_lo: int                  # inclusive, global layer index
    layer_hi: int                  # exclusive
    region_chips: int              # ||Region(i, j)||
    partitions: tuple[str, ...]    # P(i, j, k) per layer, len == hi - lo
    chip_type: str | None = None   # hetero package flavor (None = base type)

    @property
    def n_layers(self) -> int:
        return self.layer_hi - self.layer_lo


@dataclass(frozen=True)
class SegmentSchedule:
    """One ``Segment(i)``: pipelined clusters over disjoint regions."""
    clusters: tuple[ClusterAssignment, ...]
    latency: float = 0.0           # seconds for the evaluation batch
    cluster_times: tuple[float, ...] = ()

    @property
    def n_clusters(self) -> int:
        return len(self.clusters)


@dataclass(frozen=True)
class ScopeSchedule:
    """Full system schedule: sequential segments (paper Eq. 1)."""
    workload: str
    chips: int
    segments: tuple[SegmentSchedule, ...]
    latency: float = 0.0
    meta: dict = field(default_factory=dict, hash=False, compare=False)

    @property
    def n_segments(self) -> int:
        return len(self.segments)

    def layer_partition(self) -> list[tuple[int, str, int]]:
        """Flat [(layer_idx, partition, region_chips)] over the whole net."""
        out = []
        for seg in self.segments:
            for cl in seg.clusters:
                for k, p in enumerate(cl.partitions):
                    out.append((cl.layer_lo + k, p, cl.region_chips))
        return out


# ---------------------------------------------------------------------------
# Multi-model co-scheduling containers (multimodel/ subsystem).
# ---------------------------------------------------------------------------

MM_PARTITIONED = "partitioned"     # per-model chip quotas, concurrent pipelines
MM_MERGED = "merged"               # one merged pipeline over concatenated graphs
MM_TIME_MUX = "time_mux"           # whole package time-multiplexed across models
MM_MODES = (MM_PARTITIONED, MM_MERGED, MM_TIME_MUX)


@dataclass(frozen=True)
class ModelAssignment:
    """One model's share of a co-scheduled package.

    ``samples_per_beat`` is this model's batch weighting inside a merged
    pipeline (1.0 elsewhere); ``time_share`` is its slice of a
    time-multiplexed package (1.0 elsewhere).  A quota drawn from a single
    flavor names it in ``chip_type``; a mixed-flavor quota (the model's
    pipeline spans flavors) itemizes per-flavor chips in ``chip_quota``
    with ``chips`` their total and ``chip_type`` None.
    """
    model: str                     # LayerGraph name
    weight: float                  # traffic weight (relative request rate)
    chips: int                     # chips dedicated (partitioned) or total (else)
    schedule: ScopeSchedule
    chip_type: str | None = None   # hetero flavor the quota is drawn from
    chip_quota: tuple[tuple[str | None, int], ...] = ()  # mixed-flavor quota
    samples_per_beat: float = 1.0
    time_share: float = 1.0

    @property
    def throughput(self) -> float:
        """Samples/s this assignment serves for its model."""
        lat = self.schedule.latency
        if lat <= 0 or lat == float("inf"):
            return 0.0
        m = self.schedule.meta.get("m_samples", 1)
        return self.time_share * m * self.samples_per_beat / lat


@dataclass(frozen=True)
class MultiModelSchedule:
    """A co-schedule of N models onto one (optionally heterogeneous) package.

    ``mix_rate`` is the sustainable rate of the *weighted mix unit*: the
    largest lambda such that every model i can serve ``lambda * weight_i``
    samples/s.  ``weighted_throughput = mix_rate * sum(weights)`` is the
    total samples/s at the traffic mix, the figure of merit reported by
    ``benchmarks/fig11_multimodel.py``.
    """
    package: str
    chips: int
    mode: str                      # one of MM_MODES
    assignments: tuple[ModelAssignment, ...]
    mix_rate: float = 0.0
    weighted_throughput: float = 0.0
    meta: dict = field(default_factory=dict, hash=False, compare=False)

    @property
    def n_models(self) -> int:
        return len(self.assignments)

    def assignment(self, model: str) -> ModelAssignment:
        for a in self.assignments:
            if a.model == model:
                return a
        raise KeyError(model)


def mix_rate(assignments) -> float:
    """lambda = min_i throughput_i / weight_i over a set of assignments."""
    return min(
        (a.throughput / a.weight if a.weight > 0 else float("inf"))
        for a in assignments
    )


def validate_multimodel(
    sched: MultiModelSchedule,
    graphs: dict[str, LayerGraph],
    type_capacity: dict[str | None, int],
) -> dict:
    """Invariants of a co-schedule.

    * every assignment's underlying ScopeSchedule is itself valid for its
      (merged-mode: shared) graph and chip budget -- including the seam
      accounting of :func:`validate_schedule`;
    * partitioned quotas are disjoint: per chip type, dedicated chips sum to
      at most the flavor's capacity (mixed-flavor quotas are itemized via
      ``chip_quota`` and accounted per flavor);
    * time-multiplexed shares sum to at most 1;
    * mix_rate / weighted_throughput are consistent with the assignments.

    Returns a report: ``{"seam_crossings": {model: total_crossings}}`` --
    how many cross-flavor seams each model's pipeline hands activations
    through (0 for every single-flavor assignment).
    """
    assert sched.mode in MM_MODES, sched.mode
    assert sched.assignments, "empty co-schedule"
    seam_by_model: dict[str, int] = {}
    for a in sched.assignments:
        assert a.weight > 0, f"{a.model}: non-positive traffic weight"
        assert a.chips >= 1
        if a.chip_quota:
            assert a.chip_type is None, (
                f"{a.model}: chip_type and chip_quota are mutually exclusive"
            )
            assert sum(c for _, c in a.chip_quota) == a.chips, (
                f"{a.model}: chip_quota {a.chip_quota} != chips {a.chips}"
            )
        # Keyed by the schedule's workload so merged-mode assignments (which
        # share one schedule over the concatenated graph) validate against
        # the merged graph, not the per-model one.
        graph = graphs[a.schedule.workload]
        caps = dict(a.chip_quota) if a.chip_quota else None
        report = validate_schedule(graph, a.schedule, a.chips, flavor_caps=caps)
        seam_by_model[a.model] = report["seam_crossings"]
    if sched.mode == MM_PARTITIONED:
        used: dict[str | None, int] = {}
        seen_schedules: set[tuple] = set()
        for a in sched.assignments:
            # Merged sub-groups: members share one ScopeSchedule *and* one
            # resource claim over one chip region, so each distinct
            # (schedule, claim)'s chips count once.
            key = (id(a.schedule), a.chip_type, a.chips,
                   tuple(a.chip_quota or ()))
            if key in seen_schedules:
                continue
            seen_schedules.add(key)
            if a.chip_quota:
                for ctype, c in a.chip_quota:
                    used[ctype] = used.get(ctype, 0) + c
            else:
                used[a.chip_type] = used.get(a.chip_type, 0) + a.chips
        for ctype, n in used.items():
            cap = type_capacity.get(ctype)
            assert cap is not None, f"unknown chip type {ctype!r}"
            assert n <= cap, f"type {ctype!r}: {n} chips used > {cap}"
    if sched.mode == MM_TIME_MUX:
        shares = sum(a.time_share for a in sched.assignments)
        assert shares <= 1.0 + 1e-9, f"time shares sum to {shares}"
    lam = mix_rate(sched.assignments)
    assert abs(lam - sched.mix_rate) <= 1e-9 * max(1.0, abs(lam)), (
        "mix_rate inconsistent", lam, sched.mix_rate,
    )
    total_w = sum(a.weight for a in sched.assignments)
    expect = lam * total_w
    assert abs(expect - sched.weighted_throughput) <= 1e-9 * max(1.0, expect)
    return {"seam_crossings": seam_by_model}


def validate_schedule(
    graph: LayerGraph,
    sched: ScopeSchedule,
    chips: int,
    flavor_caps: dict[str | None, int] | None = None,
) -> dict:
    """Invariants: contiguous cover of all layers; regions fit the package.

    ``flavor_caps`` (mixed-flavor schedules) additionally bounds each
    segment's per-flavor chip usage by that flavor's budget.

    Seam accounting (mixed-flavor pipelines): within a segment the clusters'
    chip flavors must form *contiguous runs* -- flavors occupy contiguous
    areas of the mesh, so a placement like big, little, big would tear the
    big region apart and cross the flavor seam twice where the link model
    (``HardwareModel.seam_link_bw``) charges it once.  Non-contiguous runs
    are rejected; the returned report counts the seam crossings:
    ``{"seam_crossings": total, "seam_crossings_per_segment": [...]}``.
    """
    cursor = 0
    seam_per_segment: list[int] = []
    for seg in sched.segments:
        used = 0
        by_type: dict[str | None, int] = {}
        flavor_runs: list[str | None] = []
        for cl in seg.clusters:
            assert cl.layer_lo == cursor, (cl.layer_lo, cursor)
            assert cl.layer_hi > cl.layer_lo
            assert len(cl.partitions) == cl.n_layers
            assert cl.region_chips >= 1
            used += cl.region_chips
            by_type[cl.chip_type] = by_type.get(cl.chip_type, 0) + cl.region_chips
            if not flavor_runs or flavor_runs[-1] != cl.chip_type:
                flavor_runs.append(cl.chip_type)
            cursor = cl.layer_hi
        assert used <= chips, f"segment uses {used} > {chips} chips"
        assert len(flavor_runs) == len(set(flavor_runs)), (
            f"non-contiguous flavor runs {flavor_runs}: a flavor's clusters "
            "must occupy one contiguous stretch of the pipeline"
        )
        seam_per_segment.append(max(0, len(flavor_runs) - 1))
        if flavor_caps is not None:
            for ctype, n in by_type.items():
                cap = flavor_caps.get(ctype)
                assert cap is not None, f"unknown chip type {ctype!r}"
                assert n <= cap, (
                    f"segment uses {n} chips of type {ctype!r} > {cap}"
                )
    assert cursor == len(graph), f"schedule covers {cursor}/{len(graph)} layers"
    return {
        "seam_crossings": sum(seam_per_segment),
        "seam_crossings_per_segment": seam_per_segment,
    }


def geomean(vals) -> float:
    vals = [max(v, 1e-30) for v in vals]
    return math.exp(sum(math.log(v) for v in vals) / len(vals))
