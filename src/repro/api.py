"""One front door for the Scope DSE: ``Problem -> solve() -> Solution``.

Three PRs of growth left the entry points sprawled across
``core.search`` (``search`` / ``search_mixed`` / ``exhaustive_search`` /
``random_search``), ``core.baselines`` (the paper's three comparison
schedulers), ``multimodel`` (``co_schedule``, quota/curve searches, the two
static baselines) and the runtime bridge (``plan_for_cell`` /
``plan_for_multimodel``), each with its own kwarg dialect.  This module is
the single declarative facade the benchmarks, CLI, examples and CI all go
through -- the same shape the multi-tenant DSE literature (SCAR, Odema et
al.) exposes: one scheduler front end over many underlying strategies.

The model::

    from repro import scope

    problem  = scope.problem("resnet50", "mcm64_hetero")
    solution = scope.solve(problem)          # auto-picks the strategy
    print(solution.latency, solution.strategy, solution.diagnostics["dse_s"])

* :class:`WorkloadSpec` -- one or N ``(LayerGraph, traffic_weight)`` models
  (CNN registry names, a ``"net:w,net:w"`` mix string, raw graphs, or LM
  configs via :meth:`WorkloadSpec.lm`).
* :class:`PackageSpec` -- a hardware preset name or a
  :class:`~repro.core.hw.HardwareModel`, plus optional per-flavor chip caps
  and seam-model overrides.
* :class:`SearchOptions` -- strategy selection and every search knob
  (``mode``, ``paper_strict``, quota ``step``, mixed/refine/switch-cost,
  engine choice) in one place, with the legacy defaults.
* :func:`solve` -- dispatches through the strategy registry
  (``scope``, ``scope-mixed``, ``coschedule``, ``exhaustive``, ``random``,
  the paper baselines, ``equal-split``, ``time-mux``), auto-selecting by
  problem shape: 1 model x 1 flavor -> ``scope``; 1 model x N flavors ->
  ``scope-mixed``; N models -> ``coschedule``.  Every sub-search of one
  ``solve`` shares a single :class:`~repro.core.fastcost.FastCostModel`
  memo.
* :class:`Solution` -- the unified result: the schedule(s), per-strategy
  diagnostics (``dse_s``, engine stats, candidates, seam crossings), and
  the :meth:`Solution.deploy` bridge into the runtime
  (``plan_for_cell`` / ``plan_for_multimodel`` -> :class:`Deployment` ->
  ``build_multimodel_steps``).

Every legacy entry point remains importable and bit-identical -- the
strategies here are thin delegating wrappers over them (see the mapping
table in README.md).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Any, Callable

from .core.baselines import (
    schedule_full_pipeline,
    schedule_segmented,
    schedule_sequential,
)
from .core.costmodel import INF, CostBreakdown, CostModel
from .core.fastcost import FastCostModel
from .core.graph import (
    MM_PARTITIONED,
    LayerGraph,
    ModelAssignment,
    MultiModelSchedule,
    ScopeSchedule,
    SegmentSchedule,
    mix_rate,
    validate_multimodel,
    validate_schedule,
)
from .core.hw import HardwareModel, get_hw, validate_region_types
from .core.regions import RegionMode
from .core.search import (
    build_clusters,
    exhaustive_search,
    random_search,
    search,
    search_mixed,
)
from .core.segments import candidate_segment_counts
from .core.workloads import get_cnn
from .multimodel.baselines import equal_split, time_multiplexed
from .multimodel.coschedule import co_schedule
from .multimodel.interleave import merged_graph
from .multimodel.quota import package_flavors
from .multimodel.spec import ModelSpec, parse_mix
from .obs import Tracer, current_tracer, use_tracer

__all__ = [
    "Deployment",
    "PackageSpec",
    "Problem",
    "SearchOptions",
    "Solution",
    "SolutionCache",
    "WorkloadSpec",
    "available_strategies",
    "problem",
    "problem_fingerprint",
    "register_strategy",
    "solve",
    "solve_many",
]


# ---------------------------------------------------------------------------
# Problem model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class WorkloadSpec:
    """What to schedule: one or N ``(LayerGraph, traffic_weight)`` models.

    ``cfgs``/``seq_len`` are carried when the workload was exported from LM
    :class:`~repro.models.config.ModelConfig` objects
    (:meth:`WorkloadSpec.lm`), so :meth:`Solution.deploy` can derive
    runtime ShardPlans without re-stating them.
    """
    models: tuple[ModelSpec, ...]
    cfgs: tuple = ()                 # optional ModelConfigs aligned to models
    seq_len: int | None = None
    phase: str = "prefill"           # LM graph phase: "prefill" | "decode"

    def __post_init__(self):
        if not self.models:
            raise ValueError("empty workload")
        names = [m.name for m in self.models]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate model names in workload: {names}")

    @property
    def n_models(self) -> int:
        return len(self.models)

    @property
    def graph(self) -> LayerGraph:
        if self.n_models != 1:
            raise ValueError(
                f"{self.n_models}-model workload has no single graph"
            )
        return self.models[0].graph

    # -------------------------------------------------------- constructors
    @classmethod
    def cnn(cls, name: str, weight: float = 1.0) -> "WorkloadSpec":
        """One CNN from the workload registry (``"resnet50"``...)."""
        return cls(models=(ModelSpec(get_cnn(name), weight),))

    @classmethod
    def mix(cls, mix: str) -> "WorkloadSpec":
        """A traffic mix string: ``"resnet50:2,alexnet:1"``."""
        return cls(models=tuple(parse_mix(mix)))

    @classmethod
    def graphs(cls, entries) -> "WorkloadSpec":
        """Raw ``LayerGraph`` | ``(LayerGraph, weight)`` | ``ModelSpec``."""
        models = []
        for e in entries:
            if isinstance(e, ModelSpec):
                models.append(e)
            elif isinstance(e, LayerGraph):
                models.append(ModelSpec(e, 1.0))
            else:
                g, w = e
                models.append(ModelSpec(g, w))
        return cls(models=tuple(models))

    @classmethod
    def lm(cls, cfgs, seq_len: int, weights=None, *,
           phase: str = "prefill",
           decode: bool | None = None) -> "WorkloadSpec":
        """LM configs -> exported layer graphs (``lm_graph``), keeping the
        configs attached for :meth:`Solution.deploy`.

        ``phase`` selects which per-phase graph to export: ``"prefill"``
        (the default, full-sequence attention FLOPs) or ``"decode"``
        (one-token KV-append costs).  ``decode=True/False`` is an alias
        that overrides ``phase``; graph names embed the phase
        (``name@decode128``), so fingerprints distinguish the two.
        """
        from .core.workloads.lm import lm_graph

        if decode is not None:
            phase = "decode" if decode else "prefill"
        if phase not in ("prefill", "decode"):
            raise ValueError(f"phase must be prefill|decode, got {phase!r}")
        cfgs = tuple(cfgs)
        weights = list(weights) if weights else [1.0] * len(cfgs)
        if len(weights) != len(cfgs):
            raise ValueError(f"{len(weights)} weights for {len(cfgs)} configs")
        models = tuple(
            ModelSpec(lm_graph(cfg, seq_len, decode=(phase == "decode")), w)
            for cfg, w in zip(cfgs, weights)
        )
        return cls(models=models, cfgs=cfgs, seq_len=seq_len, phase=phase)

    @classmethod
    def of(cls, workload) -> "WorkloadSpec":
        """Coerce: WorkloadSpec | graph(s) | ModelSpec(s) | name/mix string."""
        if isinstance(workload, cls):
            return workload
        if isinstance(workload, str):
            return cls.mix(workload)
        if isinstance(workload, (LayerGraph, ModelSpec)):
            return cls.graphs([workload])
        return cls.graphs(workload)


@dataclass(frozen=True)
class PackageSpec:
    """Where to schedule: a preset name or an explicit HardwareModel.

    ``flavor_caps`` restricts how many chips of each flavor a (mixed)
    search may use -- ``((flavor, chips), ...)`` partial budgets, the same
    convention as ``search_mixed(flavor_budgets=...)``.  ``seam_bw_scale``
    / ``seam_bw_overrides`` override the package's cross-flavor seam model
    without rebuilding the HardwareModel by hand.
    """
    preset: str | None = None
    hw: HardwareModel | None = None
    flavor_caps: tuple[tuple[str | None, int], ...] | None = None
    seam_bw_scale: float | None = None
    seam_bw_overrides: tuple[tuple[str, str, float], ...] | None = None

    def __post_init__(self):
        if (self.preset is None) == (self.hw is None):
            raise ValueError("specify exactly one of preset / hw")

    def resolve(self) -> HardwareModel:
        hw = self.hw if self.hw is not None else get_hw(self.preset)
        if self.seam_bw_scale is not None:
            hw = replace(hw, seam_bw_scale=self.seam_bw_scale)
        if self.seam_bw_overrides is not None:
            hw = replace(hw, seam_bw_overrides=tuple(self.seam_bw_overrides))
        validate_region_types(hw)
        return hw

    @classmethod
    def of(cls, package) -> "PackageSpec":
        if isinstance(package, cls):
            return package
        if isinstance(package, str):
            return cls(preset=package)
        if isinstance(package, HardwareModel):
            return cls(hw=package)
        raise TypeError(f"cannot interpret package spec: {package!r}")


@dataclass(frozen=True)
class SearchOptions:
    """Every search knob, with the legacy entry points' defaults."""
    strategy: str = "auto"
    mode: RegionMode | str = RegionMode.FREE
    m_samples: int = 16
    paper_strict: bool = False
    ep_for_moe: bool = False
    segment_counts: tuple[int, ...] | None = None
    max_clusters: int | None = None
    chip_type: str | None = None     # pin a single-flavor search to one flavor
    # multi-model / quota search
    step: int = 1
    mixed: bool = True               # spanning quotas / per-cluster flavors
    mixed_step: int | None = None
    refine: bool = False             # coarse-to-fine curves (1D and 2D)
    cut_window: int = 2
    include_merged: bool = True
    include_time_mux: bool = True
    switch_cost: bool = False
    switch_period_s: float = 1.0
    # token-level LLM serving (strategy "llm-phase"): expected decode
    # tokens per request, and the phase-deployment mode to search --
    # "auto" (best of both) | "disaggregated" | "colocated"
    output_tokens: float = 64.0
    phase_mode: str = "auto"
    # validation searches
    samples: int = 10_000
    seed: int = 0
    # evaluation engine: "fast" (FastCostModel, batched populations) |
    # "reference" (paper-literal CostModel) | "jit" (FastCostModel with the
    # jax-jitted batch kernel for population scoring)
    engine: str = "fast"
    distributed_weights: bool = True
    cost: Any = None                 # pre-built CostModel: shared memo across solves
    validate: bool = True
    # observability (repro.obs): Tracer instance | output path | True;
    # excluded from problem_fingerprint -- tracing never changes the answer
    trace: Any = None
    # warm start: a previous Solution (or bare ScopeSchedule /
    # MultiModelSchedule) for the same model set.  Narrows the search to a
    # window around the incumbent -- segment counts for single-model
    # strategies, per-model quota windows + family gating for coschedule --
    # so drift / fault re-solves are interactive.  Excluded from
    # problem_fingerprint: a warm re-solve is a local refinement the
    # SolutionCache treats as equivalent to the cold answer (exhaustiveness
    # is deliberately traded for latency).
    warm_start: Any = None

    @property
    def region_mode(self) -> RegionMode:
        if isinstance(self.mode, RegionMode):
            return self.mode
        return RegionMode(self.mode)

    def make_cost(self, hw: HardwareModel) -> CostModel:
        if self.cost is not None:
            return self.cost
        if self.engine == "jit":
            return FastCostModel(hw, m_samples=self.m_samples,
                                 distributed_weights=self.distributed_weights,
                                 use_jit=True)
        cls = {"fast": FastCostModel, "reference": CostModel}[self.engine]
        return cls(hw, m_samples=self.m_samples,
                   distributed_weights=self.distributed_weights)


@dataclass(frozen=True)
class Problem:
    """A declarative DSE problem: workload x package x options."""
    workload: WorkloadSpec
    package: PackageSpec
    options: SearchOptions = SearchOptions()

    def with_options(self, **overrides) -> "Problem":
        """Same problem, some SearchOptions fields overridden (e.g.
        ``prob.with_options(strategy="time-mux")``)."""
        return replace(self, options=replace(self.options, **overrides))


def problem(workload, package, options: SearchOptions | None = None,
            **opts) -> Problem:
    """Build a :class:`Problem` from loose pieces.

    ``workload``: WorkloadSpec | name/mix string | LayerGraph(s) | ModelSpec(s).
    ``package``: PackageSpec | preset name | HardwareModel.
    ``**opts``: SearchOptions field overrides (exclusive with ``options``).
    """
    if options is not None and opts:
        raise ValueError("pass options= or keyword overrides, not both")
    return Problem(
        workload=WorkloadSpec.of(workload),
        package=PackageSpec.of(package),
        options=options if options is not None else SearchOptions(**opts),
    )


# ---------------------------------------------------------------------------
# Solution / Deployment
# ---------------------------------------------------------------------------

@dataclass
class Solution:
    """Unified result of :func:`solve`.

    Exactly one of ``schedule`` (single-model strategies) / ``multi``
    (multi-model strategies) is set, except for sampling strategies
    (``random``) which only fill ``diagnostics``.  ``diagnostics`` always
    carries ``dse_s`` and ``engine_stats``; strategy-specific keys include
    ``mode_rates`` (coschedule), ``per_flavor``
    (scope on a heterogeneous package), ``population`` (random) and
    ``seam_crossings`` (filled by validation).
    """
    problem: Problem
    strategy: str
    hw: HardwareModel
    schedule: ScopeSchedule | None = None
    multi: MultiModelSchedule | None = None
    llm: Any = None                  # LLMPlan (strategy "llm-phase")
    diagnostics: dict = field(default_factory=dict)

    # ----------------------------------------------------------- accessors
    @property
    def feasible(self) -> bool:
        if self.llm is not None:
            return self.llm.mix_rate > 0
        if self.schedule is not None:
            return self.schedule.latency < INF
        if self.multi is not None:
            return self.multi.weighted_throughput > 0
        return False

    @property
    def latency(self) -> float:
        """End-to-end batch latency (single-model solutions)."""
        if self.schedule is None:
            raise ValueError(f"strategy {self.strategy!r} has no single schedule")
        return self.schedule.latency

    @property
    def throughput(self) -> float:
        """Samples/s (single-model: m / latency; multi-model: weighted)."""
        if self.llm is not None:
            return self.llm.token_rate
        if self.schedule is not None:
            lat = self.schedule.latency
            m = self.diagnostics.get("m_samples",
                                     self.problem.options.m_samples)
            return 0.0 if (lat <= 0 or lat == INF) else m / lat
        if self.multi is not None:
            return self.multi.weighted_throughput
        return 0.0

    @property
    def weighted_throughput(self) -> float:
        if self.multi is not None:
            return self.multi.weighted_throughput
        return self.throughput

    @property
    def n_segments(self) -> int | None:
        return len(self.schedule.segments) if self.schedule else None

    # ---------------------------------------------------------- validation
    def validate(self) -> dict:
        """Run the schedule validators; returns (and stashes) the seam
        report (``{"seam_crossings": ...}``, see ``validate_schedule``)."""
        flavors = dict(package_flavors(self.hw))
        report: dict = {}
        if self.multi is not None:
            graphs = {m.name: m.graph for m in self.problem.workload.models}
            if self.multi.mode == "merged":
                mg, _ = merged_graph(list(self.problem.workload.models))
                graphs[mg.name] = mg
            # Merged sub-groups (partitioned mode, meta "merge_groups")
            # share one schedule over a group-merged graph: rebuild each
            # group's graph so its assignments validate against it.
            by_name = {m.name: m for m in self.problem.workload.models}
            for group in self.multi.meta.get("merge_groups", ()):
                mg, _ = merged_graph([by_name[n] for n in group])
                graphs[mg.name] = mg
            report = validate_multimodel(self.multi, graphs, flavors)
        elif (self.schedule is not None and self.schedule.latency < INF
              and self.schedule.segments):
            # (the sequential baseline is segment-free: nothing to validate)
            caps = flavors if self.hw.region_types else None
            report = validate_schedule(
                self.problem.workload.graph, self.schedule,
                self.schedule.chips, flavor_caps=caps,
            )
        if "seam_crossings" in report:
            self.diagnostics["seam_crossings"] = report["seam_crossings"]
        return report

    # ------------------------------------------------------------- runtime
    def verify_reference(self, rtol: float = 1e-9) -> float:
        """Re-evaluate the winning schedule(s) on a fresh reference
        :class:`CostModel` and assert engine parity; returns the reference
        latency (single-model) or 0.0 (nothing to check)."""
        opts = self.problem.options
        ref = CostModel(self.hw, m_samples=opts.m_samples,
                        distributed_weights=opts.distributed_weights)
        total = 0.0
        scheds = []
        if self.schedule is not None and self.schedule.latency < INF:
            scheds.append((self.problem.workload.graph, self.schedule))
        if self.multi is not None:
            graphs = {m.name: m.graph for m in self.problem.workload.models}
            if self.multi.mode == "merged":
                mg, _ = merged_graph(list(self.problem.workload.models))
                graphs[mg.name] = mg
            for a in self.multi.assignments:
                scheds.append((graphs[a.schedule.workload], a.schedule))
        for graph, sched in scheds:
            lat = sum(
                ref.segment_time(graph, seg.clusters)[0]
                for seg in sched.segments
            )
            assert abs(lat - sched.latency) <= rtol * max(lat, 1e-30), (
                "engine parity violated", sched.workload, lat, sched.latency,
            )
            total += lat
        return total

    # ---------------------------------------------------------- attribution
    def explain(self) -> dict:
        """Cost attribution for the solved deployment (Scope Lens).

        Decomposes every stage/quota the solver priced -- single-model
        segments, multimodel assignments (merged groups included), LLM
        prefill/decode phase quotas -- into the additive
        :data:`~repro.core.costmodel.BREAKDOWN_COMPONENTS` (compute, NoP
        comm, seam crossing, DRAM weight load, input staging) with a
        bottleneck label per stage (compute- / link- / seam- / dram- /
        staging- / kv-bound).  The components of each stage sum
        *bit-identically* to the scalar the solver optimized
        (``schedule.latency`` per stage), on whichever engine the search
        used -- the conservation invariant the property tests assert.
        """
        opts = self.problem.options
        cost = replace(opts, cost=None).make_cost(self.hw)
        out: dict = {"strategy": self.strategy, "package": self.hw.name,
                     "chips": self.hw.chips, "stages": []}

        def stage_entry(label, graph, sched, *, chips, stage, model,
                        kv=None):
            seg_bds = []
            for seg in sched.segments:
                bd, per_cl = cost.segment_breakdown(graph, seg.clusters)
                seg_bds.append((bd, per_cl))
            total = sched.latency
            merged = CostBreakdown.merge([bd for bd, _ in seg_bds], total)
            bound = merged.bound
            if kv is not None and kv.get("kv_bound"):
                bound = "kv"
            entry = {
                "label": label, "model": model, "stage": stage,
                "chips": chips, "latency": total, "bound": bound,
                "breakdown": merged.to_json(),
                "conserved": merged.conserved,
                "segments": [
                    dict(bd.to_json(), clusters=[c.to_json() for c in cls_])
                    for bd, cls_ in seg_bds
                ],
            }
            if kv:
                entry["kv"] = kv
            out["stages"].append(entry)

        if self.llm is not None:
            from .core.workloads.lm import lm_graph

            plan = self.llm
            out["mode"] = plan.mode
            out["mix_rate"] = plan.mix_rate
            m = int(self.diagnostics.get("m_samples", opts.m_samples))
            for a in plan.assignments:
                gp = lm_graph(a.cfg, plan.seq_len)
                stage_entry(f"{a.model}/prefill", gp, a.prefill_schedule,
                            chips=a.prefill_chips, stage="prefill",
                            model=a.model)
                if a.decode_schedule is not None:
                    gd = lm_graph(a.cfg, plan.seq_len, decode=True)
                    kv = {
                        "kv_seq_bytes": a.kv_seq_bytes,
                        "kv_capacity_bytes": a.kv_capacity_bytes,
                        "max_seqs": a.max_seqs,
                        # the decode envelope flattened at the memory bound
                        # when the quota holds fewer sequences than the
                        # batch the compute bound would fill
                        "kv_bound": 0 <= a.max_seqs < m,
                    }
                    stage_entry(f"{a.model}/decode", gd, a.decode_schedule,
                                chips=a.decode_chips, stage="decode",
                                model=a.model, kv=kv)
        elif self.multi is not None:
            graphs = {mo.name: mo.graph for mo in self.problem.workload.models}
            if self.multi.mode == "merged":
                mg, _ = merged_graph(list(self.problem.workload.models))
                graphs[mg.name] = mg
            by_name = {mo.name: mo for mo in self.problem.workload.models}
            for group in self.multi.meta.get("merge_groups", ()):
                mg, _ = merged_graph([by_name[n] for n in group])
                graphs[mg.name] = mg
            out["mode"] = self.multi.mode
            for a in self.multi.assignments:
                quota = (dict(a.chip_quota) if a.chip_quota
                         else {a.chip_type: a.chips})
                stage_entry(a.model, graphs[a.schedule.workload], a.schedule,
                            chips=a.chips, stage="quota", model=a.model)
                out["stages"][-1]["quota"] = {str(k): v
                                              for k, v in quota.items()}
        elif self.schedule is not None and self.schedule.latency < INF:
            stage_entry(self.schedule.workload, self.problem.workload.graph,
                        self.schedule, chips=self.schedule.chips,
                        stage="schedule", model=self.schedule.workload)

        out["ranking"] = sorted(
            ({"label": s["label"], "bound": s["bound"],
              "latency": s["latency"]} for s in out["stages"]),
            key=lambda r: -r["latency"],
        )
        return out

    def deploy(
        self,
        cfgs=None,
        *,
        seq_len: int | None = None,
        global_batch: int = 8,
        mesh_axes: tuple[str, ...] = ("data", "model"),
        kind: str | None = None,
        step: int = 1,
        switch_cost: bool = False,
    ) -> "Deployment":
        """Bridge into the runtime: derive per-model ShardPlans.

        One config -> ``plan_for_cell``; N configs ->
        ``plan_for_multimodel`` (reusing this solution's co-schedule when
        its model names match, so solve-then-deploy never searches twice).
        ``cfgs``/``seq_len`` default to the ones the workload was built
        from (:meth:`WorkloadSpec.lm`).  ``kind`` defaults by workload
        phase: a decode-phase workload plans decode ShardPlans, anything
        else keeps the legacy ``"train"``.
        """
        from .runtime.planner import plan_for_cell, plan_for_multimodel

        if kind is None:
            kind = ("decode" if self.problem.workload.phase == "decode"
                    else "train")

        cfgs = tuple(cfgs) if cfgs is not None else self.problem.workload.cfgs
        if not cfgs:
            raise ValueError(
                "deploy needs ModelConfigs: pass cfgs= or build the workload "
                "with WorkloadSpec.lm(...)"
            )
        seq_len = seq_len or self.problem.workload.seq_len
        if seq_len is None:
            raise ValueError("deploy needs seq_len= (or WorkloadSpec.lm)")
        if len(cfgs) == 1:
            plan = plan_for_cell(
                cfgs[0], seq_len, global_batch, mesh_axes,
                model_axis=self.hw.chips, kind=kind,
            )
            return Deployment(cfgs=cfgs, plans={cfgs[0].name: plan},
                              multi=None, mesh_axes=mesh_axes)
        wl = self.problem.workload
        mm = self.multi
        # Only reuse the solved co-schedule when it was built from these
        # exact configs at this seq_len (lm-graph names embed both).  A
        # merged-mode schedule spans the *concatenated* graph and has no
        # per-model GSPMD execution path: let the planner re-search without
        # the merged family instead of deriving bogus per-model plans.
        if mm is not None and (
            mm.mode == "merged"
            or seq_len != wl.seq_len
            or len(wl.cfgs) != len(cfgs)
            or any(a.name != b.name for a, b in zip(wl.cfgs, cfgs))
        ):
            mm = None        # solution doesn't cover these configs: re-plan
        mm, plans = plan_for_multimodel(
            list(cfgs), seq_len, global_batch, mesh_axes,
            model_axis=self.hw.chips,
            weights=[m.weight for m in self.problem.workload.models],
            step=step, hw=self.hw, switch_cost=switch_cost, mm=mm,
        )
        return Deployment(cfgs=cfgs, plans=plans, multi=mm,
                          mesh_axes=mesh_axes)

    # -------------------------------------------------------------- serving
    def as_multimodel(self) -> MultiModelSchedule:
        """This solution as a co-schedule: ``multi`` when set, otherwise the
        single-model schedule wrapped as a one-assignment partitioned
        deployment (the serving executor's input shape)."""
        if self.multi is not None:
            return self.multi
        if self.schedule is None or not self.feasible:
            raise ValueError(f"[{self.strategy}] nothing deployable to serve")
        sched = self.schedule
        sched.meta.setdefault(
            "m_samples",
            self.diagnostics.get("m_samples", self.problem.options.m_samples),
        )
        # Concurrent per-flavor footprint: the max over segments (segments
        # run sequentially; clusters within one run concurrently).
        by_flavor: dict[str | None, int] = {}
        for seg in sched.segments:
            seg_use: dict[str | None, int] = {}
            for cl in seg.clusters:
                seg_use[cl.chip_type] = (
                    seg_use.get(cl.chip_type, 0) + cl.region_chips
                )
            for f, c in seg_use.items():
                by_flavor[f] = max(by_flavor.get(f, 0), c)
        order = [f for f, _ in package_flavors(self.hw)]
        quota = tuple(
            (f, by_flavor[f]) for f in order if by_flavor.get(f)
        )
        spec = self.problem.workload.models[0]
        a = ModelAssignment(
            model=sched.workload,
            weight=spec.weight,
            chips=sum(by_flavor.values()),
            schedule=sched,
            chip_type=quota[0][0] if len(quota) == 1 else None,
            chip_quota=quota if len(quota) > 1 else (),
        )
        lam = mix_rate((a,))
        return MultiModelSchedule(
            package=self.hw.name, chips=self.hw.chips, mode=MM_PARTITIONED,
            assignments=(a,), mix_rate=lam,
            weighted_throughput=lam * a.weight,
            meta={"wrapped_single_model": True},
        )

    def offered_traffic(
        self, rate_scale: float = 0.8, n_requests: int = 1000
    ) -> tuple[dict[str, float], float]:
        """The default offered load: per-model Poisson rates at
        ``rate_scale`` times the solved capacity (``mix_rate * weight``),
        with the horizon sized so ~``n_requests`` arrive.  Returns
        ``(traffic, horizon_s)`` -- the single source the CLI and the
        serving bench use to replay identical traces across deployments."""
        if self.llm is not None:
            traffic = {a.model: a.rate * rate_scale
                       for a in self.llm.assignments}
            total = sum(traffic.values())
            if total <= 0:
                raise ValueError(f"[{self.strategy}] zero solved capacity")
            return traffic, n_requests / total
        mm = self.as_multimodel()
        lam = mm.mix_rate * rate_scale
        traffic = {a.model: lam * a.weight for a in mm.assignments}
        total = sum(traffic.values())
        if total <= 0:
            raise ValueError(f"[{self.strategy}] zero solved capacity")
        return traffic, n_requests / total

    def serve(
        self,
        traffic=None,
        *,
        trace=None,
        n_requests: int = 1000,
        horizon_s: float | None = None,
        seed: int = 0,
        rate_scale: float = 0.8,
        max_batch: int | None = None,
        max_delay_s: float = 2e-3,
        max_queue: int | None = None,
        slos: dict[str, float] | None = None,
        autoscale=None,
        cache: "SolutionCache | None" = None,
        faults=None,
        fault_recovery: bool = True,
        measure: bool = False,
        mesh=None,
        seq_len: int = 16,
        tracer=None,
        # token-level serving (strategy "llm-phase" solutions only)
        plan=None,
        static_batching: bool = False,
        queue_policy: str = "fifo",
        lengths=None,
        ttft_slo=None,
        tpot_slo=None,
    ):
        """Run this solution under synthetic traffic
        (:class:`repro.serving.ServingExecutor`); returns a
        :class:`~repro.serving.ServingReport`.

        ``traffic`` maps model -> arrival process (or requests/s); default
        is per-model Poisson at ``rate_scale`` times the solved capacity
        (``mix_rate * weight``), sized so ~``n_requests`` arrive.  Pass a
        pre-built ``trace`` to serve the exact same arrivals across
        deployments (the benchmark's like-for-like comparison).
        ``max_batch`` defaults to the DSE batch, which makes a saturated
        simulated server reproduce the DSE throughput figure exactly.

        ``autoscale`` (an :class:`~repro.serving.AutoscalePolicy`, or
        ``True`` for defaults) turns on the online re-solve hook: observed
        mix drift re-plans through a shared :class:`SolutionCache`
        (``cache``), charging each redeploy as weight-reload dead time.
        ``measure=True`` calibrates service times from the real jitted
        steps (``deploy()`` + ``build_multimodel_steps`` on ``mesh``).

        ``faults`` injects chip/zone/seam failures: a
        :class:`~repro.serving.FaultInjector`, a list of
        :class:`~repro.serving.FaultEvent`, or a scenario string for
        :func:`~repro.serving.parse_faults` (``"zone:little@2:6"``).  With
        ``fault_recovery=True`` (the default) every failure and repair
        triggers a re-solve on the degraded package through the shared
        ``cache`` -- the dead-chip set is part of the problem fingerprint,
        so a repeat of the same failure is a whole-solution cache hit --
        and the executor swaps fleets charging redeploy dead time.
        ``fault_recovery=False`` runs the static-degraded baseline: down
        models just queue until their chips are repaired.

        ``tracer`` records the run on the Scope Observatory timeline
        (``trace=`` being taken by request traces): a
        :class:`~repro.obs.Tracer`, ``True`` (fresh tracer, returned as
        ``report.tracer``), or a path string (Chrome trace-event JSON,
        Perfetto-loadable, written there).  Server lanes become trace
        threads with per-batch spans, queue depths become counter series,
        and fault / kill / re-solve / recovery events land as instants on
        the same timeline; mid-run re-solves (autoscale or fault recovery)
        add their solver spans too.
        """
        if self.llm is not None or plan is not None:
            return self._serve_llm(
                traffic, trace=trace, n_requests=n_requests,
                horizon_s=horizon_s, seed=seed, rate_scale=rate_scale,
                max_batch=max_batch, max_delay_s=max_delay_s,
                max_queue=max_queue, queue_policy=queue_policy,
                plan=plan, static_batching=static_batching, lengths=lengths,
                ttft_slo=ttft_slo, tpot_slo=tpot_slo, tracer=tracer,
            )
        from .serving import (
            AutoscalePolicy,
            Autoscaler,
            BatchingPolicy,
            ServingExecutor,
            measure_service_models,
            parse_faults,
            request_trace,
        )

        mm = self.as_multimodel()
        hw = self.hw
        weights = {a.model: a.weight for a in mm.assignments}

        obs_tracer, obs_path = None, None
        if tracer is not None and tracer is not False:
            if isinstance(tracer, Tracer):
                obs_tracer = tracer
            elif isinstance(tracer, str):
                obs_tracer, obs_path = Tracer(), tracer
            elif tracer is True:
                obs_tracer = Tracer()
            else:
                raise TypeError(
                    f"tracer= takes a Tracer, True, or a path; got {tracer!r}")
        if traffic is not None and trace is not None:
            raise ValueError("pass traffic= or trace=, not both")
        if trace is None:
            if traffic is None:
                traffic, default_horizon = self.offered_traffic(
                    rate_scale, n_requests)
                if horizon_s is None:
                    horizon_s = default_horizon
            if horizon_s is None:
                total_rate = sum(
                    (spec if isinstance(spec, (int, float))
                     else getattr(spec, "mean_rate", 0.0))
                    for spec in traffic.values()
                )
                if total_rate <= 0:
                    raise ValueError(
                        "cannot derive a horizon from rate-free traffic: "
                        "pass horizon_s="
                    )
                horizon_s = n_requests / total_rate
            trace = request_trace(traffic, horizon_s, seed=seed)
        elif horizon_s is None:
            horizon_s = trace[-1].t_arrive if trace else 0.0

        if max_batch is None:
            max_batch = max(
                1, int(self.diagnostics.get("m_samples",
                                            self.problem.options.m_samples))
            )
        batching = BatchingPolicy(max_batch=max_batch,
                                  max_delay_s=max_delay_s,
                                  max_queue_samples=max_queue)
        if slos is None:
            slos = {
                m.name: m.slo_s for m in self.problem.workload.models
                if getattr(m, "slo_s", None)
            }
        reload_s = {
            m.name: m.graph.total_weight_bytes / hw.dram_bw_total
            for m in self.problem.workload.models
        }

        fault_resolver = None
        if faults is not None:
            if isinstance(faults, str):
                faults = parse_faults(faults, hw, horizon_s)
            if fault_recovery:
                cache = cache or SolutionCache()
                # The degraded re-solve rebuilds this problem on the
                # surviving package.  flavor_caps are dropped (they were
                # budgeted against the pristine flavors) and any
                # caller-supplied engine is stripped so the solve takes the
                # cached path -- the degraded HardwareModel (dead_chips
                # included) is the fingerprint that separates intact from
                # degraded solutions.
                # (trace is stripped too: a path-valued trace option would
                # make every degraded re-solve overwrite the trace file;
                # re-solve spans reach the serve tracer via the ambient
                # tracer stack instead)
                # The running deployment warm-starts the degraded re-solve:
                # it narrows the search around the incumbent allocation, so
                # recovery planning is interactive rather than a cold DSE.
                fr_opts = replace(self.problem.options, cost=None,
                                  trace=None, warm_start=mm)
                if mm.mode != "time_mux":
                    # keep the recovery fleet in the deployment's latency
                    # class: a time-mux winner-by-rate would trade
                    # slice-period queueing waves against SLOs the
                    # continuously-serving deployment was sized for
                    fr_opts = replace(fr_opts, include_time_mux=False)
                fr_base = replace(self.problem, options=fr_opts)

                def fault_resolver(hw_now):
                    prob2 = replace(fr_base, package=PackageSpec(hw=hw_now))
                    sol2 = cache.solve(prob2)
                    mm2 = None
                    if sol2.feasible:
                        mm2 = (sol2.multi if sol2.multi is not None
                               else sol2.as_multimodel())
                    return mm2, {
                        "hw": hw_now.name,
                        "chips": hw_now.chips,
                        "dead_chips": len(hw_now.dead_chips),
                        "feasible": sol2.feasible,
                        "dse_s": sol2.diagnostics.get("dse_s"),
                        "cache_hit": cache.last_hit,
                        "solve_cache": dict(cache.stats),
                    }

        autoscaler = None
        if autoscale:
            if self.multi is None or len(mm.assignments) < 2:
                raise ValueError("autoscale needs a multi-model deployment")
            policy = (autoscale if isinstance(autoscale, AutoscalePolicy)
                      else AutoscalePolicy())
            cache = cache or SolutionCache()
            base = self.problem

            def resolve_fn(new_weights: dict[str, float], hw=None):
                models = tuple(
                    replace(m, weight=new_weights[m.name])
                    for m in base.workload.models
                )
                prob = replace(base,
                               workload=replace(base.workload, models=models))
                if hw is not None:
                    # mid-failure drift re-solve: plan on the surviving
                    # package (degraded fingerprints stay cache-isolated,
                    # and the fleet keeps its latency class, see the
                    # fault_resolver above)
                    opts = replace(prob.options, cost=None, trace=None,
                                   warm_start=mm)
                    if mm.mode != "time_mux":
                        opts = replace(opts, include_time_mux=False)
                    prob = replace(prob, package=PackageSpec(hw=hw),
                                   options=opts)
                else:
                    # the incumbent deployment warm-starts the drift
                    # re-solve (quota windows around its allocation)
                    prob = replace(prob, options=replace(
                        prob.options, trace=None, warm_start=mm))
                sol = cache.solve(prob)
                info = {
                    "dse_s": sol.diagnostics.get("dse_s"),
                    "cache_hit": cache.last_hit,
                    "engine_stats": sol.diagnostics.get("engine_stats", {}),
                    "solve_cache": dict(cache.stats),
                }
                return (sol.multi, info)

            autoscaler = Autoscaler(policy, resolve_fn, weights)

        service_override = None
        if measure:
            dep = self.deploy()
            if mesh is None:
                import jax

                from .launch.mesh import make_mesh

                mesh = make_mesh((1, len(jax.devices())), ("data", "model"))
            service_override = measure_service_models(dep, mesh,
                                                      seq_len=seq_len)

        ex = ServingExecutor(
            mm, hw, batching=batching, slos=slos, autoscaler=autoscaler,
            service_override=service_override, reload_s=reload_s, seed=seed,
            faults=faults, fault_resolver=fault_resolver, tracer=obs_tracer,
        )
        if obs_tracer is not None:
            # mid-run re-solves (autoscale drift, fault recovery) go through
            # solve(), which picks up the ambient tracer: their solver spans
            # land on the same timeline as the executor's sim events
            with use_tracer(obs_tracer):
                report = ex.run(trace, horizon_s=horizon_s)
        else:
            report = ex.run(trace, horizon_s=horizon_s)
        report.meta.update(
            strategy=self.strategy,
            solved_mix_rate=mm.mix_rate,
            solved_weighted_throughput=mm.weighted_throughput,
        )
        if obs_tracer is not None:
            report.tracer = obs_tracer
            if obs_path:
                obs_tracer.write(obs_path)
                report.meta["trace_path"] = obs_path
        return report

    def _serve_llm(
        self,
        traffic=None,
        *,
        trace=None,
        n_requests: int = 1000,
        horizon_s: float | None = None,
        seed: int = 0,
        rate_scale: float = 0.8,
        max_batch: int | None = None,
        max_delay_s: float = 2e-3,
        max_queue: int | None = None,
        queue_policy: str = "fifo",
        plan=None,
        static_batching: bool = False,
        lengths=None,
        ttft_slo=None,
        tpot_slo=None,
        tracer=None,
    ):
        """Token-level serving path of :meth:`serve` (``llm-phase``
        solutions): replay a token trace through the
        :class:`~repro.serving.llm.TokenExecutor`.

        ``plan`` overrides the solved :class:`~repro.serving.llm.LLMPlan`
        (e.g. to replay the losing deployment mode from
        ``diagnostics["plans"]`` on the identical trace);
        ``static_batching=True`` runs the whole-request baseline;
        ``lengths`` is a :class:`~repro.serving.TokenLengths` (or per-model
        dict) for the prompt/output draws -- default matches the plan's
        searched ``seq_len`` / ``output_tokens``; ``ttft_slo`` / ``tpot_slo``
        are seconds (float for all models, or per-model dicts).  Returns an
        :class:`~repro.serving.LLMReport`.
        """
        from .serving import BatchingPolicy, TokenLengths, request_trace
        from .serving.llm import TokenExecutor

        plan = plan if plan is not None else self.llm
        if plan is None:
            raise ValueError(
                f"[{self.strategy}] no LLMPlan to serve: solve with "
                "strategy='llm-phase' or pass plan="
            )
        hw = self.hw

        obs_tracer, obs_path = None, None
        if tracer is not None and tracer is not False:
            if isinstance(tracer, Tracer):
                obs_tracer = tracer
            elif isinstance(tracer, str):
                obs_tracer, obs_path = Tracer(), tracer
            elif tracer is True:
                obs_tracer = Tracer()
            else:
                raise TypeError(
                    f"tracer= takes a Tracer, True, or a path; got {tracer!r}")

        if traffic is not None and trace is not None:
            raise ValueError("pass traffic= or trace=, not both")
        if trace is None:
            if traffic is None:
                traffic, default_horizon = self.offered_traffic(
                    rate_scale, n_requests)
                if horizon_s is None:
                    horizon_s = default_horizon
            if horizon_s is None:
                total_rate = sum(
                    (spec if isinstance(spec, (int, float))
                     else getattr(spec, "mean_rate", 0.0))
                    for spec in traffic.values()
                )
                if total_rate <= 0:
                    raise ValueError(
                        "cannot derive a horizon from rate-free traffic: "
                        "pass horizon_s="
                    )
                horizon_s = n_requests / total_rate
            if lengths is None:
                lengths = TokenLengths(
                    prompt_mean=float(plan.seq_len),
                    output_mean=float(plan.output_tokens),
                )
            trace = request_trace(traffic, horizon_s, seed=seed,
                                  lengths=lengths)
        elif horizon_s is None:
            horizon_s = trace[-1].t_arrive if trace else 0.0

        if max_batch is None:
            max_batch = max(1, int(plan.meta.get(
                "m_samples", self.problem.options.m_samples)))
        batching = BatchingPolicy(max_batch=max_batch,
                                  max_delay_s=max_delay_s,
                                  max_queue_samples=max_queue,
                                  queue_policy=queue_policy)

        def _slo_for(spec, model):
            if isinstance(spec, dict):
                return spec.get(model)
            return spec

        slos = {
            a.model: (_slo_for(ttft_slo, a.model), _slo_for(tpot_slo, a.model))
            for a in plan.assignments
        }
        ex = TokenExecutor(plan, hw, batching=batching, slos=slos,
                           static=static_batching, seed=seed,
                           tracer=obs_tracer)
        if obs_tracer is not None:
            with use_tracer(obs_tracer):
                report = ex.run(trace, horizon_s=horizon_s)
        else:
            report = ex.run(trace, horizon_s=horizon_s)
        report.meta.update(
            strategy=self.strategy,
            solved_mix_rate=plan.mix_rate,
            solved_token_rate=plan.token_rate,
        )
        if obs_tracer is not None:
            report.tracer = obs_tracer
            if obs_path:
                obs_tracer.write(obs_path)
                report.meta["trace_path"] = obs_path
        return report

    # ------------------------------------------------------------- display
    def describe(self) -> list[str]:
        """Human-readable summary lines (CLI / examples)."""
        lines = []
        if self.llm is not None:
            from .serving.llm import describe_llm

            lines += describe_llm(self.llm)
        elif self.multi is not None:
            from .multimodel.coschedule import describe as _describe_mm

            lines += _describe_mm(self.multi)
        elif self.schedule is not None and self.feasible:
            s = self.schedule
            lines.append(
                f"{s.workload} on {self.hw.name}: latency {s.latency:.6g}s, "
                f"{self.throughput:.1f} samples/s, "
                f"{len(s.segments)} segment(s) [{self.strategy}]"
            )
            for i, seg in enumerate(s.segments):
                for cl in seg.clusters:
                    flavor = f" type={cl.chip_type}" if cl.chip_type else ""
                    kinds = "/".join(sorted(set(cl.partitions)))
                    lines.append(
                        f"  seg{i} layers[{cl.layer_lo}:{cl.layer_hi}] "
                        f"region={cl.region_chips}{flavor} P={kinds}"
                    )
        else:
            lines.append(f"[{self.strategy}] infeasible on {self.hw.name}")
        if "dse_s" in self.diagnostics:
            lines.append(f"  searched in {self.diagnostics['dse_s']:.2f}s; "
                         f"engine {self.diagnostics.get('engine_stats', {})}")
        return lines

    def to_json(self) -> dict:
        """JSON-serializable summary (the CLI's ``--json`` payload)."""
        out = {
            "strategy": self.strategy,
            "hw": self.hw.name,
            "chips": self.hw.chips,
            "feasible": self.feasible,
            "dse_s": self.diagnostics.get("dse_s"),
            "engine_stats": self.diagnostics.get("engine_stats", {}),
        }
        for key in ("seam_crossings", "mode_rates"):
            if key in self.diagnostics:
                out[key] = self.diagnostics[key]
        if self.schedule is not None:
            out.update(
                latency_s=self.schedule.latency,
                throughput=self.throughput,
                n_segments=self.n_segments,
                clusters_per_segment=[
                    s.n_clusters for s in self.schedule.segments
                ],
            )
        if self.multi is not None:
            out.update(
                mode=self.multi.mode,
                mix_rate=self.multi.mix_rate,
                weighted_throughput=self.multi.weighted_throughput,
                assignments=[
                    {
                        "model": a.model, "weight": a.weight,
                        "chips": a.chips, "chip_type": a.chip_type,
                        "chip_quota": [[t, c] for t, c in a.chip_quota],
                        "throughput": a.throughput,
                        "time_share": a.time_share,
                        "samples_per_beat": a.samples_per_beat,
                    }
                    for a in self.multi.assignments
                ],
            )
        if self.llm is not None:
            p = self.llm
            out.update(
                mode=p.mode,
                mix_rate=p.mix_rate,
                token_rate=p.token_rate,
                seq_len=p.seq_len,
                output_tokens=p.output_tokens,
                handoff_bw=p.handoff_bw,
                assignments=[
                    {
                        "model": a.model, "weight": a.weight,
                        "prefill_chips": a.prefill_chips,
                        "decode_chips": a.decode_chips,
                        "rate": a.rate,
                        "max_seqs": a.max_seqs,
                        "kv_seq_bytes": a.kv_seq_bytes,
                        "kv_capacity_bytes": a.kv_capacity_bytes,
                    }
                    for a in p.assignments
                ],
            )
        if "population" in self.diagnostics:
            pop = self.diagnostics["population"]
            out["samples"] = len(pop)
            out["best_sampled_s"] = min(pop) if pop else None
        return out


@dataclass
class Deployment:
    """Runtime-facing view of a solution: per-model ShardPlans.

    ``build_steps`` jits the serving steps on a mesh
    (:func:`repro.runtime.serve.build_multimodel_steps`).
    """
    cfgs: tuple
    plans: dict
    multi: MultiModelSchedule | None
    mesh_axes: tuple[str, ...]

    def plan(self, name: str):
        return self.plans[name]

    def build_steps(self, mesh, batch: int | None = None,
                    max_len: int | None = None, with_decode: bool = True):
        from .runtime.serve import build_multimodel_steps

        return build_multimodel_steps(
            list(self.cfgs), mesh, self.plans,
            batch=batch, max_len=max_len, with_decode=with_decode,
        )


# ---------------------------------------------------------------------------
# Strategy registry
# ---------------------------------------------------------------------------

_STRATEGIES: dict[str, Callable[[Problem, HardwareModel, CostModel], Solution]] = {}


def register_strategy(name: str):
    """Register ``fn(problem, hw, cost) -> Solution`` under ``name``."""
    def deco(fn):
        _STRATEGIES[name] = fn
        return fn
    return deco


def available_strategies() -> list[str]:
    return sorted(_STRATEGIES)


def _lookup(name: str) -> tuple[str, Callable]:
    for cand in (name, name.replace("_", "-"), name.replace("-", "_")):
        if cand in _STRATEGIES:
            return cand, _STRATEGIES[cand]
    raise KeyError(
        f"unknown strategy {name!r}; available: {available_strategies()}"
    )


def _auto_strategy(prob: Problem, hw: HardwareModel) -> str:
    """1 model x 1 flavor -> scope; 1 model x N flavors -> scope-mixed;
    N models -> coschedule."""
    if prob.workload.n_models > 1:
        return "coschedule"
    if len(hw.region_types) > 1 and prob.options.mixed:
        return "scope-mixed"
    return "scope"


def _single_graph(prob: Problem, strategy: str) -> LayerGraph:
    if prob.workload.n_models != 1:
        raise ValueError(
            f"strategy {strategy!r} schedules a single model; this workload "
            f"has {prob.workload.n_models} (use strategy='coschedule')"
        )
    return prob.workload.graph


def _flavor_budgets(prob: Problem, hw: HardwareModel):
    if prob.package.flavor_caps is not None:
        return [list(t) for t in prob.package.flavor_caps]
    return None


def _warm_parts(o: SearchOptions):
    """Split ``options.warm_start`` into its (single-model, multi-model)
    incumbents: accepts a :class:`Solution` or a bare schedule of either
    kind; anything else warms nothing."""
    warm = o.warm_start
    if warm is None:
        return None, None
    if isinstance(warm, ScopeSchedule):
        return warm, None
    if isinstance(warm, MultiModelSchedule):
        return None, warm
    sched = getattr(warm, "schedule", None)
    multi = getattr(warm, "multi", None)
    return (sched if isinstance(sched, ScopeSchedule) else None,
            multi if isinstance(multi, MultiModelSchedule) else None)


def _warm_segment_counts(o: SearchOptions, g: LayerGraph,
                         hw: HardwareModel, chips: int):
    """Warm single-model sweep: restrict the segment-count sweep to within
    one of the incumbent schedule's count (the drifted problem's optimum is
    overwhelmingly at or adjacent to the incumbent's segmentation).  Returns
    None -- no restriction -- when there is no applicable warm start or the
    caller pinned ``segment_counts`` explicitly."""
    sched, _ = _warm_parts(o)
    if sched is None or o.segment_counts is not None:
        return None
    window = [
        s for s in candidate_segment_counts(g, hw, chips)
        if abs(s - sched.n_segments) <= 1
    ]
    return window or None


@register_strategy("scope")
def _solve_scope(prob: Problem, hw: HardwareModel, cost: CostModel) -> Solution:
    """Paper Algorithm 1 (``core.search.search``).  On a heterogeneous
    package: the best *single-flavor* schedule across flavors (pin one with
    ``options.chip_type``)."""
    g = _single_graph(prob, "scope")
    o = prob.options
    kw = dict(mode=o.region_mode, ep_for_moe=o.ep_for_moe,
              segment_counts=list(o.segment_counts) if o.segment_counts else None,
              max_clusters=o.max_clusters, paper_strict=o.paper_strict)
    diagnostics: dict = {}
    if not hw.region_types or o.chip_type is not None:
        chips = hw.chips if o.chip_type is None else hw.chip_type(o.chip_type).chips
        warm = _warm_segment_counts(o, g, hw, chips)
        if warm is not None:
            kw["segment_counts"] = warm
        sched = search(g, cost, chips, chip_type=o.chip_type, **kw)
    else:
        sched, per_flavor = None, {}
        budgets = _flavor_budgets(prob, hw) or package_flavors(hw)
        for ctype, cap in budgets:
            warm = _warm_segment_counts(o, g, hw, cap)
            if warm is not None:
                kw["segment_counts"] = warm
            s = search(g, cost, cap, chip_type=ctype, **kw)
            per_flavor[ctype] = s.latency if s is not None else INF
            if s is not None and (sched is None or s.latency < sched.latency):
                sched = s
        diagnostics["per_flavor"] = per_flavor
    return Solution(problem=prob, strategy="scope", hw=hw, schedule=sched,
                    diagnostics=diagnostics)


@register_strategy("scope-mixed")
def _solve_scope_mixed(prob: Problem, hw: HardwareModel,
                       cost: CostModel) -> Solution:
    """Mixed-flavor DSE (``core.search.search_mixed``): per-cluster chip
    flavors under per-flavor budgets; never worse than the best single
    flavor."""
    g = _single_graph(prob, "scope-mixed")
    o = prob.options
    counts = list(o.segment_counts) if o.segment_counts else None
    if counts is None:
        counts = _warm_segment_counts(o, g, hw, hw.chips)
    sched = search_mixed(
        g, cost, flavor_budgets=_flavor_budgets(prob, hw),
        mode=o.region_mode, ep_for_moe=o.ep_for_moe,
        segment_counts=counts,
        max_clusters=o.max_clusters, paper_strict=o.paper_strict,
        cut_window=o.cut_window,
    )
    return Solution(problem=prob, strategy="scope-mixed", hw=hw,
                    schedule=sched)


@register_strategy("coschedule")
def _solve_coschedule(prob: Problem, hw: HardwareModel,
                      cost: CostModel) -> Solution:
    """Multi-model co-scheduling (``multimodel.co_schedule``): best of
    partitioned / spanning / merged / time-mux for N >= 1 models."""
    o = prob.options
    _, warm_mm = _warm_parts(o)
    mm = co_schedule(
        list(prob.workload.models), hw, m_samples=o.m_samples, step=o.step,
        include_merged=o.include_merged, include_time_mux=o.include_time_mux,
        include_mixed=o.mixed, paper_strict=o.paper_strict, cost=cost,
        validate=False,                 # solve() validates and keeps the report
        curve_refine=o.refine, mixed_step=o.mixed_step,
        switch_cost=o.switch_cost, switch_period_s=o.switch_period_s,
        warm_start=warm_mm,
    )
    diagnostics: dict = {}
    if mm is not None:
        for key in ("mode_rates",):
            if key in mm.meta:
                diagnostics[key] = mm.meta[key]
    return Solution(problem=prob, strategy="coschedule", hw=hw, multi=mm,
                    diagnostics=diagnostics)


@register_strategy("sequential")
def _solve_sequential(prob, hw, cost) -> Solution:
    g = _single_graph(prob, "sequential")
    sched = schedule_sequential(g, cost, hw.chips)
    return Solution(problem=prob, strategy="sequential", hw=hw, schedule=sched)


@register_strategy("full_pipeline")
def _solve_full_pipeline(prob, hw, cost) -> Solution:
    g = _single_graph(prob, "full_pipeline")
    sched = schedule_full_pipeline(g, cost, hw.chips)
    return Solution(problem=prob, strategy="full_pipeline", hw=hw,
                    schedule=sched)


@register_strategy("segmented")
def _solve_segmented(prob, hw, cost) -> Solution:
    g = _single_graph(prob, "segmented")
    o = prob.options
    sched = schedule_segmented(
        g, cost, hw.chips,
        segment_counts=list(o.segment_counts) if o.segment_counts else None,
    )
    return Solution(problem=prob, strategy="segmented", hw=hw, schedule=sched)


@register_strategy("equal-split")
def _solve_equal_split(prob, hw, cost) -> Solution:
    mm = equal_split(list(prob.workload.models), cost)
    return Solution(problem=prob, strategy="equal-split", hw=hw, multi=mm)


@register_strategy("time-mux")
def _solve_time_mux(prob, hw, cost) -> Solution:
    o = prob.options
    mm = time_multiplexed(
        list(prob.workload.models), cost,
        switch_cost=o.switch_cost, switch_period_s=o.switch_period_s,
    )
    return Solution(problem=prob, strategy="time-mux", hw=hw, multi=mm)


@register_strategy("exhaustive")
def _solve_exhaustive(prob, hw, cost) -> Solution:
    """Brute force over one segment (``core.search.exhaustive_search``);
    tiny cases only -- the Fig. 8 optimality oracle."""
    g = _single_graph(prob, "exhaustive")
    lat, clustering, regions, partitions = next(
        exhaustive_search(cost, g, hw.chips)
    )
    sched = None
    if clustering is not None and lat < INF:
        clusters = build_clusters(0, clustering, partitions, list(regions))
        _, times = cost.segment_time(g, clusters)
        sched = ScopeSchedule(
            workload=g.name, chips=hw.chips,
            segments=(SegmentSchedule(clusters, lat, tuple(times)),),
            latency=lat, meta={"method": "exhaustive"},
        )
    return Solution(problem=prob, strategy="exhaustive", hw=hw,
                    schedule=sched)


@register_strategy("random")
def _solve_random(prob, hw, cost) -> Solution:
    """Uniform random sampling of the space (``core.search.random_search``);
    the population lands in ``diagnostics["population"]`` (Fig. 8
    histograms)."""
    g = _single_graph(prob, "random")
    o = prob.options
    pop = random_search(cost, g, hw.chips, samples=o.samples, seed=o.seed)
    return Solution(
        problem=prob, strategy="random", hw=hw,
        diagnostics={"population": pop,
                     "best_sampled_s": min(pop) if pop else INF},
    )


@register_strategy("llm-phase")
def _solve_llm_phase(prob: Problem, hw: HardwareModel,
                     cost: CostModel) -> Solution:
    """Token-level phase DSE (``serving.llm.solve_phases``): disaggregated
    vs colocated prefill/decode deployments over KV-bounded throughput
    curves.  Needs an LM workload (:meth:`WorkloadSpec.lm`): the decode
    graphs and KV footprints come from the attached ModelConfigs."""
    from .serving.llm import solve_phases

    wl = prob.workload
    if not wl.cfgs or wl.seq_len is None:
        raise ValueError(
            "strategy 'llm-phase' needs ModelConfigs: build the workload "
            "with WorkloadSpec.lm(...)"
        )
    o = prob.options
    plan, diag = solve_phases(
        list(wl.cfgs), [m.weight for m in wl.models], hw, cost,
        seq_len=wl.seq_len, output_tokens=o.output_tokens,
        mode=o.phase_mode, step=o.step, paper_strict=o.paper_strict,
        m_samples=o.m_samples,
    )
    return Solution(problem=prob, strategy="llm-phase", hw=hw, llm=plan,
                    diagnostics=diag)


# ---------------------------------------------------------------------------
# solve(): the front door
# ---------------------------------------------------------------------------

def solve(prob: Problem | None = None, *, workload=None, package=None,
          options: SearchOptions | None = None, **opts) -> Solution:
    """Solve a declarative Scope DSE problem.

    Either pass a :class:`Problem`, or the pieces::

        solve(problem("resnet50:2,alexnet:1", "mcm64", step=1))
        solve(workload="resnet50", package="mcm64_hetero", mode="uniform")

    Dispatches through the strategy registry (``options.strategy``;
    ``"auto"`` selects by problem shape), builds one shared evaluation
    engine for every sub-search, validates the result (seam accounting
    included) and stamps ``dse_s`` / ``engine_stats`` diagnostics.
    """
    if prob is None:
        if workload is None or package is None:
            raise ValueError("solve() needs a Problem or workload= + package=")
        prob = problem(workload, package, options=options, **opts)
    elif workload is not None or package is not None or options is not None or opts:
        raise ValueError("pass a Problem or loose pieces, not both")

    hw = prob.package.resolve()
    o = prob.options
    if o.cost is not None and o.cost.hw != hw:
        raise ValueError(
            f"options.cost was built for {o.cost.hw.name}, but this problem "
            f"resolves to {hw.name}: sharing the engine would evaluate "
            "against the wrong hardware"
        )
    cost = o.make_cost(hw)
    name = o.strategy
    if name in ("auto", "", None):
        name = _auto_strategy(prob, hw)
    name, fn = _lookup(name)

    tr, trace_path = _resolve_trace(o.trace)
    t0 = time.time()
    with use_tracer(tr):
        with tr.span(f"solve:{name}", strategy=name, hw=hw.name,
                     models=len(prob.workload.models)) as sp:
            sol = fn(prob, hw, cost)
            if sol.feasible and sol.schedule is not None:
                sp.set(latency=sol.schedule.latency)
    sol.strategy = name
    sol.diagnostics.setdefault("dse_s", time.time() - t0)
    sol.diagnostics.setdefault("m_samples", cost.m)
    sol.diagnostics.setdefault("engine_stats", dict(cost.stats))
    if tr:
        tr.metrics.counter("solve.calls").inc()
        tr.metrics.update_counters(sol.diagnostics["engine_stats"],
                                   prefix="engine.")
        if o.trace is not None:
            sol.diagnostics["trace"] = tr
        if trace_path:
            tr.write(trace_path)
    if o.validate and sol.feasible:
        sol.validate()
    return sol


def _resolve_trace(spec):
    """Map ``SearchOptions.trace`` to (tracer, output path).

    ``None``/falsy -> the ambient tracer (no-op unless a caller installed
    one via ``use_tracer``); a :class:`~repro.obs.Tracer` -> itself; a path
    string -> fresh tracer written there after the solve; ``True`` -> fresh
    tracer attached to ``diagnostics["trace"]``.
    """
    if isinstance(spec, Tracer):
        return spec, None
    if isinstance(spec, str):
        return Tracer(), spec
    if spec:
        return Tracer(), None
    return current_tracer(), None


# ---------------------------------------------------------------------------
# solve_many / SolutionCache: repeated solves sharing one engine memo
# ---------------------------------------------------------------------------

def _hw_fingerprint(hw: HardwareModel) -> HardwareModel:
    # HardwareModel is a frozen dataclass of scalars and tuples: the value
    # itself is the key, so no perf field can be forgotten from a summary.
    return hw


def problem_fingerprint(prob: Problem, hw: HardwareModel | None = None) -> tuple:
    """Hashable identity of a Problem's *solution*: workload graphs (by
    name/size/volume), traffic weights, the resolved hardware (the full
    frozen HardwareModel), flavor caps, and every result-affecting
    SearchOptions field.  Two problems with equal fingerprints solve to
    the same Solution, so :class:`SolutionCache` may return the cached
    one.  ``trace`` never changes the answer and ``warm_start`` only
    narrows the search around an incumbent (a warm re-solve is treated as
    equivalent to the cold answer), so both are deliberately excluded --
    repeated re-solves of the same drifted mix stay whole-solution hits
    regardless of which incumbent seeded them."""
    if hw is None:
        hw = prob.package.resolve()
    wl = prob.workload
    models = tuple(
        (m.name, round(m.weight, 9), len(m.graph),
         round(m.graph.total_flops, 3),
         round(m.graph.total_weight_bytes, 3),
         getattr(m, "slo_s", None))
        for m in wl.models
    )
    o = prob.options
    opts = (
        o.strategy, o.region_mode.value, o.m_samples, o.paper_strict,
        o.ep_for_moe,
        tuple(o.segment_counts) if o.segment_counts else None,
        o.max_clusters, o.chip_type,
        o.step, o.mixed, o.mixed_step, o.refine, o.cut_window,
        o.include_merged, o.include_time_mux, o.switch_cost,
        o.switch_period_s, o.output_tokens, o.phase_mode,
        o.samples, o.seed, o.engine,
        o.distributed_weights,
    )
    caps = (tuple(tuple(c) for c in prob.package.flavor_caps)
            if prob.package.flavor_caps is not None else None)
    return (models, wl.seq_len, _hw_fingerprint(hw), caps, opts)


class SolutionCache:
    """Memoized :func:`solve`: one shared evaluation engine per (hardware,
    engine-options) pair across *all* solves, plus a whole-``Solution``
    cache keyed by :func:`problem_fingerprint`.

    This is the serving autoscaler's solver (repeated re-solves of similar
    mixes are near-free: the engine memo carries cluster costs across
    mixes, and a mix seen before is a solution hit) and the backing store
    of :func:`solve_many`.  ``stats`` records the hit rates.
    """

    def __init__(self):
        self._engines: dict[tuple, CostModel] = {}
        self._solutions: dict[tuple, Solution] = {}
        self.hits = 0
        self.misses = 0
        self.last_hit = False

    def engine_for(self, prob: Problem, hw: HardwareModel) -> CostModel:
        o = prob.options
        if o.cost is not None:
            return o.cost
        key = (_hw_fingerprint(hw), o.engine, o.m_samples,
               o.distributed_weights)
        eng = self._engines.get(key)
        if eng is None:
            eng = self._engines[key] = o.make_cost(hw)
        return eng

    def solve(self, prob: Problem) -> Solution:
        if prob.options.cost is not None:
            # A caller-supplied engine is outside the declarative problem
            # identity the fingerprint captures: solve directly, uncached
            # (neither reusing nor poisoning default-engine entries).
            self.misses += 1
            self.last_hit = False
            return solve(prob)
        hw = prob.package.resolve()
        key = problem_fingerprint(prob, hw)
        sol = self._solutions.get(key)
        tr = current_tracer()
        if sol is not None:
            self.hits += 1
            self.last_hit = True
            tr.metrics.counter("solve_cache.hits").inc()
            tr.instant("solve-cache:hit", strategy=sol.strategy)
            return sol
        self.misses += 1
        self.last_hit = False
        tr.metrics.counter("solve_cache.misses").inc()
        cost = self.engine_for(prob, hw)
        tr.metrics.counter("solve_cache.engines").set(len(self._engines))
        sol = solve(replace(prob, options=replace(prob.options, cost=cost)))
        # Keep the caller's cost-free Problem as the solution's identity:
        # downstream re-solves derived from sol.problem (the autoscaler's
        # resolve_fn) must take the cached path, not the cost bypass above.
        sol.problem = prob
        sol.diagnostics["solve_cache"] = dict(self.stats)
        self._solutions[key] = sol
        return sol

    @property
    def stats(self) -> dict:
        return {
            "solution_hits": self.hits,
            "solution_misses": self.misses,
            "solutions": len(self._solutions),
            "engines": len(self._engines),
        }


def solve_many(
    problems, cache: SolutionCache | None = None
) -> list[Solution]:
    """Solve a batch of problems through one :class:`SolutionCache`: every
    sub-search of every problem shares one ``FastCostModel`` memo per
    hardware, and duplicate problems are whole-solution hits.  Each
    returned Solution's ``diagnostics["solve_cache"]`` snapshots the hit
    rates at its solve time."""
    cache = cache or SolutionCache()
    return [cache.solve(p) for p in problems]
