"""End-to-end behaviour tests: DSE -> plan -> train/serve on a real (1-device)
mesh, with checkpointed fault-tolerant training over the data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.workloads.lm import lm_graph
from repro.data import make_batch_iterator
from repro.ft import ResilientTrainer
from repro.launch.mesh import single_device_mesh
from repro.models import init_kv_cache, init_params
from repro.optim import make_optimizer
from repro.runtime.planner import plan_for_cell
from repro.runtime.serve import build_decode_step, build_prefill_step, greedy_generate
from repro.runtime.train import build_train_step


class TestPlanner:
    def test_plan_decode_is_isp(self):
        cfg = get_smoke_config("granite-3-8b")
        plan = plan_for_cell(cfg, 1024, 8, ("data", "model"), 16, kind="decode")
        assert plan.p1 == plan.p2 == "ISP"

    def test_plan_train_runs_dse(self):
        cfg = get_smoke_config("granite-3-8b")
        plan = plan_for_cell(cfg, 4096, 32, ("data", "model"), 16, kind="train")
        assert plan.meta.get("dse")
        assert plan.p1 in ("WSP", "ISP")

    @pytest.mark.parametrize("arch", ["granite-3-8b", "jamba-v0.1-52b", "rwkv6-3b"])
    def test_lm_graph_flops_match_param_count(self, arch):
        """Graph-export sanity: forward FLOPs ~ 2 * N_active * tokens."""
        from repro.configs import ARCHS

        cfg = ARCHS[arch]
        S = 2048
        g = lm_graph(cfg, S)
        expected = 2.0 * cfg.n_active_params * S
        # attention quadratic term and capacity overhead allow slack
        assert 0.7 * expected < g.total_flops < 2.0 * expected

    def test_lm_graph_weight_bytes_match(self):
        from repro.configs import ARCHS

        cfg = ARCHS["granite-3-8b"]
        g = lm_graph(cfg, 1024)
        expected = 2.0 * cfg.n_params           # bf16
        assert abs(g.total_weight_bytes - expected) / expected < 0.1


class TestEndToEnd:
    def test_train_ckpt_restart_serve(self, tmp_path):
        """The full story: plan -> jitted train steps -> injected failure ->
        restart from checkpoint -> greedy decoding from the trained params."""
        cfg = get_smoke_config("granite-3-8b")
        mesh = single_device_mesh()
        plan = plan_for_cell(cfg, 32, 8, ("data", "model"), 1, kind="train",
                             use_dse=False)
        step, _ = build_train_step(cfg, mesh, plan, base_lr=5e-3, warmup=5)
        params = init_params(cfg, jax.random.PRNGKey(0))
        init_fn, _u = make_optimizer(cfg.optimizer)
        opt = init_fn(params)

        it = make_batch_iterator(cfg, batch=8, seq=32, seed=0)
        batches = {}

        def batch_fn(s):
            while s not in batches:
                i, b = next(it)
                batches[i] = {k: jnp.asarray(v) for k, v in b.items()}
            return batches[s]

        def injector(s):
            if s == 12 and not getattr(injector, "fired", False):
                injector.fired = True
                raise RuntimeError("injected failure")

        trainer = ResilientTrainer(
            train_step=step, batch_fn=batch_fn, ckpt_dir=str(tmp_path),
            ckpt_every=5,
        )
        params, opt, hist = trainer.run(params, opt, n_steps=20,
                                        failure_injector=injector)
        losses = [h["loss"] for h in hist]
        assert losses[-1] < losses[0], losses   # learning the Markov chain
        assert getattr(injector, "fired", False)

        # serve from the trained params
        dstep, _ = build_decode_step(cfg, mesh, plan, batch=4, max_len=16)
        caches = init_kv_cache(cfg, 4, 16, jnp.float32)
        toks, _ = greedy_generate(
            cfg, params, dstep, caches,
            prompt_last_token=jnp.ones((4, 1), jnp.int32), start_pos=0, steps=4,
        )
        assert toks.shape == (4, 4)
        assert int(toks.max()) < cfg.padded_vocab

    def test_prefill_matches_forward(self):
        cfg = get_smoke_config("paligemma-3b")
        mesh = single_device_mesh()
        plan = plan_for_cell(cfg, 32, 4, ("data", "model"), 1, kind="prefill",
                             use_dse=False)
        pf, _ = build_prefill_step(cfg, mesh, plan)
        params = init_params(cfg, jax.random.PRNGKey(1))
        toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, cfg.vocab)
        emb = jax.random.normal(jax.random.PRNGKey(3), (2, cfg.frontend_tokens, cfg.d_model))
        logits = pf(params, toks, emb)
        assert logits.shape == (2, 12 + cfg.frontend_tokens, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
