"""The co-scheduler: best of {partitioned quotas, merged pipeline, time-mux}.

``co_schedule`` is the subsystem's entry point.  It searches the three
co-scheduling families over one shared FastCostModel (the cluster-cost memo
is what makes the joint sweep affordable -- engine stats land in the result
meta) and returns the best :class:`MultiModelSchedule` by weighted
throughput.  Time multiplexing is itself a legal co-schedule, so the result
is by construction at least as good as either fig11 baseline.
"""
from __future__ import annotations

import time

from ..core.costmodel import CostModel
from ..core.fastcost import FastCostModel
from ..core.graph import MultiModelSchedule, validate_multimodel
from ..core.hw import HardwareModel, validate_region_types
from ..obs import current_tracer
from .baselines import time_multiplexed
from .curves import build_curves
from .interleave import merged_graph, search_merged, search_merged_groups
from .quota import package_flavors, search_partitioned, search_partitioned_mixed
from .spec import ModelSpec


def _warm_fits(warm: MultiModelSchedule, flavors) -> bool:
    """Whether the incumbent's allocation still fits this package's flavor
    capacities.  A degraded re-solve (chips died under the incumbent) must
    re-open the full search -- anchoring quota windows to an allocation the
    surviving package cannot hold would steer the refinement into the dead
    zone's former capacity."""
    used: dict[str | None, int] = {}
    seen: set[tuple] = set()
    for a in warm.assignments:
        # merged groups share one schedule and one resource claim
        key = (id(a.schedule), a.chip_type, a.chips,
               tuple(a.chip_quota or ()))
        if key in seen:
            continue
        seen.add(key)
        if a.chip_quota:
            for t, q in a.chip_quota:
                used[t] = used.get(t, 0) + q
        else:
            used[a.chip_type] = used.get(a.chip_type, 0) + a.chips
    caps = dict(flavors)
    if warm.mode == "time_mux":         # whole-package time slices overlap
        return max(used.values(), default=0) <= max(caps.values(), default=0)
    return all(used.get(t, 0) <= cap for t, cap in caps.items()) and all(
        t in caps for t in used
    )


def co_schedule(
    specs: list[ModelSpec],
    hw: HardwareModel,
    m_samples: int = 16,
    step: int = 1,
    include_merged: bool = True,
    include_time_mux: bool = True,
    include_mixed: bool = True,
    paper_strict: bool = False,
    cost: CostModel | None = None,
    validate: bool = True,
    curve_refine: bool = False,
    mixed_step: int | None = None,
    switch_cost: bool = False,
    switch_period_s: float = 1.0,
    warm_start: MultiModelSchedule | None = None,
) -> MultiModelSchedule | None:
    """Jointly schedule ``specs`` onto one package.

    ``step`` coarsens the quota grid (1 = exhaustive; ``curve_refine``
    re-samples the coarse curves -- 1D *and* mixed F-dimensional -- around
    each argmax); ``cost`` lets callers supply a pre-warmed engine (its
    memo then carries over between calls).  On heterogeneous packages (any
    flavor count >= 2) ``include_mixed`` also searches quotas that span
    flavors -- one model's pipeline on big *and* little chips.
    ``switch_cost`` charges the time-mux mode for per-slice weight
    re-deployment (see ``baselines.time_multiplexed``).

    ``warm_start`` (an incumbent :class:`MultiModelSchedule` for the same
    model set -- e.g. the deployment a serving re-solve is drifting away
    from) turns the search into a local refinement: curves sample only a
    window around each model's incumbent chip count
    (:func:`~.curves.build_curves` ``windows``), and the expensive
    families the incumbent did not use (spanning quotas, merged
    pipelines) are skipped.  The result is a valid co-schedule found in a
    fraction of the cold solve's time, not a certificate of global
    optimality -- interactive re-solves trade exhaustiveness for latency.
    """
    validate_region_types(hw)
    names = [s.name for s in specs]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate model names in mix: {names}")
    if cost is None:
        cost = FastCostModel(hw, m_samples=m_samples)
    t0 = time.time()
    tr = current_tracer()
    flavors = package_flavors(hw)

    windows = None
    if warm_start is not None:
        inc = {a.model: a.chips for a in warm_start.assignments}
        if set(inc) == set(names) and _warm_fits(warm_start, flavors):
            windows = inc
            # Only re-search the families the incumbent landed in (plus
            # the always-cheap partitioned quotas and time-mux): the warm
            # re-solve's job is tracking a drifted mix, not re-opening
            # every scheduling dimension.
            merged_inc = (warm_start.mode == "merged"
                          or bool(warm_start.meta.get("merge_groups")))
            include_merged = include_merged and merged_inc
            include_mixed = include_mixed and any(
                a.chip_quota for a in warm_start.assignments
            )
    with tr.span("coschedule:curves", models=len(specs),
                 flavors=len(flavors), warm=windows is not None):
        curves = build_curves(specs, cost, flavors, step, paper_strict,
                              refine=curve_refine, windows=windows)

    candidates: list[tuple[str, MultiModelSchedule]] = []
    with tr.span("coschedule:partitioned"):
        part = search_partitioned(specs, cost, step, paper_strict,
                                  curves=curves)
    if part is not None:
        candidates.append((part.mode, part))
    if include_mixed and len(flavors) >= 2:
        with tr.span("coschedule:partitioned-mixed"):
            mixed = search_partitioned_mixed(
                specs, cost, step, paper_strict, curves=curves,
                mixed_step=mixed_step, mixed_refine=curve_refine,
            )
        if mixed is not None:
            candidates.append(("partitioned:mixed", mixed))
    if include_merged and len(specs) > 1:
        with tr.span("coschedule:merged", flavors=len(flavors)):
            for ctype, _cap in flavors:
                merged = search_merged(specs, cost, chip_type=ctype,
                                       paper_strict=paper_strict)
                if merged is not None:
                    label = f"{merged.mode}:{ctype}" if ctype else merged.mode
                    candidates.append((label, merged))
        # Between all-merged and fully-partitioned: merged sub-groups
        # sharing the package through the quota search (proper partitions
        # of the model set; gated to small N inside).
        with tr.span("coschedule:merged-groups"):
            grouped = search_merged_groups(
                specs, cost, step=step, paper_strict=paper_strict,
                curves=curves,
            )
        if grouped is not None:
            candidates.append(("partitioned:merged-groups", grouped))
    if include_time_mux:
        with tr.span("coschedule:time-mux"):
            tm = time_multiplexed(specs, cost, curves=curves,
                                  switch_cost=switch_cost,
                                  switch_period_s=switch_period_s)
        if tm is not None:
            candidates.append((tm.mode, tm))
    if not candidates:
        return None

    best = max(candidates, key=lambda c: c[1].weighted_throughput)[1]
    best.meta.update({
        "dse_s": time.time() - t0,
        "engine_stats": dict(cost.stats),
        "warm_start": windows is not None,
        "mode_rates": {
            label: c.weighted_throughput for label, c in candidates
        },
    })
    if validate:
        graphs = {s.name: s.graph for s in specs}
        if best.mode == "merged":
            mg, _ = merged_graph(specs)
            graphs[mg.name] = mg
        by_name = {s.name: s for s in specs}
        for group in best.meta.get("merge_groups", ()):
            # Merged sub-groups validate against their group's merged graph
            # (deterministic rebuild: merged_graph is a pure function of
            # the members and their default batch scales).
            mg, _ = merged_graph([by_name[m] for m in group])
            graphs[mg.name] = mg
        type_capacity = dict(flavors)
        validate_multimodel(best, graphs, type_capacity)
    return best


def describe(sched: MultiModelSchedule) -> list[str]:
    """Human-readable co-schedule summary (CLI / examples)."""
    lines = [
        f"{sched.package}: {sched.n_models} models, mode={sched.mode}, "
        f"mix rate {sched.mix_rate:.1f}/s, "
        f"weighted throughput {sched.weighted_throughput:.1f} samples/s"
    ]
    for a in sched.assignments:
        extras = []
        if a.chip_type:
            extras.append(f"type={a.chip_type}")
        if a.chip_quota:
            extras.append(
                "quota=" + "+".join(f"{c}x{t}" for t, c in a.chip_quota if c)
            )
        if a.samples_per_beat != 1.0:
            extras.append(f"{a.samples_per_beat:g} samples/beat")
        if a.time_share != 1.0:
            extras.append(f"{a.time_share * 100:.0f}% of time")
        lines.append(
            f"  {a.model:12s} w={a.weight:g}  {a.chips:4d} chips  "
            f"{a.throughput:9.1f} samples/s  {' '.join(extras)}"
        )
    return lines
