"""Token-level serving metrics: TTFT / TPOT percentiles, KV occupancy,
SLO-gated token goodput.

Whole-request latency is the wrong yardstick for autoregressive serving --
a request streaming 500 tokens is *supposed* to take long.  The LLM report
gates goodput on the two quantities users actually feel:

* **TTFT** -- time to first token (arrival to the end of the prefill pass,
  hand-off delay included on disaggregated deployments);
* **TPOT** -- time per output token, ``(t_last - t_first) / (n - 1)`` over
  the decode stream.

**Token goodput** counts the output tokens of completed requests that met
*both* SLOs, divided by the makespan.  KV occupancy is recorded as a
time-weighted :class:`~repro.obs.TimeSeries` per model (``kv_bytes/<m>``
in the registry) -- its peak must stay under the searched capacity bound,
which the benchmarks assert.  Conservation is strict at request
granularity: arrived == completed + dropped-by-cause + in-flight at end.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

from ...obs import MetricsRegistry, TimeSeries
from ..metrics import aggregate_waterfalls, percentile

__all__ = ["LLM_WATERFALL_COMPONENTS", "LLMModelMetrics", "LLMReport",
           "summarize_llm"]

#: Per-request latency waterfall for token-level serving, in causal order:
#: arrival -> prefill-batch start -> first token -> decode-eligible ->
#: pool admission -> last token.  Folding the components left-to-right
#: reproduces end-to-end latency bit-exactly (single-token requests stop
#: after ``prefill``).
LLM_WATERFALL_COMPONENTS = (
    "queue_wait", "prefill", "kv_handoff", "admission_wait", "decode")


@dataclass
class LLMModelMetrics:
    model: str
    chips: int                      # prefill + decode quota (shared once
    #                                 when colocated)
    arrived_requests: int = 0
    completed_requests: int = 0
    dropped_requests: int = 0
    drop_causes: dict = field(default_factory=dict)   # cause -> requests
    queued_end_requests: int = 0    # still in flight when the run ended
    prefill_batches: int = 0
    decode_steps: int = 0
    admitted_midbatch: int = 0      # sequences joining a running decode batch
    prompt_tokens: int = 0          # of completed requests
    output_tokens: int = 0
    token_throughput: float = 0.0   # output tokens / s
    token_goodput: float = 0.0      # SLO-gated output tokens / s
    ttft_mean_s: float = 0.0
    ttft_p50_s: float = 0.0
    ttft_p95_s: float = 0.0
    ttft_p99_s: float = 0.0
    tpot_mean_s: float = 0.0
    tpot_p50_s: float = 0.0
    tpot_p95_s: float = 0.0
    tpot_p99_s: float = 0.0
    ttft_slo_s: float | None = None
    tpot_slo_s: float | None = None
    slo_attainment: float = 1.0     # completed requests meeting both SLOs
    kv_peak_bytes: float = 0.0
    kv_mean_bytes: float = 0.0
    kv_capacity_bytes: float = 0.0

    def to_json(self) -> dict:
        return dict(self.__dict__)


@dataclass
class LLMReport:
    """One token-level serving run, aggregated."""
    mode: str                       # "disaggregated" | "colocated"
    batching: str                   # "continuous" | "static"
    package: str
    chips: int
    seed: int
    horizon_s: float
    makespan_s: float
    per_model: dict[str, LLMModelMetrics] = field(default_factory=dict)
    total_arrived: int = 0          # requests
    total_completed: int = 0
    total_dropped: int = 0
    total_queued_end: int = 0
    prompt_tokens: int = 0
    output_tokens: int = 0
    token_throughput: float = 0.0
    token_goodput: float = 0.0
    ttft_p95_s: float = 0.0
    tpot_p95_s: float = 0.0
    slo_attainment: float = 1.0
    admitted_midbatch: int = 0
    utilization: float = 0.0
    waterfalls: dict = field(default_factory=dict)  # model -> [per-request]
    meta: dict = field(default_factory=dict)
    metrics: Any = None             # MetricsRegistry
    tracer: Any = None

    @property
    def conserved(self) -> bool:
        """Strict request conservation with attributed drops."""
        if self.total_arrived != (self.total_completed + self.total_dropped
                                  + self.total_queued_end):
            return False
        for m in self.per_model.values():
            if m.arrived_requests != (m.completed_requests
                                      + m.dropped_requests
                                      + m.queued_end_requests):
                return False
            if sum(m.drop_causes.values()) != m.dropped_requests:
                return False
        return True

    def explain(self) -> dict:
        """Aggregate per-request waterfalls: where does TTFT+decode time go?"""
        return aggregate_waterfalls(self.waterfalls,
                                    order=LLM_WATERFALL_COMPONENTS)

    def to_json(self) -> dict:
        out = {k: v for k, v in self.__dict__.items()
               if k not in ("per_model", "meta", "metrics", "tracer",
                            "waterfalls")}
        out["conserved"] = self.conserved
        out["per_model"] = {m: mm.to_json() for m, mm in self.per_model.items()}
        out["meta"] = self.meta
        if self.waterfalls:
            out["explain"] = self.explain()
        return out

    def describe(self) -> list[str]:
        lines = [
            f"{self.package} [{self.mode}/{self.batching}] seed={self.seed}: "
            f"{self.total_completed}/{self.total_arrived} requests, "
            f"{self.output_tokens} tokens in {self.makespan_s:.3f}s -> "
            f"goodput {self.token_goodput:.1f} tok/s "
            f"(throughput {self.token_throughput:.1f}), "
            f"TTFT p95 {self.ttft_p95_s * 1e3:.1f}ms, "
            f"TPOT p95 {self.tpot_p95_s * 1e3:.2f}ms"
        ]
        for m in self.per_model.values():
            kv = (f"  KV peak {m.kv_peak_bytes / 2**20:.1f}/"
                  f"{m.kv_capacity_bytes / 2**20:.0f} MiB"
                  if m.kv_capacity_bytes else "")
            lines.append(
                f"  {m.model:20s} {m.chips:3d} chips  "
                f"{m.completed_requests:5d} done  "
                f"{m.token_goodput:8.1f} tok/s  "
                f"TTFT p95 {m.ttft_p95_s * 1e3:7.1f}ms  "
                f"TPOT p95 {m.tpot_p95_s * 1e3:6.2f}ms  "
                f"slo {m.slo_attainment:.0%}  midbatch {m.admitted_midbatch}"
                f"{kv}"
            )
        return lines


def summarize_llm(
    *,
    mode: str,
    batching: str,
    package: str,
    chips: int,
    seed: int,
    horizon_s: float,
    makespan_s: float,
    arrived: dict[str, int],
    dropped: dict[str, dict[str, int]],            # model -> cause -> requests
    queued_end: dict[str, int],
    completions: dict[str, list[tuple]],           # (ttft, tpot|None, prompt, out)
    slos: dict[str, tuple[float | None, float | None]],
    model_chips: dict[str, int],
    prefill_batches: dict[str, int],
    decode_steps: dict[str, int],
    admitted_midbatch: dict[str, int],
    kv_traces: dict[str, list[tuple[float, float]]],
    kv_capacity: dict[str, float],
    busy_chip_s: dict[str, float],
    queue_traces: dict[str, list[tuple[float, float]]] | None = None,
    waterfalls: dict[str, list[dict]] | None = None,
    meta: dict | None = None,
) -> LLMReport:
    span = max(makespan_s, 1e-12)
    registry = MetricsRegistry()
    rep = LLMReport(mode=mode, batching=batching, package=package,
                    chips=chips, seed=seed, horizon_s=horizon_s,
                    makespan_s=makespan_s, waterfalls=waterfalls or {},
                    meta=meta or {}, metrics=registry)
    all_ttft: list[float] = []
    all_tpot: list[float] = []
    good_tokens = 0
    met_total = done_total = 0
    busy_total = 0.0
    for model in sorted(arrived):
        recs = completions.get(model, [])
        ttfts = sorted(r[0] for r in recs)
        tpots = sorted(r[1] for r in recs if r[1] is not None)
        ttft_slo, tpot_slo = slos.get(model, (None, None))
        good = met = 0
        for ttft, tpot, _, out in recs:
            ok = (ttft_slo is None or ttft <= ttft_slo) and (
                tpot_slo is None or tpot is None or tpot <= tpot_slo)
            if ok:
                met += 1
                good += out
        causes = dropped.get(model, {})
        out_tokens = sum(r[3] for r in recs)
        kv = registry.series[f"kv_bytes/{model}"] = TimeSeries()
        kv.extend(kv_traces.get(model, []))
        if queue_traces and queue_traces.get(model):
            qs = registry.series[f"queue_depth/{model}"] = TimeSeries()
            qs.extend(queue_traces[model])
        registry.histogram(f"ttft_s/{model}").values.extend(ttfts)
        registry.histogram(f"tpot_s/{model}").values.extend(tpots)
        registry.counter(f"llm.admitted_midbatch/{model}").set(
            admitted_midbatch.get(model, 0))
        mm = LLMModelMetrics(
            model=model, chips=model_chips.get(model, 0),
            arrived_requests=arrived[model],
            completed_requests=len(recs),
            dropped_requests=sum(causes.values()),
            drop_causes=dict(causes),
            queued_end_requests=queued_end.get(model, 0),
            prefill_batches=prefill_batches.get(model, 0),
            decode_steps=decode_steps.get(model, 0),
            admitted_midbatch=admitted_midbatch.get(model, 0),
            prompt_tokens=sum(r[2] for r in recs),
            output_tokens=out_tokens,
            token_throughput=out_tokens / span,
            token_goodput=good / span,
            ttft_mean_s=sum(ttfts) / len(ttfts) if ttfts else 0.0,
            ttft_p50_s=percentile(ttfts, 50),
            ttft_p95_s=percentile(ttfts, 95),
            ttft_p99_s=percentile(ttfts, 99),
            tpot_mean_s=sum(tpots) / len(tpots) if tpots else 0.0,
            tpot_p50_s=percentile(tpots, 50),
            tpot_p95_s=percentile(tpots, 95),
            tpot_p99_s=percentile(tpots, 99),
            ttft_slo_s=ttft_slo, tpot_slo_s=tpot_slo,
            slo_attainment=met / len(recs) if recs else 1.0,
            kv_peak_bytes=kv.max,
            kv_mean_bytes=kv.mean(makespan_s),
            kv_capacity_bytes=kv_capacity.get(model, 0.0),
        )
        rep.per_model[model] = mm
        rep.total_arrived += mm.arrived_requests
        rep.total_completed += mm.completed_requests
        rep.total_dropped += mm.dropped_requests
        rep.total_queued_end += mm.queued_end_requests
        rep.prompt_tokens += mm.prompt_tokens
        rep.output_tokens += mm.output_tokens
        rep.admitted_midbatch += mm.admitted_midbatch
        all_ttft.extend(ttfts)
        all_tpot.extend(tpots)
        good_tokens += good
        met_total += met
        done_total += len(recs)
        busy_total += busy_chip_s.get(model, 0.0)
    registry.counter("llm.admitted_midbatch").set(rep.admitted_midbatch)
    all_ttft.sort()
    all_tpot.sort()
    rep.token_throughput = rep.output_tokens / span
    rep.token_goodput = good_tokens / span
    rep.ttft_p95_s = percentile(all_ttft, 95)
    rep.tpot_p95_s = percentile(all_tpot, 95)
    rep.slo_attainment = met_total / done_total if done_total else 1.0
    rep.utilization = busy_total / (max(1, chips) * span)
    return rep
