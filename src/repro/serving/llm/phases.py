"""Phase DSE: disaggregated vs colocated prefill/decode deployments.

An autoregressive request is two workloads with opposite shapes: prefill is
one compute-dense pass over the prompt (the ``lm_graph(cfg, S)`` the facade
already schedules), decode is ``n_out - 1`` latency-bound single-token
passes against a growing KV cache (``lm_graph(cfg, S, decode=True)``).  The
phase DSE schedules both graphs per model and searches two deployments:

* **disaggregated** -- separate prefill and decode quotas per model (2N
  quotas through the min-rate allocator), with the prompt's KV cache handed
  off over the mesh boundary between them, charged like PR 2's model-
  boundary staging: a rate cap of ``handoff_bw / kv_prompt_bytes`` on the
  whole mix plus a per-request latency the executor adds to TTFT.
* **colocated** -- one quota per model; prefill batches and decode steps
  interleave on the same server (no hand-off, but the phases steal beats
  from each other at serve time).

Decode quotas use KV-bounded curves (:func:`~repro.multimodel.curves.
kv_bound_curve`): where the quota's KV budget holds fewer than ``m``
sequences, its curve flattens at the memory bound instead of the compute
bound, so the allocator sees memory starvation directly.

Rates are *mix rates* in the PR 2 sense: ``r`` such that model ``i``
receives ``r * weight_i`` requests/s, each costing one prefill sample and
``output_tokens - 1`` decode samples (the first token is produced by the
prefill pass itself).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field

from ...core.costmodel import CostModel
from ...core.fastcost import FastCostModel
from ...core.graph import ScopeSchedule
from ...core.hw import HardwareModel
from ...core.workloads.lm import lm_graph
from ...models.config import ModelConfig
from ...multimodel.curves import kv_bound_curve, throughput_curve
from ...multimodel.quota import package_flavors
from ...obs import current_tracer
from .kv import UNBOUNDED, kv_seq_bytes


@dataclass
class PhaseAssignment:
    """One model's slice of an :class:`LLMPlan`."""
    model: str                     # config name (traffic key)
    weight: float
    cfg: ModelConfig
    prefill_chips: int
    decode_chips: int              # colocated: == prefill_chips (one server)
    prefill_schedule: ScopeSchedule
    decode_schedule: ScopeSchedule | None   # None when output_tokens <= 1
    kv_seq_bytes: float            # resident state/seq at full context
    kv_capacity_bytes: float       # the searched bound (decode quota memory)
    max_seqs: int                  # floor(capacity / kv_seq_bytes)
    rate: float                    # requests/s this model sustains at the mix


@dataclass
class LLMPlan:
    """A solved phase deployment -- the token executor's input."""
    package: str
    chips: int
    mode: str                      # "disaggregated" | "colocated"
    chip_type: str | None
    seq_len: int
    output_tokens: float
    assignments: list[PhaseAssignment]
    mix_rate: float                # requests/s per unit of mix weight
    handoff_bw: float              # bytes/s for prefill->decode KV transfer
    meta: dict = field(default_factory=dict)

    @property
    def used_chips(self) -> int:
        if self.mode == "colocated":
            return sum(a.prefill_chips for a in self.assignments)
        return sum(a.prefill_chips + a.decode_chips for a in self.assignments)

    @property
    def token_rate(self) -> float:
        """Output tokens/s of the whole mix at the sustainable rate."""
        return self.mix_rate * sum(
            a.weight * self.output_tokens for a in self.assignments
        )


def _allocate(tables: list[list[float]], chips: int) -> tuple[float, list[int]]:
    """Split ``chips`` among items maximizing the *minimum* per-item rate.

    ``tables[i][q]`` is item ``i``'s rate when granted ``q`` chips (a
    monotone envelope lookup, so non-decreasing in ``q``).  Classic minimax
    allocation DP, O(items * chips^2) -- cheap at package scale.
    """
    n = len(tables)
    nxt = [math.inf] * (chips + 1)
    choice = [[0] * (chips + 1) for _ in range(n)]
    for i in range(n - 1, -1, -1):
        cur = [0.0] * (chips + 1)
        t = tables[i]
        for c in range(chips + 1):
            best, best_q = -1.0, 0
            for q in range(c + 1):
                v = min(t[q], nxt[c - q])
                if v > best:
                    best, best_q = v, q
            cur[c] = best
            choice[i][c] = best_q
        nxt = cur
    quotas, c = [], chips
    for i in range(n):
        q = choice[i][c]
        quotas.append(q)
        c -= q
    return nxt[chips], quotas


def solve_phases(
    cfgs: list[ModelConfig],
    weights: list[float],
    hw: HardwareModel,
    cost: CostModel | None = None,
    *,
    seq_len: int,
    output_tokens: float = 64.0,
    mode: str = "auto",
    step: int = 1,
    paper_strict: bool = False,
    m_samples: int = 16,
) -> tuple[LLMPlan | None, dict]:
    """Search phase deployments for an LLM mix; returns ``(plan, diag)``.

    ``mode`` picks the family ("disaggregated" / "colocated") or lets the
    search choose ("auto").  ``diag["plans"]`` carries *both* solved plans
    so callers (benchmarks, CLI baselines) can replay the loser on the
    same trace.
    """
    if mode not in ("auto", "disaggregated", "colocated"):
        raise ValueError(f"unknown phase mode {mode!r}")
    if len(cfgs) != len(weights) or not cfgs:
        raise ValueError("cfgs and weights must align and be non-empty")
    if cost is None:
        cost = FastCostModel(hw, m_samples=m_samples)
    t0 = time.time()
    tr = current_tracer()
    # Phase quotas live in one flavor pool (the largest on hetero packages);
    # spanning quotas for LLM phases are future work.
    ctype, cap = max(package_flavors(hw), key=lambda f: f[1])

    n_d = max(0.0, output_tokens - 1.0)    # decode tokens per request
    env_p: dict[str, list] = {}
    env_d: dict[str, list] = {}
    seq_bytes: dict[str, float] = {}
    prompt_bytes: dict[str, float] = {}
    full_ctx = seq_len + int(math.ceil(output_tokens))
    for cfg in cfgs:
        with tr.span("llm:curves", model=cfg.name, chips=cap):
            cp = throughput_curve(cost, lm_graph(cfg, seq_len), cap,
                                  ctype, step, paper_strict)
            cd = throughput_curve(cost, lm_graph(cfg, seq_len, decode=True),
                                  cap, ctype, step, paper_strict)
        sb = kv_seq_bytes(cfg, full_ctx)
        seq_bytes[cfg.name] = sb
        prompt_bytes[cfg.name] = kv_seq_bytes(cfg, seq_len)
        env_p[cfg.name] = cp.envelope(cap)
        env_d[cfg.name] = kv_bound_curve(
            cd, sb, hw.kv_bytes_per_chip).envelope(cap)

    def p_rate(name: str, q: int, w: float) -> float:
        pt = env_p[name][q] if q else None
        return pt.throughput / w if pt else 0.0

    def d_rate(name: str, q: int, w: float) -> float:
        if n_d <= 0:
            return math.inf
        pt = env_d[name][q] if q else None
        return pt.throughput / (w * n_d) if pt else 0.0

    # The KV hand-off crosses the quota boundary like a model seam: budget
    # one mesh cut of flavor links, shared by the whole mix.
    handoff_bw = hw.flavor_link_bw(ctype) * min(hw.mesh_shape)

    # ---- disaggregated: 2N quotas through the min-rate allocator --------
    tables, items = [], []
    for cfg, w in zip(cfgs, weights):
        tables.append([p_rate(cfg.name, q, w) for q in range(cap + 1)])
        items.append((cfg.name, "prefill"))
        if n_d > 0:
            tables.append([d_rate(cfg.name, q, w) for q in range(cap + 1)])
            items.append((cfg.name, "decode"))
    r_disagg, quotas = _allocate(tables, cap)
    kv_flux = sum(w * prompt_bytes[c.name] for c, w in zip(cfgs, weights))
    handoff_cap = handoff_bw / kv_flux if kv_flux > 0 else math.inf
    r_disagg = min(r_disagg, handoff_cap)
    disagg_q = {}
    for (name, phase), q in zip(items, quotas):
        disagg_q.setdefault(name, {})[phase] = q

    # ---- colocated: one quota per model, phases share the server --------
    tables = []
    for cfg, w in zip(cfgs, weights):
        row = []
        for q in range(cap + 1):
            rp, rd = p_rate(cfg.name, q, w), d_rate(cfg.name, q, w)
            row.append(0.0 if not (rp and rd)
                       else 1.0 / (1.0 / rp + (1.0 / rd if rd < math.inf else 0.0)))
        tables.append(row)
    r_coloc, quotas_c = _allocate(tables, cap)
    coloc_q = {cfg.name: q for cfg, q in zip(cfgs, quotas_c)}

    def build(mode_: str, rate: float) -> LLMPlan | None:
        if rate <= 0:
            return None
        assignments = []
        for cfg, w in zip(cfgs, weights):
            name, sb = cfg.name, seq_bytes[cfg.name]
            if mode_ == "disaggregated":
                qp = disagg_q[name].get("prefill", 0)
                qd = disagg_q[name].get("decode", 0)
                pp = env_p[name][qp] if qp else None
                pd = env_d[name][qd] if qd else None
            else:
                q = coloc_q[name]
                pp = env_p[name][q] if q else None
                pd = env_d[name][q] if q else None
            if pp is None or (n_d > 0 and pd is None):
                return None
            if mode_ == "colocated":
                # one physical quota sized for the hungrier phase
                chips = max(pp.chips, pd.chips if pd else 0)
                pchips = dchips = chips
            else:
                pchips = pp.chips
                dchips = pd.chips if pd else 0
            kv_cap = hw.kv_bytes_per_chip * dchips
            assignments.append(PhaseAssignment(
                model=name, weight=w, cfg=cfg,
                prefill_chips=pchips, decode_chips=dchips,
                prefill_schedule=pp.schedule,
                decode_schedule=pd.schedule if pd else None,
                kv_seq_bytes=sb,
                kv_capacity_bytes=kv_cap,
                max_seqs=(int(kv_cap // sb) if sb > 0 else UNBOUNDED),
                rate=rate * w,
            ))
        return LLMPlan(
            package=hw.name, chips=hw.chips, mode=mode_, chip_type=ctype,
            seq_len=seq_len, output_tokens=output_tokens,
            assignments=assignments, mix_rate=rate,
            handoff_bw=handoff_bw if mode_ == "disaggregated" else 0.0,
        )

    plans = {"disaggregated": build("disaggregated", r_disagg),
             "colocated": build("colocated", r_coloc)}
    mode_rates = {m: (p.mix_rate if p else 0.0) for m, p in plans.items()}
    if mode == "auto":
        chosen = max(plans, key=lambda m: mode_rates[m])
    else:
        chosen = mode
    plan = plans[chosen]
    diag = {
        "plans": plans,
        "mode_rates": mode_rates,
        "handoff_rate_cap": handoff_cap,
        "dse_s": time.time() - t0,
        "engine_stats": dict(cost.stats) if hasattr(cost, "stats") else {},
    }
    if plan is not None:
        plan.meta.update({"mode_rates": mode_rates, "dse_s": diag["dse_s"],
                          "m_samples": cost.m})
    return plan, diag


def describe_llm(plan: LLMPlan) -> list[str]:
    """Human-readable phase plan summary (CLI / examples)."""
    lines = [
        f"{plan.package}: {len(plan.assignments)} models, mode={plan.mode}, "
        f"mix rate {plan.mix_rate:.2f} req/s, "
        f"{plan.token_rate:.1f} tokens/s "
        f"(prefill {plan.seq_len} tok, ~{plan.output_tokens:g} out)"
    ]
    for a in plan.assignments:
        kv = (f"KV {a.kv_capacity_bytes / 2**20:.0f} MiB "
              f"(<= {a.max_seqs} seqs)" if a.max_seqs < UNBOUNDED else "KV -")
        if plan.mode == "colocated":
            quota = f"{a.prefill_chips:3d} chips shared"
        else:
            quota = f"{a.prefill_chips:3d}p + {a.decode_chips:3d}d chips"
        lines.append(
            f"  {a.model:20s} w={a.weight:g}  {quota}  "
            f"{a.rate:8.2f} req/s  {kv}"
        )
    return lines
