"""rwkv6-3b "Finch" [ssm]: 32L d_model=2560 (attention-free) channel-mix
d_ff=8960 vocab=65536, data-dependent decay [arXiv:2404.05892; hf].

Attention-free => ISP applies to channel dims only; WSP over sequence uses
chunked WKV state handoff (DESIGN.md SS5).  Runs the long_500k cell.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    n_layers=32,
    d_model=2560,
    n_heads=40,                 # wkv heads = d_model / rwkv_head_dim
    n_kv_heads=40,
    d_ff=8960,
    vocab=65536,
    block_pattern=("rwkv",),
    rwkv_head_dim=64,
)
