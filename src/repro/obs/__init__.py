"""Scope Observatory: unified tracing + metrics across the DSE and executor.

See :mod:`repro.obs.trace` (span tracer, Chrome trace-event export),
:mod:`repro.obs.metrics` (counters / gauges / histograms / time-weighted
series), and :mod:`repro.obs.dashboard` (self-contained HTML rendering of
timelines, sparklines, and explain() breakdowns).  Front doors elsewhere:
``SearchOptions(trace=...)``, ``Solution.serve(tracer=...)``, and
``python -m repro solve/serve --trace ... --dashboard ...``.
"""
from .dashboard import render_dashboard, write_dashboard
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NULL_METRICS,
    NullRegistry,
    TimeSeries,
)
from .trace import (
    NULL_TRACER,
    NullTracer,
    Tracer,
    current_tracer,
    traced,
    use_tracer,
    validate_chrome_trace,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_METRICS",
    "NULL_TRACER",
    "NullRegistry",
    "NullTracer",
    "TimeSeries",
    "Tracer",
    "current_tracer",
    "render_dashboard",
    "traced",
    "use_tracer",
    "validate_chrome_trace",
    "write_dashboard",
]
