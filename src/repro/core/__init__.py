"""Scope core: the paper's merged-pipeline scheduler and analytical models."""
from .costmodel import CostModel, LayerTime  # noqa: F401
from .fastcost import FastCostModel  # noqa: F401
from .graph import (  # noqa: F401
    PARTITION_EP,
    PARTITION_ISP,
    PARTITION_WSP,
    ClusterAssignment,
    LayerGraph,
    LayerNode,
    ModelAssignment,
    MultiModelSchedule,
    ScopeSchedule,
    SegmentSchedule,
    chain,
    validate_multimodel,
    validate_schedule,
)
from .hw import (  # noqa: F401
    ChipType,
    HardwareModel,
    get_hw,
    mcm_hetero,
    mcm_table_iii,
    tpu_v5e,
)
from .regions import RegionMode  # noqa: F401
from .baselines import (  # noqa: F401
    ALL_METHODS,
    schedule_full_pipeline,
    schedule_scope,
    schedule_segmented,
    schedule_sequential,
)
from .search import search, search_segment  # noqa: F401
