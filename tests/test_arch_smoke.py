"""Per-architecture smoke tests: reduced configs, one forward/train/decode
step on CPU, asserting output shapes and finiteness (assignment SSf)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models import decode_step, forward, init_kv_cache, init_params, loss_fn

B, S = 2, 16


def _inputs(cfg):
    key = jax.random.PRNGKey(0)
    if cfg.frontend == "audio_stub":
        emb = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
        return None, emb, S
    if cfg.frontend == "vision_stub":
        ft = cfg.frontend_tokens
        toks = jax.random.randint(key, (B, S - ft), 0, cfg.vocab)
        emb = jax.random.normal(key, (B, ft, cfg.d_model), jnp.float32)
        return toks, emb, S
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return toks, None, S


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(1))
    tokens, emb, S_total = _inputs(cfg)
    logits, _ = forward(params, cfg, tokens, emb)
    assert logits.shape == (B, S_total, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_finite_loss_and_grads(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(2))
    tokens, emb, S_total = _inputs(cfg)
    labels = jax.random.randint(jax.random.PRNGKey(3), (B, S_total), 0, cfg.vocab)

    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, tokens, labels, emb)
    )(params)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_step_matches_cache_semantics(arch):
    cfg = get_smoke_config(arch)
    if cfg.frontend != "none":
        pytest.skip("frontend stubs decode from token path only after prefill")
    params = init_params(cfg, jax.random.PRNGKey(4))
    caches = init_kv_cache(cfg, B, max_len=S, dtype=jnp.float32)
    tok = jax.random.randint(jax.random.PRNGKey(5), (B, 1), 0, cfg.vocab)
    pos = jnp.zeros((B,), jnp.int32)
    logits, new_caches = decode_step(params, cfg, tok, pos, caches)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache structure preserved
    assert jax.tree.structure(caches) == jax.tree.structure(new_caches)


@pytest.mark.parametrize("arch", ["granite-3-8b", "rwkv6-3b", "jamba-v0.1-52b"])
def test_prefill_then_decode_consistency(arch):
    """Decoding token-by-token must reproduce the prefill logits."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(6))
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, 6), 0, cfg.vocab)
    full_logits, _ = forward(params, cfg, toks)

    caches = init_kv_cache(cfg, B, max_len=8, dtype=jnp.float32)
    outs = []
    for t in range(6):
        pos = jnp.full((B,), t, jnp.int32)
        lg, caches = decode_step(params, cfg, toks[:, t : t + 1], pos, caches)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full_logits, np.float32),
        np.asarray(dec_logits, np.float32),
        rtol=2e-3, atol=2e-3,
    )
