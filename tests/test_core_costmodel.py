"""Unit tests for the Scope analytical cost model (paper Eqs. 1-7, Table II)."""
import math

import pytest

from repro.core.costmodel import INF, CostModel
from repro.core.graph import (
    PARTITION_ISP,
    PARTITION_WSP,
    ClusterAssignment,
    LayerNode,
    chain,
)
from repro.core.hw import eff, mcm_table_iii


def mk_layer(name="l", flops=1e9, w=100e3, inb=50e3, outb=50e3, halo=1e3,
             wspp=784.0, ispp=256.0, **kw):
    return LayerNode(
        name=name, kind="conv", flops=flops, weight_bytes=w, in_bytes=inb,
        out_bytes=outb, halo_bytes=halo, wsp_parallel=wspp, isp_parallel=ispp, **kw,
    )


@pytest.fixture
def cost():
    return CostModel(mcm_table_iii(16), m_samples=16)


class TestEff:
    def test_exact_multiple(self):
        assert eff(256, 16) == 1.0

    def test_partial(self):
        assert eff(8, 16) == 0.5

    def test_degenerate(self):
        assert eff(0, 16) < 1e-6

    def test_monotone_in_dim_at_fixed_tiles(self):
        assert eff(17, 16) < eff(32, 16)


class TestTableII:
    """Communication volumes, paper Table II."""

    def test_case1_wsp_wsp_is_halo(self, cost):
        l = mk_layer(halo=1000)
        n = 4
        assert cost.comm_volume(l, PARTITION_WSP, n, PARTITION_WSP, n, True) == 1000 * (n - 1)

    def test_case1_wsp_isp(self, cost):
        l = mk_layer(outb=500)
        assert cost.comm_volume(l, PARTITION_WSP, 4, PARTITION_ISP, 4, True) == 3 * 500

    def test_case1_isp_wsp_adds_halo(self, cost):
        l = mk_layer(outb=500, halo=100)
        v = cost.comm_volume(l, PARTITION_ISP, 4, PARTITION_WSP, 4, True)
        assert v == 3 * 500 + 3 * 100

    def test_case1_isp_isp(self, cost):
        l = mk_layer(outb=500)
        assert cost.comm_volume(l, PARTITION_ISP, 4, PARTITION_ISP, 4, True) == 3 * 500

    def test_case2_to_wsp_is_output_once(self, cost):
        l = mk_layer(outb=500)
        assert cost.comm_volume(l, PARTITION_WSP, 4, PARTITION_WSP, 8, False) == 500
        assert cost.comm_volume(l, PARTITION_ISP, 4, PARTITION_WSP, 8, False) == 500

    def test_case2_to_isp_replicates_into_next_region(self, cost):
        l = mk_layer(outb=500)
        assert cost.comm_volume(l, PARTITION_WSP, 4, PARTITION_ISP, 8, False) == 8 * 500

    def test_network_output_free(self, cost):
        l = mk_layer(outb=500)
        assert cost.comm_volume(l, PARTITION_ISP, 4, None, None, False) == 0.0


class TestEq7Overlap:
    def test_layer_time_overlaps_comm_and_comp(self, cost):
        l = mk_layer()
        t = cost.layer_time(l, PARTITION_WSP, 4, PARTITION_WSP, 4, True)
        assert t.total == t.pre + max(t.comm, t.comp)
        assert t.unoverlapped == t.pre + t.comm + t.comp
        assert t.total <= t.unoverlapped

    def test_no_overlap_mode(self):
        c = CostModel(mcm_table_iii(16), m_samples=16, overlap=False)
        l = mk_layer()
        cl = ClusterAssignment(0, 1, 4, (PARTITION_WSP,))
        g = chain("g", [l])
        t_o = CostModel(mcm_table_iii(16), m_samples=16).cluster_time(g, cl, None, True, True)
        t_n = c.cluster_time(g, cl, None, True, True)
        assert t_n >= t_o


class TestComputePhase:
    def test_isp_flatlines_when_overpartitioned(self, cost):
        """Paper SSII-B: ISP 'reduces the parallelizable weight dimension'."""
        l = mk_layer(ispp=64.0)  # 64 output channels, granule 16
        t4 = cost.comp_time(l, PARTITION_ISP, 4)    # 16 ch/chip: full
        t16 = cost.comp_time(l, PARTITION_ISP, 16)  # 4 ch/chip: 25% fill
        assert t4 == pytest.approx(l.flops / (4 * cost.hw.flops_per_chip))
        # beyond the granule limit, adding chips stops helping:
        assert t16 == pytest.approx(t4)

    def test_wsp_scales(self, cost):
        l = mk_layer(wspp=784.0)
        t2 = cost.comp_time(l, PARTITION_WSP, 2)
        t8 = cost.comp_time(l, PARTITION_WSP, 8)
        assert t8 < t2 / 2.5  # near-linear scaling while M_local >> granule


class TestWeightPlacement:
    def test_isp_shards(self, cost):
        g = chain("g", [mk_layer(w=800e3)])
        cl = ClusterAssignment(0, 1, 8, (PARTITION_ISP,))
        p = cost.place_weights(g, cl)
        assert p.feasible
        assert p.resident_bytes_per_chip == pytest.approx(100e3)
        assert p.gather_bytes == (0.0,)

    def test_wsp_small_replicates(self, cost):
        g = chain("g", [mk_layer(w=100e3)])
        cl = ClusterAssignment(0, 1, 8, (PARTITION_WSP,))
        p = cost.place_weights(g, cl)
        assert p.feasible and p.gather_bytes == (0.0,)
        assert p.resident_bytes_per_chip == pytest.approx(100e3)

    def test_wsp_large_goes_distributed(self, cost):
        """Paper SSIII-B: oversized WSP weights are tiled + exchanged per beat."""
        w = 2 * 1024 * 1024  # 2 MiB > 1 MiB cap
        g = chain("g", [mk_layer(w=w)])
        cl = ClusterAssignment(0, 1, 8, (PARTITION_WSP,))
        p = cost.place_weights(g, cl)
        assert p.feasible  # 256 KiB tile + 512 KiB double-buffer < 1 MiB
        assert p.resident_bytes_per_chip == pytest.approx(w / 8)
        assert p.gather_bytes[0] == pytest.approx(w * 7 / 8)

    def test_infeasible_when_even_distributed_overflows(self, cost):
        w = 64 * 1024 * 1024
        g = chain("g", [mk_layer(w=w)])
        cl = ClusterAssignment(0, 1, 2, (PARTITION_WSP,))
        p = cost.place_weights(g, cl)
        assert not p.feasible
        assert cost.cluster_time(g, cl, None, True, True) == INF


class TestSegmentTime:
    def test_eq2_pipeline_fill(self):
        """T_seg = load + (m + Nc - 1) * max_j T_cluster."""
        cost = CostModel(mcm_table_iii(16), m_samples=16)
        layers = [mk_layer(name=f"l{i}") for i in range(4)]
        g = chain("g", layers)
        cls = tuple(
            ClusterAssignment(i, i + 1, 4, (PARTITION_WSP,)) for i in range(4)
        )
        total, times = cost.segment_time(g, cls)
        assert len(times) == 4
        bottleneck = max(times)
        first = g.layers[0]
        load = (
            g.total_weight_bytes / cost.hw.dram_bw_total
            + cost.m * first.in_bytes / cost.hw.dram_bw_total
        )
        assert total == pytest.approx(load + (16 + 4 - 1) * bottleneck)

    def test_deeper_pipeline_more_bubbles(self):
        cost = CostModel(mcm_table_iii(16), m_samples=4)
        layers = [mk_layer(name=f"l{i}", halo=0.0) for i in range(4)]
        g = chain("g", layers)
        merged = (ClusterAssignment(0, 4, 16, (PARTITION_WSP,) * 4),)
        split = tuple(ClusterAssignment(i, i + 1, 4, (PARTITION_WSP,)) for i in range(4))
        t_m, _ = cost.segment_time(g, merged)
        t_s, _ = cost.segment_time(g, split)
        # identical layers, perfectly balanced both ways; fill bubbles should
        # decide: merged has Nc=1 (no bubbles) but 4x weaker per-beat regions.
        assert t_m != t_s  # the tradeoff is real and model-resolved
