"""Scope Lens: cost attribution, latency waterfalls, dashboard rendering.

Two conservation invariants anchor this suite, both *bit-exact* (``==``,
not approx):

* every :class:`~repro.core.costmodel.CostBreakdown` folds back to the
  scalar the solver optimized, on the reference and fast engines alike,
  across region modes, mixed flavors and LM graphs;
* every completed request's latency waterfall folds back to its
  end-to-end latency, through faults, redeploys, and mid-batch LLM
  admission.
"""
import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import repro as scope
from repro.configs import get_smoke_config
from repro.core.costmodel import (
    BREAKDOWN_COMPONENTS,
    CostBreakdown,
    CostModel,
    INF,
    SAME_FLAVOR,
    conserve_components,
    fold_components,
)
from repro.core.fastcost import FastCostModel
from repro.core.graph import ClusterAssignment
from repro.core.hw import mcm_hetero, mcm_table_iii
from repro.core.workloads import get_cnn
from repro.core.workloads.lm import lm_graph
from repro.obs import Tracer, use_tracer
from repro.serving.metrics import WATERFALL_COMPONENTS
from repro.serving.llm.metrics import LLM_WATERFALL_COMPONENTS


def random_clusters(graph, hw, rng, *, mixed: bool):
    """A random full-graph pipeline: contiguous clusters, random chips,
    partitions and (optionally mixed) flavors."""
    L = len(graph)
    n_cl = rng.randint(1, min(L, 6))
    cuts = sorted(rng.sample(range(1, L), n_cl - 1)) if n_cl > 1 else []
    bounds, cursor = [], 0
    for c in cuts + [L]:
        bounds.append((cursor, c))
        cursor = c
    flavors = [t.name for t in hw.region_types] or [None]
    out = []
    for lo, hi in bounds:
        span = hi - lo
        t = rng.randint(0, span)
        parts = tuple(["WSP"] * t + ["ISP"] * (span - t))
        ctype = rng.choice(flavors) if mixed else flavors[0]
        out.append(ClusterAssignment(
            layer_lo=lo, layer_hi=hi,
            region_chips=rng.randint(1, max(1, hw.chips // n_cl)),
            partitions=parts, chip_type=ctype))
    return tuple(out)


class TestConserveHelpers:
    @given(st.lists(st.floats(min_value=0.0, max_value=1e3), min_size=1,
                    max_size=5),
           total=st.floats(min_value=0.0, max_value=1e4))
    @settings(max_examples=60, deadline=None)
    def test_residual_fold_is_exact(self, vals, total):
        names = BREAKDOWN_COMPONENTS[:len(vals)]
        comps = dict(zip(names, vals))
        out = conserve_components(comps, total, order=names)
        assert fold_components(out, names) == total

    def test_inf_total_parks_in_dram(self):
        comps = dict.fromkeys(BREAKDOWN_COMPONENTS, 1.0)
        out = conserve_components(comps, INF)
        assert out["dram"] == INF
        assert fold_components(out) == INF

    def test_merge_conserves(self):
        a = CostBreakdown.build({"compute": 1.0, "nop_comm": 0.1,
                                 "seam": 0.0, "dram": 0.05, "staging": 0.0},
                                1.15)
        b = CostBreakdown.build({"compute": 0.4, "nop_comm": 0.7,
                                 "seam": 0.0, "dram": 0.0, "staging": 0.0},
                                1.1)
        m = CostBreakdown.merge([a, b], 2.25)
        assert m.conserved
        assert m.bottleneck in BREAKDOWN_COMPONENTS


class TestBreakdownConservation:
    """segment_breakdown folds to segment_time, bit-identically, on both
    engines -- random pipelines, mixed flavors, CNN and LM graphs."""

    @given(
        arch=st.sampled_from(
            ["cnn:alexnet", "cnn:resnet18", "lm:gemma2-9b",
             "lm:granite-moe-1b-a400m"]),
        hetero=st.booleans(),
        seed=st.integers(min_value=0, max_value=10_000),
    )
    @settings(max_examples=25, deadline=None)
    def test_segment_breakdown_bit_identical(self, arch, hetero, seed):
        kind, name = arch.split(":")
        g = (get_cnn(name) if kind == "cnn"
             else lm_graph(get_smoke_config(name), seq_len=128))
        hw = mcm_hetero(16) if hetero else mcm_table_iii(16)
        rng = random.Random(seed)
        clusters = random_clusters(g, hw, rng, mixed=hetero)
        ref = CostModel(hw, m_samples=16)
        fast = FastCostModel(hw, m_samples=16)
        for cost in (ref, fast):
            total, _times = cost.segment_time(g, clusters)
            bd, per_cluster = cost.segment_breakdown(g, clusters)
            assert bd.total == total
            assert fold_components(bd.components) == total
            assert bd.conserved
            for j, cl in enumerate(clusters):
                nxt = clusters[j + 1] if j + 1 < len(clusters) else None
                ct = cost.cluster_time(g, cl, nxt, j == 0, nxt is None)
                assert fold_components(per_cluster[j].components) == ct
        # cross-engine: same totals -> identical attribution
        rbd, _ = ref.segment_breakdown(g, clusters)
        fbd, _ = fast.segment_breakdown(g, clusters)
        assert rbd.total == fbd.total
        assert rbd.components == fbd.components

    def test_nonoverlap_and_literal_pre_variants(self):
        g = get_cnn("alexnet")
        hw = mcm_table_iii(16)
        rng = random.Random(7)
        clusters = random_clusters(g, hw, rng, mixed=False)
        for kw in ({"overlap": False}, {"literal_pre": True},
                   {"overlap": False, "literal_pre": True}):
            for cost in (CostModel(hw, m_samples=16, **kw),
                         FastCostModel(hw, m_samples=16, **kw)):
                bd, _ = cost.segment_breakdown(g, clusters)
                assert bd.conserved


class TestSolutionExplain:
    @pytest.mark.parametrize("engine", ["fast", "reference"])
    @pytest.mark.parametrize("mode", ["free", "uniform"])
    def test_single_model_conserves(self, engine, mode):
        prob = scope.problem("alexnet", "mcm16", m_samples=8,
                             engine=engine, mode=mode)
        sol = scope.solve(prob)
        ex = sol.explain()
        assert ex["stages"], "explain produced no stages"
        for stg in ex["stages"]:
            assert stg["conserved"], stg
            assert fold_components(stg["breakdown"]["components"]) == \
                stg["latency"]
            assert stg["bound"] in ("compute", "link", "seam", "dram",
                                    "staging", "kv")
        assert ex["ranking"] == sorted(
            ex["ranking"], key=lambda r: -r["latency"])

    def test_multimodel_hetero_quotas_conserve(self):
        prob = scope.problem("resnet50:2,resnet18:1", "mcm16_hetero",
                             m_samples=8)
        sol = scope.solve(prob)
        ex = sol.explain()
        assert len(ex["stages"]) == 2
        for stg in ex["stages"]:
            assert stg["conserved"], stg
            assert stg["quota"], "multimodel stages must carry their quota"

    def test_llm_phase_explain(self, llm_sol):
        ex = llm_sol.explain()
        labels = [s["label"] for s in ex["stages"]]
        assert any("prefill" in lab for lab in labels)
        assert any("decode" in lab for lab in labels)
        for stg in ex["stages"]:
            assert stg["conserved"], stg


@pytest.fixture(scope="module")
def llm_sol():
    cfgs = [get_smoke_config("gemma2-9b")]
    wl = scope.WorkloadSpec.lm(cfgs, 128)
    prob = scope.problem(wl, "mcm16", strategy="llm-phase",
                         output_tokens=32.0, m_samples=8)
    sol = scope.solve(prob)
    assert sol.feasible
    return sol


@pytest.fixture(scope="module")
def serve_sol():
    prob = scope.problem("resnet50:1,alexnet:1", "mcm16", m_samples=8)
    sol = scope.solve(prob)
    assert sol.feasible
    return sol


def _assert_waterfalls_conserve(rep, order):
    n = sum(len(v) for v in rep.waterfalls.values())
    assert n == rep.total_completed
    for wfs in rep.waterfalls.values():
        for wf in wfs:
            comps = {k: wf[k] for k in order}
            assert fold_components(comps, order) == wf["total"]
            assert all(k in wf for k in order)
    ex = rep.explain()
    assert ex["conserved"]
    return ex


class TestServingWaterfalls:
    @given(seed=st.integers(min_value=0, max_value=1_000))
    @settings(max_examples=5, deadline=None)
    def test_every_request_conserves(self, seed):
        prob = scope.problem("alexnet", "mcm16", m_samples=8)
        sol = scope.solve(prob)
        rep = sol.serve(n_requests=120, seed=seed)
        _assert_waterfalls_conserve(rep, WATERFALL_COMPONENTS)

    def test_chaos_serve_attributes_dead_time(self, serve_sol):
        rep = serve_sol.serve(n_requests=300, seed=11,
                              faults="chip:0,0@20%:60%")
        ex = _assert_waterfalls_conserve(rep, WATERFALL_COMPONENTS)
        assert set(ex["dead_time_s"]) == {"fault", "autoscale", "time_mux"}
        assert ex["overall"]["requests"] == rep.total_completed
        # every component surfaces with a share; shares sum to ~1
        shares = sum(c["share"]
                     for c in ex["overall"]["components"].values())
        assert shares == pytest.approx(1.0, abs=1e-9)

    def test_report_json_carries_explain(self, serve_sol):
        rep = serve_sol.serve(n_requests=80, seed=2)
        js = rep.to_json()
        assert "waterfalls" not in js
        assert js["explain"]["conserved"]


class TestLLMWaterfalls:
    def test_token_requests_conserve_with_midbatch(self, llm_sol):
        rep = llm_sol.serve(n_requests=250, seed=3)
        assert rep.admitted_midbatch > 0, \
            "fixture must exercise mid-batch admission"
        ex = _assert_waterfalls_conserve(rep, LLM_WATERFALL_COMPONENTS)
        assert set(ex["overall"]["components"]) == \
            set(LLM_WATERFALL_COMPONENTS)

    def test_static_batching_conserves(self, llm_sol):
        rep = llm_sol.serve(n_requests=150, seed=5, static_batching=True)
        _assert_waterfalls_conserve(rep, LLM_WATERFALL_COMPONENTS)

    def test_queue_and_kv_series_exported(self, llm_sol):
        tr = Tracer(clock=lambda: 0.0)
        rep = llm_sol.serve(n_requests=100, seed=4, tracer=tr)
        snap = rep.metrics.snapshot()
        series = snap.get("series", {})
        assert any(k.startswith("kv_bytes/") for k in series)
        assert any(k.startswith("queue_depth/") for k in series)
        counters = {e[1] for e in tr.events if e[0] == "C"}
        assert any(n.startswith("kv_bytes/") for n in counters)
        assert any(n.startswith("queue:") for n in counters)
        llm_lanes = {e[3] for e in tr.events
                     if e[0] == "X" and e[2] == "llm"}
        assert any(lane.endswith("/prefill") for lane in llm_lanes)
        assert any(lane.endswith("/decode") for lane in llm_lanes)


class TestTraceSummaryCounters:
    def test_engine_and_cache_counters_surface(self):
        tr = Tracer()
        prob = scope.problem("alexnet", "mcm16", m_samples=8, trace=tr)
        cache = scope.SolutionCache()
        with use_tracer(tr):
            cache.solve(prob)
            cache.solve(prob)          # second solve: a whole-solution hit
        text = tr.summary()
        for needle in ("engine.batch_evals", "engine.batch_rows",
                       "solve_cache.hits", "solve_cache.misses"):
            assert needle in text, f"{needle} missing from:\n{text}"
        snap = tr.metrics.snapshot()["counters"]
        assert snap["solve_cache.hits"] == 1
        assert snap["solve_cache.misses"] == 1


class TestDashboard:
    def test_render_from_serving_run(self, serve_sol):
        from repro.obs import render_dashboard, validate_chrome_trace

        tr = Tracer(clock=lambda: 0.0)
        rep = serve_sol.serve(n_requests=150, seed=9,
                              faults="chip:0,0@20%:50%", tracer=tr)
        html = render_dashboard(
            title="test", solution_explain=serve_sol.explain(),
            serving_explain=rep.explain(), tracer=tr,
            meta={"case": "chaos"})
        assert html.startswith("<!doctype html>")
        assert "DSE cost attribution" in html
        assert "fault-window" in html
        assert "Counter tracks" in html
        # waterfall table renders one row per model plus the overall row
        for model in rep.per_model:
            assert f"<td class='l'>{model}</td>" in html
        assert "<td class='l'>overall</td>" in html
        assert "<script" not in html and "http" not in html.replace(
            "http://www.w3.org", "")
        # deterministic: same inputs -> bytewise identical page
        again = render_dashboard(
            title="test", solution_explain=serve_sol.explain(),
            serving_explain=rep.explain(), tracer=tr,
            meta={"case": "chaos"})
        assert html == again
        assert not validate_chrome_trace(tr.to_chrome(),
                                         expect_fault_events=True)

    def test_render_empty(self):
        from repro.obs import render_dashboard

        html = render_dashboard(title="empty")
        assert "nothing to show" in html
