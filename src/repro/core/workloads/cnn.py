"""Layer graphs for the paper's evaluation networks (SSV-A).

AlexNet, VGG16, DarkNet19, ResNet-18/34/50/101/152 at 224x224, 8-bit
weights/activations (1 byte/element), per-sample costs.

Linearization conventions (documented deviations):
* pooling is folded into the producing conv (its *transmitted* output and the
  downstream spatial size are post-pool; FLOPs are the conv's own),
* residual-shortcut projection convs are folded into the first conv of their
  block (adds FLOPs/weights; keeps the graph a chain, as the paper's Table I
  indexing assumes),
* ``halo_bytes`` is the per-split-boundary WSP overlap volume:
  (k-1) * width * in_ch bytes for a conv row-split,
* classifier FC layers are OFF by default (``include_fc=False``): a 37 MB
  AlexNet fc6 can never be buffered on-package (1 MiB weight buffer/chiplet,
  Table III), so -- like prior chiplet-scheduling work the paper builds on --
  the evaluated stacks are the convolutional trunks.  DarkNet19's conv
  classifier head is kept (it is a 1x1 conv).
"""
from __future__ import annotations

from ..graph import LayerGraph, LayerNode, chain

BYTES = 1  # int8


def conv(
    name: str,
    in_hw: int,
    in_ch: int,
    out_ch: int,
    k: int,
    stride: int = 1,
    pool: int = 1,
    extra_flops: float = 0.0,
    extra_weights: float = 0.0,
) -> tuple[LayerNode, int]:
    """Returns (node, spatial size seen by the next layer)."""
    out_hw = max(1, in_hw // stride)
    post_hw = max(1, out_hw // pool)
    macs = float(out_hw) ** 2 * out_ch * in_ch * k * k
    weights = float(in_ch) * out_ch * k * k * BYTES
    node = LayerNode(
        name=name,
        kind="conv",
        flops=2.0 * macs + extra_flops,
        weight_bytes=weights + extra_weights,
        in_bytes=float(in_hw) ** 2 * in_ch * BYTES,
        out_bytes=float(post_hw) ** 2 * out_ch * BYTES,
        halo_bytes=float(max(0, k - 1)) * in_hw * in_ch * BYTES,
        # WSP splits are row stripes (halo above is per row seam), so the
        # useful WSP parallelism is the OUTPUT ROW count, not pixel count.
        wsp_parallel=float(out_hw),
        isp_parallel=float(out_ch),
    )
    return node, post_hw


def fc(name: str, in_dim: int, out_dim: int) -> LayerNode:
    macs = float(in_dim) * out_dim
    return LayerNode(
        name=name,
        kind="fc",
        flops=2.0 * macs,
        weight_bytes=macs * BYTES,
        in_bytes=float(in_dim) * BYTES,
        out_bytes=float(out_dim) * BYTES,
        halo_bytes=0.0,
        wsp_parallel=1.0,            # a single sample's FC has no spatial dim
        isp_parallel=float(out_dim),
    )


def alexnet(include_fc: bool = False) -> LayerGraph:
    layers = []
    n, hw = conv("conv1", 224, 3, 96, 11, stride=4, pool=2); layers.append(n)
    n, hw = conv("conv2", hw, 96, 256, 5, pool=2); layers.append(n)
    n, hw = conv("conv3", hw, 256, 384, 3); layers.append(n)
    n, hw = conv("conv4", hw, 384, 384, 3); layers.append(n)
    n, hw = conv("conv5", hw, 384, 256, 3, pool=2); layers.append(n)
    if include_fc:
        layers.append(fc("fc6", hw * hw * 256, 4096))
        layers.append(fc("fc7", 4096, 4096))
        layers.append(fc("fc8", 4096, 1000))
    return chain("alexnet", layers)


def vgg16(include_fc: bool = False) -> LayerGraph:
    cfg = [
        (64, 2, True), (128, 2, True), (256, 3, True), (512, 3, True), (512, 3, True),
    ]
    layers, hw, in_ch, idx = [], 224, 3, 1
    for out_ch, reps, do_pool in cfg:
        for r in range(reps):
            n, hw = conv(
                f"conv{idx}", hw, in_ch, out_ch, 3,
                pool=2 if (do_pool and r == reps - 1) else 1,
            )
            layers.append(n)
            in_ch = out_ch
            idx += 1
    if include_fc:
        layers.append(fc("fc14", hw * hw * 512, 4096))
        layers.append(fc("fc15", 4096, 4096))
        layers.append(fc("fc16", 4096, 1000))
    return chain("vgg16", layers)


def darknet19() -> LayerGraph:
    layers, hw, in_ch, idx = [], 224, 3, 1

    def add(out_ch, k, pool=1):
        nonlocal hw, in_ch, idx
        n, hw = conv(f"conv{idx}", hw, in_ch, out_ch, k, pool=pool)
        layers.append(n)
        in_ch = out_ch
        idx += 1

    add(32, 3, pool=2)
    add(64, 3, pool=2)
    add(128, 3); add(64, 1); add(128, 3, pool=2)
    add(256, 3); add(128, 1); add(256, 3, pool=2)
    add(512, 3); add(256, 1); add(512, 3); add(256, 1); add(512, 3, pool=2)
    add(1024, 3); add(512, 1); add(1024, 3); add(512, 1); add(1024, 3)
    add(1000, 1)  # classifier conv + global average pool
    return chain("darknet19", layers)


def _resnet(name: str, block_cfg: list[int], bottleneck: bool, include_fc: bool = False) -> LayerGraph:
    layers = []
    n, hw = conv("conv1", 224, 3, 64, 7, stride=2, pool=2)
    layers.append(n)
    in_ch = 64
    widths = [64, 128, 256, 512]
    for stage, (reps, width) in enumerate(zip(block_cfg, widths)):
        out_ch = width * (4 if bottleneck else 1)
        for b in range(reps):
            stride = 2 if (stage > 0 and b == 0) else 1
            proj_f = proj_w = 0.0
            if b == 0 and (in_ch != out_ch or stride != 1):
                proj_hw = max(1, hw // stride)
                proj_f = 2.0 * float(proj_hw) ** 2 * out_ch * in_ch
                proj_w = float(in_ch) * out_ch * BYTES
            if bottleneck:
                n, hw2 = conv(f"s{stage}b{b}_c1", hw, in_ch, width, 1, stride=stride,
                              extra_flops=proj_f, extra_weights=proj_w)
                layers.append(n)
                n, hw2 = conv(f"s{stage}b{b}_c2", hw2, width, width, 3)
                layers.append(n)
                n, hw2 = conv(f"s{stage}b{b}_c3", hw2, width, out_ch, 1)
                layers.append(n)
            else:
                n, hw2 = conv(f"s{stage}b{b}_c1", hw, in_ch, width, 3, stride=stride,
                              extra_flops=proj_f, extra_weights=proj_w)
                layers.append(n)
                n, hw2 = conv(f"s{stage}b{b}_c2", hw2, width, out_ch, 3)
                layers.append(n)
            hw = hw2
            in_ch = out_ch
    if include_fc:
        layers.append(fc("fc", in_ch, 1000))
    return chain(name, layers)


def resnet18():
    return _resnet("resnet18", [2, 2, 2, 2], bottleneck=False)

def resnet34():
    return _resnet("resnet34", [3, 4, 6, 3], bottleneck=False)

def resnet50():
    return _resnet("resnet50", [3, 4, 6, 3], bottleneck=True)

def resnet101():
    return _resnet("resnet101", [3, 4, 23, 3], bottleneck=True)

def resnet152():
    return _resnet("resnet152", [3, 8, 36, 3], bottleneck=True)


CNN_WORKLOADS = {
    "alexnet": alexnet,
    "vgg16": vgg16,
    "darknet19": darknet19,
    "resnet18": resnet18,
    "resnet34": resnet34,
    "resnet50": resnet50,
    "resnet101": resnet101,
    "resnet152": resnet152,
}


def get_cnn(name: str) -> LayerGraph:
    return CNN_WORKLOADS[name]()
