"""Parity suite: FastCostModel vs the reference CostModel.

The fast engine's contract (fastcost.py) is *exact parity*: identical
cluster/segment/system times within 1e-9 rtol (bit-identical in practice)
and the same argmin schedules out of the DSE, across RegionModes,
``ep_for_moe``, ``literal_pre``, ``distributed_weights`` and ``overlap``
settings, for CNN and LM graphs.
"""
import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.costmodel import INF, CostModel
from repro.core.fastcost import FastCostModel
from repro.core.graph import ClusterAssignment, LayerNode, chain, validate_schedule
from repro.core.hw import mcm_hetero, mcm_table_iii
from repro.core.baselines import schedule_scope, schedule_segmented
from repro.core.regions import RegionMode
from repro.core.search import (
    evaluate_segment,
    search,
    search_mixed,
    search_segment,
    search_segment_mixed,
)
from repro.core.workloads import get_cnn
from repro.core.workloads.lm import lm_graph
from repro.configs import get_smoke_config

RTOL = 1e-9


def close(a: float, b: float) -> bool:
    if a == b:
        return True
    if a == INF or b == INF:
        return False
    return abs(a - b) <= RTOL * max(abs(a), abs(b))


def make_models(chips: int, **kw):
    hw = mcm_table_iii(chips)
    return CostModel(hw, m_samples=16, **kw), FastCostModel(hw, m_samples=16, **kw)


def random_segment_configs(graph, chips: int, samples: int, seed: int = 0):
    """Random (clustering, partitions, regions) over a whole graph."""
    rng = random.Random(seed)
    L = len(graph)
    for _ in range(samples):
        n_cluster = rng.randint(1, min(L, chips))
        cuts = sorted(rng.sample(range(1, L), n_cluster - 1)) if n_cluster > 1 else []
        bounds, cursor = [], 0
        for c in cuts + [L]:
            bounds.append((cursor, c))
            cursor = c
        rcuts = sorted(rng.sample(range(1, chips), n_cluster - 1)) if n_cluster > 1 else []
        regions, prev = [], 0
        for c in rcuts + [chips]:
            regions.append(c - prev)
            prev = c
        choices = ("WSP", "ISP")
        partitions = tuple(rng.choice(choices) for _ in range(L))
        yield tuple(bounds), partitions, regions


class TestClusterParity:
    @pytest.mark.parametrize("net,chips", [("alexnet", 16), ("resnet18", 32)])
    def test_random_segment_configs_match(self, net, chips):
        g = get_cnn(net)
        ref, fast = make_models(chips)
        n_inf = n_fin = 0
        for clustering, partitions, regions in random_segment_configs(g, chips, 120):
            lr, tr = evaluate_segment(ref, g, 0, clustering, partitions, regions)
            lf, tf = evaluate_segment(fast, g, 0, clustering, partitions, regions)
            assert close(lr, lf), (clustering, partitions, regions, lr, lf)
            for a, b in zip(tr, tf):
                assert close(a, b)
            n_inf += lr == INF
            n_fin += lr < INF
        assert n_fin > 5   # the sample must actually exercise finite configs

    def test_large_cluster_vectorized_path(self):
        """Clusters > _SCALAR_MAX_LAYERS route through the NumPy body; pin
        its parity explicitly (the small-graph tests only hit the scalar
        path)."""
        from repro.core.fastcost import _SCALAR_MAX_LAYERS

        g = get_cnn("resnet50")
        L = len(g)
        assert L > _SCALAR_MAX_LAYERS
        ref, fast = make_models(64)
        for idx in (0, L // 3, L // 2, L):          # whole graph = one cluster
            partitions = tuple(["WSP"] * idx + ["ISP"] * (L - idx))
            for n in (8, 33, 64):
                lr, _ = evaluate_segment(ref, g, 0, ((0, L),), partitions, [n])
                lf, _ = evaluate_segment(fast, g, 0, ((0, L),), partitions, [n])
                assert close(lr, lf), (idx, n, lr, lf)
        # two big clusters: exercises the Case 2 boundary with big statics
        cut = L // 2
        parts = tuple(["WSP"] * cut + ["ISP"] * (L - cut))
        lr, tr = evaluate_segment(ref, g, 0, ((0, cut), (cut, L)), parts, [31, 33])
        lf, tf = evaluate_segment(fast, g, 0, ((0, cut), (cut, L)), parts, [31, 33])
        assert close(lr, lf)
        for a, b in zip(tr, tf):
            assert close(a, b)

    def test_resnet152_flagship_graph_parity(self):
        """Per-candidate parity on the paper's flagship 151-layer graph
        (running the full reference DSE here would take minutes; random
        configs cover the same evaluation paths per candidate)."""
        g = get_cnn("resnet152")
        ref, fast = make_models(256)
        n_fin = 0
        for clustering, partitions, regions in random_segment_configs(g, 256, 40, seed=17):
            lr, _ = evaluate_segment(ref, g, 0, clustering, partitions, regions)
            lf, _ = evaluate_segment(fast, g, 0, clustering, partitions, regions)
            assert close(lr, lf), (len(clustering), lr, lf)
            n_fin += lr < INF
        assert n_fin > 0

    @pytest.mark.parametrize("literal_pre", [False, True])
    @pytest.mark.parametrize("distributed_weights", [False, True])
    @pytest.mark.parametrize("overlap", [False, True])
    def test_flags_parity(self, literal_pre, distributed_weights, overlap):
        g = get_cnn("alexnet")
        ref, fast = make_models(
            16, literal_pre=literal_pre,
            distributed_weights=distributed_weights, overlap=overlap,
        )
        for clustering, partitions, regions in random_segment_configs(g, 16, 60, seed=3):
            lr, _ = evaluate_segment(ref, g, 0, clustering, partitions, regions)
            lf, _ = evaluate_segment(fast, g, 0, clustering, partitions, regions)
            assert close(lr, lf), (clustering, partitions, regions, lr, lf)

    def test_cluster_time_api_parity(self):
        g = get_cnn("alexnet")
        ref, fast = make_models(16)
        cl = ClusterAssignment(0, 3, 8, ("WSP", "WSP", "ISP"))
        nxt = ClusterAssignment(3, 5, 8, ("ISP", "ISP"))
        assert close(
            ref.cluster_time(g, cl, nxt, True, False),
            fast.cluster_time(g, cl, nxt, True, False),
        )
        assert close(
            ref.cluster_time(g, cl, None, True, True),
            fast.cluster_time(g, cl, None, True, True),
        )


class TestLMGraphParity:
    @pytest.mark.parametrize("arch", ["granite-3-8b", "granite-moe-1b-a400m"])
    def test_lm_random_configs(self, arch):
        cfg = get_smoke_config(arch)
        g = lm_graph(cfg, seq_len=256)
        ref, fast = make_models(16)
        for clustering, partitions, regions in random_segment_configs(g, 16, 50, seed=11):
            lr, _ = evaluate_segment(ref, g, 0, clustering, partitions, regions)
            lf, _ = evaluate_segment(fast, g, 0, clustering, partitions, regions)
            assert close(lr, lf)

    def test_moe_ep_partitions(self):
        """EP partitions (expert parallelism) agree between engines."""
        cfg = get_smoke_config("granite-moe-1b-a400m")
        g = lm_graph(cfg, seq_len=256)
        L = len(g)
        ref, fast = make_models(16)
        ep = tuple(
            "EP" if l.n_experts > 1 else ("WSP" if i < L // 2 else "ISP")
            for i, l in enumerate(g.layers)
        )
        clustering = ((0, L // 2), (L // 2, L))
        lr, _ = evaluate_segment(ref, g, 0, clustering, ep, [8, 8])
        lf, _ = evaluate_segment(fast, g, 0, clustering, ep, [8, 8])
        assert close(lr, lf)


class TestSearchParity:
    """Same argmin out of Algorithm 1, not just close values."""

    @pytest.mark.parametrize("mode", [RegionMode.FREE, RegionMode.UNIFORM])
    def test_search_segment_same_result(self, mode):
        g = get_cnn("alexnet")
        ref, fast = make_models(16)
        rr = search_segment(ref, g, 0, len(g), 16, mode=mode)
        rf = search_segment(fast, g, 0, len(g), 16, mode=mode)
        assert close(rr.latency, rf.latency)
        assert rr.clusters == rf.clusters

    def test_search_segment_ep_for_moe(self):
        cfg = get_smoke_config("granite-moe-1b-a400m")
        g = lm_graph(cfg, seq_len=256)
        ref, fast = make_models(16)
        rr = search_segment(ref, g, 0, len(g), 16, ep_for_moe=True)
        rf = search_segment(fast, g, 0, len(g), 16, ep_for_moe=True)
        assert close(rr.latency, rf.latency)
        assert rr.clusters == rf.clusters

    def test_full_dse_same_schedule(self):
        g = get_cnn("resnet18")
        ref, fast = make_models(64)
        sr = schedule_scope(g, ref, 64)
        sf = schedule_scope(g, fast, 64)
        assert close(sr.latency, sf.latency)
        assert [s.clusters for s in sr.segments] == [s.clusters for s in sf.segments]
        validate_schedule(g, sf, 64)

    def test_segmented_baseline_same_schedule(self):
        g = get_cnn("alexnet")
        ref, fast = make_models(16)
        sr = schedule_segmented(g, ref, 16)
        sf = schedule_segmented(g, fast, 16)
        assert close(sr.latency, sf.latency)


class TestMixedFlavorParity:
    """Per-cluster chip flavors: seam-aware parity between the engines.

    Adjacent clusters of one segment sit on *different* flavors of a
    heterogeneous package, so the last-layer boundary term crosses the
    flavor seam (hw.seam_link_bw) -- exactly the term the extended memo key
    (next_chip_type) must keep apart.
    """

    HW = dict(big_fraction=0.5, little_flops_scale=0.4, little_nop_scale=0.6)

    def _mixed_configs(self, g, chips, samples, seed=0):
        rng = random.Random(seed)
        for clustering, partitions, regions in random_segment_configs(
            g, chips, samples, seed
        ):
            ctypes = tuple(
                rng.choice(("big", "little")) for _ in clustering
            )
            yield clustering, partitions, regions, ctypes

    def test_mixed_random_configs_fast_vs_reference(self):
        hw = mcm_hetero(16, **self.HW)
        g = get_cnn("alexnet")
        ref = CostModel(hw, m_samples=16)
        fast = FastCostModel(hw, m_samples=16)
        n_mixed = 0
        for clustering, partitions, regions, ctypes in self._mixed_configs(
            g, 16, 80, seed=23
        ):
            lr, tr = evaluate_segment(ref, g, 0, clustering, partitions,
                                      regions, chip_type=ctypes)
            lf, tf = evaluate_segment(fast, g, 0, clustering, partitions,
                                      regions, chip_type=ctypes)
            assert close(lr, lf), (clustering, partitions, ctypes, lr, lf)
            for a, b in zip(tr, tf):
                assert close(a, b)
            n_mixed += len(set(ctypes)) > 1 and lr < INF
        assert n_mixed > 5   # genuinely mixed finite configs were exercised

    def test_mixed_memo_vs_fresh(self):
        """Memoized answers on mixed-flavor segments == a fresh engine's."""
        hw = mcm_hetero(16, **self.HW)
        g = get_cnn("alexnet")
        fast = FastCostModel(hw, m_samples=16)
        cfgs = list(self._mixed_configs(g, 16, 40, seed=5))
        first = [
            evaluate_segment(fast, g, 0, c, p, r, chip_type=t)[0]
            for c, p, r, t in cfgs
        ]
        second = [
            evaluate_segment(fast, g, 0, c, p, r, chip_type=t)[0]
            for c, p, r, t in cfgs
        ]
        assert first == second
        fresh = FastCostModel(mcm_hetero(16, **self.HW), m_samples=16)
        third = [
            evaluate_segment(fresh, g, 0, c, p, r, chip_type=t)[0]
            for c, p, r, t in cfgs
        ]
        assert first == third

    def test_neighbor_flavor_not_cached_across(self):
        """The same cluster against a big vs little *neighbor* must be two
        memo entries (the seam bandwidth differs), and the cross-flavor
        hand-off must not be faster than the intra-flavor one."""
        hw = mcm_hetero(16, **self.HW)
        g = get_cnn("alexnet")
        fast = FastCostModel(hw, m_samples=16)
        clustering = ((0, 3), (3, 5))
        partitions = ("ISP",) * 5
        lat_same, _ = evaluate_segment(
            fast, g, 0, clustering, partitions, [8, 8],
            chip_type=("big", "big"),
        )
        computes_same = fast.stats["cluster_computes"]
        lat_cross, _ = evaluate_segment(
            fast, g, 0, clustering, partitions, [8, 8],
            chip_type=("big", "little"),
        )
        assert fast.stats["cluster_computes"] > computes_same
        # seam runs at the weaker (little) link bw and little chips compute
        # slower, so the mixed variant cannot beat all-big here
        assert lat_cross >= lat_same
        # both flavors' seam view agrees with the hardware model
        assert hw.seam_link_bw("big", "little") == hw.flavor_link_bw("little")
        assert hw.seam_link_bw("big", "big") == hw.flavor_link_bw("big")

    @pytest.mark.parametrize("mode", [RegionMode.FREE, RegionMode.UNIFORM])
    def test_search_segment_mixed_reference_parity(self, mode):
        """The mixed-flavor segment search's winner re-evaluates identically
        on the reference model, and never loses to the single-flavor search
        at the same per-flavor budgets -- in both RegionModes."""
        hw = mcm_hetero(16, **self.HW)
        g = get_cnn("alexnet")
        fast = FastCostModel(hw, m_samples=16)
        budgets = [("big", 8), ("little", 8)]
        res = search_segment_mixed(fast, g, 0, len(g), budgets, mode=mode)
        assert res is not None and res.latency < INF
        ref = CostModel(hw, m_samples=16)
        lat_ref, times_ref = ref.segment_time(g, res.clusters)
        assert close(lat_ref, res.latency)
        for a, b in zip(times_ref, res.cluster_times):
            assert close(a, b)
        for ctype, chips in budgets:
            sr = search_segment(fast, g, 0, len(g), chips, mode=mode,
                                chip_type=ctype)
            if sr is not None:
                assert res.latency <= sr.latency + 1e-12

    def test_search_mixed_dominates_single_flavor(self):
        hw = mcm_hetero(32, **self.HW)
        g = get_cnn("resnet18")
        fast = FastCostModel(hw, m_samples=16)
        mixed = search_mixed(g, fast)
        assert mixed is not None
        for ctype in ("big", "little"):
            single = search(g, fast, hw.chip_type(ctype).chips,
                            chip_type=ctype)
            if single is not None:
                assert mixed.latency <= single.latency + 1e-12
        # the full mixed winner also matches the reference model exactly
        ref = CostModel(hw, m_samples=16)
        total = sum(ref.segment_time(g, seg.clusters)[0]
                    for seg in mixed.segments)
        assert close(total, mixed.latency)
        validate_schedule(g, mixed, hw.chips,
                          flavor_caps={t.name: t.chips
                                       for t in hw.region_types})


class TestMemoSoundness:
    def test_memoized_matches_fresh(self):
        """The same model instance answers identically before/after warmup."""
        g = get_cnn("resnet18")
        _, fast = make_models(32)
        cfgs = list(random_segment_configs(g, 32, 40, seed=5))
        first = [evaluate_segment(fast, g, 0, c, p, r)[0] for c, p, r in cfgs]
        second = [evaluate_segment(fast, g, 0, c, p, r)[0] for c, p, r in cfgs]
        assert first == second
        fresh = FastCostModel(mcm_table_iii(32), m_samples=16)
        third = [evaluate_segment(fresh, g, 0, c, p, r)[0] for c, p, r in cfgs]
        assert first == third

    @given(
        flops=st.lists(st.floats(min_value=1e6, max_value=1e12), min_size=2, max_size=12),
        chips=st.integers(min_value=2, max_value=32),
        split=st.integers(min_value=1, max_value=11),
        trans=st.integers(min_value=0, max_value=12),
    )
    @settings(max_examples=60, deadline=None)
    def test_property_parity_synthetic(self, flops, chips, split, trans):
        """Memoized fast evaluations == fresh reference, any synthetic graph."""
        L = len(flops)
        layers = [
            LayerNode(
                name=f"l{i}", kind="conv", flops=float(f),
                weight_bytes=64e3 * (1 + i % 3), in_bytes=32e3, out_bytes=32e3,
                halo_bytes=512.0, wsp_parallel=28.0 + i, isp_parallel=128.0,
            )
            for i, f in enumerate(flops)
        ]
        g = chain("synthetic", layers)
        cut = min(split, L - 1) if L > 1 else 0
        clustering = ((0, L),) if cut == 0 else ((0, cut), (cut, L))
        n_cl = len(clustering)
        if n_cl > chips:
            return
        regions = [chips // n_cl] * n_cl
        regions[0] += chips - sum(regions)
        t = min(trans, L)
        partitions = tuple(["WSP"] * t + ["ISP"] * (L - t))
        ref, fast = make_models(chips)
        lr, tr = evaluate_segment(ref, g, 0, clustering, partitions, regions)
        # evaluate twice: cold then memoized
        lf1, _ = evaluate_segment(fast, g, 0, clustering, partitions, regions)
        lf2, tf = evaluate_segment(fast, g, 0, clustering, partitions, regions)
        assert lf1 == lf2
        assert close(lr, lf1)
        for a, b in zip(tr, tf):
            assert close(a, b)


class TestBatchedPopulationParity:
    """cluster_population (batched array program) vs the scalar memo path.

    The tentpole contract: batching is an execution strategy, not a
    semantic -- the batched evaluator must be *bit-identical* to scoring
    the same rows one at a time through the scalar memoized path, across
    region flavors, explicit/hint partition specs and EP expert layers,
    and must leave the memo in the same warmed state.
    """

    @staticmethod
    def _random_rows(g, hw, rng, k_rows):
        from repro.core.costmodel import SAME_FLAVOR

        L = len(g)
        flavors = [t.name for t in hw.region_types] or [None]
        rows = []
        for _ in range(k_rows):
            lo = rng.randrange(0, L)
            hi = rng.randint(lo + 1, L)
            span = hi - lo
            ctype = rng.choice(flavors)
            if rng.random() < 0.5:
                spec = (rng.randint(0, span), rng.random() < 0.5)
            else:
                t = rng.randint(0, span)
                parts = ["WSP"] * t + ["ISP"] * (span - t)
                if rng.random() < 0.5:
                    for d, layer in enumerate(g.layers[lo:hi]):
                        if layer.n_experts > 1:
                            parts[d] = "EP"
                spec = tuple(parts)
            n = rng.randint(1, max(2, hw.chips // 2))
            if hi < L and rng.random() < 0.8:
                next_p0 = rng.choice(["WSP", "ISP"])
                next_n = rng.randint(1, 8)
                next_ctype = rng.choice([SAME_FLAVOR] + flavors)
            else:
                next_p0, next_n, next_ctype = None, None, SAME_FLAVOR
            rows.append((lo, hi, spec, n, next_p0, next_n, ctype, next_ctype))
        return rows

    @given(
        arch=st.sampled_from(
            ["cnn:alexnet", "cnn:resnet18", "lm:granite-moe-1b-a400m"]
        ),
        hetero=st.booleans(),
        seed=st.integers(min_value=0, max_value=10_000),
    )
    @settings(max_examples=20, deadline=None)
    def test_population_matches_scalar_bitwise(self, arch, hetero, seed):
        kind, name = arch.split(":")
        g = (get_cnn(name) if kind == "cnn"
             else lm_graph(get_smoke_config(name), seq_len=256))
        hw = mcm_hetero(16) if hetero else mcm_table_iii(16)
        rng = random.Random(seed)
        rows = self._random_rows(g, hw, rng, 40)
        fast_batched = FastCostModel(hw, m_samples=16)
        fast_scalar = FastCostModel(hw, m_samples=16)
        got = fast_batched.cluster_population(g, rows)
        # The base-class implementation loops the scalar memoized
        # cluster_time -- the exact path the batched evaluator replaces.
        want = CostModel.cluster_population(fast_scalar, g, rows)
        assert got.tolist() == want.tolist()
        # and rtol-parity against the reference engine
        ref = CostModel(hw, m_samples=16)
        for a, b in zip(got, ref.cluster_population(g, rows)):
            assert close(float(a), float(b))
        # the batch warmed the memo: a repeat is pure cache hits
        misses0 = fast_batched.stats["cluster_computes"]
        again = fast_batched.cluster_population(g, rows)
        assert again.tolist() == got.tolist()
        assert fast_batched.stats["cluster_computes"] == misses0
