from .sharding import ShardPlan, make_constrain, param_pspecs, cache_pspecs  # noqa: F401
