"""Checkpoint + fault-tolerance tests: atomic save/restore, corruption
detection, elastic restore, restart-on-failure, straggler flagging."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import latest_step, restore_checkpoint, save_checkpoint
from repro.ckpt.checkpoint import prune_checkpoints
from repro.data import SyntheticLM
from repro.ft import ResilientTrainer, StragglerMonitor


def small_tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((5,), jnp.bfloat16), "d": jnp.zeros((), jnp.int32)},
    }


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = small_tree()
        save_checkpoint(str(tmp_path), 7, tree, meta={"note": "x"})
        assert latest_step(str(tmp_path)) == 7
        restored, manifest = restore_checkpoint(str(tmp_path), 7, tree)
        assert manifest["step"] == 7
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32)
            )

    def test_corruption_detected(self, tmp_path):
        tree = small_tree()
        path = save_checkpoint(str(tmp_path), 1, tree)
        victim = os.path.join(path, "leaf_00000.npy")
        with open(victim, "r+b") as f:
            f.seek(64)
            f.write(b"\xff\xff\xff")
        with pytest.raises(IOError, match="corruption"):
            restore_checkpoint(str(tmp_path), 1, tree)

    def test_prune_keeps_latest(self, tmp_path):
        tree = small_tree()
        for s in (1, 2, 3, 4, 5):
            save_checkpoint(str(tmp_path), s, tree)
        prune_checkpoints(str(tmp_path), keep=2)
        assert latest_step(str(tmp_path)) == 5
        assert sorted(
            int(d.split("_")[1]) for d in os.listdir(tmp_path)
        ) == [4, 5]

    def test_elastic_restore_different_sharding(self, tmp_path):
        """A checkpoint restores under different target shardings (the
        1-device stand-in for a mesh change)."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import single_device_mesh

        tree = small_tree()
        save_checkpoint(str(tmp_path), 3, tree)
        mesh = single_device_mesh()
        sh = jax.tree.map(
            lambda x: NamedSharding(mesh, P(*([None] * x.ndim))), tree
        )
        restored, _ = restore_checkpoint(str(tmp_path), 3, tree, shardings=sh)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32)
            )


class TestResilientTrainer:
    def _mini_problem(self, tmp_path):
        """Quadratic 'training': params -> params - lr * grad."""
        def train_step(params, opt, batch):
            loss = jnp.mean((params["w"] - batch["target"]) ** 2)
            params = {"w": params["w"] - 0.1 * 2 * (params["w"] - batch["target"])}
            return params, opt, {"loss": loss}

        def batch_fn(step):
            return {"target": jnp.ones((4,)) * 2.0}

        return ResilientTrainer(
            train_step=train_step, batch_fn=batch_fn,
            ckpt_dir=str(tmp_path), ckpt_every=5,
        )

    def test_runs_and_checkpoints(self, tmp_path):
        tr = self._mini_problem(tmp_path)
        params, opt, hist = tr.run({"w": jnp.zeros((4,))}, {}, n_steps=12)
        assert len(hist) == 12
        assert hist[-1]["loss"] < hist[0]["loss"]
        assert latest_step(str(tmp_path)) == 10

    def test_restart_from_failure(self, tmp_path):
        tr = self._mini_problem(tmp_path)
        fail_at = {7}
        fired = []

        def injector(step):
            if step in fail_at and step not in fired:
                fired.append(step)
                raise RuntimeError("injected node failure")

        params, opt, hist = tr.run(
            {"w": jnp.zeros((4,))}, {}, n_steps=12, failure_injector=injector
        )
        # failed at 7 -> restored to checkpoint 5 -> replayed to the end
        steps = [h["step"] for h in hist]
        assert steps.count(6) == 2 and steps.count(7) == 2
        assert steps[-1] == 12

    def test_poison_step_aborts(self, tmp_path):
        tr = self._mini_problem(tmp_path)

        def injector(step):
            if step == 3:
                raise RuntimeError("always fails")

        with pytest.raises(RuntimeError, match="failed"):
            tr.run({"w": jnp.zeros((4,))}, {}, n_steps=12,
                   failure_injector=injector)


class TestStraggler:
    def test_flags_outlier(self):
        mon = StragglerMonitor(threshold=2.0)
        for i in range(10):
            assert not mon.observe(i, 0.1)
        assert mon.observe(10, 0.5)
        assert mon.flagged and mon.flagged[0][0] == 10


class TestSyntheticData:
    def test_deterministic(self):
        src = SyntheticLM(vocab=101, seed=3)
        a = src.batch(5, 4, 16)
        b = src.batch(5, 4, 16)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_labels_shift(self):
        src = SyntheticLM(vocab=101, seed=3, noise=0.0)
        d = src.batch(0, 2, 8)
        # noiseless chain: label = (a * token + b) % V
        np.testing.assert_array_equal(
            d["labels"], (31 * d["tokens"] + 7) % 101
        )

    def test_learnable_structure(self):
        """Majority of transitions follow the chain -> a model can learn it."""
        src = SyntheticLM(vocab=101, seed=0, noise=0.1)
        d = src.batch(1, 8, 128)
        match = (d["labels"] == (31 * d["tokens"] + 7) % 101).mean()
        assert match > 0.8
