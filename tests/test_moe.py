"""MoE dispatch correctness: grouped local dispatch vs the dense oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.config import ModelConfig, MoEConfig
from repro.models.moe import init_moe, moe_ffn, moe_ffn_dense_fallback


def mk_cfg(E=4, K=2, cf=8.0, groups=4, gated=True, d=32, ff=16):
    return ModelConfig(
        name="t", n_layers=2, d_model=d, n_heads=2, n_kv_heads=2, d_ff=ff,
        vocab=64, ffn_gated=gated, param_dtype="float32",
        moe=MoEConfig(n_experts=E, top_k=K, capacity_factor=cf,
                      dispatch_groups=groups),
    )


@pytest.mark.parametrize("E,K,gated", [(4, 1, True), (4, 2, True), (8, 2, False)])
def test_matches_dense_oracle_with_ample_capacity(E, K, gated):
    cfg = mk_cfg(E=E, K=K, gated=gated, cf=float(E))  # capacity >= all tokens
    params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    out = moe_ffn(params, x, cfg)
    ref = moe_ffn_dense_fallback(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


@given(groups=st.sampled_from([1, 2, 4, 8]))
@settings(max_examples=4, deadline=None)
def test_group_count_invariance(groups):
    """With ample capacity the result must not depend on dispatch grouping."""
    cfg = dataclasses.replace(mk_cfg(cf=8.0), moe=MoEConfig(
        n_experts=4, top_k=2, capacity_factor=8.0, dispatch_groups=groups))
    params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    out = moe_ffn(params, x, cfg)
    ref = moe_ffn_dense_fallback(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_capacity_drops_are_bounded():
    """With tight capacity some tokens drop (zero contribution), but outputs
    stay finite and most tokens are served."""
    cfg = mk_cfg(cf=1.0)
    params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out = moe_ffn(params, x, cfg)
    ref = moe_ffn_dense_fallback(params, x, cfg)
    assert np.isfinite(np.asarray(out)).all()
    # at least half the tokens match the oracle exactly (not dropped)
    match = np.isclose(np.asarray(out), np.asarray(ref), rtol=1e-3, atol=1e-3).all(-1)
    assert match.mean() > 0.5


def test_grad_flows_through_dispatch():
    cfg = mk_cfg()
    params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))

    def loss(p):
        return jnp.sum(moe_ffn(p, x, cfg) ** 2)

    g = jax.grad(loss)(params)
    gn = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
