"""SSV-B(1) search-cost table: DSE wall time per (net x chips) + space size.

Paper reference point: ResNet-152 x 256 chiplets searched in ~1 hour on a
laptop CPU over an O(10^164) space; our Algorithm 1 implementation covers
the same space in about a minute on one core (we also report Q_total from
Eq. 8/9 for the record).
"""
from __future__ import annotations

import math
import time

from repro.core.costmodel import CostModel
from repro.core.baselines import schedule_scope
from repro.core.hw import mcm_table_iii
from repro.core.workloads import get_cnn

from .common import M_SAMPLES, cached

CASES = [("alexnet", 16), ("resnet50", 64), ("resnet152", 256)]


def q_total(L: int, C: int) -> float:
    """Eq. 9 (log10): 2^L * sum_i C(L-1, i-1) C(C-1, i-1)."""
    total = 0.0
    for i in range(1, min(L, C) + 1):
        total += math.comb(L - 1, i - 1) * math.comb(C - 1, i - 1)
    return L * math.log10(2) + math.log10(total)


def run(refresh: bool = False):
    def _go():
        rows = []
        for net, chips in CASES:
            g = get_cnn(net)
            cost = CostModel(mcm_table_iii(chips), m_samples=M_SAMPLES)
            t0 = time.time()
            sched = schedule_scope(g, cost, chips)
            dt = time.time() - t0
            rows.append({
                "net": net, "chips": chips, "layers": len(g),
                "search_s": dt, "latency_s": sched.latency,
                "log10_Q_total": q_total(len(g), chips),
            })
        return rows

    return cached("search_time", _go, refresh)


def report(rows) -> list[str]:
    lines = ["net,chips,layers,log10_space,search_s"]
    for r in rows:
        lines.append(
            f"{r['net']},{r['chips']},{r['layers']},"
            f"{r['log10_Q_total']:.0f},{r['search_s']:.1f}"
        )
    lines.append("# paper: resnet152x256 space O(10^164), search ~1h on i7")
    return lines
