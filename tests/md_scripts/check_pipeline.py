import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
# Multi-device CPU test worker: the shard_map merged pipeline must reproduce
# the plain forward pass, and a pipeline train step must reduce the loss.
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.configs import get_smoke_config           # noqa: E402
from repro.launch.mesh import make_pipeline_mesh     # noqa: E402
from repro.models import forward, init_params        # noqa: E402
from repro.runtime.pipeline import build_pipeline_train_step, pipeline_forward  # noqa: E402


def main():
    assert len(jax.devices()) == 8
    cfg = get_smoke_config("granite-3-8b")          # 2 repeats
    import dataclasses
    cfg = dataclasses.replace(cfg, n_layers=4, remat=False)  # 4 repeats -> 4 stages
    mesh = make_pipeline_mesh(n_stages=4, n_data=2)
    params = init_params(cfg, jax.random.PRNGKey(0))

    n_micro, mb, S = 4, 4, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (n_micro, mb, S), 0, cfg.vocab)

    logits_pipe = pipeline_forward(params, cfg, toks, mesh, n_stages=4)
    # reference: plain forward per microbatch
    ref = jnp.stack(
        [forward(params, cfg, toks[i])[0] for i in range(n_micro)], axis=0
    )
    np.testing.assert_allclose(
        np.asarray(logits_pipe, np.float32), np.asarray(ref, np.float32),
        rtol=2e-3, atol=2e-3,
    )

    labels = jax.random.randint(jax.random.PRNGKey(2), (n_micro, mb, S), 0, cfg.vocab)
    step = build_pipeline_train_step(cfg, mesh, n_stages=4, n_micro=n_micro, lr=5e-2)
    batch = {"tokens": toks, "labels": labels}
    losses = []
    for _ in range(5):
        params, loss = step(params, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
    print("OK pipeline matches; loss", [round(l, 4) for l in losses])


if __name__ == "__main__":
    main()
