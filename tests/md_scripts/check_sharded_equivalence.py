import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
# Multi-device CPU test worker: numeric equivalence of sharded vs single-
# device execution, and collective-pattern assertions (Table II analogue).
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.configs import get_smoke_config           # noqa: E402
from repro.launch.mesh import make_mesh              # noqa: E402
from repro.launch.hlo_analysis import collective_stats  # noqa: E402
from repro.models import init_params, loss_fn        # noqa: E402
from repro.runtime.sharding import ShardPlan, make_constrain  # noqa: E402


def main():
    assert len(jax.devices()) == 8, jax.devices()
    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = get_smoke_config("granite-3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 4, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)

    # single-device reference
    ref = float(loss_fn(params, cfg, toks, labels))

    results = {}
    for name, plan in {
        "isp": ShardPlan(("data", "model"), p1="ISP", p2="ISP"),
        "wsp": ShardPlan(("data", "model"), p1="WSP", p2="WSP"),
        "mixed": ShardPlan(("data", "model"), p1="WSP", p2="ISP", transition_repeat=1),
    }.items():
        c1 = make_constrain(mesh, plan, 1)
        c2 = make_constrain(mesh, plan, 2)
        fn = jax.jit(lambda p, t, l: loss_fn(
            p, cfg, t, l, constrain=c1, constrain2=c2,
            transition_repeat=plan.transition_repeat,
        ))
        with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
            loss = float(fn(params, toks, labels))
            hlo = fn.lower(params, toks, labels).compile().as_text()
        stats = collective_stats(hlo)
        results[name] = (loss, stats.total_bytes, dict(stats.count_by_kind))
        assert abs(loss - ref) < 5e-3, (name, loss, ref)

    # WSP (sequence-sharded) must communicate differently than ISP
    assert results["isp"][1] > 0, "ISP plan produced no collectives"
    assert results["wsp"][1] > 0, "WSP plan produced no collectives"
    print("OK", ref, {k: (round(v[0], 4), v[1]) for k, v in results.items()})


if __name__ == "__main__":
    main()
