from .cnn import CNN_WORKLOADS, get_cnn  # noqa: F401
