"""Online re-solve hook: watch the traffic mix, re-plan when it drifts.

The executor polls :meth:`Autoscaler.maybe_resolve` on a periodic check
cadence.  The autoscaler keeps a sliding window of admitted samples per
model; when the observed mix's L1 distance from the currently-deployed
weights exceeds ``drift_threshold`` (and the dwell / min-sample guards
pass), it quantizes the observed shares onto a coarse weight grid and asks
its ``resolve_fn`` for a fresh co-schedule at the new mix.

``resolve_fn`` is injected by :meth:`repro.api.Solution.serve`: it rebuilds
the original :class:`~repro.api.Problem` with the new weights and solves it
through a shared :class:`~repro.api.SolutionCache` -- so every re-solve
reuses one ``FastCostModel`` memo, and a mix that flips back to a
previously-seen ratio is a whole-solution cache hit (hit rates land in the
serving report's ``autoscale.solve_cache``).  The executor charges each
applied re-solve as a switch-cost event: the new fleet accepts no work for
the deployment's weight-reload time.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Callable

from ..obs import current_tracer

__all__ = ["AutoscalePolicy", "Autoscaler", "normalize_mix", "quantize_mix"]


def normalize_mix(weights: dict[str, float]) -> dict[str, float]:
    total = sum(weights.values())
    if total <= 0:
        raise ValueError(f"non-positive mix {weights}")
    return {m: w / total for m, w in weights.items()}


def quantize_mix(shares: dict[str, float], quantum: float) -> dict[str, float]:
    """Snap observed shares onto a ``quantum`` grid (floor at one quantum):
    nearby mixes collapse onto one fingerprint, so the solution cache hits
    when traffic returns to a familiar ratio."""
    return {
        m: max(quantum, round(s / quantum) * quantum)
        for m, s in shares.items()
    }


@dataclass(frozen=True)
class AutoscalePolicy:
    window_s: float = 2.0           # sliding observation window
    check_every_s: float = 0.5      # executor poll cadence
    drift_threshold: float = 0.5    # L1 distance between normalized mixes
    min_requests: int = 16          # don't re-plan on a near-empty window
    min_dwell_s: float = 1.0        # cool-down after a redeploy
    weight_quantum: float = 0.125   # re-solve weight grid

    def __post_init__(self):
        if not (0 < self.drift_threshold <= 2):
            raise ValueError(f"drift_threshold {self.drift_threshold}: the "
                             "L1 distance between mixes lies in (0, 2]")
        if self.check_every_s <= 0 or self.window_s <= 0:
            raise ValueError("window_s / check_every_s must be > 0")


class Autoscaler:
    """Sliding-window mix observer + re-solve trigger.

    ``resolve_fn(weights) -> (MultiModelSchedule | None, info_dict)`` does
    the actual planning; ``info`` should carry ``dse_s`` / ``cache_hit`` /
    ``solve_cache`` (the facade's :class:`~repro.api.SolutionCache` stats).
    """

    def __init__(
        self,
        policy: AutoscalePolicy,
        resolve_fn: Callable[[dict[str, float]], tuple],
        weights0: dict[str, float],
    ):
        self.policy = policy
        self.resolve_fn = resolve_fn
        self.current = normalize_mix(weights0)
        self._window: deque[tuple[float, str, int]] = deque()
        self._last_change = -float("inf")
        self.checks = 0
        self.events: list[dict] = []
        # last drift the check loop computed (0 until the window fills);
        # the executor samples it into the trace's drift counter track
        self.last_drift = 0.0

    # ------------------------------------------------------------ observing
    def observe(self, t: float, model: str, samples: int) -> None:
        self._window.append((t, model, samples))
        self._prune(t)

    def _prune(self, t: float) -> None:
        cutoff = t - self.policy.window_s
        w = self._window
        while w and w[0][0] < cutoff:
            w.popleft()

    def observed_shares(self) -> tuple[dict[str, float], int]:
        counts: dict[str, int] = {}
        for _, m, s in self._window:
            counts[m] = counts.get(m, 0) + s
        total = sum(counts.values())
        if total == 0:
            return {}, 0
        return {m: c / total for m, c in counts.items()}, len(self._window)

    def _l1(self, shares: dict[str, float]) -> float:
        models = set(shares) | set(self.current)
        return sum(
            abs(shares.get(m, 0.0) - self.current.get(m, 0.0))
            for m in models
        )

    def drift(self) -> float:
        """L1 distance between the observed window mix and the deployed
        weights (0 = identical, 2 = disjoint)."""
        shares, n = self.observed_shares()
        return self._l1(shares) if n else 0.0

    # ------------------------------------------------------------ resolving
    def maybe_resolve(self, t: float, hw=None):
        """Executor hook: returns ``(new_mm, event_dict)`` or ``None``.

        ``hw`` (only passed while the executor is running degraded after a
        chip failure) is the surviving package; a resolve_fn that accepts
        it re-plans on the degraded hardware instead of the pristine one.
        """
        self.checks += 1
        self._prune(t)
        pol = self.policy
        if t - self._last_change < pol.min_dwell_s:
            return None
        shares, n_requests = self.observed_shares()
        if n_requests < pol.min_requests:
            return None
        l1 = self._l1(shares)
        self.last_drift = l1
        if l1 < pol.drift_threshold:
            return None
        # Only re-weight models the deployment already serves: a model with
        # zero window traffic keeps a floor quantum so its server survives.
        full = {m: shares.get(m, 0.0) for m in self.current}
        weights = quantize_mix(full, pol.weight_quantum)
        # hw is only forwarded when set, so 1-argument resolve_fns (every
        # pre-fault caller) keep working unchanged
        with current_tracer().span("autoscale:re-solve", drift=round(l1, 6),
                                   degraded=hw is not None):
            mm, info = (self.resolve_fn(weights) if hw is None
                        else self.resolve_fn(weights, hw=hw))
        if mm is None:
            return None
        event = {
            "t": t, "drift": l1,
            "observed": {m: round(s, 6) for m, s in shares.items()},
            "old_weights": dict(self.current),
            "new_weights": weights,
            **info,
        }
        self.events.append(event)
        self.current = normalize_mix(weights)
        self._last_change = t
        return mm, event

    def cache_stats(self) -> dict:
        """Last-known solver cache stats (for the serving report)."""
        if self.events:
            return self.events[-1].get("solve_cache", {})
        return {}
