"""Serving metrics: latency percentiles, goodput, queues, utilization, SLOs.

The executor feeds per-request completion records and per-server counters
into :func:`summarize`, which produces a :class:`ServingReport` -- the JSON
payload of ``python -m repro serve --json`` and the rows of
``BENCH_serving.json``.

Definitions (per model and aggregated):

* **throughput** -- completed samples / makespan (arrival start to last
  completion);
* **goodput** -- SLO-satisfying completed samples / makespan (== throughput
  when the model has no SLO);
* **latency** -- request sojourn time, arrival to batch completion
  (p50/p95/p99 by nearest-rank on the exact sorted latencies);
* **queue depth** -- time-weighted mean and max of queued samples;
* **utilization** -- busy chip-seconds / (quota chips x makespan); the
  aggregate weights each model by its chip quota, so idle chips of the
  package count against it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

from ..core.costmodel import conserve_components, fold_components
from ..obs import MetricsRegistry, TimeSeries

__all__ = [
    "WATERFALL_COMPONENTS",
    "ModelMetrics",
    "ServingReport",
    "aggregate_waterfalls",
    "conserve_waterfall",
    "percentile",
    "summarize",
]

# Latency-waterfall components (Scope Lens).  Per completed request they
# fold -- in this fixed order -- bit-identically to the measured end-to-end
# latency (same conservation machinery as the DSE CostBreakdown).
WATERFALL_COMPONENTS = ("queue_wait", "batch_delay", "service",
                        "stall_time_mux", "dead_fault", "dead_autoscale")


def conserve_waterfall(components: dict, total: float,
                       order=WATERFALL_COMPONENTS) -> dict:
    """Waterfall components adjusted to fold bit-identically to ``total``."""
    return conserve_components(components, total, order=order)


def aggregate_waterfalls(waterfalls: dict[str, list[dict]],
                         order=WATERFALL_COMPONENTS) -> dict:
    """Aggregate per-request waterfalls into an attribution table.

    Returns per-model and overall rows: request count, mean latency,
    per-component mean seconds + share of total, the dominant component,
    and whether every request's components conserved its latency exactly.
    """
    def rows(wfs: list[dict]) -> dict:
        n = len(wfs)
        sums = dict.fromkeys(order, 0.0)
        total = 0.0
        conserved = True
        for wf in wfs:
            for k in order:
                sums[k] += wf.get(k, 0.0)
            total += wf["total"]
            if fold_components(wf, order) != wf["total"]:
                conserved = False
        comp = {
            k: {"mean_s": sums[k] / n if n else 0.0,
                "share": sums[k] / total if total > 0 else 0.0}
            for k in order
        }
        dominant = (max(order, key=lambda k: sums[k]) if n else None)
        return {"requests": n,
                "latency_mean_s": total / n if n else 0.0,
                "components": comp, "dominant": dominant,
                "conserved": conserved}

    out = {"per_model": {m: rows(wfs) for m, wfs in sorted(waterfalls.items())},
           "overall": rows([wf for wfs in waterfalls.values() for wf in wfs])}
    out["conserved"] = (out["overall"]["conserved"]
                        and all(r["conserved"]
                                for r in out["per_model"].values()))
    return out


def percentile(sorted_vals: list[float], q: float) -> float:
    """Nearest-rank percentile of an ascending list (0 when empty)."""
    if not sorted_vals:
        return 0.0
    k = max(1, int(-(-q * len(sorted_vals) // 100)))   # ceil without floats
    return sorted_vals[min(k, len(sorted_vals)) - 1]


def _queue_series(trace: list[tuple[float, int]]) -> TimeSeries:
    """The queue-depth step trace as an obs :class:`TimeSeries`.

    Statistics are time-weighted over the whole run (time 0 to ``t_end``;
    the queue is empty before its first event), so per-model values in one
    report share a denominator."""
    ts = TimeSeries()
    ts.extend(trace)
    return ts


@dataclass
class ModelMetrics:
    model: str
    chips: int
    arrived_requests: int = 0
    arrived_samples: int = 0
    completed_requests: int = 0
    completed_samples: int = 0
    dropped_requests: int = 0
    dropped_samples: int = 0
    # dropped, attributed: cause -> (requests, samples); the per-cause sums
    # equal the aggregate dropped_* fields (strict conservation evidence)
    drop_causes: dict = field(default_factory=dict)
    # samples still queued when the run ended (a failed-and-never-repaired
    # server strands its queue; conservation counts them explicitly)
    queued_end_requests: int = 0
    queued_end_samples: int = 0
    batches: int = 0
    throughput: float = 0.0
    goodput: float = 0.0
    latency_mean_s: float = 0.0
    latency_p50_s: float = 0.0
    latency_p95_s: float = 0.0
    latency_p99_s: float = 0.0
    latency_max_s: float = 0.0
    queue_mean: float = 0.0
    queue_max: int = 0
    queue_p95: float = 0.0          # time-weighted p95 of the depth series
    utilization: float = 0.0
    busy_s: float = 0.0
    slo_s: float | None = None
    slo_attainment: float = 1.0    # completed requests meeting the SLO

    def to_json(self) -> dict:
        return dict(self.__dict__)


@dataclass
class ServingReport:
    """Everything one simulated serving run produced."""
    mode: str                       # co-schedule mode the deployment ran
    package: str
    chips: int
    seed: int
    horizon_s: float                # arrival window
    makespan_s: float               # last completion (drain included)
    per_model: dict[str, ModelMetrics] = field(default_factory=dict)
    # aggregates
    total_arrived: int = 0
    total_completed: int = 0
    total_dropped: int = 0
    total_queued_end: int = 0       # samples stranded in queues at run end
    throughput: float = 0.0         # completed samples/s over the makespan
    goodput: float = 0.0            # SLO-satisfying samples/s
    latency_p95_s: float = 0.0      # over all requests
    slo_attainment: float = 1.0
    utilization: float = 0.0        # busy chip-seconds / (package x makespan)
    placement: dict = field(default_factory=dict)   # model -> per-flavor coords
    autoscale: dict | None = None
    faults: dict | None = None      # fault log / recovery metrics (see executor)
    meta: dict = field(default_factory=dict)
    # observability (repro.obs): queue-depth TimeSeries et al live here;
    # report.tracer is set by Solution.serve(tracer=...)
    metrics: Any = None             # MetricsRegistry
    tracer: Any = None              # Tracer
    # per-request latency waterfalls: model -> [ {component: s, total: s} ]
    waterfalls: dict = field(default_factory=dict)

    def explain(self) -> dict:
        """Latency attribution (Scope Lens): per-request waterfalls
        aggregated per model and overall, dead time by cause.  Every
        completed request's components fold bit-identically to its
        measured latency (``["conserved"]``)."""
        out = aggregate_waterfalls(self.waterfalls)
        out["dead_time_s"] = {
            "fault": sum(wf["dead_fault"] for wfs in self.waterfalls.values()
                         for wf in wfs),
            "autoscale": sum(wf["dead_autoscale"]
                             for wfs in self.waterfalls.values()
                             for wf in wfs),
            "time_mux": sum(wf["stall_time_mux"]
                            for wfs in self.waterfalls.values()
                            for wf in wfs),
        }
        return out

    @property
    def conserved(self) -> bool:
        """Strict conservation: every arrived sample was served, is still
        queued, or was dropped for a named cause."""
        if self.total_arrived != (self.total_completed + self.total_dropped
                                  + self.total_queued_end):
            return False
        # every drop must carry a cause that sums back to the aggregate
        for m in self.per_model.values():
            by_cause = sum(s for _, s in m.drop_causes.values())
            if by_cause != m.dropped_samples:
                return False
        return True

    def to_json(self) -> dict:
        out = {
            k: v for k, v in self.__dict__.items()
            if k not in ("per_model", "placement", "autoscale", "meta",
                         "metrics", "tracer", "waterfalls")
        }
        out["conserved"] = self.conserved
        if self.waterfalls:
            out["explain"] = self.explain()
        out["per_model"] = {m: mm.to_json() for m, mm in self.per_model.items()}
        out["placement"] = {
            m: {str(f): len(coords) for f, coords in zones.items()}
            for m, zones in self.placement.items()
        }
        if self.autoscale is not None:
            out["autoscale"] = self.autoscale
        out["meta"] = self.meta
        return out

    def describe(self) -> list[str]:
        lines = [
            f"{self.package} [{self.mode}] seed={self.seed}: "
            f"{self.total_completed}/{self.total_arrived} samples in "
            f"{self.makespan_s:.3f}s -> goodput {self.goodput:.1f}/s "
            f"(throughput {self.throughput:.1f}/s), p95 "
            f"{self.latency_p95_s * 1e3:.2f}ms, util {self.utilization:.0%}"
        ]
        for m in self.per_model.values():
            slo = (f" slo {m.slo_attainment:.0%}@{m.slo_s * 1e3:g}ms"
                   if m.slo_s else "")
            lines.append(
                f"  {m.model:12s} {m.chips:3d} chips  "
                f"{m.completed_samples:6d} done  {m.goodput:8.1f}/s  "
                f"p95 {m.latency_p95_s * 1e3:7.2f}ms  q~{m.queue_mean:.1f}"
                f"{slo}"
            )
        if self.autoscale is not None:
            ev = self.autoscale.get("events", [])
            lines.append(
                f"  autoscale: {len(ev)} re-solve(s), "
                f"cache {self.autoscale.get('solve_cache', {})}"
            )
        if self.faults is not None:
            f = self.faults
            ttr = f.get("mean_ttr_s")
            lines.append(
                f"  faults: {f.get('events', 0)} event(s), availability "
                f"{f.get('availability', 1.0):.1%}, "
                + (f"mean time-to-recover {ttr:.3f}s"
                   if ttr is not None else "no recovery needed")
                + (f", {f['unrecovered']} unrecovered"
                   if f.get("unrecovered") else "")
            )
            pre, post = f.get("goodput_pre_fault"), f.get(
                "goodput_post_recovery")
            if pre is not None and post is not None:
                lines.append(
                    f"    goodput pre-fault {pre:.1f}/s -> post-recovery "
                    f"{post:.1f}/s (through failure windows "
                    f"{f.get('goodput_in_failure') or 0.0:.1f}/s)"
                )
            if self.total_queued_end:
                lines.append(
                    f"    {self.total_queued_end} samples still queued at "
                    "run end (unrepaired capacity)"
                )
        return lines


def summarize(
    *,
    mode: str,
    package: str,
    chips: int,
    seed: int,
    horizon_s: float,
    makespan_s: float,
    arrived: dict[str, tuple[int, int]],          # model -> (requests, samples)
    dropped: dict[str, dict[str, tuple[int, int]]],   # model -> cause -> (r, s)
    latencies: dict[str, list[float]],            # per completed *request*
    request_samples: dict[str, list[int]],        # aligned with latencies
    batches: dict[str, int],
    busy_s: dict[str, float],
    model_chips: dict[str, int],
    queue_traces: dict[str, list[tuple[float, int]]],
    slos: dict[str, float | None],
    placement: dict,
    autoscale: dict | None = None,
    meta: dict | None = None,
    package_busy_chip_s: float | None = None,
    queued_end: dict[str, tuple[int, int]] | None = None,
    faults: dict | None = None,
    waterfalls: dict[str, list[dict]] | None = None,
) -> ServingReport:
    span = max(makespan_s, 1e-12)
    registry = MetricsRegistry()
    rep = ServingReport(mode=mode, package=package, chips=chips, seed=seed,
                        horizon_s=horizon_s, makespan_s=makespan_s,
                        placement=placement, autoscale=autoscale,
                        faults=faults, meta=meta or {}, metrics=registry,
                        waterfalls=waterfalls or {})
    all_lat: list[float] = []
    good_total = busy_chip_s = 0.0
    slo_met = slo_reqs = 0
    for model in sorted(arrived):
        a_req, a_smp = arrived[model]
        causes = dropped.get(model, {})
        d_req = sum(r for r, _ in causes.values())
        d_smp = sum(s for _, s in causes.values())
        q_req, q_smp = (queued_end or {}).get(model, (0, 0))
        lats = sorted(latencies.get(model, []))
        smps = request_samples.get(model, [])
        done_req = len(smps)
        done_smp = sum(smps)
        slo = slos.get(model)
        good = done_smp
        met = done_req
        if slo is not None:
            good = sum(s for lat, s in zip(latencies[model], smps)
                       if lat <= slo)
            met = sum(1 for lat in latencies[model] if lat <= slo)
        q_series = registry.series[f"queue_depth/{model}"] = _queue_series(
            queue_traces.get(model, []))
        q_mean = q_series.mean(makespan_s)
        q_max = q_series.max
        q_p95 = q_series.percentile(95, makespan_s)
        registry.histogram(f"latency_s/{model}").values.extend(lats)
        chips_m = model_chips.get(model, 0)
        busy = busy_s.get(model, 0.0)
        mm = ModelMetrics(
            model=model, chips=chips_m,
            arrived_requests=a_req, arrived_samples=a_smp,
            completed_requests=done_req, completed_samples=done_smp,
            dropped_requests=d_req, dropped_samples=d_smp,
            drop_causes={c: tuple(v) for c, v in causes.items()},
            queued_end_requests=q_req, queued_end_samples=q_smp,
            batches=batches.get(model, 0),
            throughput=done_smp / span,
            goodput=good / span,
            latency_mean_s=sum(lats) / done_req if done_req else 0.0,
            latency_p50_s=percentile(lats, 50),
            latency_p95_s=percentile(lats, 95),
            latency_p99_s=percentile(lats, 99),
            latency_max_s=lats[-1] if lats else 0.0,
            queue_mean=q_mean, queue_max=q_max, queue_p95=q_p95,
            utilization=busy / span if chips_m else 0.0,
            busy_s=busy, slo_s=slo,
            slo_attainment=met / done_req if done_req else 1.0,
        )
        rep.per_model[model] = mm
        rep.total_arrived += a_smp
        rep.total_completed += done_smp
        rep.total_dropped += d_smp
        rep.total_queued_end += q_smp
        all_lat.extend(lats)
        good_total += good
        busy_chip_s += busy * chips_m
        slo_met += met
        slo_reqs += done_req
    all_lat.sort()
    rep.throughput = rep.total_completed / span
    rep.goodput = good_total / span
    rep.latency_p95_s = percentile(all_lat, 95)
    rep.slo_attainment = slo_met / slo_reqs if slo_reqs else 1.0
    # callers whose servers share one physical resource (merged pipelines)
    # pass the de-duplicated busy chip-seconds explicitly
    if package_busy_chip_s is not None:
        busy_chip_s = package_busy_chip_s
    rep.utilization = busy_chip_s / (max(1, chips) * span)
    return rep
