"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Dispatch strategy (MegaBlocks/MaxText-style grouping, SPMD-friendly):
  1. router logits -> top-k expert ids + gates per token,
  2. flatten (token, k) slots, sort by expert id,
  3. slot position inside its expert group = rank - group_start,
  4. scatter into dense per-expert buffers [E, C, d] (capacity C, overflow
     dropped -- standard capacity-factor semantics),
  5. batched expert matmuls [E, C, d] x [E, d, ff] (this einsum is what EP
     shards over the 'model'/'expert' axis),
  6. gather back and combine with gates.

FLOPs = tokens * top_k * capacity_factor * expert_ffn -- the honest active
compute, not n_experts * dense.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    moe = cfg.moe
    d = cfg.d_model
    ff = moe.d_ff or cfg.d_ff
    E = moe.n_experts
    ks = jax.random.split(key, 4)
    s_in, s_out = d ** -0.5, ff ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, E)) * s_in).astype(jnp.float32),
        "w1": (jax.random.normal(ks[1], (E, d, ff)) * s_in).astype(dtype),
        "w2": (jax.random.normal(ks[2], (E, ff, d)) * s_out).astype(dtype),
    }
    if cfg.ffn_gated:
        p["w3"] = (jax.random.normal(ks[3], (E, d, ff)) * s_in).astype(dtype)
    return p


def _dispatch_group(xg, selg, gateg, E, K, C, dtype):
    """Local dispatch of one token group.  xg [Tg,d], selg/gateg [Tg,K].
    Returns (buffer [E*C, d], slot [Tg*K], tok [Tg*K], gate_sorted)."""
    Tg, d = xg.shape
    sel_flat = selg.reshape(Tg * K)
    tok_flat = jnp.repeat(jnp.arange(Tg), K)
    order = jnp.argsort(sel_flat)
    sel_sorted = sel_flat[order]
    tok_sorted = tok_flat[order]
    group_sizes = jnp.bincount(sel_flat, length=E)
    group_start = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(group_sizes)[:-1].astype(jnp.int32)]
    )
    pos = jnp.arange(Tg * K) - group_start[sel_sorted]
    keep = pos < C
    slot = jnp.where(keep, sel_sorted * C + pos, E * C)    # overflow row
    buf = jnp.zeros((E * C + 1, d), dtype)
    buf = buf.at[slot].set(xg[tok_sorted])
    gate_sorted = gateg.reshape(Tg * K)[order]
    return buf[: E * C], slot, tok_sorted, gate_sorted


def moe_ffn(params: dict, x: jax.Array, cfg: ModelConfig,
            constrain=lambda a, tag: a) -> jax.Array:
    """Grouped local dispatch (SPMD-scalable).

    A single *global* sort would force GSPMD to replicate the dispatch
    buffers and index vectors on every chip (hundreds of GB at 1M tokens).
    Instead tokens are reshaped into G groups -- an axis GSPMD shards over
    (data x model) -- the sort/scatter runs *per group* (vmap), and the
    grouped buffer [G, E, Cg, d] is transposed to [E, G*Cg, d] for the
    expert matmuls: that sharded transpose is exactly the dispatch
    all-to-all.  Capacity is per group (standard local-capacity semantics).
    """
    moe = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = moe.n_experts, moe.top_k
    G = min(moe.dispatch_groups, T)
    while T % G:
        G -= 1
    Tg = T // G
    Cg = max(1, int(moe.capacity_factor * Tg * K / E))

    xf = x.reshape(T, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), params["router"])
    gates_all = jax.nn.softmax(logits, axis=-1)
    gates, sel = jax.lax.top_k(gates_all, K)              # [T, K]
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

    xg = constrain(xf.reshape(G, Tg, d), "moe:groups")
    selg = sel.reshape(G, Tg, K)
    gateg = gates.reshape(G, Tg, K)
    bufs, slots, toks, gsort = jax.vmap(
        lambda a, b, c: _dispatch_group(a, b, c, E, K, Cg, x.dtype)
    )(xg, selg, gateg)                                     # bufs [G, E*Cg, d]

    # dispatch all-to-all: [G@shards, E, Cg, d] -> [E@shards, G, Cg, d].
    # Stays 4D (a pure transpose): dim-merging reshapes defeat GSPMD's
    # all-to-all pattern and fall back to 32 GiB all-gathers.
    eb = constrain(bufs.reshape(G, E, Cg, d), "moe:groups")
    eb = constrain(eb.transpose(1, 0, 2, 3), "moe:buffers")   # [E, G, Cg, d]

    h = jnp.einsum("egcd,edf->egcf", eb, params["w1"],
                   preferred_element_type=jnp.float32)
    if cfg.ffn_gated:
        h = jax.nn.silu(h) * jnp.einsum(
            "egcd,edf->egcf", eb, params["w3"], preferred_element_type=jnp.float32
        )
    else:
        h = jax.nn.gelu(h)
    out_e = jnp.einsum(
        "egcf,efd->egcd", h.astype(x.dtype), params["w2"],
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)
    out_e = constrain(out_e, "moe:buffers")

    # combine all-to-all back to groups, then local gather + scatter-add
    og = constrain(out_e.transpose(1, 0, 2, 3), "moe:groups")  # [G, E, Cg, d]
    og = og.reshape(G, E * Cg, d)

    def _combine(out_flat, slot, tok, gate):
        padded = jnp.concatenate([out_flat, jnp.zeros((1, d), x.dtype)], axis=0)
        contrib = padded[slot] * gate[:, None].astype(x.dtype)
        return jnp.zeros((Tg, d), x.dtype).at[tok].add(contrib)

    out = jax.vmap(_combine)(og, slots, toks, gsort)       # [G, Tg, d]
    out = constrain(out, "moe:groups")
    return out.reshape(B, S, d)


def moe_ffn_dense_fallback(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Every-token-through-every-expert oracle (tests only: exact, slow)."""
    moe = cfg.moe
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), params["router"])
    gates_all = jax.nn.softmax(logits, axis=-1)
    gates, sel = jax.lax.top_k(gates_all, moe.top_k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)
    h = jnp.einsum("td,edf->etf", xf, params["w1"], preferred_element_type=jnp.float32)
    if cfg.ffn_gated:
        h = jax.nn.silu(h) * jnp.einsum(
            "td,edf->etf", xf, params["w3"], preferred_element_type=jnp.float32
        )
    else:
        h = jax.nn.gelu(h)
    per_e = jnp.einsum("etf,efd->etd", h.astype(x.dtype), params["w2"],
                       preferred_element_type=jnp.float32)   # [E, T, d]
    mask = jax.nn.one_hot(sel, moe.n_experts, dtype=jnp.float32)  # [T,K,E]
    w = (mask * gates[..., None]).sum(1)                          # [T,E]
    out = jnp.einsum("etd,te->td", per_e.astype(jnp.float32), w)
    return out.reshape(B, S, d).astype(x.dtype)
