"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Wires the full stack: config -> Scope DSE plan -> sharded train step ->
synthetic data -> fault-tolerant loop with checkpointing.  On this CPU
container it is exercised with the reduced (smoke) configs; on a TPU pod the
same entry point runs the full configs over the production mesh.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.data import make_batch_iterator
from repro.ft import ResilientTrainer, StragglerMonitor
from repro.launch.mesh import make_mesh
from repro.models import init_params
from repro.optim import make_optimizer
from repro.runtime.planner import plan_for_cell
from repro.runtime.train import build_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", default="1x1",
                    help="data x model, e.g. 16x16")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--no-dse", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    dims = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_mesh(dims, ("data", "model"))
    plan = plan_for_cell(cfg, args.seq, args.batch, ("data", "model"),
                         model_axis=dims[1], kind="train",
                         use_dse=not args.no_dse)
    print(f"plan: {plan.p1}->{plan.p2} @ repeat {plan.transition_repeat} "
          f"(dse meta: {plan.meta})")

    step, _ = build_train_step(cfg, mesh, plan, base_lr=args.lr,
                               warmup=max(1, args.steps // 20),
                               total_steps=args.steps)
    params = init_params(cfg, jax.random.PRNGKey(0))
    init_fn, _u = make_optimizer(cfg.optimizer)
    opt = init_fn(params)

    it = make_batch_iterator(cfg, batch=args.batch, seq=args.seq)
    cache = {}

    def batch_fn(s):
        while s not in cache:
            i, b = next(it)
            cache[i] = {k: jnp.asarray(v) for k, v in b.items()}
            if len(cache) > 4:
                cache.pop(min(k for k in cache if k != s), None)
        return cache[s]

    mon = StragglerMonitor()
    trainer = ResilientTrainer(
        train_step=step, batch_fn=batch_fn, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, straggler=mon,
        on_straggler=lambda s, dt: print(f"  [straggler] step {s}: {dt:.2f}s"),
    )
    params, opt, hist = trainer.run(params, opt, n_steps=args.steps)
    for h in hist:
        if h["step"] % max(1, args.steps // 20) == 0 or h["step"] == 1:
            print(f"step {h['step']:5d} loss {h['loss']:.4f} ({h['time']*1e3:.0f} ms)")
    print(f"final loss {hist[-1]['loss']:.4f}; stragglers flagged: {len(mon.flagged)}")


if __name__ == "__main__":
    main()
