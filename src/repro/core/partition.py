"""Per-layer partition search (paper SSIV-B, third dimension).

Observation exploited by the paper: shallow layers have large activations
(=> WSP avoids replicating them) while deep layers have large weights
(=> ISP avoids replicating those).  The per-layer 2^L choice collapses to a
single WSP->ISP transition index: layers [0, idx) use WSP, layers [idx, L)
use ISP -- L+1 candidates, linear complexity.

Beyond-paper extension (``ep_for_moe``): MoE FFN layers may use EP (expert
parallelism) instead of the transition-dictated choice; the DSE tries both.
"""
from __future__ import annotations

from itertools import product
from typing import Iterator

from .graph import PARTITION_EP, PARTITION_ISP, PARTITION_WSP, LayerGraph


def transition_partitions(L: int, idx: int) -> tuple[str, ...]:
    """WSP for the first ``idx`` layers, ISP for the rest."""
    return tuple([PARTITION_WSP] * idx + [PARTITION_ISP] * (L - idx))


def enumerate_transition_points(L: int) -> Iterator[tuple[str, ...]]:
    for idx in range(L + 1):
        yield transition_partitions(L, idx)


def enumerate_exhaustive(L: int) -> Iterator[tuple[str, ...]]:
    """All 2^L assignments -- only for the validation experiment (Fig. 8)."""
    yield from product((PARTITION_WSP, PARTITION_ISP), repeat=L)


def apply_ep(graph: LayerGraph, partitions: tuple[str, ...], lo: int = 0) -> tuple[str, ...]:
    """Flip MoE FFN layers to EP (beyond-paper, DESIGN.md SS7)."""
    out = list(partitions)
    for k in range(len(partitions)):
        if graph.layers[lo + k].n_experts > 1:
            out[k] = PARTITION_EP
    return tuple(out)
