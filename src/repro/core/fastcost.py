"""Batched + memoized DSE evaluation engine (drop-in for :class:`CostModel`).

The reference :class:`~repro.core.costmodel.CostModel` walks Python objects
layer by layer for every candidate the DSE proposes.  Algorithm 1 proposes
millions of candidates for the paper's larger cases (resnet152 x 256), and
nearly all of them share cluster sub-problems with candidates evaluated
moments earlier: the transition-point sweep changes a few layers' partitions,
the CMT sweep re-splits the same layer ranges, and ``rebalance`` moves one
chip between two regions while every other region is untouched.

:class:`FastCostModel` exploits this twice over:

1. **Vectorized cluster evaluation.**  Per graph it precomputes NumPy arrays
   of ``flops``, ``weight_bytes``, ``in/out_bytes``, ``halo_bytes``,
   ``wsp/isp_parallel`` and expert counts (plus a weight-bytes prefix sum for
   segment load terms).  A cluster's computation time (Eq. 5), intra-region
   communication (Table II Case 1), and the greedy weight-placement plan
   (paper SSIII-B) are then array expressions over ``layers[lo:hi]`` instead
   of per-layer Python loops.  The array expressions replicate the reference
   model's arithmetic *operation by operation* so results agree to the last
   few ulps (the parity suite in ``tests/test_fastcost.py`` asserts 1e-9
   rtol; in practice values are almost always bit-identical).

2. **Cross-candidate memoization.**  The steady-state beat time of a cluster
   (Eq. 3 body) depends only on

   ``(graph, layer_lo, layer_hi, partitions, region_chips, chip_type,
      next_first_partition, next_chips, next_chip_type)``

   which is exactly the memo key.  Why this is sound: every term of the
   reference ``cluster_time`` reads only (a) the layer records in
   ``[layer_lo, layer_hi)`` -- fixed by the graph and the bounds, (b) the
   per-layer partition choices, the region size ``n`` and the region's chip
   flavor -- in the key, and (c) for the *last* layer's Table II Case 2
   hand-off, the next cluster's first-layer partition, region size and chip
   flavor (the hand-off crosses the flavor seam, whose bandwidth depends on
   both endpoints' flavors) -- also in the key.  Nothing else
   (segment membership, position within the segment, the allocation of other
   regions) enters the formula, so two candidates that agree on the key have
   equal cluster cost by construction.  The memo is shared across the
   transition-point sweep, the CMT sweep, the rebalance walk, the
   segment-count sweep, and the baselines, because they all funnel through
   :meth:`FastCostModel.cluster_time` / :meth:`segment_evaluator`.

The memo is also what makes ``rebalance`` *incremental*: moving one chip
from region ``f`` to region ``s`` changes the keys of clusters ``f`` and
``s`` (their ``region_chips``) and of their left boundary neighbors
``f-1`` / ``s-1`` (their ``next_chips``); ``_SegmentSweep.move`` re-probes
exactly those slots and every other cluster of the segment keeps its cached
time, so a rebalance step costs O(changed clusters), not O(all clusters).
``FastCostModel.stats`` (segment_evals / cluster_computes / memo sizes)
exposes this in benchmarks.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from .costmodel import INF, SAME_FLAVOR, CostModel, _flavor_tuple
from .graph import ClusterAssignment, LayerGraph
from .hw import eff

_WSP, _ISP, _EP = 0, 1, 2
_CODE = {"WSP": _WSP, "ISP": _ISP, "EP": _EP}
_PSTR = {_WSP: "WSP", _ISP: "ISP", _EP: "EP"}


@dataclass(frozen=True)
class _GraphData:
    """Per-graph NumPy precomputation (held alive for id() stability)."""
    graph: LayerGraph
    flops: np.ndarray
    weight_bytes: np.ndarray
    in_bytes: np.ndarray
    out_bytes: np.ndarray
    halo_bytes: np.ndarray
    wsp: np.ndarray
    isp: np.ndarray
    n_experts: np.ndarray
    active_experts: np.ndarray
    is_expert: np.ndarray          # n_experts > 1 (apply_ep's flip condition)
    expert_prefix: np.ndarray      # prefix sum of is_expert, len L+1
    wprefix: np.ndarray            # prefix sum of weight_bytes, len L+1
    dram_idx: tuple[int, ...]      # meta["dram_input"] layers (merged graphs)


def _graph_data(graph: LayerGraph) -> _GraphData:
    ls = graph.layers
    arr = lambda f: np.array([f(l) for l in ls], dtype=np.float64)
    w = arr(lambda l: l.weight_bytes)
    nexp = arr(lambda l: float(l.n_experts))
    is_expert = nexp > 1
    return _GraphData(
        graph=graph,
        flops=arr(lambda l: l.flops),
        weight_bytes=w,
        in_bytes=arr(lambda l: l.in_bytes),
        out_bytes=arr(lambda l: l.out_bytes),
        halo_bytes=arr(lambda l: l.halo_bytes),
        wsp=arr(lambda l: l.wsp_parallel),
        isp=arr(lambda l: l.isp_parallel),
        n_experts=nexp,
        active_experts=arr(lambda l: float(l.active_experts)),
        is_expert=is_expert,
        expert_prefix=np.concatenate(([0], np.cumsum(is_expert))),
        wprefix=np.concatenate(([0.0], np.cumsum(w))),
        dram_idx=tuple(
            i for i, l in enumerate(ls) if l.meta.get("dram_input")
        ),
    )


def _veff(dim: np.ndarray, granule: int) -> np.ndarray:
    """Vectorized :func:`repro.core.hw.eff` (same expression order).

    ``np.maximum(tiles, 1.0)`` only guards the ``dim <= 0`` lanes (whose
    result is overwritten with 1e-9 anyway); for dim > 0, tiles >= 1 and the
    quotient is bit-identical to the scalar ``eff``.
    """
    tiles = np.ceil(dim / granule)
    e = dim / (granule * np.maximum(tiles, 1.0))
    return np.where(dim <= 0, 1e-9, e)


def _seqsum(a) -> float:
    """Left-to-right Python summation, matching the reference model's ``sum``/
    ``+=`` accumulation bit-for-bit (NumPy's pairwise sum would not)."""
    return sum(a.tolist(), 0.0)


_STATIC = None      # sentinel key holding a cell's _ClusterStatic
_BODY = "body"      # sentinel key holding a cell's per-n body cache
_INF_BODY = (INF,)  # marker: placement infeasible at this n
# Below this cluster size a tight scalar loop beats NumPy dispatch overhead;
# the scalar path reuses the reference model's exact scalar arithmetic.
_SCALAR_MAX_LAYERS = 32
# Below this cluster size the 2D (k x layer) seed-phase batch fill is not
# worth its NumPy dispatch either; the lazy per-k paths handle it.
_BATCH_MIN_LAYERS = 8


class _ClusterStatic:
    """Allocation-independent precomputation for one (lo, hi, partitions).

    Everything here depends only on the memo cell's identity, so it is built
    once and reused for every region size ``n`` the DSE probes against this
    cluster -- the per-``n`` cost below is a handful of array expressions.
    """

    __slots__ = (
        "lo", "hi", "last_layer", "last_p", "fl", "w", "wsp",
        "isp", "is_wsp", "is_isp", "is_ep", "any_ep", "m_base", "men",
        "flip_order", "flip_w", "out_i", "halo_i", "ep_edge", "ww_edge",
        "iw_edge", "rows", "codes_l", "flip_l", "w_l",
    )

    def __init__(self, gd: _GraphData, lo: int, hi: int, codes: np.ndarray):
        self.lo, self.hi = lo, hi
        self.last_layer = gd.graph.layers[hi - 1]
        self.last_p = _PSTR[int(codes[-1])]
        self.fl = gd.flops[lo:hi]
        self.w = gd.weight_bytes[lo:hi]
        self.wsp = gd.wsp[lo:hi]
        self.isp = gd.isp[lo:hi]
        is_wsp, is_isp, is_ep = codes == _WSP, codes == _ISP, codes == _EP
        self.is_wsp, self.is_isp, self.is_ep = is_wsp, is_isp, is_ep
        self.any_ep = bool(is_ep.any())
        # EP activation dim is n-independent (Eq. 5 EP branch); others get
        # the plain wsp dim here and are divided by n per allocation.
        self.m_base = np.where(
            is_ep,
            self.wsp * (gd.active_experts[lo:hi] / np.maximum(1.0, gd.n_experts[lo:hi])),
            self.wsp,
        )
        self.men = np.maximum(1.0, gd.n_experts[lo:hi])
        # Distributed-weight flip order: replicated WSP layers, largest
        # first; stable sort matches the reference ``sorted(key=-w)``.
        wsp_idx = np.nonzero(is_wsp)[0]
        self.flip_order = wsp_idx[np.argsort(-self.w[wsp_idx], kind="stable")]
        self.flip_w = self.w[self.flip_order]
        # Table II Case 1 edge classification for intra-cluster hand-offs.
        if hi - lo > 1:
            p, q = codes[:-1], codes[1:]
            self.out_i = gd.out_bytes[lo : hi - 1]
            self.halo_i = gd.halo_bytes[lo : hi - 1]
            self.ep_edge = (p == _EP) | (q == _EP)
            self.ww_edge = (p == _WSP) & (q == _WSP)
            self.iw_edge = (p == _ISP) & (q == _WSP)
        else:
            self.out_i = self.halo_i = self.ep_edge = self.ww_edge = self.iw_edge = None
        # Scalar fast path (small clusters): per-layer tuples in plain
        # Python floats, so a body evaluation is one tight loop with the
        # reference model's exact arithmetic and no NumPy dispatch overhead.
        if hi - lo <= _SCALAR_MAX_LAYERS:
            self.codes_l = codes.tolist()
            self.w_l = self.w.tolist()
            self.rows = list(zip(
                self.fl.tolist(), self.w_l, self.wsp.tolist(),
                self.isp.tolist(), self.codes_l, gd.out_bytes[lo:hi].tolist(),
                gd.halo_bytes[lo:hi].tolist(), self.m_base.tolist(),
                self.men.tolist(),
            ))
            self.flip_l = self.flip_order.tolist()
        else:
            self.rows = None
            self.codes_l = self.flip_l = self.w_l = None


class FastCostModel(CostModel):
    """CostModel-compatible engine with vectorized + memoized evaluation.

    Exact-parity contract: for any (graph, schedule) the reference model can
    evaluate, ``cluster_time`` / ``segment_time`` / ``system_time`` return
    the same values within 1e-9 rtol, and the DSE driven through
    :meth:`segment_evaluator` picks the same argmin schedules.
    """

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._graphs: dict[int, _GraphData] = {}
        # Two-level memo: (graph, lo, hi, partitions) -> {(n, next_p0,
        # next_n) -> time}.  The outer lookup (hashing the partition tuple)
        # happens once per candidate; the per-allocation probes in the
        # rebalance inner loop only hash small int tuples.
        self._memo: dict[tuple, dict] = {}
        self._codes_cache: dict[tuple[str, ...], np.ndarray] = {}
        # _evals/_misses/_probes/_batched_bodies inherited from CostModel
        self.batched_seed_fill = True   # 2D (k x layer) seed-phase fill

    # ------------------------------------------------------------- plumbing
    def graph_data(self, graph: LayerGraph) -> _GraphData:
        gd = self._graphs.get(id(graph))
        if gd is None or gd.graph is not graph:
            gd = _graph_data(graph)
            self._graphs[id(graph)] = gd
        return gd

    def clear_memo(self) -> None:
        self._graphs.clear()
        self._memo.clear()
        self._evals = self._misses = self._probes = self._batched_bodies = 0

    @property
    def stats(self) -> dict:
        """Counters proving the memo/incrementality claims in benchmarks.

        Same schema as the reference :class:`CostModel.stats`;
        ``memo_hits = cluster_probes - cluster_computes`` is what the
        cross-candidate memo saved.
        """
        return {
            "segment_evals": self._evals,
            "cluster_computes": self._misses,
            "cluster_probes": self._probes,
            "memo_hits": self._probes - self._misses,
            "memo_cells": len(self._memo),
            "memo_entries": sum(len(c) - 2 for c in self._memo.values()),
            "batched_bodies": self._batched_bodies,
        }

    def _cluster_cell(
        self, gd: _GraphData, lo: int, hi: int, partitions: tuple[str, ...],
        ctype: str | None = None,
    ) -> dict:
        """Memo cell for an explicit partition tuple (generic API path)."""
        key = (id(gd.graph), lo, hi, partitions, ctype)
        cell = self._memo.get(key)
        if cell is None:
            cell = self._memo[key] = {
                _STATIC: _ClusterStatic(gd, lo, hi, self._codes(partitions)),
                _BODY: {},
            }
        return cell

    def _cluster_cell_hint(
        self, gd: _GraphData, lo: int, hi: int, k: int, ep: bool,
        ctype: str | None = None,
    ) -> dict:
        """Memo cell for a WSP^k ISP^(len-k) transition slice (DSE path).

        Algorithm 1's partition dimension only ever produces transition
        slices (optionally with MoE layers flipped to EP), so the DSE keys
        cells by the small ``(lo, hi, k, ep)`` tuple instead of hashing a
        partition tuple per probe -- and slices that coincide across
        different segment-level transition points share one cell.  ``ctype``
        (the hetero chip flavor) completes the key: cached times are only
        valid for the flavor whose scaled hardware computed them, so flavors
        never share cells (asserted in tests/test_multimodel.py).
        """
        key = (id(gd.graph), lo, hi, k, ep, ctype)
        cell = self._memo.get(key)
        if cell is None:
            codes = np.full(hi - lo, _ISP, dtype=np.int8)
            codes[:k] = _WSP
            if ep:
                codes[gd.is_expert[lo:hi]] = _EP
            cell = self._memo[key] = {
                _STATIC: _ClusterStatic(gd, lo, hi, codes),
                _BODY: {},
            }
        return cell

    def _codes(self, partitions: tuple[str, ...]) -> np.ndarray:
        c = self._codes_cache.get(partitions)
        if c is None:
            c = np.array([_CODE[p] for p in partitions], dtype=np.int8)
            self._codes_cache[partitions] = c
        return c

    # ------------------------------------------------- vectorized evaluation
    def _cluster_cost(self, st: _ClusterStatic, n: int,
                      next_p0: str | None, next_n: int | None,
                      body_cache: dict | None = None,
                      ctype: str | None = None,
                      next_ctype: str | None = SAME_FLAVOR) -> float:
        """Vectorized reference ``cluster_time`` for one memoized static.

        The last layer's Table II Case 2 boundary term is the only part that
        depends on the *next* cluster (its first partition, region size, and
        -- across a flavor seam -- its chip flavor), so the expensive array
        work -- the ``body`` -- is keyed by ``n`` alone in ``body_cache``
        and the final assembly is three scalar operations.  During
        rebalance, a donor's left neighbor changes only ``next_n``: its
        re-evaluation is a body cache hit plus scalar math, no NumPy at all.
        """
        body = body_cache.get(n) if body_cache is not None else None
        if body is None:
            body = self._cluster_body(st, n, self.hw_for(ctype))
            if body_cache is not None:
                body_cache[n] = body
        if body is _INF_BODY:
            return INF
        head, pre_last, comp_last = body
        comm_last = self.comm_time(
            st.last_layer, st.last_p, n, next_p0, next_n, False, ctype,
            next_ctype,
        )
        if self.overlap:
            t_last = pre_last + (comm_last if comm_last >= comp_last else comp_last)
        else:
            t_last = (pre_last + comm_last) + comp_last
        return head + t_last

    def _cluster_body(self, st: _ClusterStatic, n: int, hw=None):
        """Per-(cluster, n) array work: placement + Eq. 5/7 for all layers,
        minus the last layer's next-dependent comm.  Returns ``(head_sum,
        pre_last, comp_last)`` or ``_INF_BODY`` when weights don't fit.
        ``hw`` is the (possibly chip-type-scaled) hardware of the region."""
        if hw is None:
            hw = self.hw
        if st.rows is not None:
            return self._cluster_body_scalar(st, n, hw)
        w = st.w
        # --- greedy weight placement (reference place_weights, SSIII-B)
        if st.any_ep:
            div = np.where(st.is_ep, np.minimum(float(n), st.men), float(n))
            resident = np.where(st.is_wsp, w, w / div)
        else:
            resident = np.where(st.is_wsp, w, w / n)
        cap = hw.weight_capacity_per_chip
        s = _seqsum(resident)
        gather = None
        transient = 0.0
        if self.distributed_weights and s > cap and len(st.flip_order):
            # Reference semantics: flip the largest replicated WSP layers to
            # distributed storage one at a time while the (sequentially
            # re-summed) residency exceeds capacity.  Guess the flip count
            # from a running delta, then verify with the reference's exact
            # left-to-right sums so the boundary decision is bit-identical.
            def exact_after(t: int) -> float:
                r = resident.copy()
                idx = st.flip_order[:t]
                r[idx] = w[idx] / n
                return _seqsum(r)

            deltas = st.flip_w - st.flip_w / n      # residency drop per flip
            run = s - np.cumsum(deltas)
            t = int(np.searchsorted(-run, -cap))    # first t with run[t-1] <= cap
            t = min(t + 1, len(st.flip_order))
            while t > 0 and exact_after(t - 1) <= cap:
                t -= 1
            while t < len(st.flip_order) and exact_after(t) > cap:
                t += 1
            flips = st.flip_order[:t]
            resident[flips] = w[flips] / n
            gather = np.zeros_like(w)
            gather[flips] = w[flips] * (n - 1) / n
            s = _seqsum(resident)
            transient = max(
                ((2.0 * w[k]) / n for k in np.nonzero(gather > 0)[0]),
                default=0.0,
            )
        if (s + transient) > cap:
            return _INF_BODY

        # --- Eq. 5 computation (vectorized CostModel._util / comp_time)
        m_local = np.where(st.is_wsp, st.wsp / n, st.m_base)
        n_local = np.where(st.is_isp, st.isp / n, st.isp)
        util = _veff(m_local, hw.m_granule) * _veff(n_local, hw.n_granule)
        comp = st.fl / ((n * hw.flops_per_chip) * util)

        # --- Table II Case 1 comm for intra-cluster hand-offs (vectorized)
        pre = None
        if gather is not None:
            pre = gather / hw.nop_bw_per_chip
        if self.literal_pre:
            lit = w / hw.dram_bw_total
            pre = lit if pre is None else pre + lit
        if st.out_i is not None:
            vo = (n - 1) * st.out_i
            ha = st.halo_i * max(0, n - 1)
            vol = np.where(
                st.ep_edge, 2.0 * st.out_i,
                np.where(st.ww_edge, ha, np.where(st.iw_edge, vo + ha, vo)),
            )
            comm_i = np.where(vol <= 0, 0.0, vol / (n * hw.nop_bw_per_chip))
            # Eq. 7 per layer for layers [0, L-1), summed in reference order
            if self.overlap:
                head_arr = np.maximum(comm_i, comp[:-1])
            else:
                head_arr = comm_i + comp[:-1]
            if pre is not None:
                head_arr = (
                    pre[:-1] + head_arr if self.overlap
                    else (pre[:-1] + comm_i) + comp[:-1]
                )
            head = _seqsum(head_arr)
        else:
            head = 0.0
        pre_last = float(pre[-1]) if pre is not None else 0.0
        comp_last = float(comp[-1])
        return (head, pre_last, comp_last)

    def _cluster_body_scalar(self, st: _ClusterStatic, n: int, hw=None):
        """Small-cluster body: one tight loop of the reference model's exact
        scalar arithmetic (no NumPy dispatch), bit-identical by construction."""
        if hw is None:
            hw = self.hw
        cap = hw.weight_capacity_per_chip
        rows = st.rows
        L = len(rows)
        # --- greedy weight placement (reference place_weights, SSIII-B)
        resident = []
        append = resident.append
        for fl, w, wsp, isp, code, out, halo, m_base, men in rows:
            if code == _WSP:
                append(w)
            elif code == _EP:
                append(w / min(n, men))
            else:
                append(w / n)
        s = sum(resident)
        gather = None
        transient = 0.0
        if self.distributed_weights and s > cap and st.flip_l:
            gather = [0.0] * L
            w_l = st.w_l
            for k in st.flip_l:
                if s <= cap:
                    break
                wk = w_l[k]
                resident[k] = wk / n
                gather[k] = wk * (n - 1) / n
                s = sum(resident)
            transient = max(
                (2.0 * w_l[k] / n for k in range(L) if gather[k] > 0),
                default=0.0,
            )
        if (s + transient) > cap:
            return _INF_BODY
        # --- Eq. 5 / Table II Case 1 / Eq. 7 per layer (reference order)
        mg, ng = hw.m_granule, hw.n_granule
        peak, nop = hw.flops_per_chip, hw.nop_bw_per_chip
        dram = hw.dram_bw_total
        literal, overlap = self.literal_pre, self.overlap
        head = 0.0
        pre_last = comp_last = 0.0
        nm1 = n - 1
        last = L - 1
        for i, (fl, w, wsp, isp, code, out, halo, m_base, men) in enumerate(rows):
            if code == _WSP:
                m_l, n_l = wsp / n, isp
            elif code == _ISP:
                m_l, n_l = wsp, isp / n
            else:
                m_l, n_l = m_base, isp
            util = eff(m_l, mg) * eff(n_l, ng)
            comp = fl / (n * peak * util)
            pre = 0.0
            if literal:
                pre += w / dram
            if gather is not None and gather[i] > 0:
                pre += gather[i] / nop
            if i == last:
                pre_last, comp_last = pre, comp
                break
            ncode = rows[i + 1][4]
            if code == _EP or ncode == _EP:
                vol = 2.0 * out
            elif code == _WSP:
                vol = halo * nm1 if ncode == _WSP else nm1 * out
            elif ncode == _WSP:
                vol = nm1 * out + halo * nm1
            else:
                vol = nm1 * out
            comm = 0.0 if vol <= 0 else vol / (n * nop)
            if overlap:
                head += pre + (comm if comm >= comp else comp)
            else:
                head += pre + comm + comp
        return (head, pre_last, comp_last)

    # ------------------------------------------------- 2D seed-phase fill
    def _batch_seed_fill(self, gd: _GraphData, lo: int, hi: int, n: int,
                         ctype: str | None = None) -> None:
        """Batched (k x layer) bodies for every transition slice of one span.

        Algorithm 1's seed phase probes the same cluster span at the same
        region size ``n`` under every transition index ``k`` (WSP for the
        first ``k`` layers, ISP for the rest).  Filling those ``L + 1``
        bodies one row at a time repeats the identical array setup per row;
        this computes them as one ``(k x layer)`` matrix pass and writes the
        results into the per-k memo cells the sweep will probe.

        Exactness: every elementwise expression mirrors ``_cluster_body``
        operation by operation, and row reductions use ``np.cumsum`` (a
        strictly left-to-right accumulation, like ``_seqsum`` and the scalar
        path's ``+=``), so the stored bodies are bit-identical to what the
        lazy per-k evaluation would produce.  Rows whose weight placement
        overflows capacity (they need the greedy distributed-weight flip
        walk, or are infeasible) fall back to the per-k path, as do EP
        variants (never batched).
        """
        L = hi - lo
        hw = self.hw_for(ctype)
        cells = [
            self._cluster_cell_hint(gd, lo, hi, k, False, ctype)
            for k in range(L + 1)
        ]
        need = [k for k in range(L + 1) if n not in cells[k][_BODY]]
        if not need:
            return
        w = gd.weight_bytes[lo:hi]
        fl = gd.flops[lo:hi]
        wsp = gd.wsp[lo:hi]
        isp = gd.isp[lo:hi]
        ks = np.array(need, dtype=np.int64)
        lidx = np.arange(L)
        is_wsp = lidx[None, :] < ks[:, None]                    # K x L

        # --- residency (replicated WSP / sharded ISP), row-wise exact sums
        resident = np.where(is_wsp, w, w / n)
        s = np.cumsum(resident, axis=1)[:, -1]
        cap = hw.weight_capacity_per_chip
        over = s > cap
        if over.any():
            # These rows need the greedy flip walk (or are INF): per-k path.
            for row in np.nonzero(over)[0]:
                cell = cells[need[row]]
                cell[_BODY][n] = self._cluster_body(cell[_STATIC], n, hw)
        good = np.nonzero(~over)[0]
        if not len(good):
            return
        ks_g = ks[good]
        is_wsp = is_wsp[good]

        # --- Eq. 5 computation (rows of _cluster_body's vectorized path)
        m_local = np.where(is_wsp, wsp / n, wsp)
        n_local = np.where(is_wsp, isp, isp / n)
        util = _veff(m_local, hw.m_granule) * _veff(n_local, hw.n_granule)
        comp = fl / ((n * hw.flops_per_chip) * util)

        lit = (w / hw.dram_bw_total) if self.literal_pre else None
        if L > 1:
            # Transition-slice edge (l, l+1): WSP->WSP iff l <= k-2,
            # WSP->ISP iff l == k-1, ISP->ISP otherwise (ISP->WSP and EP
            # edges cannot occur in a WSP^k ISP^(L-k) row).
            out_i = gd.out_bytes[lo : hi - 1]
            halo_i = gd.halo_bytes[lo : hi - 1]
            vo = (n - 1) * out_i
            ha = halo_i * max(0, n - 1)
            ww = lidx[None, : L - 1] <= (ks_g[:, None] - 2)
            vol = np.where(ww, ha, vo)
            comm_i = np.where(vol <= 0, 0.0, vol / (n * hw.nop_bw_per_chip))
            comph = comp[:, :-1]
            if self.overlap:
                head_arr = np.maximum(comm_i, comph)
            else:
                head_arr = comm_i + comph
            if lit is not None:
                head_arr = (
                    lit[None, :-1] + head_arr if self.overlap
                    else (lit[None, :-1] + comm_i) + comph
                )
            head = np.cumsum(head_arr, axis=1)[:, -1]
        else:
            head = np.zeros(len(good))
        pre_last = float(lit[-1]) if lit is not None else 0.0
        comp_last = comp[:, -1]
        for row, krow in enumerate(ks_g.tolist()):
            cells[krow][_BODY][n] = (
                float(head[row]), pre_last, float(comp_last[row])
            )
        self._batched_bodies += len(good)

    # -------------------------------------------------------------- memoized
    def _cluster_time_fast(
        self,
        gd: _GraphData,
        lo: int,
        hi: int,
        partitions: tuple[str, ...],
        n: int,
        next_p0: str | None,
        next_n: int | None,
        ctype: str | None = None,
        next_ctype: str | None = None,
    ) -> float:
        cell = self._cluster_cell(gd, lo, hi, partitions, ctype)
        # The entry key carries the *neighbor's* flavor too: the last
        # layer's boundary term crosses the seam, so a cached time is only
        # valid against a next cluster of the same flavor.
        self._probes += 1
        k = (n, next_p0, next_n, next_ctype)
        t = cell.get(k)
        if t is None:
            self._misses += 1
            t = cell[k] = self._cluster_cost(
                cell[_STATIC], n, next_p0, next_n, cell[_BODY], ctype,
                next_ctype,
            )
        return t

    # --------------------------------------------- CostModel-compatible API
    def cluster_time(
        self,
        graph: LayerGraph,
        cluster: ClusterAssignment,
        next_cluster: ClusterAssignment | None,
        first_in_segment: bool,
        last_in_segment: bool,
    ) -> float:
        next_p0 = next_cluster.partitions[0] if next_cluster is not None else None
        next_n = next_cluster.region_chips if next_cluster is not None else None
        next_ct = next_cluster.chip_type if next_cluster is not None else None
        return self._cluster_time_fast(
            self.graph_data(graph),
            cluster.layer_lo,
            cluster.layer_hi,
            cluster.partitions,
            cluster.region_chips,
            next_p0,
            next_n,
            cluster.chip_type,
            next_ct,
        )

    def segment_time(
        self, graph: LayerGraph, clusters: tuple[ClusterAssignment, ...]
    ) -> tuple[float, list[float]]:
        gd = self.graph_data(graph)
        times = []
        for j, cl in enumerate(clusters):
            nxt = clusters[j + 1] if j + 1 < len(clusters) else None
            next_p0 = nxt.partitions[0] if nxt is not None else None
            next_n = nxt.region_chips if nxt is not None else None
            next_ct = nxt.chip_type if nxt is not None else None
            times.append(
                self._cluster_time_fast(
                    gd, cl.layer_lo, cl.layer_hi, cl.partitions,
                    cl.region_chips, next_p0, next_n, cl.chip_type, next_ct,
                )
            )
        bottleneck = max(times)
        if bottleneck == INF:
            return INF, times
        load = 0.0
        if not self.literal_pre:
            seg_weights = sum(
                float(gd.wprefix[cl.layer_hi] - gd.wprefix[cl.layer_lo])
                for cl in clusters
            )
            load += seg_weights / self.hw.dram_bw_total
        first_lo = clusters[0].layer_lo
        load += self.m * graph.layers[first_lo].in_bytes / self.hw.dram_bw_total
        if gd.dram_idx:
            # Mid-segment DRAM-staged entry layers (merged model boundaries);
            # mirrors the reference segment_time loop in index order.
            for i in gd.dram_idx:
                if i != first_lo and any(
                    cl.layer_lo <= i < cl.layer_hi for cl in clusters
                ):
                    load += self.m * graph.layers[i].in_bytes / self.hw.dram_bw_total
        n_cl = len(clusters)
        return load + (self.m + n_cl - 1) * bottleneck, times

    # --------------------------------------------------------- DSE hot path
    def segment_sweeper(self, graph, seg_lo, clustering, chip_type=None):
        """Per-clustering factory for Algorithm 1's partition sweep.

        Returns ``sweeper(partitions, transition=None) -> eval_fn`` where
        ``eval_fn(alloc) -> (latency, times)`` and ``eval_fn.move`` is the
        incremental rebalance path.  The allocation-independent precomputation
        (layer spans, Eq. 2 load terms, per-slot memo cells) lives in one
        reusable :class:`_SegmentSweep`; advancing the transition index by one
        only touches the single cluster whose partition slice changed.
        ``sweeper.prefill(seed)`` batch-fills the seed-phase bodies (2D
        ``k x layer`` vectorization) for every transition slice at once.
        ``chip_type`` is one flavor name (whole segment) or a per-cluster
        flavor sequence (mixed pipeline, seam-aware boundary terms).
        """
        sweep = _SegmentSweep(self, graph, seg_lo, clustering, chip_type)

        def configure(partitions, transition=None):
            sweep.set_partitions(partitions, transition)
            return sweep

        configure.prefill = sweep.prefill_seed
        return configure

    def segment_evaluator(self, graph, seg_lo, clustering, partitions,
                          transition=None, chip_type=None):
        """One-shot evaluator (CostModel-compatible); see segment_sweeper."""
        return self.segment_sweeper(graph, seg_lo, clustering, chip_type)(
            partitions, transition
        )


class _SegmentSweep:
    """Reusable segment evaluator: one clustering, many partition sets.

    ``set_partitions`` swaps in the memo cells for the given partition
    choice; Algorithm 1's linear transition sweep changes the slice of only
    one cluster per step, so consecutive calls re-probe a single slot.
    Calling the object evaluates a region allocation; :meth:`move`
    re-evaluates a one-chip transfer by recomputing only the donor/receiver
    clusters and their boundary-comm neighbors (the clusters whose memo keys
    contain the changed region sizes).
    """

    __slots__ = (
        "model", "gd", "spans", "rel", "n_cl", "load_const", "m",
        "fill_factor", "has_expert", "first_expert", "cells", "statics",
        "next_p0s", "cur_k", "cur_ep", "ctypes", "next_ctypes",
    )

    def __init__(self, model: FastCostModel, graph: LayerGraph, seg_lo: int,
                 clustering, chip_type=None) -> None:
        self.model = model
        # One flavor name applies to every cluster; a sequence gives each
        # cluster its own flavor (mixed pipelines).  next_ctypes[j] feeds the
        # seam-aware boundary term of slot j's memo entry key.
        self.ctypes = list(_flavor_tuple(chip_type, len(clustering)))
        self.next_ctypes = self.ctypes[1:] + [None]
        gd = model.graph_data(graph)
        self.gd = gd
        self.rel = tuple(clustering)
        self.spans = [(seg_lo + lo, seg_lo + hi) for lo, hi in clustering]
        n_cl = len(self.spans)
        self.n_cl = n_cl
        epre = gd.expert_prefix
        self.has_expert = [bool(epre[hi] > epre[lo]) for lo, hi in self.spans]
        self.first_expert = [bool(gd.is_expert[lo]) for lo, _ in self.spans]
        load_const = 0.0
        if not model.literal_pre:
            seg_weights = sum(
                float(gd.wprefix[hi] - gd.wprefix[lo]) for lo, hi in self.spans
            )
            load_const += seg_weights / model.hw.dram_bw_total
        first_lo = self.spans[0][0]
        load_const += (
            model.m * graph.layers[first_lo].in_bytes / model.hw.dram_bw_total
        )
        for i in gd.dram_idx:
            # mid-segment DRAM-staged entry layers (merged model boundaries)
            if i != first_lo and any(lo <= i < hi for lo, hi in self.spans):
                load_const += (
                    model.m * graph.layers[i].in_bytes / model.hw.dram_bw_total
                )
        self.load_const = load_const
        self.m = model.m
        self.fill_factor = model.m + n_cl - 1
        self.cells = [None] * n_cl
        self.statics = [None] * n_cl
        self.next_p0s = [None] * n_cl          # next_p0s[j] = slot j+1's first p
        self.cur_k = [None] * n_cl
        self.cur_ep = [None] * n_cl

    def set_partitions(self, partitions, transition=None) -> None:
        model, gd = self.model, self.gd
        if transition is None:
            # Generic path (arbitrary partition tuples): tuple-keyed cells.
            for j, (lo, hi) in enumerate(self.rel):
                p = partitions[lo:hi]
                cell = model._cluster_cell(gd, *self.spans[j], p, self.ctypes[j])
                self.cells[j] = cell
                self.statics[j] = cell[_STATIC]
                self.cur_k[j] = self.cur_ep[j] = None
                if j > 0:
                    self.next_p0s[j - 1] = p[0]
            return
        idx, ep_variant = transition
        for j, (lo, hi) in enumerate(self.rel):
            k = idx - lo
            if k < 0:
                k = 0
            elif k > hi - lo:
                k = hi - lo
            ep_j = ep_variant and self.has_expert[j]
            if k == self.cur_k[j] and ep_j == self.cur_ep[j]:
                continue
            cell = model._cluster_cell_hint(gd, *self.spans[j], k, ep_j,
                                            self.ctypes[j])
            self.cells[j] = cell
            self.statics[j] = cell[_STATIC]
            self.cur_k[j] = k
            self.cur_ep[j] = ep_j
            if j > 0:
                self.next_p0s[j - 1] = (
                    "EP" if (ep_j and self.first_expert[j])
                    else ("WSP" if k > 0 else "ISP")
                )

    def _probe(self, j: int, n: int, next_n: int | None) -> float:
        next_p0 = self.next_p0s[j]
        next_ct = self.next_ctypes[j]
        self.model._probes += 1
        k = (n, next_p0, next_n, next_ct)
        cell = self.cells[j]
        t = cell.get(k)
        if t is None:
            self.model._misses += 1
            t = cell[k] = self.model._cluster_cost(
                self.statics[j], n, next_p0, next_n, cell[_BODY],
                self.ctypes[j], next_ct,
            )
        return t

    def __call__(self, alloc):
        model = self.model
        model._evals += 1
        model._probes += self.n_cl
        n_cl = self.n_cl
        cells = self.cells
        statics = self.statics
        next_p0s = self.next_p0s
        cost = model._cluster_cost
        ctypes = self.ctypes
        next_ctypes = self.next_ctypes
        times = []
        append = times.append
        bottleneck = 0.0
        for j in range(n_cl):
            next_n = alloc[j + 1] if j + 1 < n_cl else None
            k = (alloc[j], next_p0s[j], next_n, next_ctypes[j])
            cell = cells[j]
            t = cell.get(k)
            if t is None:
                model._misses += 1
                t = cell[k] = cost(
                    statics[j], alloc[j], next_p0s[j], next_n, cell[_BODY],
                    ctypes[j], next_ctypes[j],
                )
            if t > bottleneck:
                bottleneck = t
            append(t)
        if bottleneck == INF:
            return INF, times
        return self.load_const + self.fill_factor * bottleneck, times

    def prefill_seed(self, alloc) -> None:
        """Batch-fill the seed-phase bodies of every transition slice.

        Called once per (clustering, seed allocation) by search_segment
        before the transition sweep; spans below _BATCH_MIN_LAYERS stay on
        the lazy per-k paths (scalar loops beat NumPy dispatch there).
        """
        model = self.model
        if not model.batched_seed_fill:
            return
        for j, (lo, hi) in enumerate(self.spans):
            if hi - lo >= _BATCH_MIN_LAYERS:
                model._batch_seed_fill(self.gd, lo, hi, alloc[j], self.ctypes[j])

    def move(self, base_alloc, base_times, dst, src, k=1):
        """Incremental re-eval after moving ``k`` chips src -> dst."""
        self.model._evals += 1
        n_cl = self.n_cl
        alloc = list(base_alloc)
        alloc[dst] += k
        alloc[src] -= k
        times = list(base_times)
        for j in {dst, src, dst - 1, src - 1}:
            if 0 <= j < n_cl:
                times[j] = self._probe(
                    j, alloc[j], alloc[j + 1] if j + 1 < n_cl else None
                )
        bottleneck = max(times)
        if bottleneck == INF:
            return INF, alloc, times
        return self.load_const + self.fill_factor * bottleneck, alloc, times
