"""Per-model throughput curves over chip counts -- the quota search's table.

For each (model, chip flavor) the quota search needs ``throughput(c)`` for
every candidate quota ``c``.  Each point is a full Scope DSE
(``search(graph, cost, c, chip_type=t)``); all points share one
:class:`~repro.core.fastcost.FastCostModel`, whose cluster-cost memo is keyed
on ``(graph, layer range, partitions, region_chips, ..., chip_type)`` -- so
consecutive ``c`` values re-solve mostly-cached sub-problems and a whole
curve costs a small multiple of one search (engine stats in the fig11
benchmark demonstrate the reuse).

Scope throughput is *not* monotone in chips (NoP overheads / utilization
collapse, paper Fig. 9), so a quota of ``c`` chips is served by the best
schedule using **at most** ``c`` chips (the rest idle): the curve exposes
that monotone envelope via :meth:`ThroughputCurve.envelope`.

Two extensions for large / heterogeneous packages:

* **Coarse-to-fine sampling** (``refine=True``): sample the coarse ``step``
  grid, then re-sample at step 1 inside one coarse cell around the argmax.
  The envelope stays correct at every quota (coarse points lower-bound it);
  only the peak region gets the exact resolution, which is where the quota
  search's winning candidates live.  ~10x fewer searches on 512+ chip
  packages.
* **Mixed-flavor curves** (:class:`MixedCurve`): throughput over per-flavor
  chip budget *tuples* (any flavor count), each point a full mixed-flavor
  DSE (:func:`repro.core.search.search_mixed`) that may land different
  clusters of the pipeline on different flavors.  The quota search combines
  these with the single-flavor envelopes so one model of a co-schedule can
  span flavors.
"""
from __future__ import annotations

import itertools
import math

from dataclasses import dataclass, field

import numpy as np

from ..core.costmodel import INF, CostModel
from ..core.graph import LayerGraph, ScopeSchedule
from ..core.search import search, search_mixed
from ..obs import current_tracer


@dataclass
class CurvePoint:
    chips: int
    latency: float
    throughput: float
    schedule: ScopeSchedule | None
    # KV-cache concurrency bound at this quota (set by kv_bound_curve when
    # the memory bound binds; None on pure compute-bound points).
    max_seqs: int | None = None


@dataclass
class ThroughputCurve:
    """throughput(c) for one (model, chip flavor), plus monotone envelope."""
    model: str
    chip_type: str | None
    points: dict[int, CurvePoint] = field(default_factory=dict)

    def envelope(self, max_chips: int) -> list[CurvePoint | None]:
        """``envelope()[c]`` = best point using at most ``c`` chips, for
        every c in 0..max_chips (index 0 is None) -- O(1) quota lookups."""
        out: list[CurvePoint | None] = [None] * (max_chips + 1)
        best = None
        for c in range(1, max_chips + 1):
            pt = self.points.get(c)
            if (
                pt is not None and pt.schedule is not None
                and (best is None or pt.throughput > best.throughput)
            ):
                best = pt
            out[c] = best
        return out


def candidate_counts(max_chips: int, step: int = 1) -> list[int]:
    """Curve sample points: all of 1..max_chips at ``step=1``; otherwise the
    same grid ``quota._flavor_splits`` enumerates -- multiples of ``step``
    plus the remainder-shifted multiples (the first model of a flavor group
    absorbs ``max_chips % step``) plus {1, max_chips} -- so every coarse
    quota resolves to a schedule actually sized for it."""
    step = max(1, step)
    if step == 1:
        return list(range(1, max_chips + 1))
    rem = max_chips % step
    pts = set(range(step, max_chips + 1, step)) | {1, max_chips}
    if rem:
        pts |= set(range(step + rem, max_chips + 1, step))
    return sorted(pts)


def warm_counts(center: int, max_chips: int, width: int) -> list[int]:
    """Warm-start sample window: counts within ``width`` of the incumbent's
    ``center`` chips, plus {1, max_chips} so the monotone envelope stays
    defined at every quota (tiny quotas forward-fill from 1; quotas above
    the window forward-fill from its top)."""
    lo = max(1, min(center, max_chips) - width)
    hi = min(max_chips, center + width)
    return sorted({1, max_chips} | set(range(lo, hi + 1)))


def throughput_curve(
    cost: CostModel,
    graph: LayerGraph,
    max_chips: int,
    chip_type: str | None = None,
    step: int = 1,
    paper_strict: bool = False,
    refine: bool = False,
    counts: list[int] | None = None,
) -> ThroughputCurve:
    curve = ThroughputCurve(graph.name, chip_type)

    def sample(c: int) -> None:
        sched = search(graph, cost, c, chip_type=chip_type,
                       paper_strict=paper_strict)
        if sched is None or sched.latency == INF:
            curve.points[c] = CurvePoint(c, INF, 0.0, None)
            return
        sched.meta["m_samples"] = cost.m
        curve.points[c] = CurvePoint(
            c, sched.latency, cost.m / sched.latency, sched
        )

    with current_tracer().span("curve", model=graph.name,
                               flavor=chip_type or "base",
                               max_chips=max_chips, step=step) as sp:
        for c in (counts if counts is not None
                  else candidate_counts(max_chips, step)):
            sample(c)
        if refine and step > 1:
            # Coarse-to-fine: fill the one-coarse-cell neighborhood of the
            # argmax at step 1, where the quota search's winners concentrate.
            best = max(
                (p for p in curve.points.values() if p.schedule is not None),
                key=lambda p: p.throughput,
                default=None,
            )
            if best is not None:
                lo = max(1, best.chips - step + 1)
                hi = min(max_chips, best.chips + step - 1)
                for c in range(lo, hi + 1):
                    if c not in curve.points:
                        sample(c)
        sp.set(points=len(curve.points))
    return curve


def build_curves(
    specs,
    cost: CostModel,
    flavors: list[tuple[str | None, int]],
    step: int = 1,
    paper_strict: bool = False,
    refine: bool = False,
    windows: dict[str, int] | None = None,
) -> dict[tuple[str, str | None], ThroughputCurve]:
    """Curves for every (model, flavor) pair, all through one shared memo.

    ``windows`` maps model name -> incumbent chip count (a warm start):
    each curve samples only :func:`warm_counts` around the incumbent
    instead of the full grid, making a re-solve's curve pass a handful of
    (mostly memo-hit) searches.  The envelopes stay defined everywhere --
    quotas off the window just resolve to the nearest sampled schedule
    below them -- so the quota enumeration is unchanged, merely anchored
    near the incumbent allocation.
    """
    out = {}
    for spec in specs:
        counts_by_cap: dict[int, list[int]] = {}
        if windows is not None and spec.name in windows:
            center = windows[spec.name]
            for _, cap in flavors:
                width = max(2, step, cap // 16)
                counts_by_cap[cap] = warm_counts(center, cap, width)
        for ctype, cap in flavors:
            out[(spec.name, ctype)] = throughput_curve(
                cost, spec.graph, cap, ctype, step, paper_strict, refine,
                counts=counts_by_cap.get(cap),
            )
    return out


# ---------------------------------------------------------------------------
# KV-cache-bounded curves: the memory axis of autoregressive decode
# ---------------------------------------------------------------------------

def service_law(sched: ScopeSchedule) -> tuple[int, float]:
    """``(stages, beat)`` of a solved schedule -- the serving executor's
    inversion ``beat = latency / (stages - 1 + m)`` of the pipeline model,
    so ``(stages - 1 + b) * beat`` is the service time of a ``b``-sample
    batch on this schedule."""
    m = sched.meta.get("m_samples", 1)
    stages = sum(len(seg.clusters) for seg in sched.segments) or 1
    return stages, sched.latency / (stages - 1 + m)


def kv_bound_curve(curve: ThroughputCurve, seq_bytes: float,
                   capacity_per_chip: float) -> ThroughputCurve:
    """KV-capacity-bounded view of a decode throughput curve.

    A quota of ``c`` chips holds at most ``K = floor(c * capacity_per_chip
    / seq_bytes)`` concurrent sequences of KV cache.  A server whose batch
    is capped at ``K`` sustains ``K / ((stages - 1 + K) * beat)`` samples/s
    under the point's own service law, which falls below the compute rate
    ``m / latency`` exactly when ``K < m``.  Points where the memory bound
    does not bind are returned as the *same object* -- with infinite
    capacity (or zero per-sequence state) the result is bit-identical to
    the input curve -- while KV-starved points flatten to the bound
    (``max_seqs`` records ``K``) and quotas too small for even one
    sequence become infeasible.
    """
    if seq_bytes <= 0:
        return curve
    out = ThroughputCurve(curve.model, curve.chip_type)
    for c, pt in curve.points.items():
        cap = capacity_per_chip * pt.chips
        if pt.schedule is None or math.isinf(cap):
            out.points[c] = pt
            continue
        K = int(cap // seq_bytes)
        if K <= 0:
            out.points[c] = CurvePoint(pt.chips, INF, 0.0, None, max_seqs=0)
            continue
        stages, beat = service_law(pt.schedule)
        bound = K / ((stages - 1 + K) * beat)
        if bound >= pt.throughput:
            out.points[c] = pt
        else:
            out.points[c] = CurvePoint(pt.chips, pt.latency, bound,
                                       pt.schedule, max_seqs=K)
    return out


# ---------------------------------------------------------------------------
# Mixed-flavor curves: one model spanning several chip flavors
# ---------------------------------------------------------------------------

@dataclass
class MixedPoint:
    quota: tuple[int, ...]         # chips per flavor, aligned with curve.flavors
    latency: float
    throughput: float
    schedule: ScopeSchedule | None


@dataclass
class MixedCurve:
    """throughput(c_0, ..., c_{F-1}) for one model over F chip flavors."""
    model: str
    flavors: tuple[str | None, ...]
    points: dict[tuple[int, ...], MixedPoint] = field(default_factory=dict)

    def envelope(self, caps, *envs):
        """F-dimensional monotone envelope combining this curve with the
        flavors' 1D envelopes.

        ``table[c_0][c_1]...[c_{F-1}]`` is the best record reachable with
        at most ``c_f`` chips of flavor ``f``: ``(throughput, kind,
        flavor_idx, point)`` where ``kind`` is ``"single"`` (a 1D
        CurvePoint on one flavor) or ``"mixed"`` (a MixedPoint spanning
        flavors), or ``None`` when nothing fits.  The table is an
        object-dtype ndarray (``prod(caps + 1)`` cells, one DP pass in C
        order); 2-flavor callers keep their ``table[a][b]`` indexing.
        """
        def better(x, y):
            return y if x is None or (y is not None and y[0] > x[0]) else x

        shape = tuple(c + 1 for c in caps)
        table = np.empty(shape, dtype=object)
        get_point = self.points.get
        for idx in np.ndindex(shape):
            cand = None
            for f, env in enumerate(envs):
                c = idx[f]
                if c > 0 and env[c] is not None:
                    cand = better(cand, (env[c].throughput, "single", f, env[c]))
            pt = get_point(idx)
            if pt is not None and pt.schedule is not None:
                cand = better(cand, (pt.throughput, "mixed", None, pt))
            for f in range(len(caps)):
                if idx[f] > 0:
                    prev = idx[:f] + (idx[f] - 1,) + idx[f + 1:]
                    cand = better(cand, table[prev])
            table[idx] = cand
        return table


# Refinement cell budget: a window sampled at step 1 may hold at most this
# many budget pairs; larger windows are walked with a coarser stride first
# (successive halving), so refinement cost stays bounded on big packages
# where a full step-1 cell would be (2*step-1)^2 mixed DSEs.
_MAX_REFINE_CELL = 81


def _refine_grid(center: int, span: int, cap: int, stride: int) -> list[int]:
    """Stride-spaced budgets covering ``center +- span``, clipped to [1, cap]
    (both window edges always included so the cell is fully bracketed)."""
    lo, hi = max(1, center - span), min(cap, center + span)
    pts = list(range(lo, hi + 1, stride))
    if pts[-1] != hi:
        pts.append(hi)
    return pts


def mixed_throughput_curve(
    cost: CostModel,
    graph: LayerGraph,
    flavors: list[tuple[str | None, int]],
    step: int = 1,
    paper_strict: bool = False,
    cut_window: int = 2,
    refine: bool = False,
) -> MixedCurve:
    """Sample mixed-flavor DSEs over the flavors' budget grid (any F >= 2).

    Only genuinely mixed budgets (at least two flavors > 0) are sampled --
    pure quotas are covered by the 1D curves, and :meth:`MixedCurve.envelope`
    merges both.  With three or more flavors each axis grid also includes 0,
    so points spanning any flavor *subset* are reachable.  ``step`` walks
    the same coarse grid as the 1D curves (a point's budget tuple is a
    *cap*, so coarse points stay valid under the envelope).

    ``refine=True`` is the F-dimensional analogue of the 1D coarse-to-fine
    curves: after the coarse grid, the one-coarse-cell neighborhood of the
    argmax budget tuple is re-sampled down to step 1.  Small cells are
    filled exactly (mirroring the 1D pass); cells larger than
    ``_MAX_REFINE_CELL`` tuples are narrowed by successive halving --
    re-sample the window at a quarter of the current stride around the
    running argmax until stride 1 -- so the pass stays a bounded multiple
    of the coarse grid even at 512-chip flavors.
    """
    assert len(flavors) >= 2, "mixed curves need at least two flavors"
    types = tuple(t for t, _ in flavors)
    caps = [cap for _, cap in flavors]
    F = len(flavors)
    curve = MixedCurve(graph.name, types)

    def sample(quota: tuple[int, ...]) -> None:
        sched = search_mixed(
            graph, cost, [(t, q) for t, q in zip(types, quota) if q > 0],
            paper_strict=paper_strict, cut_window=cut_window,
            include_single_flavor=False,
        )
        if sched is None or sched.latency == INF:
            curve.points[quota] = MixedPoint(quota, INF, 0.0, None)
            return
        sched.meta["m_samples"] = cost.m
        curve.points[quota] = MixedPoint(
            quota, sched.latency, cost.m / sched.latency, sched
        )

    # Per-axis sample grids: the 1D candidate counts, plus 0 when a third
    # flavor exists (a point may skip flavors; with F == 2 skipping either
    # flavor degenerates to a pure quota the 1D curves already cover).
    grids = [
        ([0] if F > 2 else []) + candidate_counts(cap, step) for cap in caps
    ]
    with current_tracer().span("curve:mixed", model=graph.name,
                               flavors="/".join(str(t) for t in types),
                               step=step) as sp:
        for quota in itertools.product(*grids):
            if sum(1 for q in quota if q > 0) >= 2:
                sample(quota)

        s = step
        while refine and s > 1:
            best = max(
                (p for p in curve.points.values() if p.schedule is not None),
                key=lambda p: p.throughput,
                default=None,
            )
            if best is None:
                break
            span = s - 1
            stride = (
                1 if (2 * span + 1) ** F <= _MAX_REFINE_CELL
                else max(2, s // 4)
            )
            for quota in itertools.product(*[
                _refine_grid(best.quota[f], span, caps[f], stride)
                for f in range(F)
            ]):
                if quota not in curve.points and (
                    sum(1 for q in quota if q > 0) >= 2
                ):
                    sample(quota)
            if stride == 1:
                break
            s = stride
        sp.set(points=len(curve.points))
    return curve
