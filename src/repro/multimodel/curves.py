"""Per-model throughput curves over chip counts -- the quota search's table.

For each (model, chip flavor) the quota search needs ``throughput(c)`` for
every candidate quota ``c``.  Each point is a full Scope DSE
(``search(graph, cost, c, chip_type=t)``); all points share one
:class:`~repro.core.fastcost.FastCostModel`, whose cluster-cost memo is keyed
on ``(graph, layer range, partitions, region_chips, ..., chip_type)`` -- so
consecutive ``c`` values re-solve mostly-cached sub-problems and a whole
curve costs a small multiple of one search (engine stats in the fig11
benchmark demonstrate the reuse).

Scope throughput is *not* monotone in chips (NoP overheads / utilization
collapse, paper Fig. 9), so a quota of ``c`` chips is served by the best
schedule using **at most** ``c`` chips (the rest idle): the curve exposes
that monotone envelope via :meth:`ThroughputCurve.envelope`.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from ..core.costmodel import INF, CostModel
from ..core.graph import LayerGraph, ScopeSchedule
from ..core.search import search


@dataclass
class CurvePoint:
    chips: int
    latency: float
    throughput: float
    schedule: ScopeSchedule | None


@dataclass
class ThroughputCurve:
    """throughput(c) for one (model, chip flavor), plus monotone envelope."""
    model: str
    chip_type: str | None
    points: dict[int, CurvePoint] = field(default_factory=dict)

    def envelope(self, max_chips: int) -> list[CurvePoint | None]:
        """``envelope()[c]`` = best point using at most ``c`` chips, for
        every c in 0..max_chips (index 0 is None) -- O(1) quota lookups."""
        out: list[CurvePoint | None] = [None] * (max_chips + 1)
        best = None
        for c in range(1, max_chips + 1):
            pt = self.points.get(c)
            if (
                pt is not None and pt.schedule is not None
                and (best is None or pt.throughput > best.throughput)
            ):
                best = pt
            out[c] = best
        return out


def candidate_counts(max_chips: int, step: int = 1) -> list[int]:
    """Curve sample points: all of 1..max_chips at ``step=1``; otherwise the
    same grid ``quota._flavor_splits`` enumerates -- multiples of ``step``
    plus the remainder-shifted multiples (the first model of a flavor group
    absorbs ``max_chips % step``) plus {1, max_chips} -- so every coarse
    quota resolves to a schedule actually sized for it."""
    step = max(1, step)
    if step == 1:
        return list(range(1, max_chips + 1))
    rem = max_chips % step
    pts = set(range(step, max_chips + 1, step)) | {1, max_chips}
    if rem:
        pts |= set(range(step + rem, max_chips + 1, step))
    return sorted(pts)


def throughput_curve(
    cost: CostModel,
    graph: LayerGraph,
    max_chips: int,
    chip_type: str | None = None,
    step: int = 1,
    paper_strict: bool = False,
) -> ThroughputCurve:
    curve = ThroughputCurve(graph.name, chip_type)
    for c in candidate_counts(max_chips, step):
        sched = search(graph, cost, c, chip_type=chip_type,
                       paper_strict=paper_strict)
        if sched is None or sched.latency == INF:
            curve.points[c] = CurvePoint(c, INF, 0.0, None)
            continue
        sched.meta["m_samples"] = cost.m
        curve.points[c] = CurvePoint(
            c, sched.latency, cost.m / sched.latency, sched
        )
    return curve


def build_curves(
    specs,
    cost: CostModel,
    flavors: list[tuple[str | None, int]],
    step: int = 1,
    paper_strict: bool = False,
) -> dict[tuple[str, str | None], ThroughputCurve]:
    """Curves for every (model, flavor) pair, all through one shared memo."""
    out = {}
    for spec in specs:
        for ctype, cap in flavors:
            out[(spec.name, ctype)] = throughput_curve(
                cost, spec.graph, cap, ctype, step, paper_strict
            )
    return out
