"""Serving bench: does the DSE winner also win *under load*?

For every fig11 traffic mix, solve the three deployments (``coschedule``,
``equal-split``, ``time-mux``) through one shared
:class:`~repro.api.SolutionCache`, then replay the *identical* seeded
request trace against each through the serving executor
(:mod:`repro.serving`).  The offered load is ``LOAD_FRACTION`` of the
co-schedule's solved capacity -- above the static baselines' capacity on
every committed mix, so a deployment that loses the DSE also saturates in
simulation: the co-schedule must achieve weighted goodput >= both
baselines (asserted), and its p95 is reported alongside.

A token-level scenario does the same for LLM serving: the ``llm-phase``
DSE picks a prefill/decode deployment (disaggregated vs colocated) for a
two-model smoke mix, and the chosen plan under continuous batching must
beat the best *whole-request* baseline -- both solved modes replayed with
static batching on the identical token trace -- by >= 1.1x SLO-gated
token goodput, with KV occupancy never exceeding the searched bound
(asserted, conservation strict).

A second scenario exercises the autoscale hook: traffic whose mix flips
hot/cold between phases, served once by the static co-schedule and once
with ``autoscale=`` enabled.  The autoscaler must demonstrably re-solve on
each flip -- with the re-solves hitting the shared engine memo, and the
return to a previously-seen mix hitting the whole-solution cache
(asserted; hit counts are committed in the row).

Results land in ``BENCH_serving.json`` at the repo root.
"""
from __future__ import annotations

import json
import os
import time

from repro import scope
from repro.serving import AutoscalePolicy, phased_trace, request_trace

from .common import M_SAMPLES

ROOT_BENCH = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                          "BENCH_serving.json")

# The fig11 mixes (benchmarks/fig11_multimodel.py).
MIXES = [
    ("resnet50:1,alexnet:1", "mcm16"),
    ("resnet152:1,resnet18:1", "mcm64"),
    ("resnet50:2,resnet18:1,alexnet:1", "mcm64"),
    ("resnet50:1,resnet18:1", "mcm64_hetero"),
    ("resnet50:4,resnet18:1", "mcm64_hetero"),
]

LOAD_FRACTION = 0.95       # offered load vs the co-schedule's capacity
N_REQUESTS = 1500
# Time-mux deployments round-robin on a 1s scheduling period: goodput is
# only meaningful once the horizon spans several periods (a shorter trace
# ends before late slices even open).
MIN_HORIZON_S = 8.0
SEED = 0


def _serve_row(rep) -> dict:
    # Time-weighted p95 of each model's queue-depth series (repro.obs
    # TimeSeries, via the report's metrics registry) -- gated: a p95 outside
    # [mean-ish, max] means the step-series accounting broke.
    queue_p95 = {}
    for m, mm in rep.per_model.items():
        assert 0 <= mm.queue_p95 <= mm.queue_max, (
            "queue p95 outside [0, max]", m, mm.queue_p95, mm.queue_max)
        queue_p95[m] = mm.queue_p95
    # per-row bottleneck labels: the dominant waterfall component (queue
    # wait / batch delay / service / dead time) per model, gated on the
    # exact-conservation invariant
    ex = rep.explain() if rep.waterfalls else None
    if ex is not None:
        assert ex["conserved"], "serving waterfalls not conserved"
    return {
        "mode": rep.mode,
        "goodput": rep.goodput,
        "throughput": rep.throughput,
        "p95_ms": rep.latency_p95_s * 1e3,
        "p99_ms": max(m.latency_p99_s for m in rep.per_model.values()) * 1e3,
        "utilization": rep.utilization,
        "completed": rep.total_completed,
        "arrived": rep.total_arrived,
        "conserved": rep.conserved,
        "makespan_s": rep.makespan_s,
        "queue_p95": queue_p95,
        "bottleneck": ({m: r["dominant"] for m, r in ex["per_model"].items()}
                       if ex else {}),
        "bottleneck_overall": (ex["overall"]["dominant"]
                               if ex and "overall" in ex else None),
    }


def run_mix(mix: str, hw_name: str, cache: scope.SolutionCache) -> dict:
    prob = scope.problem(mix, hw_name, m_samples=M_SAMPLES)
    co, eq, tm = scope.solve_many(
        [prob.with_options(strategy=s)
         for s in ("coschedule", "equal-split", "time-mux")],
        cache=cache,
    )
    assert co.feasible, (mix, hw_name)
    traffic, horizon = co.offered_traffic(LOAD_FRACTION, N_REQUESTS)
    horizon = max(horizon, MIN_HORIZON_S)
    trace = request_trace(traffic, horizon, seed=SEED)

    row = {
        "mix": mix, "hw": hw_name, "chips": co.hw.chips,
        "seed": SEED, "load_fraction": LOAD_FRACTION,
        "offered_rate": sum(traffic.values()),
        "n_requests": len(trace),
        "solved": {
            "coschedule": co.weighted_throughput,
            "equal-split": eq.weighted_throughput if eq.feasible else 0.0,
            "time-mux": tm.weighted_throughput if tm.feasible else 0.0,
        },
        "serving": {},
    }
    for name, sol in (("coschedule", co), ("equal-split", eq),
                      ("time-mux", tm)):
        if not sol.feasible:
            row["serving"][name] = None
            continue
        rep = sol.serve(trace=trace, horizon_s=horizon, seed=SEED)
        assert rep.conserved, (mix, name)
        row["serving"][name] = _serve_row(rep)

    co_good = row["serving"]["coschedule"]["goodput"]
    for name in ("equal-split", "time-mux"):
        base = row["serving"][name]
        if base is not None:
            assert co_good >= base["goodput"] * (1 - 1e-9), (
                "DSE winner lost goodput under load", mix, name,
                co_good, base["goodput"],
            )
    row["co_wins_goodput"] = True
    return row


# LLM scenario knobs: a gemma2+granite smoke mix on mcm16, decode-heavy
# requests (64 expected output tokens, cv 1.0 -- the long tail is what
# static batching drains on) at 90% of the chosen plan's capacity.
LLM_ARCHS = [("gemma2-9b", 2.0), ("granite-3-8b", 1.0)]
LLM_HW = "mcm16"
LLM_SEQ = 128
LLM_OUT = 64.0
LLM_SLO_TTFT_S = 0.05
LLM_SLO_TPOT_S = 0.002
LLM_GOODPUT_MARGIN = 1.1


def _llm_row(rep) -> dict:
    for m, mm in rep.per_model.items():
        assert mm.kv_peak_bytes <= mm.kv_capacity_bytes + 1e-6, (
            "KV occupancy exceeded the searched bound", m,
            mm.kv_peak_bytes, mm.kv_capacity_bytes)
    ex = rep.explain() if rep.waterfalls else None
    if ex is not None:
        assert ex["conserved"], "LLM waterfalls not conserved"
    return {
        "mode": rep.mode,
        "batching": rep.batching,
        "token_goodput": rep.token_goodput,
        "token_throughput": rep.token_throughput,
        "ttft_p95_ms": rep.ttft_p95_s * 1e3,
        "tpot_p95_ms": rep.tpot_p95_s * 1e3,
        "slo_attainment": rep.slo_attainment,
        "admitted_midbatch": rep.admitted_midbatch,
        "completed": rep.total_completed,
        "arrived": rep.total_arrived,
        "conserved": rep.conserved,
        "utilization": rep.utilization,
        "kv_peak_mib": {m: mm.kv_peak_bytes / 2**20
                        for m, mm in rep.per_model.items()},
        "kv_capacity_mib": {m: mm.kv_capacity_bytes / 2**20
                            for m, mm in rep.per_model.items()},
        "bottleneck": ({m: r["dominant"] for m, r in ex["per_model"].items()}
                       if ex else {}),
        "bottleneck_overall": (ex["overall"]["dominant"]
                               if ex and "overall" in ex else None),
    }


def run_llm() -> dict:
    """Token-level serving: the llm-phase DSE choice (continuous batching)
    vs the best whole-request baseline -- both solved deployment modes
    replayed with static batching on the identical token trace.  The
    chosen plan must win SLO-gated token goodput by >= 1.1x (asserted),
    admit mid-batch, keep KV under the searched bound, and conserve."""
    from repro.configs import get_smoke_config
    from repro.serving import TokenLengths

    cfgs = [get_smoke_config(n) for n, _ in LLM_ARCHS]
    wl = scope.WorkloadSpec.lm(cfgs, LLM_SEQ, [w for _, w in LLM_ARCHS])
    prob = scope.problem(wl, LLM_HW, strategy="llm-phase",
                         output_tokens=LLM_OUT, m_samples=M_SAMPLES)
    sol = scope.solve(prob)
    assert sol.feasible
    traffic, horizon = sol.offered_traffic(0.9, 1200)
    lengths = TokenLengths(prompt_mean=LLM_SEQ, output_mean=LLM_OUT,
                           output_cv=1.0, output_max=512)
    trace = request_trace(traffic, horizon, seed=SEED, lengths=lengths)
    kw = dict(trace=trace, horizon_s=horizon, seed=SEED,
              ttft_slo=LLM_SLO_TTFT_S, tpot_slo=LLM_SLO_TPOT_S)
    chosen = sol.serve(**kw)
    assert chosen.conserved
    assert chosen.admitted_midbatch > 0, \
        "continuous batching must admit into running decode batches"
    baselines = {}
    best = 0.0
    for mode, plan in sol.diagnostics["plans"].items():
        if plan is None:
            baselines[f"{mode}-static"] = None
            continue
        rep = sol.serve(plan=plan, static_batching=True, **kw)
        assert rep.conserved
        baselines[f"{mode}-static"] = _llm_row(rep)
        best = max(best, rep.token_goodput)
    ratio = chosen.token_goodput / max(1e-12, best)
    assert ratio >= LLM_GOODPUT_MARGIN, (
        "phase DSE must beat the best whole-request baseline",
        chosen.token_goodput, best, ratio)
    return {
        "archs": [f"{n}:{w:g}" for n, w in LLM_ARCHS],
        "hw": LLM_HW, "seed": SEED,
        "seq_len": LLM_SEQ, "output_tokens": LLM_OUT,
        "ttft_slo_ms": LLM_SLO_TTFT_S * 1e3,
        "tpot_slo_ms": LLM_SLO_TPOT_S * 1e3,
        "load_fraction": 0.9,
        "n_requests": len(trace),
        "mode_rates": sol.diagnostics["mode_rates"],
        "chosen_mode": sol.llm.mode,
        "solved_token_rate": sol.llm.token_rate,
        "chosen": _llm_row(chosen),
        "baselines": baselines,
        "goodput_vs_best_static": ratio,
    }


def run_drift() -> dict:
    """The autoscale scenario: a skewed mix flips hot/cold/hot at 75%
    offered load -- the static deployment (solved for 1:1 traffic) leaves
    the hot model ~27% over capacity every phase, while the autoscaled one
    re-plans within its observation window (re-solves share one engine
    memo; the flip back to the hot mix is a whole-solution cache hit)."""
    mix, hw_name = "alexnet:1,resnet18:1", "mcm16"
    cache = scope.SolutionCache()        # fresh: stats legible in the row
    prob = scope.problem(mix, hw_name, m_samples=M_SAMPLES)
    sol = cache.solve(prob)
    mm = sol.as_multimodel()
    names = sorted(a.model for a in mm.assignments)

    # Warm vs cold re-solve of the hot phase's mix: the cold figure is a
    # from-scratch solve (fresh engine, full quota grid); the warm figure
    # is the autoscaler's actual path -- shared engine memo plus
    # warm_start quota windows around the incumbent deployment.  The warm
    # re-solve is what keeps mid-run re-planning interactive (< 1s,
    # gated in scripts/ci.sh).
    drifted = scope.problem(f"{names[0]}:0.85,{names[1]}:0.15", hw_name,
                            m_samples=M_SAMPLES)
    t0 = time.perf_counter()
    cold_sol = scope.solve(drifted)
    resolve_cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm_sol = cache.solve(drifted.with_options(warm_start=sol))
    resolve_warm_s = time.perf_counter() - t0
    assert warm_sol.feasible and cold_sol.feasible
    assert warm_sol.multi.meta.get("warm_start"), \
        "the drifted re-solve must actually take the warm path"
    total = mm.mix_rate * sum(a.weight for a in mm.assignments) * 0.75
    hot = {names[0]: 0.85 * total, names[1]: 0.15 * total}
    cold = {names[0]: 0.15 * total, names[1]: 0.85 * total}
    trace = phased_trace([(hot, 3.0), (cold, 3.0), (hot, 3.0)], seed=SEED)
    policy = AutoscalePolicy(window_s=0.15, check_every_s=0.05,
                             drift_threshold=0.5, min_requests=50,
                             min_dwell_s=0.2, weight_quantum=0.25)
    static = sol.serve(trace=trace, max_delay_s=5e-4, seed=SEED)
    auto = sol.serve(trace=trace, max_delay_s=5e-4, seed=SEED,
                     autoscale=policy, cache=cache)
    events = auto.autoscale["events"]
    assert len(events) >= 2, "each mix flip must trigger a re-solve"
    assert any(e["cache_hit"] for e in events), \
        "returning to a seen mix must hit the solution cache"
    assert auto.conserved and static.conserved
    assert auto.goodput >= static.goodput - 1e-9, \
        "autoscaling must not lose goodput on the drift scenario"
    return {
        "mix": mix, "hw": hw_name, "seed": SEED,
        "phases": "85/15 -> 15/85 -> 85/15 of solved capacity x 0.75, "
                  "3s each",
        "n_requests": len(trace),
        "static": _serve_row(static),
        "autoscaled": _serve_row(auto),
        "autoscale_events": [
            {k: e[k] for k in
             ("t", "drift", "new_weights", "cache_hit", "dse_s",
              "redeploy_s")}
            for e in events
        ],
        "solve_cache": auto.autoscale["solve_cache"],
        "resolve_cold_s": resolve_cold_s,
        "resolve_warm_s": resolve_warm_s,
        "resolve_speedup": resolve_cold_s / max(1e-12, resolve_warm_s),
        "p95_improvement": (
            static.latency_p95_s / max(1e-12, auto.latency_p95_s)
        ),
    }


def run_faults() -> dict:
    """The resilience scenario: the little zone of mcm16_hetero fails
    twice mid-run at 75% offered load.  The same trace + fault schedule is
    served twice -- statically degraded (down servers wait for the repair)
    and with the degraded re-solve -- and recovery must demonstrably pay:
    strictly better SLO-gated goodput and p95, with the first degraded
    solve a SolutionCache miss and the repeat failure a whole-solution
    hit (asserted; committed in the row)."""
    mix, hw_name = "alexnet:1:500,resnet18:1:500", "mcm16_hetero"
    cache = scope.SolutionCache()        # fresh: stats legible in the row
    prob = scope.problem(mix, hw_name, m_samples=M_SAMPLES)
    sol = cache.solve(prob)
    traffic, horizon = sol.offered_traffic(0.75, 4 * N_REQUESTS)
    horizon = max(horizon, 4.0)
    trace = request_trace(traffic, horizon, seed=SEED)
    faults = "zone:little@20%:40%; zone:little@60%:80%"
    kw = dict(trace=trace, horizon_s=horizon, seed=SEED, cache=cache,
              faults=faults)
    static = sol.serve(fault_recovery=False, **kw)
    auto = sol.serve(**kw)
    assert auto.conserved and static.conserved
    recs = auto.faults["recoveries"]
    assert [r["cache_hit"] for r in recs if r["resolved"]] == [False, True], \
        "first degraded solve must miss, the repeat failure must hit"
    assert auto.goodput > static.goodput, \
        "degraded re-solve must win SLO-gated goodput through failures"
    assert auto.latency_p95_s < static.latency_p95_s, \
        "degraded re-solve must win p95 through failures"
    def _fault_row(rep):
        f = rep.faults
        return dict(_serve_row(rep), availability=f["availability"],
                    mean_ttr_s=f["mean_ttr_s"],
                    goodput_in_failure=f["goodput_in_failure"],
                    goodput_pre_fault=f["goodput_pre_fault"],
                    goodput_post_recovery=f["goodput_post_recovery"],
                    queued_end=rep.total_queued_end)
    return {
        "mix": mix, "hw": hw_name, "seed": SEED, "load_fraction": 0.75,
        "faults": faults, "n_requests": len(trace),
        "horizon_s": horizon,
        "static_degraded": _fault_row(static),
        "autoscaled_degraded": _fault_row(auto),
        "recoveries": [
            {k: r.get(k) for k in
             ("t_fail", "target", "ttr_s", "resolved", "cache_hit")}
            for r in recs
        ],
        "solve_cache": dict(cache.stats),
        "goodput_improvement": auto.goodput / max(1e-12, static.goodput),
        "p95_improvement": (
            static.latency_p95_s / max(1e-12, auto.latency_p95_s)
        ),
    }


def run(refresh: bool = False, mixes=None) -> dict:
    if not refresh and os.path.exists(ROOT_BENCH):
        with open(ROOT_BENCH) as f:
            return json.load(f)
    cache = scope.SolutionCache()
    out = {
        "load_fraction": LOAD_FRACTION,
        "n_requests": N_REQUESTS,
        "mixes": [run_mix(m, h, cache) for m, h in (mixes or MIXES)],
        "llm": run_llm(),
        "drift": run_drift(),
        "faults": run_faults(),
        "solve_cache": cache.stats,
    }
    with open(ROOT_BENCH, "w") as f:
        json.dump(out, f, indent=1)
    return out


def report(result: dict) -> list[str]:
    lines = ["mix,hw,co_goodput,eq_goodput,tm_goodput,co_p95_ms,eq_p95_ms,"
             "tm_p95_ms"]
    for r in result["mixes"]:
        s = r["serving"]
        def g(name, key):
            return s[name][key] if s[name] else 0.0
        lines.append(
            f"{r['mix']},{r['hw']},"
            f"{g('coschedule', 'goodput'):.0f},"
            f"{g('equal-split', 'goodput'):.0f},{g('time-mux', 'goodput'):.0f},"
            f"{g('coschedule', 'p95_ms'):.2f},"
            f"{g('equal-split', 'p95_ms'):.2f},{g('time-mux', 'p95_ms'):.2f}"
        )
    llm = result.get("llm")
    if llm:
        c = llm["chosen"]
        lines.append(
            f"# llm: {','.join(llm['archs'])} on {llm['hw']} -> "
            f"{llm['chosen_mode']} chosen, token goodput "
            f"{c['token_goodput']:.0f}/s continuous vs best static "
            f"({llm['goodput_vs_best_static']:.2f}x), TTFT p95 "
            f"{c['ttft_p95_ms']:.2f}ms, TPOT p95 {c['tpot_p95_ms']:.3f}ms, "
            f"midbatch {c['admitted_midbatch']}"
        )
    d = result["drift"]
    lines.append(
        f"# drift: {len(d['autoscale_events'])} re-solve(s), cache "
        f"{d['solve_cache']}, p95 {d['static']['p95_ms']:.2f}ms static -> "
        f"{d['autoscaled']['p95_ms']:.2f}ms autoscaled, re-solve "
        f"{d['resolve_cold_s']:.2f}s cold -> {d['resolve_warm_s']:.2f}s warm"
    )
    f = result.get("faults")
    if f:
        s, a = f["static_degraded"], f["autoscaled_degraded"]
        lines.append(
            f"# faults: goodput {s['goodput']:.0f}/s static-degraded -> "
            f"{a['goodput']:.0f}/s re-solved ({f['goodput_improvement']:.2f}x"
            f"), p95 {s['p95_ms']:.2f}ms -> {a['p95_ms']:.2f}ms, "
            f"availability {s['availability']:.3f} -> {a['availability']:.3f}"
            f", cache {f['solve_cache']}"
        )
    return lines


if __name__ == "__main__":
    import sys

    res = run(refresh="--refresh" in sys.argv)
    for line in report(res):
        print(line)
