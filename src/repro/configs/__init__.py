from .registry import ARCHS, get_config, get_smoke_config, SHAPES, get_shape  # noqa: F401
