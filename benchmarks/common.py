"""Shared benchmark utilities: scheduling runs with a JSON result cache.

All scheduling goes through the solver facade (``repro.scope.solve``); the
method name maps 1:1 onto a registered strategy (``scope`` / ``segmented``
/ ``sequential`` / ``full_pipeline`` / ...).
"""
from __future__ import annotations

import json
import os

from repro import scope

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
M_SAMPLES = 16          # inference batch streamed through the pipeline


def _cache_path(name: str) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, name + ".json")


def cached(name: str, fn, refresh: bool = False):
    path = _cache_path(name)
    if not refresh and os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    out = fn()
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    return out


def solve_cnn(net: str, hw, method: str = "scope", **opts) -> scope.Solution:
    """One facade solve on the default fast engine (exact CostModel parity)."""
    opts.setdefault("m_samples", M_SAMPLES)
    return scope.solve(scope.problem(net, hw, strategy=method, **opts))


def run_method(net: str, chips: int, method: str) -> dict:
    sol = solve_cnn(net, f"mcm{chips}", method)
    row = {"net": net, "chips": chips, "method": method,
           "valid": sol.feasible, "search_s": sol.diagnostics["dse_s"]}
    if not sol.feasible:
        return row
    row.update(
        latency_s=sol.latency,
        throughput=sol.throughput,
        n_segments=len(sol.schedule.segments) or None,
        clusters_per_segment=[s.n_clusters for s in sol.schedule.segments],
    )
    return row
