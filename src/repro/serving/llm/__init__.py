"""Token-level LLM serving: phase DSE, KV-cache bounds, continuous batching.

The subsystem splits an autoregressive request into its two phases and
makes each a first-class DSE citizen:

* :mod:`.kv` -- per-sequence resident state (KV blocks / SSM state) and
  per-quota capacity, the memory axis the decode search trades against;
* :mod:`.phases` -- disaggregated vs colocated deployment search over
  KV-bounded throughput curves (:func:`solve_phases` -> :class:`LLMPlan`);
* :mod:`.engine` -- :class:`TokenExecutor`, a deterministic DES with
  continuous batching, EDF/SLO-aware queueing, and a static whole-request
  baseline mode;
* :mod:`.metrics` -- TTFT/TPOT percentiles, KV occupancy series, and
  SLO-gated token goodput (:class:`LLMReport`).

Front door: ``scope.solve(..., options=SearchOptions(strategy="llm-phase"))``
on an ``WorkloadSpec.lm`` problem, then ``Solution.serve(...)``.
"""
from .engine import TokenExecutor, simulate_tokens
from .kv import kv_capacity_bytes, kv_seq_bytes, max_concurrent_seqs
from .metrics import LLMModelMetrics, LLMReport, summarize_llm
from .phases import LLMPlan, PhaseAssignment, describe_llm, solve_phases

__all__ = [
    "LLMModelMetrics",
    "LLMPlan",
    "LLMReport",
    "PhaseAssignment",
    "TokenExecutor",
    "describe_llm",
    "kv_capacity_bytes",
    "kv_seq_bytes",
    "max_concurrent_seqs",
    "simulate_tokens",
    "solve_phases",
    "summarize_llm",
]
