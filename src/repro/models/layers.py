"""Shared primitive layers (pure JAX, functional)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0.0:
        return x
    return cap * jnp.tanh(x / cap)


def rope_freqs(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions [...,] -> (cos, sin) of shape [..., head_dim//2], fp32."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [..., S, n, head_dim]; cos/sin [..., S, head_dim//2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s], axis=-1).astype(x.dtype)


def dense(x: jax.Array, w: jax.Array) -> jax.Array:
    """[..., d_in] @ [d_in, d_out] with bf16-safe accumulation."""
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def ffn(params: dict, x: jax.Array, gated: bool) -> jax.Array:
    if gated:
        h = jax.nn.silu(dense(x, params["w1"])) * dense(x, params["w3"])
    else:
        h = jax.nn.gelu(dense(x, params["w1"]))
    return dense(h, params["w2"])


def init_ffn(key, d_model: int, d_ff: int, gated: bool, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = d_model ** -0.5
    scale_out = d_ff ** -0.5
    p = {
        "w1": (jax.random.normal(k1, (d_model, d_ff)) * scale_in).astype(dtype),
        "w2": (jax.random.normal(k2, (d_ff, d_model)) * scale_out).astype(dtype),
    }
    if gated:
        p["w3"] = (jax.random.normal(k3, (d_model, d_ff)) * scale_in).astype(dtype)
    return p
