"""Distributed train step builder: loss -> grads -> clip -> optimizer.

Features:
* two-zone Scope execution (WSP/ISP transition from the schedule),
* gradient accumulation via ``lax.scan`` over microbatches (memory lever),
* optimizer selected per config (AdamW / Adafactor for the 400B MoE),
* donated params/opt-state buffers,
* optional int8 gradient quantization with error feedback (the compressed
  DP all-reduce path used by the shard_map pipeline runtime; under plain
  GSPMD it compresses the accumulation buffers).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import init_params, loss_fn
from ..models.config import ModelConfig
from ..optim import clip_by_global_norm, cosine_schedule, make_optimizer
from .compression import compress_decompress
from .sharding import (
    ShardPlan,
    batch_pspecs,
    make_constrain,
    opt_pspecs,
    param_pspecs,
    sanitize_pspecs,
    to_shardings,
    zero_shard,
)


def build_train_step(
    cfg: ModelConfig,
    mesh: Mesh,
    plan: ShardPlan,
    base_lr: float = 3e-4,
    warmup: int = 100,
    total_steps: int = 10_000,
    max_grad_norm: float = 1.0,
    compress: bool = False,
):
    """Returns (train_step, shardings dict).  train_step(params, opt, batch)
    -> (params, opt, metrics)."""
    init_fn, update_fn = make_optimizer(cfg.optimizer)
    lr = cosine_schedule(base_lr, warmup, total_steps)
    c1 = make_constrain(mesh, plan, zone=1)
    c2 = make_constrain(mesh, plan, zone=2)
    t_rep = plan.transition_repeat

    def microbatch_loss(params, tokens, labels, femb):
        return loss_fn(
            params, cfg, tokens, labels, femb,
            constrain=c1, constrain2=c2, transition_repeat=t_rep,
        )

    grad_fn = jax.value_and_grad(microbatch_loss)

    def train_step(params, opt_state, batch):
        tokens = batch.get("tokens")
        labels = batch["labels"]
        femb = batch.get("frontend_embeds")
        A = cfg.accum_steps
        if A > 1:
            B = labels.shape[0]
            assert B % A == 0, (B, A)
            mb = {
                k: v.reshape(A, B // A, *v.shape[1:])
                for k, v in batch.items()
            }

            def body(carry, xs):
                loss_acc, g_acc = carry
                loss, g = grad_fn(params, xs["tokens"], xs["labels"],
                                  xs.get("frontend_embeds"))
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (loss_acc + loss, g_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), g0), mb
            )
            loss = loss_sum / A
            grads = jax.tree.map(lambda g: g / A, grads)
        else:
            loss, grads = grad_fn(params, tokens, labels, femb)

        if compress:
            grads = jax.tree.map(compress_decompress, grads)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        params, opt_state = update_fn(params, grads, opt_state, lr)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    params_shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    p_specs = sanitize_pspecs(param_pspecs(cfg, plan, mesh), params_shapes, mesh)
    opt_shapes = jax.eval_shape(init_fn, params_shapes)
    o_specs = sanitize_pspecs(
        opt_pspecs(cfg, plan, mesh, p_specs, cfg.optimizer), opt_shapes, mesh
    )
    if plan.zero:
        # shape-aware ZeRO: shard moments over 'data' on a divisible dim
        o_specs = zero_shard(o_specs, opt_shapes, mesh)
    b_specs = batch_pspecs(cfg, plan)
    shardings = {
        "params": to_shardings(mesh, p_specs),
        "opt": to_shardings(mesh, o_specs),
        "batch": to_shardings(mesh, b_specs),
    }
    metric_sharding = {
        "loss": NamedSharding(mesh, P()),
        "grad_norm": NamedSharding(mesh, P()),
    }
    jitted = jax.jit(
        train_step,
        in_shardings=(shardings["params"], shardings["opt"], shardings["batch"]),
        out_shardings=(shardings["params"], shardings["opt"], metric_sharding),
        donate_argnums=(0, 1),
    )
    return jitted, shardings
