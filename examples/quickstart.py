"""Quickstart: schedule a network with Scope and inspect the result.

Everything goes through the solver facade (``repro.scope``): build a
declarative Problem, ``solve()`` it (the paper's full DSE, Algorithm 1),
compare against the three baseline schedulers by just switching the
strategy, and print the chosen segments / clusters / regions / partitions
-- the paper's Table I variables.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro import scope

NET, CHIPS = "resnet50", 64

prob = scope.problem(NET, f"mcm{CHIPS}")
graph = prob.workload.graph
print(f"{NET}: {len(graph)} layers, {graph.total_flops / 1e9:.1f} GFLOPs, "
      f"{graph.total_weight_bytes / 1e6:.1f} MB weights on {CHIPS} chiplets\n")

solutions = {}
for name in ("sequential", "full_pipeline", "segmented", "scope"):
    sol = scope.solve(prob.with_options(strategy=name))
    solutions[name] = sol if sol.feasible else None
    print(f"{name:14s} "
          f"{'%8.3f ms' % (sol.latency * 1e3) if sol.feasible else '  invalid'}"
          f"   {sol.throughput:8.1f} samples/s")

best = solutions["scope"]
sched = best.schedule
print(f"\nScope schedule ({sched.meta['n_segments']} segments, "
      f"searched in {best.diagnostics['dse_s']:.2f}s):")
for i, seg in enumerate(sched.segments):
    print(f"  segment {i}: {seg.n_clusters} clusters")
    for cl, t in zip(seg.clusters, seg.cluster_times):
        kinds = {p for p in cl.partitions}
        print(f"    layers[{cl.layer_lo:3d}:{cl.layer_hi:3d}] "
              f"region={cl.region_chips:3d} chips  P={'/'.join(sorted(kinds))}"
              f"  beat={t * 1e6:7.1f} us")

speedup = solutions["segmented"].latency / best.latency
print(f"\nScope vs segmented pipeline: {speedup:.2f}x")
