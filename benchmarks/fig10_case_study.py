"""Fig. 10: ResNet-152 x 256-chiplet case study.

(a) per-cluster computational-load balance: Scope's merged clusters have a
    lower load variance than the segmented pipeline's per-layer stages;
(b) energy breakdown (MAC / SRAM / NoP / DRAM): roughly equal totals --
    the throughput win comes from utilization, not an energy trade.
Also reports the segment counts (paper: segmented=3 vs Scope=2).
"""
from __future__ import annotations

import statistics

from repro.core.energy import schedule_energy
from repro.core.workloads import get_cnn

from .common import M_SAMPLES, cached, solve_cnn

NET, CHIPS = "resnet152", 256


def _balance(graph, sched):
    """Pipeline stage-matching quality: CV of per-cluster *beat times*
    (the paper's Fig 10a 'balanced distribution with smaller variance')."""
    times = [t for seg in sched.segments for t in seg.cluster_times]
    if not times or statistics.mean(times) == 0:
        return float("nan")
    return statistics.pstdev(times) / statistics.mean(times)


def run(refresh: bool = False):
    def _go():
        from repro import scope
        from repro.core.hw import get_hw

        g = get_cnn(NET)
        # One engine shared by both solves and the energy accounting.
        hw = get_hw(f"mcm{CHIPS}")
        cost = scope.SearchOptions(m_samples=M_SAMPLES).make_cost(hw)
        seg_sol = solve_cnn(NET, hw, "segmented", cost=cost)
        sc_sol = solve_cnn(NET, hw, "scope", cost=cost)
        seg, sc = seg_sol.schedule, sc_sol.schedule
        e_seg = schedule_energy(cost, g, seg)
        e_sc = schedule_energy(cost, g, sc)
        return {
            "segmented": {
                "latency_s": seg.latency,
                "n_segments": len(seg.segments),
                "clusters": [s.n_clusters for s in seg.segments],
                "load_cv": _balance(g, seg),
                "energy": e_seg.normalized(e_sc.total),
                "energy_total_J": e_seg.total,
            },
            "scope": {
                "latency_s": sc.latency,
                "n_segments": len(sc.segments),
                "clusters": [s.n_clusters for s in sc.segments],
                "load_cv": _balance(g, sc),
                "energy": e_sc.normalized(e_sc.total),
                "energy_total_J": e_sc.total,
            },
            "speedup": seg.latency / sc.latency,
            "energy_ratio": e_sc.total / e_seg.total,
        }

    return cached("fig10_case_study", _go, refresh)


def report(r) -> list[str]:
    lines = ["method,n_segments,load_cv,mac,sram,nop,dram,total_J"]
    for m in ("segmented", "scope"):
        d = r[m]
        e = d["energy"]
        lines.append(
            f"{m},{d['n_segments']},{d['load_cv']:.3f},"
            f"{e['mac']:.3f},{e['sram']:.3f},{e['nop']:.3f},{e['dram']:.3f},"
            f"{d['energy_total_J']:.4e}"
        )
    lines.append(f"# scope speedup {r['speedup']:.2f}x at energy ratio "
                 f"{r['energy_ratio']:.3f} (paper: ~equal energy)")
    lines.append(f"# cluster-load CV: scope {r['scope']['load_cv']:.3f} vs "
                 f"segmented {r['segmented']['load_cv']:.3f} (paper Fig 10a: "
                 "scope more balanced)")
    return lines
