"""Multi-model co-scheduling subsystem tests.

* quota search vs brute-force enumeration over all chip splits (tiny cases,
  homogeneous and heterogeneous packages);
* hetero-region memo-key correctness: no cross-flavor cache hits, parity
  with a reference model built on the flavor-scaled hardware;
* MultiModelSchedule validation;
* merged interleaving construction;
* regions.rebalance(paper_strict=...) semantics;
* 2D (k x layer) batched seed-phase fill parity.
"""
import math

import pytest

from repro.core.costmodel import INF, CostModel
from repro.core.fastcost import FastCostModel
from repro.core.graph import (
    MM_PARTITIONED,
    ClusterAssignment,
    LayerNode,
    ModelAssignment,
    MultiModelSchedule,
    ScopeSchedule,
    SegmentSchedule,
    chain,
    validate_multimodel,
    validate_schedule,
)
from repro.core.hw import (
    ChipType,
    get_hw,
    mcm_hetero,
    mcm_hetero3,
    mcm_table_iii,
    validate_region_types,
)
from repro.core.regions import rebalance
from repro.core.search import evaluate_segment, search, search_mixed, search_segment
from repro.core.workloads import get_cnn
from repro.multimodel import (
    ModelSpec,
    brute_force_partitioned,
    co_schedule,
    equal_split,
    merged_graph,
    parse_mix,
    search_merged,
    search_partitioned,
    search_partitioned_mixed,
    time_multiplexed,
)
from repro.multimodel.curves import throughput_curve
from repro.multimodel.quota import package_flavors


def tiny_graph(name: str, flops_scale: float = 1.0, L: int = 3):
    layers = [
        LayerNode(
            name=f"l{i}", kind="conv", flops=flops_scale * (2.0 + i) * 1e8,
            weight_bytes=48e3 * (1 + i % 2), in_bytes=32e3, out_bytes=24e3,
            halo_bytes=512.0, wsp_parallel=28.0, isp_parallel=128.0,
        )
        for i in range(L)
    ]
    return chain(name, layers)


def close(a, b, rtol=1e-9):
    return a == b or abs(a - b) <= rtol * max(abs(a), abs(b))


# ---------------------------------------------------------- quota parity

class TestQuotaParity:
    def test_tiny_homogeneous_matches_brute_force(self):
        hw = mcm_table_iii(8)
        specs = [
            ModelSpec(tiny_graph("a", 1.0), 1.0),
            ModelSpec(tiny_graph("b", 3.0), 2.0),
        ]
        cost = FastCostModel(hw, m_samples=16)
        fast = search_partitioned(specs, cost)
        lam_bf, assign_bf = brute_force_partitioned(specs, hw, m_samples=16)
        assert fast is not None and lam_bf > 0
        assert close(fast.mix_rate, lam_bf), (fast.mix_rate, lam_bf)

    def test_tiny_heterogeneous_matches_brute_force(self):
        hw = mcm_hetero(8, big_fraction=0.5, little_flops_scale=0.4)
        specs = [
            ModelSpec(tiny_graph("a", 1.0), 1.0),
            ModelSpec(tiny_graph("b", 4.0), 1.0),
        ]
        cost = FastCostModel(hw, m_samples=16)
        fast = search_partitioned(specs, cost)
        lam_bf, assign_bf = brute_force_partitioned(specs, hw, m_samples=16)
        assert fast is not None and lam_bf > 0
        assert close(fast.mix_rate, lam_bf), (fast.mix_rate, lam_bf)

    def test_equal_split_is_dominated(self):
        """Equal split is one of the enumerated quotas -> co >= equal."""
        hw = mcm_table_iii(16)
        specs = parse_mix("alexnet:1,resnet18:1")
        cost = FastCostModel(hw, m_samples=16)
        co = co_schedule(specs, hw, cost=cost)
        eq = equal_split(specs, cost)
        tm = time_multiplexed(specs, cost)
        assert co.weighted_throughput >= eq.weighted_throughput - 1e-9
        assert co.weighted_throughput >= tm.weighted_throughput - 1e-9

    def test_envelope_handles_non_monotone_curves(self):
        hw = mcm_table_iii(16)
        cost = FastCostModel(hw, m_samples=16)
        curve = throughput_curve(cost, get_cnn("alexnet"), 16)
        env = curve.envelope(16)
        assert env[0] is None
        tps = [env[c].throughput for c in range(1, 17) if env[c]]
        assert all(b >= a - 1e-12 for a, b in zip(tps, tps[1:]))
        # envelope point never uses more chips than the quota
        for c in range(1, 17):
            if env[c]:
                assert env[c].chips <= c


# ------------------------------------------------------ hetero memo keys

class TestHeteroMemo:
    def test_no_cross_flavor_cache_hits(self):
        """The same cluster evaluated under two flavors must be computed
        twice (distinct memo cells) and give flavor-scaled results."""
        hw = mcm_hetero(16, big_fraction=0.5, little_flops_scale=0.5)
        g = get_cnn("alexnet")
        fast = FastCostModel(hw, m_samples=16)
        clustering = ((0, len(g)),)
        partitions = tuple(["WSP"] * 2 + ["ISP"] * (len(g) - 2))
        lat_big, _ = evaluate_segment(fast, g, 0, clustering, partitions, [8],
                                      chip_type="big")
        computes_after_big = fast.stats["cluster_computes"]
        lat_little, _ = evaluate_segment(fast, g, 0, clustering, partitions,
                                         [8], chip_type="little")
        computes_after_little = fast.stats["cluster_computes"]
        # little must NOT have been served from big's cache
        assert computes_after_little > computes_after_big
        assert lat_big < lat_little  # little has half the FLOPs/chip
        # re-evaluating either flavor is now a pure cache hit
        lat_big2, _ = evaluate_segment(fast, g, 0, clustering, partitions, [8],
                                       chip_type="big")
        assert lat_big2 == lat_big
        assert fast.stats["cluster_computes"] == computes_after_little

    @pytest.mark.parametrize("flavor", ["big", "little"])
    def test_flavor_parity_with_scaled_reference(self, flavor):
        """Evaluating on a flavor == reference model on the scaled hardware."""
        hw = mcm_hetero(16, big_fraction=0.5,
                        little_flops_scale=0.4, little_nop_scale=0.6)
        g = get_cnn("alexnet")
        fast = FastCostModel(hw, m_samples=16)
        ref = CostModel(hw.typed(flavor), m_samples=16)
        L = len(g)
        for t in (0, 2, L):
            partitions = tuple(["WSP"] * t + ["ISP"] * (L - t))
            for regions in ([8], [3, 5]):
                clustering = (
                    ((0, L),) if len(regions) == 1 else ((0, 2), (2, L))
                )
                lf, _ = evaluate_segment(fast, g, 0, clustering, partitions,
                                         regions, chip_type=flavor)
                lr, _ = evaluate_segment(ref, g, 0, clustering, partitions,
                                         regions)
                assert close(lf, lr), (flavor, t, regions, lf, lr)

    def test_search_prefers_big_flavor(self):
        hw = mcm_hetero(32, big_fraction=0.5, little_flops_scale=0.25)
        cost = FastCostModel(hw, m_samples=16)
        g = get_cnn("resnet18")
        sb = search(g, cost, 16, chip_type="big")
        sl = search(g, cost, 16, chip_type="little")
        assert sb.latency < sl.latency

    def test_validate_region_types(self):
        bad = mcm_table_iii(16)
        bad = bad.__class__(**{**bad.__dict__,
                               "region_types": (ChipType("big", 9),
                                                ChipType("little", 9))})
        with pytest.raises(AssertionError):
            validate_region_types(bad)


# ------------------------------------------------------ mixed quotas

class TestMixedQuota:
    """Mixed-flavor quota splits: one model spanning both chip flavors."""

    def test_mixed_beats_or_matches_single_flavor_brute_force(self):
        """The mixed-enabled co-schedule on mcm_hetero must be >= the
        exhaustive *single-flavor* quota assignment (brute force with fresh
        searches per candidate)."""
        hw = mcm_hetero(8, big_fraction=0.5, little_flops_scale=0.4)
        specs = [
            ModelSpec(tiny_graph("a", 1.0), 1.0),
            ModelSpec(tiny_graph("b", 3.0), 2.0),
        ]
        cost = FastCostModel(hw, m_samples=16)
        co = co_schedule(specs, hw, cost=cost)   # validates internally
        lam_bf, _ = brute_force_partitioned(specs, hw, m_samples=16)
        assert lam_bf > 0
        assert co.mix_rate >= lam_bf * (1 - 1e-9), (co.mix_rate, lam_bf)

    def test_search_partitioned_mixed_dominates_and_validates(self):
        hw = mcm_hetero(8, big_fraction=0.5, little_flops_scale=0.5)
        specs = [
            ModelSpec(tiny_graph("a", 1.0), 1.0),
            ModelSpec(tiny_graph("b", 2.0), 1.0),
        ]
        cost = FastCostModel(hw, m_samples=16)
        part = search_partitioned(specs, cost)
        pm = search_partitioned_mixed(specs, cost)
        assert pm is not None
        # the mixed enumeration includes every single-flavor quota split
        # through the 1D envelopes, so it can only do better
        assert pm.weighted_throughput >= part.weighted_throughput * (1 - 1e-9)
        graphs = {s.name: s.graph for s in specs}
        validate_multimodel(pm, graphs, dict(package_flavors(hw)))

    def test_spanning_wins_when_weights_overflow_one_flavor(self):
        """A model whose weights overflow either flavor's chips alone: the
        single-flavor search is forced into sequential segments (one per
        layer, each re-deployed through DRAM), while the mixed per-cluster
        flavor search pipelines the whole model across both flavors in one
        segment -- a strict win."""
        cap = mcm_table_iii(4).weight_capacity_per_chip
        layers = [
            LayerNode(
                name=f"l{i}", kind="conv", flops=1e9,
                weight_bytes=1.5 * cap, in_bytes=32e3, out_bytes=24e3,
                wsp_parallel=28.0, isp_parallel=128.0,
            )
            for i in range(2)
        ]
        g = chain("fat", layers)
        # 2 big + 2 little, mildly asymmetric so the little run does not
        # itself become a worse bottleneck than the sequential re-deploys
        hw = mcm_hetero(4, big_fraction=0.5,
                        little_flops_scale=0.9, little_nop_scale=0.9)
        cost = FastCostModel(hw, m_samples=16)
        singles = []
        for ctype in ("big", "little"):
            s = search(g, cost, 2, chip_type=ctype)
            assert s is None or s.n_segments == 2   # can't fit one segment
            if s is not None:
                singles.append(s.latency)
        mixed = search_mixed(g, cost)
        assert mixed is not None and mixed.latency < float("inf")
        assert mixed.latency < min(singles)         # strictly better
        assert mixed.n_segments == 1                # one pipelined wave
        flavors_used = {
            cl.chip_type for seg in mixed.segments for cl in seg.clusters
        }
        assert flavors_used == {"big", "little"}
        # and the quota layer surfaces it as a spanning assignment
        co = co_schedule([ModelSpec(g, 1.0)], hw, cost=cost)
        assert co is not None and co.weighted_throughput > 0
        a = co.assignments[0]
        assert a.chip_quota and len([c for _, c in a.chip_quota if c]) == 2

    def test_time_mux_switch_cost_charged(self):
        hw = mcm_table_iii(16)
        specs = parse_mix("alexnet:1,resnet18:1")
        cost = FastCostModel(hw, m_samples=16)
        free = time_multiplexed(specs, cost)
        paid = time_multiplexed(specs, cost, switch_cost=True)
        slow = time_multiplexed(specs, cost, switch_cost=True,
                                switch_period_s=0.01)
        assert paid.weighted_throughput < free.weighted_throughput
        # longer periods amortize the reload: monotone in the period
        assert paid.weighted_throughput > slow.weighted_throughput
        # useful shares stay a valid time split
        assert sum(a.time_share for a in paid.assignments) <= 1.0 + 1e-9
        graphs = {s.name: s.graph for s in specs}
        validate_multimodel(paid, graphs, {None: hw.chips})

    def test_grouped_rebalance_conserves_pools(self):
        """groups= restricts chip moves to within a pool: per-pool totals
        are invariants of the walk, and the bottleneck pool equalizes."""
        def eval_fn(alloc):
            # pool 0 is the 10x-slower flavor, so it owns the bottleneck
            times = [10.0 / alloc[0], 10.0 / alloc[1],
                     1.0 / alloc[2], 1.0 / alloc[3]]
            return max(times), times

        seed = [1, 7, 4, 4]
        groups = [0, 0, 1, 1]
        alloc, lat, _ = rebalance(seed, eval_fn, groups=groups)
        assert alloc[0] + alloc[1] == 8     # pool totals conserved --
        assert alloc[2] + alloc[3] == 8     # no chip crossed the seam
        assert alloc[:2] == [4, 4]          # bottleneck pool equalized
        assert lat == 10.0 / 4

    def test_mixed_curve_2d_refine(self):
        """The 2D coarse-to-fine pass: a refined coarse mixed curve adds
        points only around the argmax and recovers the exhaustive grid's
        peak (small cells are filled exactly, mirroring the 1D pass)."""
        from repro.multimodel.curves import mixed_throughput_curve

        hw = mcm_hetero(8, big_fraction=0.5, little_flops_scale=0.5)
        g = get_cnn("alexnet")
        flavors = package_flavors(hw)
        cost = FastCostModel(hw, m_samples=16)
        peak = lambda c: max(p.throughput for p in c.points.values())
        exact = mixed_throughput_curve(cost, g, flavors, step=1)
        coarse = mixed_throughput_curve(cost, g, flavors, step=2)
        refined = mixed_throughput_curve(cost, g, flavors, step=2,
                                         refine=True)
        assert len(coarse.points) < len(refined.points) <= len(exact.points)
        assert peak(refined) >= peak(coarse)
        assert peak(refined) <= peak(exact) * (1 + 1e-12)
        # step=2 cells are tiny -> filled at stride 1: exact peak recovery
        assert math.isclose(peak(refined), peak(exact), rel_tol=1e-9)
        # refined coarse points are a superset of the plain coarse grid
        assert set(coarse.points) <= set(refined.points)

    def test_mixed_refine_threads_through_quota_search(self):
        hw = mcm_hetero(8, big_fraction=0.5, little_flops_scale=0.5)
        specs = [
            ModelSpec(tiny_graph("a", 1.0), 1.0),
            ModelSpec(tiny_graph("b", 2.0), 1.0),
        ]
        cost = FastCostModel(hw, m_samples=16)
        base = search_partitioned_mixed(specs, cost, mixed_step=2)
        refined = search_partitioned_mixed(specs, cost, mixed_step=2,
                                           mixed_refine=True)
        assert refined is not None and refined.meta["mixed_refine"]
        # refinement only adds candidate points: never worse
        assert (refined.weighted_throughput
                >= base.weighted_throughput * (1 - 1e-12))
        assert refined.meta["mixed_points"] >= base.meta["mixed_points"]

    def test_coarse_to_fine_refine(self):
        """refine=True fills the argmax neighborhood: the refined coarse
        curve recovers the exhaustive curve's peak with far fewer points."""
        hw = mcm_table_iii(16)
        g = get_cnn("alexnet")
        cost = FastCostModel(hw, m_samples=16)
        exact = throughput_curve(cost, g, 16, step=1)
        coarse = throughput_curve(cost, g, 16, step=4)
        refined = throughput_curve(cost, g, 16, step=4, refine=True)
        best = lambda c: max(p.throughput for p in c.points.values())
        assert len(coarse.points) < len(refined.points) < len(exact.points)
        assert best(refined) >= best(coarse)
        assert best(refined) <= best(exact) * (1 + 1e-12)
        # the peak sits inside the refined argmax window for this curve
        assert math.isclose(best(refined), best(exact), rel_tol=1e-9)


# ----------------------------------------------------------- validation

class TestMultiModelScheduleValidation:
    def _co(self, mix="alexnet:1,resnet18:1", chips=16):
        hw = mcm_table_iii(chips)
        specs = parse_mix(mix)
        co = co_schedule(specs, hw)   # validates internally
        return co, specs, hw

    def test_co_schedule_validates(self):
        co, specs, hw = self._co()
        assert co.mode in ("partitioned", "merged", "time_mux")
        assert co.weighted_throughput > 0
        assert math.isclose(
            co.weighted_throughput,
            co.mix_rate * sum(s.weight for s in specs),
        )

    def test_overallocated_partition_rejected(self):
        co, specs, hw = self._co()
        part = search_partitioned(
            specs, FastCostModel(hw, m_samples=16)
        )
        # double one quota so the per-type chips sum overflows the package
        a0 = part.assignments[0]
        bloated = MultiModelSchedule(
            package=part.package, chips=part.chips, mode=MM_PARTITIONED,
            assignments=(
                ModelAssignment(
                    model=a0.model, weight=a0.weight,
                    chips=hw.chips + 1,
                    schedule=a0.schedule, chip_type=a0.chip_type,
                ),
            ) + part.assignments[1:],
            mix_rate=part.mix_rate,
            weighted_throughput=part.weighted_throughput,
        )
        graphs = {s.name: s.graph for s in specs}
        with pytest.raises(AssertionError):
            validate_multimodel(bloated, graphs, {None: hw.chips})

    def test_inconsistent_mix_rate_rejected(self):
        co, specs, hw = self._co()
        wrong = MultiModelSchedule(
            package=co.package, chips=co.chips, mode=co.mode,
            assignments=co.assignments,
            mix_rate=co.mix_rate * 2.0,
            weighted_throughput=co.weighted_throughput,
        )
        graphs = {s.name: s.graph for s in specs}
        mg, _ = merged_graph(specs)
        graphs[mg.name] = mg
        with pytest.raises(AssertionError):
            validate_multimodel(wrong, graphs, {None: hw.chips})


# ------------------------------------------------------------ interleave

class TestMergedInterleave:
    def test_merged_graph_concatenates_and_scales(self):
        specs = [
            ModelSpec(tiny_graph("a"), 1.0),
            ModelSpec(tiny_graph("b"), 2.0),
        ]
        mg, scales = merged_graph(specs)
        assert scales == [1, 2]
        assert len(mg) == 6
        # model b's layers carry 2 samples per beat
        assert mg.layers[3].flops == 2 * specs[1].graph.layers[0].flops
        # model-final layers: outputs leave via DRAM, no NoP hand-off
        assert mg.layers[2].out_bytes == 0.0 and mg.layers[2].halo_bytes == 0.0
        assert mg.layers[5].out_bytes == 0.0
        # model-initial layers past the first are DRAM-staged entry points,
        # charged by the segment load term wherever the boundary lands
        assert mg.layers[3].meta.get("dram_input") is True
        assert "dram_input" not in mg.layers[0].meta

    def test_boundary_staging_charged_and_engines_agree(self):
        """The mid-segment model boundary's DRAM staging is charged under
        every partition pair (incl. WSP->WSP, which has no NoP volume), and
        both engines agree on flagged graphs."""
        from dataclasses import replace as _rep

        specs = [ModelSpec(tiny_graph("a")), ModelSpec(tiny_graph("b"))]
        mg, _ = merged_graph(specs)
        hw = mcm_table_iii(8)
        ref = CostModel(hw, m_samples=16)
        fast = FastCostModel(hw, m_samples=16)
        clustering = ((0, len(mg)),)
        for partitions in (("WSP",) * len(mg), ("ISP",) * len(mg)):
            lr, _ = evaluate_segment(ref, mg, 0, clustering, partitions, [8])
            lf, _ = evaluate_segment(fast, mg, 0, clustering, partitions, [8])
            assert lr == lf, (partitions, lr, lf)
            stripped = chain(
                mg.name + "_noflag",
                tuple(_rep(n, meta={}) for n in mg.layers),
            )
            l0, _ = evaluate_segment(ref, stripped, 0, clustering,
                                     partitions, [8])
            expect = ref.m * mg.layers[3].in_bytes / hw.dram_bw_total
            assert close(lr - l0, expect), (lr - l0, expect)

    def test_search_merged_feasible_and_consistent(self):
        hw = mcm_table_iii(16)
        specs = parse_mix("alexnet:1,resnet18:1")
        cost = FastCostModel(hw, m_samples=16)
        mm = search_merged(specs, cost)
        assert mm is not None
        assert mm.mode == "merged"
        # both models share the one merged schedule
        assert mm.assignments[0].schedule is mm.assignments[1].schedule
        lam = min(a.throughput / a.weight for a in mm.assignments)
        assert math.isclose(lam, mm.mix_rate)


# ---------------------------------------------------------- paper_strict

class TestPaperStrict:
    def test_inf_seed_not_repaired(self):
        calls = []

        def eval_fn(alloc):
            calls.append(tuple(alloc))
            # region 0 infeasible below 3 chips
            if alloc[0] < 3:
                return INF, [INF, 1.0]
            return 1.0 / alloc[0], [1.0 / alloc[0], 1.0 / alloc[1]]

        alloc, lat, _ = rebalance([1, 7], eval_fn, paper_strict=True)
        assert lat == INF and alloc == [1, 7] and len(calls) == 1

        alloc, lat, _ = rebalance([1, 7], eval_fn)   # default repairs
        assert lat < INF and alloc[0] >= 3

    def test_single_donor_only(self):
        """A tied fastest donor terminates strict rebalance; the default
        retries the next-fastest donor and finds the improvement."""
        def eval_fn(alloc):
            a, b, c = alloc
            # slowest is region 2; donating from region 0 (fastest) ties,
            # donating from region 1 improves.
            times = [0.1 - 0.001 * a, 0.3 - 0.01 * b, 1.0 / c]
            return max(times), times

        strict = rebalance([4, 4, 4], eval_fn, paper_strict=True)
        loose = rebalance([4, 4, 4], eval_fn)
        assert loose[1] <= strict[1]

    def test_search_segment_strict_never_better(self):
        g = get_cnn("alexnet")
        cost = FastCostModel(mcm_table_iii(16), m_samples=16)
        loose = search_segment(cost, g, 0, len(g), 16)
        strict = search_segment(cost, g, 0, len(g), 16, paper_strict=True)
        assert strict.latency >= loose.latency - 1e-12


# ---------------------------------------------------- seam accounting

def _typed_schedule(types, chips_each=1):
    """A 1-segment schedule over len(types) single-layer clusters, cluster
    i on flavor types[i]."""
    g = tiny_graph("t", L=len(types))
    clusters = tuple(
        ClusterAssignment(
            layer_lo=i, layer_hi=i + 1, region_chips=chips_each,
            partitions=("ISP",), chip_type=t,
        )
        for i, t in enumerate(types)
    )
    sched = ScopeSchedule(
        workload="t", chips=chips_each * len(types),
        segments=(SegmentSchedule(clusters, 1.0, (1.0,) * len(types)),),
        latency=1.0,
    )
    return g, sched


class TestSeamAccounting:
    def test_homogeneous_counts_zero(self):
        g, sched = _typed_schedule([None, None, None])
        report = validate_schedule(g, sched, 3)
        assert report["seam_crossings"] == 0
        assert report["seam_crossings_per_segment"] == [0]

    def test_contiguous_runs_counted(self):
        g, sched = _typed_schedule(["big", "big", "little"])
        report = validate_schedule(g, sched, 3)
        assert report["seam_crossings"] == 1

    def test_non_contiguous_runs_rejected(self):
        g, sched = _typed_schedule(["big", "little", "big"])
        with pytest.raises(AssertionError, match="non-contiguous"):
            validate_schedule(g, sched, 3)

    def test_searched_mixed_schedules_validate(self):
        """Every schedule the mixed DSE emits passes the seam validator
        (its flavor-run layer builds contiguous runs by construction)."""
        hw = mcm_hetero(8, big_fraction=0.5, little_flops_scale=0.5)
        cost = FastCostModel(hw, m_samples=16)
        g = get_cnn("alexnet")
        sched = search_mixed(g, cost)
        caps = dict(package_flavors(hw))
        report = validate_schedule(g, sched, hw.chips, flavor_caps=caps)
        flavors_used = {
            cl.chip_type for seg in sched.segments for cl in seg.clusters
        }
        if len(flavors_used) > 1:
            assert report["seam_crossings"] >= 1

    def test_multimodel_reports_per_model(self):
        hw = mcm_table_iii(16)
        specs = parse_mix("alexnet:1,resnet18:1")
        co = co_schedule(specs, hw)
        graphs = {s.name: s.graph for s in specs}
        if co.mode == "merged":
            mg, _ = merged_graph(specs)
            graphs[mg.name] = mg
        report = validate_multimodel(co, graphs, {None: hw.chips})
        assert set(report["seam_crossings"]) == {s.name for s in specs}
        assert all(v == 0 for v in report["seam_crossings"].values())


# ------------------------------------------------- 3+ flavor spanning quotas

class TestThreeFlavorMixed:
    def test_preset_registered_and_valid(self):
        hw = get_hw("mcm48_hetero3")
        assert [t.name for t in hw.region_types] == ["big", "mid", "little"]
        assert sum(t.chips for t in hw.region_types) == 48

    def test_three_flavor_spanning_quotas_solve(self):
        hw = mcm_hetero3(6)    # 2 chips per flavor: tiny regression case
        specs = [
            ModelSpec(tiny_graph("a", 1.0), 1.0),
            ModelSpec(tiny_graph("b", 2.0), 1.0),
        ]
        cost = FastCostModel(hw, m_samples=16)
        mixed = search_partitioned_mixed(specs, cost)
        assert mixed is not None
        assert mixed.meta["family"] == "partitioned_mixed"
        # k-flavor spanning quotas subsume single-flavor quotas: the mixed
        # envelope contains every single-flavor point, so the result is at
        # least as good as the best single-flavor partitioning.
        part = search_partitioned(specs, cost)
        if part is not None:
            assert (
                mixed.weighted_throughput
                >= part.weighted_throughput - 1e-12
            )
        for a in mixed.assignments:
            if a.chip_quota:
                assert sum(c for _, c in a.chip_quota) == a.chips

    def test_coschedule_runs_mixed_without_warning(self):
        import warnings as _warnings

        hw = mcm_hetero3(6)
        specs = [
            ModelSpec(tiny_graph("a", 1.0), 1.0),
            ModelSpec(tiny_graph("b", 2.0), 1.0),
        ]
        with _warnings.catch_warnings():
            _warnings.simplefilter("error")
            co = co_schedule(specs, hw)
        assert co is not None
        assert "mixed_fallback" not in co.meta
        # the spanning family ran and is listed among the mode rates
        assert "partitioned:mixed" in co.meta["mode_rates"]
        # co_schedule picks the max, so it is >= the best single flavor
        assert co.weighted_throughput >= max(
            co.meta["mode_rates"].values()
        ) - 1e-12

    def test_facade_three_flavor_mixed(self):
        from repro import scope

        hw = mcm_hetero3(6)
        g1, g2 = tiny_graph("a", 1.0), tiny_graph("b", 2.0)
        sol = scope.solve(scope.problem(
            scope.WorkloadSpec.graphs([g1, g2]), hw,
            strategy="coschedule",
        ))
        assert sol.multi is not None
        assert "mixed_fallback" not in sol.diagnostics
        assert "partitioned:mixed" in sol.diagnostics["mode_rates"]


# ------------------------------------------------- merged sub-groups

class TestMergedGroups:
    def _specs(self):
        return [
            ModelSpec(tiny_graph("a", 1.0), 2.0),
            ModelSpec(tiny_graph("b", 2.0), 1.0),
            ModelSpec(tiny_graph("c", 0.5), 1.0),
        ]

    def test_groups_share_schedule_and_validate(self):
        from repro.multimodel import search_merged_groups

        hw = mcm_table_iii(8)
        specs = self._specs()
        cost = FastCostModel(hw, m_samples=16)
        mm = search_merged_groups(specs, cost)
        assert mm is not None
        assert mm.mode == MM_PARTITIONED
        groups = mm.meta["merge_groups"]
        assert groups and all(len(g) >= 2 for g in groups)
        # group members share one schedule object over one chip region
        for group in groups:
            scheds = {
                id(a.schedule) for a in mm.assignments if a.model in group
            }
            assert len(scheds) == 1
        # shared-schedule chips count once against capacity
        graphs = {s.name: s.graph for s in specs}
        by_name = {s.name: s for s in specs}
        for group in groups:
            mg, _ = merged_graph([by_name[m] for m in group])
            graphs[mg.name] = mg
        validate_multimodel(mm, graphs, {None: hw.chips})

    def test_coschedule_at_least_both_extremes(self):
        hw = mcm_table_iii(8)
        specs = self._specs()
        cost = FastCostModel(hw, m_samples=16)
        co = co_schedule(specs, hw, cost=cost)
        assert co is not None
        part = search_partitioned(specs, cost)
        merged = search_merged(specs, cost)
        for extreme in (part, merged):
            if extreme is not None:
                assert (
                    co.weighted_throughput
                    >= extreme.weighted_throughput - 1e-12
                )

    def test_two_models_skip_groups(self):
        from repro.multimodel import search_merged_groups

        hw = mcm_table_iii(8)
        specs = self._specs()[:2]
        cost = FastCostModel(hw, m_samples=16)
        assert search_merged_groups(specs, cost) is None


# ------------------------------------------------------ batched seed fill

class TestBatchedSeedFill:
    @pytest.mark.parametrize("net,chips", [("resnet18", 32), ("resnet50", 64)])
    def test_search_identical_with_and_without(self, net, chips):
        g = get_cnn(net)
        on = FastCostModel(mcm_table_iii(chips), m_samples=16)
        off = FastCostModel(mcm_table_iii(chips), m_samples=16)
        off.batched_seed_fill = False
        s_on = search(g, on, chips)
        s_off = search(g, off, chips)
        assert s_on.latency == s_off.latency          # bit-identical
        assert [seg.clusters for seg in s_on.segments] == [
            seg.clusters for seg in s_off.segments
        ]
        assert on.stats["batched_bodies"] > 0
        assert off.stats["batched_bodies"] == 0

    def test_batch_fill_bodies_match_lazy(self):
        from repro.core.fastcost import _BODY, _STATIC
        g = get_cnn("resnet50")
        L = len(g)
        fast = FastCostModel(mcm_table_iii(64), m_samples=16)
        gd = fast.graph_data(g)
        fast._batch_seed_fill(gd, 0, L, 33)
        lazy = FastCostModel(mcm_table_iii(64), m_samples=16)
        gdl = lazy.graph_data(g)
        for k in range(L + 1):
            cell_b = fast._cluster_cell_hint(gd, 0, L, k, False, None)
            cell_l = lazy._cluster_cell_hint(gdl, 0, L, k, False, None)
            body_l = lazy._cluster_body(cell_l[_STATIC], 33)
            assert cell_b[_BODY][33] == body_l, k


class TestKFlavorEnvelopeParity:
    """The F-dimensional MixedCurve DP vs its 2-flavor special case.

    The k-flavor generalization must be an exact superset: embedding a
    2-flavor problem as a 3-flavor one whose third flavor has zero
    capacity yields cell-for-cell the same winning (throughput, kind)
    records, and the 2-flavor candidate ordering (tie-breaks included)
    is unchanged.
    """

    @staticmethod
    def _env(tps):
        from repro.multimodel.curves import CurvePoint, ThroughputCurve

        sentinel = object()
        curve = ThroughputCurve("m", None, {
            c: CurvePoint(c, 1.0 / tp, tp, sentinel)
            for c, tp in tps.items()
        })
        return curve.envelope(max(tps))

    def test_degenerate_third_flavor_matches_two_flavor(self):
        from repro.multimodel.curves import MixedCurve, MixedPoint

        env_big = self._env({1: 2.0, 2: 5.0, 3: 4.0})
        env_little = self._env({1: 1.0, 2: 5.0, 3: 6.0})
        sentinel = object()
        pts2 = {
            (1, 1): (0.2, 5.5), (2, 1): (0.1, 7.0), (1, 3): (0.5, 5.8),
        }
        curve2 = MixedCurve("m", ("big", "little"), {
            q: MixedPoint(q, lat, tp, sentinel)
            for q, (lat, tp) in pts2.items()
        })
        curve3 = MixedCurve("m", ("big", "little", "ghost"), {
            q + (0,): MixedPoint(q + (0,), lat, tp, sentinel)
            for q, (lat, tp) in pts2.items()
        })
        table2 = curve2.envelope((3, 3), env_big, env_little)
        table3 = curve3.envelope((3, 3, 0), env_big, env_little, [None])
        for a in range(4):
            for b in range(4):
                r2, r3 = table2[a][b], table3[a][b][0]
                assert (r2 is None) == (r3 is None), (a, b)
                if r2 is not None:
                    assert r2[0] == r3[0], (a, b)      # same throughput
                    assert r2[1] == r3[1], (a, b)      # same kind
                    if r2[1] == "single":
                        assert r2[2] == r3[2], (a, b)  # same flavor pick

    def test_ties_break_identically(self):
        """Equal-throughput single vs mixed candidates pick the same winner
        in both formulations (candidate order: singles in flavor order,
        then mixed, then predecessors in flavor order)."""
        from repro.multimodel.curves import MixedCurve, MixedPoint

        env_a = self._env({1: 4.0})
        env_b = self._env({1: 4.0})
        sentinel = object()
        mixed = {(1, 1): MixedPoint((1, 1), 0.25, 4.0, sentinel)}
        curve2 = MixedCurve("m", ("big", "little"), dict(mixed))
        curve3 = MixedCurve("m", ("big", "little", "ghost"), {
            (1, 1, 0): MixedPoint((1, 1, 0), 0.25, 4.0, sentinel)
        })
        r2 = curve2.envelope((1, 1), env_a, env_b)[1][1]
        r3 = curve3.envelope((1, 1, 0), env_a, env_b, [None])[1][1][0]
        # strict > in the DP's better(): first candidate (flavor 0's
        # single) wins every tie, in both formulations
        assert r2[1] == r3[1] == "single"
        assert r2[2] == r3[2] == 0
