"""Scope Lens: a dependency-free single-file HTML dashboard.

Renders one self-contained page -- inline CSS + inline SVG, no external
scripts, fonts, or fetches -- from the same artifacts the CLIs already
produce:

* a :class:`~repro.obs.Tracer` -> an SVG **timeline** (one row per
  ``group/lane``, spans as rects, instants as markers, fault->recovery
  spans shaded as windows) plus **sparklines** for every counter track
  (queue depths, KV occupancy);
* ``Solution.explain()`` -> per-stage **cost breakdown tables** (where did
  the solver's latency go: compute / NoP / seam / DRAM / staging, with the
  bottleneck ranking);
* ``report.explain()`` (whole-request or token-level) -> per-model
  **latency waterfall tables** (queue wait, batch delay, service, dead
  time by cause | prefill, hand-off, admission, decode).

Everything is simulated/derived data -- the page is bytewise deterministic
for a deterministic run (no wall-clock stamps), so CI can diff it.

Front doors: ``python -m repro solve ... --dashboard out.html`` and
``python -m repro serve ... --dashboard out.html``, or
:func:`write_dashboard` directly.
"""
from __future__ import annotations

import html
import json

__all__ = ["render_dashboard", "write_dashboard"]

# muted categorical palette, keyed per group in first-use order
_PALETTE = ("#4c9be8", "#e8a33d", "#53b87f", "#c96fc9", "#d96c5f",
            "#8a8fe8", "#b5a642", "#5fc9c0")

_CSS = """
body { background:#14171c; color:#d7dce2; font:13px/1.45 system-ui,
       -apple-system, 'Segoe UI', sans-serif; margin:24px; }
h1 { font-size:20px; margin:0 0 4px; }
h2 { font-size:15px; margin:28px 0 8px; color:#9fb3c8;
     border-bottom:1px solid #2a2f37; padding-bottom:4px; }
h3 { font-size:13px; margin:14px 0 4px; color:#8aa0b4; }
.sub { color:#6c7a89; margin-bottom:18px; }
table { border-collapse:collapse; margin:6px 0 14px; }
th, td { padding:3px 10px; text-align:right; border-bottom:1px solid #242a32;
         font-variant-numeric:tabular-nums; }
th { color:#8aa0b4; font-weight:600; }
td.l, th.l { text-align:left; }
.bar { display:inline-block; height:9px; background:#4c9be8;
       vertical-align:middle; border-radius:2px; }
.bound { padding:1px 7px; border-radius:9px; font-size:11px;
         background:#26303b; color:#9fc1e0; }
.ok { color:#53b87f; } .bad { color:#d96c5f; }
svg { background:#181c22; border:1px solid #242a32; border-radius:4px; }
.lane-label { fill:#8aa0b4; font-size:10px; }
.tick { fill:#5a6673; font-size:9px; }
.spark-name { color:#8aa0b4; display:inline-block; width:240px; }
.legend span { margin-right:16px; }
.fault-window { fill:#d96c5f; fill-opacity:0.16; }
.marker-fault { stroke:#d96c5f; } .marker-recovered { stroke:#53b87f; }
.marker-redeploy { stroke:#e8a33d; } .marker-admit { stroke:#8a8fe8; }
"""


def _esc(s) -> str:
    return html.escape(str(s), quote=True)


def _fmt_s(v: float) -> str:
    """Engineering-ish seconds: ms below 1s, µs below 1ms."""
    a = abs(v)
    if a >= 1.0 or v == 0.0:
        return f"{v:.4g} s"
    if a >= 1e-3:
        return f"{v * 1e3:.4g} ms"
    return f"{v * 1e6:.4g} µs"


# ---------------------------------------------------------------- timeline

def _marker_class(name: str) -> str:
    if name.startswith("fault"):
        return "marker-fault"
    if name.startswith("recovered"):
        return "marker-recovered"
    if name.startswith("redeploy"):
        return "marker-redeploy"
    if name.startswith("admit"):
        return "marker-admit"
    return "marker-redeploy"


def _timeline_svg(events, max_spans_per_lane: int = 400) -> str:
    """Inline SVG Gantt of the tracer's span events.

    One row per ``(group, lane)`` in first-use order; instants become
    vertical markers; ``fault:fail`` .. ``recovered`` instant pairs shade
    a translucent window across every row.
    """
    spans: dict[tuple, list] = {}
    instants: list[tuple] = []
    for ph, name, group, lane, t0, t1, _args in events:
        if ph == "X":
            spans.setdefault((group, lane), []).append((t0, t1, name))
        elif ph == "i":
            instants.append((t0, name, group))
    if not spans and not instants:
        return "<p class='sub'>(no span events)</p>"

    ts = [t for evs in spans.values() for t0, t1, _ in evs for t in (t0, t1)]
    ts += [t for t, _, _ in instants]
    tmin, tmax = min(ts), max(ts)
    rng = max(tmax - tmin, 1e-12)

    gutter, width, row_h = 190, 860, 16
    lanes = sorted(spans) or [("", "")]
    h = len(lanes) * row_h + 28

    def x(t: float) -> float:
        return gutter + (t - tmin) / rng * width

    groups: list = []
    parts = [f"<svg width='{gutter + width + 16}' height='{h}' "
             f"xmlns='http://www.w3.org/2000/svg'>"]

    # fault->recovery windows first, behind everything
    open_fault = None
    for t, name, _g in sorted(instants):
        if name.startswith("fault:fail") and open_fault is None:
            open_fault = t
        elif name.startswith("recovered") and open_fault is not None:
            parts.append(
                f"<rect class='fault-window' x='{x(open_fault):.1f}' y='14' "
                f"width='{max(1.0, x(t) - x(open_fault)):.1f}' "
                f"height='{h - 28}'/>")
            open_fault = None
    if open_fault is not None:           # failure never recovered in-run
        parts.append(
            f"<rect class='fault-window' x='{x(open_fault):.1f}' y='14' "
            f"width='{max(1.0, x(tmax) - x(open_fault)):.1f}' "
            f"height='{h - 28}'/>")

    for row, key in enumerate(lanes):
        group, lane = key
        if group not in groups:
            groups.append(group)
        color = _PALETTE[groups.index(group) % len(_PALETTE)]
        y = 16 + row * row_h
        label = f"{group}/{lane}" if lane else group
        parts.append(f"<text class='lane-label' x='4' y='{y + 11}'>"
                     f"{_esc(label[:34])}</text>")
        evs = sorted(spans.get(key, ()))
        dropped = max(0, len(evs) - max_spans_per_lane)
        if dropped:
            # keep the widest spans so the picture stays representative
            evs = sorted(sorted(evs, key=lambda e: e[0] - e[1])
                         [:max_spans_per_lane])
        for t0, t1, name in evs:
            w = max(0.75, x(t1) - x(t0))
            parts.append(
                f"<rect x='{x(t0):.2f}' y='{y + 2}' width='{w:.2f}' "
                f"height='{row_h - 5}' fill='{color}' fill-opacity='0.8'>"
                f"<title>{_esc(name)} [{_fmt_s(t0)} .. {_fmt_s(t1)}]"
                f"</title></rect>")
        if dropped:
            parts.append(f"<text class='tick' x='{gutter + width + 2}' "
                         f"y='{y + 11}'>+{dropped}</text>")

    for t, name, _g in instants:
        parts.append(
            f"<line class='{_marker_class(name)}' x1='{x(t):.2f}' y1='14' "
            f"x2='{x(t):.2f}' y2='{h - 14}' stroke-width='1.25' "
            f"stroke-dasharray='3,2'><title>{_esc(name)} @ {_fmt_s(t)}"
            f"</title></line>")

    for frac in (0.0, 0.25, 0.5, 0.75, 1.0):
        t = tmin + frac * rng
        parts.append(f"<text class='tick' x='{x(t):.1f}' y='{h - 3}' "
                     f"text-anchor='middle'>{_fmt_s(t)}</text>")
    parts.append("</svg>")
    n_faults = sum(1 for _, n, _ in instants if n.startswith("fault"))
    legend = (f"<p class='legend sub'><span>spans: "
              f"{sum(len(v) for v in spans.values())}</span>"
              f"<span>instants: {len(instants)}</span>"
              f"<span class='bad'>fault events: {n_faults}</span></p>")
    return "".join(parts) + legend


def _sparklines(events, w: int = 560, h: int = 46) -> str:
    """One sparkline per counter track (queue depths, KV occupancy, ...)."""
    tracks: dict[tuple, list] = {}
    for ph, name, group, _lane, t0, _t1, args in events:
        if ph == "C":
            v = args.get("value", 0)
            tracks.setdefault((group, name), []).append((t0, float(v)))
    if not tracks:
        return ""
    out = ["<h2>Counter tracks</h2>"]
    for (group, name), pts in sorted(tracks.items()):
        pts.sort()
        tmin, tmax = pts[0][0], pts[-1][0]
        vmax = max(v for _, v in pts)
        rng_t = max(tmax - tmin, 1e-12)
        rng_v = max(vmax, 1e-12)
        # step-wise polyline (counters hold their value between samples)
        coords = []
        last_y = h - 2
        for t, v in pts:
            px = 2 + (t - tmin) / rng_t * (w - 4)
            py = h - 2 - (v / rng_v) * (h - 8)
            coords.append(f"{px:.1f},{last_y:.1f} {px:.1f},{py:.1f}")
            last_y = py
        out.append(
            f"<div><span class='spark-name'>{_esc(group)}/{_esc(name)} "
            f"(max {vmax:g})</span>"
            f"<svg width='{w}' height='{h}'><polyline fill='none' "
            f"stroke='#4c9be8' stroke-width='1.2' "
            f"points='{' '.join(coords)}'/></svg></div>")
    return "".join(out)


# ------------------------------------------------------------- breakdowns

def _share_bar(share: float, width: int = 90) -> str:
    return (f"<span class='bar' style='width:{max(1, int(share * width))}px'>"
            f"</span> {share:.0%}")


def _solution_tables(ex: dict) -> str:
    """Tables from ``Solution.explain()``: one row per stage, component
    columns, the solver's own scalar, and the conservation verdict."""
    stages = ex.get("stages") or []
    if not stages:
        return ""
    comp_names: list = []
    for st in stages:
        for c in st.get("breakdown", {}).get("components", {}):
            if c not in comp_names:
                comp_names.append(c)
    out = [
        "<h2>DSE cost attribution</h2>",
        f"<p class='sub'>strategy {_esc(ex.get('strategy'))} &middot; "
        f"package {_esc(ex.get('package'))} &middot; "
        f"{ex.get('chips')} chips</p>",
        "<table><tr><th class='l'>stage</th><th>chips</th><th>latency</th>"
        "<th>bound</th>",
    ]
    out += [f"<th>{_esc(c)}</th>" for c in comp_names]
    out.append("<th>conserved</th></tr>")
    for st in stages:
        bd = st.get("breakdown", {})
        comps = bd.get("components", {})
        total = max(st.get("latency") or 0.0, 1e-300)
        cons = st.get("conserved")
        out.append(
            f"<tr><td class='l'>{_esc(st.get('label'))}</td>"
            f"<td>{st.get('chips')}</td>"
            f"<td>{_fmt_s(st.get('latency') or 0.0)}</td>"
            f"<td><span class='bound'>{_esc(st.get('bound'))}</span></td>")
        out += [f"<td>{_share_bar(comps.get(c, 0.0) / total)}</td>"
                for c in comp_names]
        out.append(f"<td class='{'ok' if cons else 'bad'}'>"
                   f"{'yes' if cons else 'NO'}</td></tr>")
    out.append("</table>")
    rank = ex.get("ranking") or []
    if rank:
        out.append("<h3>Bottleneck ranking</h3><table>"
                   "<tr><th class='l'>stage</th><th>bound</th>"
                   "<th>latency</th></tr>")
        for r in rank:
            out.append(f"<tr><td class='l'>{_esc(r['label'])}</td>"
                       f"<td><span class='bound'>{_esc(r['bound'])}</span>"
                       f"</td><td>{_fmt_s(r['latency'])}</td></tr>")
        out.append("</table>")
    return "".join(out)


def _waterfall_tables(ex: dict, title: str) -> str:
    """Tables from ``report.explain()``: per-model mean waterfalls."""
    rows = {k: v for k, v in ex.items()
            if isinstance(v, dict) and "components" in v}
    rows.update({k: v for k, v in ex.get("per_model", {}).items()
                 if isinstance(v, dict) and "components" in v})
    if not rows:
        return ""
    comp_names: list = []
    for r in rows.values():
        for c in r["components"]:
            if c not in comp_names:
                comp_names.append(c)
    cons = ex.get("conserved")
    out = [
        f"<h2>{_esc(title)}</h2>",
        f"<p class='sub'>latency conservation: "
        f"<span class='{'ok' if cons else 'bad'}'>"
        f"{'exact' if cons else 'VIOLATED'}</span></p>",
        "<table><tr><th class='l'>model</th><th>requests</th>"
        "<th>mean latency</th><th>dominant</th>",
    ]
    out += [f"<th>{_esc(c)}</th>" for c in comp_names]
    out.append("</tr>")
    ordered = sorted(k for k in rows if k != "overall")
    if "overall" in rows:
        ordered.append("overall")
    for name in ordered:
        r = rows[name]
        out.append(
            f"<tr><td class='l'>{_esc(name)}</td><td>{r['requests']}</td>"
            f"<td>{_fmt_s(r['latency_mean_s'])}</td>"
            f"<td><span class='bound'>{_esc(r.get('dominant'))}</span></td>")
        out += [f"<td>{_share_bar(r['components'].get(c, {}).get('share', 0.0))}"
                f"</td>" for c in comp_names]
        out.append("</tr>")
    out.append("</table>")
    dead = ex.get("dead_time_s")
    if dead:
        out.append("<h3>Dead time by cause</h3><table><tr>")
        out += [f"<th>{_esc(k)}</th>" for k in dead]
        out.append("</tr><tr>")
        out += [f"<td>{_fmt_s(v)}</td>" for v in dead.values()]
        out.append("</tr></table>")
    return "".join(out)


# ------------------------------------------------------------------ entry

def render_dashboard(*, title: str = "Scope Lens", tracer=None,
                     solution_explain: dict | None = None,
                     serving_explain: dict | None = None,
                     serving_title: str = "Serving latency waterfalls",
                     meta: dict | None = None) -> str:
    """Build the dashboard HTML string from any subset of artifacts."""
    body = [f"<h1>{_esc(title)}</h1>"]
    if meta:
        body.append("<p class='sub'>" + " &middot; ".join(
            f"{_esc(k)}: {_esc(v)}" for k, v in meta.items()) + "</p>")
    if solution_explain:
        body.append(_solution_tables(solution_explain))
    if serving_explain:
        body.append(_waterfall_tables(serving_explain, serving_title))
    if tracer is not None and getattr(tracer, "events", None):
        body.append("<h2>Timeline</h2>")
        body.append(_timeline_svg(tracer.events))
        body.append(_sparklines(tracer.events))
    if len(body) == 1:
        body.append("<p class='sub'>(nothing to show)</p>")
    return ("<!doctype html><html><head><meta charset='utf-8'>"
            f"<title>{_esc(title)}</title><style>{_CSS}</style></head>"
            f"<body>{''.join(body)}</body></html>\n")


def write_dashboard(path: str, **kwargs) -> str:
    """Render and write the dashboard; returns ``path``."""
    with open(path, "w") as fh:
        fh.write(render_dashboard(**kwargs))
    return path


def _json_default(o):
    return repr(o)


def dump_explain(path: str, explain: dict) -> str:
    """Write an ``explain()`` dict as JSON next to a dashboard (debug aid)."""
    with open(path, "w") as fh:
        json.dump(explain, fh, indent=1, sort_keys=True,
                  default=_json_default)
        fh.write("\n")
    return path
