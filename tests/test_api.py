"""Solver-facade tests: the one front door (repro.scope).

* facade-vs-legacy bit-identical parity: ``solve()`` against direct
  ``search`` / ``search_mixed`` / ``co_schedule`` calls on the
  resnet18/resnet50 x mcm16/mcm64_hetero matrix, both RegionModes
  (facade and legacy share one engine memo -- memoization is exact, so
  sharing changes nothing but wall time);
* strategy auto-selection by problem shape + registry behavior;
* Deployment round-trip: solve -> deploy == plan_for_multimodel, without
  a second search;
* the ``python -m repro solve`` CLI (JSON payload parity).
"""
import json

import pytest

from repro import scope
from repro.core.costmodel import INF
from repro.core.fastcost import FastCostModel
from repro.core.hw import get_hw, mcm_table_iii
from repro.core.regions import RegionMode
from repro.core.search import search, search_mixed
from repro.core.workloads import get_cnn
from repro.multimodel import co_schedule, parse_mix


def _shared(hw, m_samples=16):
    return FastCostModel(hw, m_samples=m_samples)


def _facade(net, hw, cost, mode, **opts):
    return scope.solve(scope.problem(
        net, hw, mode=mode, cost=cost, **opts
    ))


def _assert_same_schedule(sol, legacy):
    assert legacy is not None and sol.feasible
    assert sol.latency == legacy.latency          # bit-identical
    assert len(sol.schedule.segments) == len(legacy.segments)
    for a, b in zip(sol.schedule.segments, legacy.segments):
        assert a.clusters == b.clusters
        assert a.cluster_times == b.cluster_times


# ---------------------------------------------------------------- parity

PARITY_FAST = [
    ("resnet18", "mcm16", "free"),
    ("resnet18", "mcm16", "uniform"),
    ("resnet50", "mcm16", "free"),
    ("resnet50", "mcm16", "uniform"),
    ("resnet18", "mcm64_hetero", "free"),
    ("resnet18", "mcm64_hetero", "uniform"),
    ("resnet50", "mcm64_hetero", "uniform"),
]
PARITY_SLOW = [
    ("resnet50", "mcm64_hetero", "free"),
]


def _check_parity(net, hw_name, mode):
    hw = get_hw(hw_name)
    cost = _shared(hw)
    g = get_cnn(net)
    sol = _facade(net, hw, cost, mode)
    if hw.region_types:
        assert sol.strategy == "scope-mixed"
        legacy = search_mixed(g, cost, mode=RegionMode(mode))
    else:
        assert sol.strategy == "scope"
        legacy = search(g, cost, hw.chips, mode=RegionMode(mode))
    _assert_same_schedule(sol, legacy)


@pytest.mark.parametrize("net,hw_name,mode", PARITY_FAST)
def test_solve_matches_legacy(net, hw_name, mode):
    _check_parity(net, hw_name, mode)


@pytest.mark.slow
@pytest.mark.parametrize("net,hw_name,mode", PARITY_SLOW)
def test_solve_matches_legacy_slow(net, hw_name, mode):
    _check_parity(net, hw_name, mode)


def test_solve_matches_co_schedule_homogeneous():
    hw = get_hw("mcm16")
    cost = _shared(hw)
    specs = parse_mix("resnet18:1,resnet50:1")
    sol = scope.solve(scope.problem("resnet18:1,resnet50:1", hw, cost=cost))
    legacy = co_schedule(specs, hw, cost=cost)
    assert sol.strategy == "coschedule"
    assert sol.multi.mode == legacy.mode
    assert sol.multi.mix_rate == legacy.mix_rate
    assert sol.weighted_throughput == legacy.weighted_throughput
    assert [a.chips for a in sol.multi.assignments] == [
        a.chips for a in legacy.assignments
    ]


@pytest.mark.slow
def test_solve_matches_co_schedule_hetero():
    hw = get_hw("mcm64_hetero")
    cost = _shared(hw)
    specs = parse_mix("resnet18:1,resnet50:1")
    opts = dict(step=4, mixed_step=16)
    sol = scope.solve(scope.problem(
        "resnet18:1,resnet50:1", hw, cost=cost, **opts
    ))
    legacy = co_schedule(specs, hw, cost=cost, **opts)
    assert sol.multi.mode == legacy.mode
    assert sol.weighted_throughput == legacy.weighted_throughput
    assert [(a.chips, a.chip_type, a.chip_quota)
            for a in sol.multi.assignments] == [
        (a.chips, a.chip_type, a.chip_quota) for a in legacy.assignments
    ]


def test_exhaustive_and_random_strategies():
    from repro.core.graph import chain
    from repro.core.search import exhaustive_search, random_search

    g = chain("alexnet[:4]", get_cnn("alexnet").layers[:4])
    hw = mcm_table_iii(16).with_chips(6)
    cost = _shared(hw)
    best = scope.solve(scope.problem(
        scope.WorkloadSpec.graphs([g]), hw,
        options=scope.SearchOptions(strategy="exhaustive", cost=cost),
    ))
    lat, _, _, _ = next(exhaustive_search(cost, g, 6))
    assert best.latency == lat
    rand = scope.solve(scope.problem(
        scope.WorkloadSpec.graphs([g]), hw,
        options=scope.SearchOptions(strategy="random", cost=cost,
                                    samples=200, seed=3),
    ))
    legacy_pop = random_search(cost, g, 6, samples=200, seed=3)
    assert rand.diagnostics["population"] == legacy_pop
    # the exhaustive optimum lower-bounds everything sampled, and
    # Algorithm 1 lands near it (paper Fig. 8 narrative)
    assert best.latency <= min(legacy_pop) + 1e-15
    alg1 = scope.solve(scope.problem(
        scope.WorkloadSpec.graphs([g]), hw,
        options=scope.SearchOptions(strategy="scope", cost=cost),
    ))
    assert best.latency <= alg1.latency <= 1.25 * best.latency


def test_baseline_strategies_match_legacy():
    from repro.core.baselines import ALL_METHODS

    hw = get_hw("mcm16")
    cost = _shared(hw)
    for method in ("sequential", "segmented", "scope"):
        sol = _facade("alexnet", hw, cost, "free", strategy=method)
        legacy = ALL_METHODS[method](get_cnn("alexnet"), cost, 16)
        assert sol.latency == legacy.latency, method


# ------------------------------------------------------- auto-selection

class TestAutoSelection:
    def test_single_model_single_flavor(self):
        sol = scope.solve(workload="alexnet", package="mcm16")
        assert sol.strategy == "scope"

    def test_single_model_many_flavors(self):
        sol = scope.solve(workload="alexnet", package="mcm16_hetero")
        assert sol.strategy == "scope-mixed"

    def test_single_model_many_flavors_mixed_off(self):
        sol = scope.solve(workload="alexnet", package="mcm16_hetero",
                          mixed=False)
        assert sol.strategy == "scope"
        assert set(sol.diagnostics["per_flavor"]) == {"big", "little"}

    def test_multi_model(self):
        sol = scope.solve(workload="alexnet:1,resnet18:1", package="mcm16")
        assert sol.strategy == "coschedule"

    def test_explicit_strategy_wins(self):
        sol = scope.solve(workload="alexnet:1,resnet18:1", package="mcm16",
                          strategy="time-mux")
        assert sol.strategy == "time-mux"
        assert sol.multi.mode == "time_mux"

    def test_unknown_strategy_lists_available(self):
        with pytest.raises(KeyError, match="coschedule"):
            scope.solve(workload="alexnet", package="mcm16",
                        strategy="nonesuch")

    def test_register_strategy(self):
        from repro.api import _STRATEGIES

        @scope.register_strategy("everything-is-42")
        def _fake(prob, hw, cost):
            return scope.Solution(problem=prob, strategy="everything-is-42",
                                  hw=hw, diagnostics={"answer": 42})

        try:
            sol = scope.solve(workload="alexnet", package="mcm16",
                              strategy="everything-is-42")
            assert sol.diagnostics["answer"] == 42
        finally:
            _STRATEGIES.pop("everything-is-42")


# ----------------------------------------------------- problem plumbing

class TestProblemModel:
    def test_flavor_caps_restrict_budgets(self):
        hw = get_hw("mcm16_hetero")
        cost = _shared(hw)
        prob = scope.Problem(
            workload=scope.WorkloadSpec.cnn("alexnet"),
            package=scope.PackageSpec(hw=hw,
                                      flavor_caps=(("big", 4), ("little", 4))),
            options=scope.SearchOptions(cost=cost),
        )
        sol = scope.solve(prob)
        legacy = search_mixed(get_cnn("alexnet"), cost,
                              flavor_budgets=[("big", 4), ("little", 4)])
        _assert_same_schedule(sol, legacy)

    def test_seam_override_changes_result_model(self):
        base = scope.PackageSpec.of("mcm16_hetero").resolve()
        derated = scope.PackageSpec(
            preset="mcm16_hetero", seam_bw_scale=0.25
        ).resolve()
        assert derated.seam_link_bw("big", "little") == (
            0.25 * base.seam_link_bw("big", "little")
        )

    def test_workload_coercions(self):
        assert scope.WorkloadSpec.of("alexnet").n_models == 1
        assert scope.WorkloadSpec.of("alexnet:2,resnet18:1").n_models == 2
        g = get_cnn("alexnet")
        assert scope.WorkloadSpec.of(g).graph is g
        assert scope.WorkloadSpec.of([(g, 2.0)]).models[0].weight == 2.0
        with pytest.raises(ValueError):
            scope.problem("alexnet", "mcm16", options=scope.SearchOptions(),
                          step=2)

    def test_m_samples_flows_to_throughput(self):
        sol = scope.solve(workload="alexnet", package="mcm16", m_samples=32)
        assert sol.throughput == 32 / sol.latency

    def test_shared_cost_on_wrong_hardware_rejected(self):
        cost = _shared(mcm_table_iii(16))
        with pytest.raises(ValueError, match="wrong hardware"):
            scope.solve(workload="alexnet", package="mcm64", cost=cost)


# ------------------------------------------------------------ deployment

class TestDeployment:
    @pytest.fixture(scope="class")
    def lm_setup(self):
        from dataclasses import replace

        from repro.configs import get_smoke_config
        from repro.core.hw import ChipType, tpu_v5e

        cfgs = (get_smoke_config("granite-3-8b"),
                get_smoke_config("granite-20b"))
        hw = replace(
            tpu_v5e(8, (1, 8)),
            name="tpu_v5e_8_hetero",
            region_types=(
                ChipType("big", 4),
                ChipType("little", 4, flops_scale=0.5, nop_bw_scale=0.75),
            ),
        )
        return cfgs, hw

    def test_roundtrip_matches_planner(self, lm_setup):
        from repro.runtime.planner import plan_for_multimodel

        cfgs, hw = lm_setup
        wl = scope.WorkloadSpec.lm(cfgs, seq_len=64, weights=[2.0, 1.0])
        sol = scope.solve(scope.problem(
            wl, hw, m_samples=8, include_merged=False,
        ))
        assert sol.strategy == "coschedule" and sol.feasible
        dep = sol.deploy(global_batch=8, mesh_axes=("data", "model"))
        # deploy reuses the already-solved co-schedule: no second search
        assert dep.multi is sol.multi
        mm, plans = plan_for_multimodel(
            list(cfgs), 64, 8, ("data", "model"), model_axis=8,
            weights=[2.0, 1.0], hw=hw,
        )
        assert set(dep.plans) == set(plans)
        for name, direct in plans.items():
            p = dep.plans[name]
            assert (p.p1, p.p2, p.transition_repeat) == (
                direct.p1, direct.p2, direct.transition_repeat
            )
            assert p.stage_chip_types == direct.stage_chip_types
            assert p.meta["quota_chips"] == direct.meta["quota_chips"]
            assert p.meta["co_mode"] == direct.meta["co_mode"]

    def test_merged_mode_not_reused_for_plans(self, lm_setup):
        """A merged-mode co-schedule spans the concatenated graph and has
        no per-model execution path: deploy must re-plan (merged excluded)
        instead of deriving per-model ShardPlans from it."""
        from dataclasses import replace

        cfgs, hw = lm_setup
        wl = scope.WorkloadSpec.lm(cfgs, seq_len=64, weights=[2.0, 1.0])
        sol = scope.solve(scope.problem(
            wl, hw, m_samples=8, include_merged=False,
        ))
        sol.multi = replace(sol.multi, mode="merged")
        dep = sol.deploy(global_batch=8)
        assert dep.multi is not sol.multi
        assert dep.multi.mode != "merged"
        assert set(dep.plans) == {c.name for c in cfgs}

    def test_single_cfg_uses_plan_for_cell(self, lm_setup):
        cfgs, _ = lm_setup
        from repro.core.hw import tpu_v5e

        wl = scope.WorkloadSpec.lm(cfgs[:1], seq_len=64)
        sol = scope.solve(scope.problem(wl, tpu_v5e(8, (1, 8)), m_samples=8))
        dep = sol.deploy(global_batch=8)
        plan = dep.plans[cfgs[0].name]
        assert plan.meta["kind"] == "train" and plan.meta["dse"] is True

    def test_deploy_without_cfgs_raises(self):
        sol = scope.solve(workload="alexnet", package="mcm16")
        with pytest.raises(ValueError, match="ModelConfigs"):
            sol.deploy(global_batch=8)


# ------------------------------------------------------------------- CLI

class TestCLI:
    def test_solve_json_parity(self, capsys):
        from repro.__main__ import main

        main(["solve", "--mix", "alexnet", "--hw", "mcm16", "--json"])
        out = json.loads(capsys.readouterr().out)
        assert out["strategy"] == "scope" and out["feasible"]
        legacy = search(get_cnn("alexnet"),
                        _shared(mcm_table_iii(16)), 16)
        assert out["latency_s"] == legacy.latency
        assert out["seam_crossings"] == 0

    def test_solve_multimodel_text(self, capsys):
        from repro.__main__ import main

        main(["solve", "--mix", "alexnet:1,resnet18:1", "--hw", "mcm16",
              "--baselines"])
        out = capsys.readouterr().out
        assert "2 models" in out and "equal-split" in out

    def test_legacy_cli_shim(self, capsys):
        from repro.multimodel.cli import main

        main(["--mix", "alexnet:1,resnet18:1", "--hw", "mcm16"])
        assert "2 models" in capsys.readouterr().out

    def test_strategies_command(self, capsys):
        from repro.__main__ import main

        main(["strategies"])
        out = capsys.readouterr().out.split()
        assert "scope" in out and "coschedule" in out


# ------------------------------------------------------------ validation

class TestSolutionValidation:
    def test_seam_crossings_reported(self):
        sol = scope.solve(workload="resnet18", package="mcm16_hetero")
        assert sol.strategy == "scope-mixed"
        assert "seam_crossings" in sol.diagnostics
        crossings = sol.diagnostics["seam_crossings"]
        flavors = {cl.chip_type for seg in sol.schedule.segments
                   for cl in seg.clusters}
        if len(flavors) == 1:
            assert crossings == 0
        else:
            assert crossings >= 1

    def test_verify_reference_parity(self):
        sol = scope.solve(workload="alexnet", package="mcm16_hetero")
        ref = sol.verify_reference()
        assert ref == pytest.approx(sol.latency, rel=1e-9)

    def test_infeasible_solution(self):
        # full_pipeline is invalid when L > chips
        sol = scope.solve(workload="resnet50", package="mcm16",
                          strategy="full_pipeline")
        assert not sol.feasible
        assert sol.throughput == 0.0


class TestWarmStart:
    """options.warm_start: interactive re-solves seeded by an incumbent."""

    def test_coschedule_drift_refinement(self):
        prob = scope.problem("alexnet:1,resnet18:1", "mcm16", m_samples=16)
        sol = scope.solve(prob)
        drifted = scope.problem("alexnet:3,resnet18:1", "mcm16", m_samples=16)
        cold = scope.solve(drifted)
        warm = scope.solve(drifted.with_options(warm_start=sol))
        assert warm.feasible
        assert warm.multi.meta.get("warm_start") is True
        assert cold.multi.meta.get("warm_start") is False
        # a local refinement, not a cold-quality regression
        assert warm.weighted_throughput >= 0.9 * cold.weighted_throughput

    def test_single_model_warm_matches_cold(self):
        prob = scope.problem("resnet18", "mcm16", m_samples=16)
        cold = scope.solve(prob)
        warm = scope.solve(prob.with_options(warm_start=cold))
        # the window contains the incumbent's segment count, and the sweep
        # is deterministic: the warm solve lands on the same schedule
        assert warm.schedule.latency == cold.schedule.latency
        assert warm.schedule.segments == cold.schedule.segments

    def test_warm_rejected_when_incumbent_does_not_fit(self):
        big = scope.solve(scope.problem(
            "alexnet:1,resnet18:1", "mcm64", m_samples=16))
        small = scope.problem("alexnet:1,resnet18:1", "mcm16", m_samples=16)
        warm = scope.solve(small.with_options(warm_start=big))
        # the 64-chip incumbent cannot anchor a 16-chip package: the solve
        # must fall back to the full (cold) search
        assert warm.feasible
        assert warm.multi.meta.get("warm_start") is False
        cold = scope.solve(small)
        assert warm.weighted_throughput == cold.weighted_throughput

    def test_warm_start_excluded_from_fingerprint(self):
        prob = scope.problem("alexnet:1,resnet18:1", "mcm16", m_samples=16)
        sol = scope.solve(prob)
        fp_cold = scope.problem_fingerprint(prob)
        fp_warm = scope.problem_fingerprint(prob.with_options(warm_start=sol))
        assert fp_cold == fp_warm
