"""Benchmark driver: one section per paper table/figure + the roofline table.

``PYTHONPATH=src python -m benchmarks.run [--quick] [--refresh]``

Sections:
  fig8   search quality vs exhaustive/random space   (paper SSV-B(1))
  fig7   throughput, 8 nets x 3 scales x 4 methods   (paper Fig. 7)
  fig9   scalability 16..256 chiplets                (paper Fig. 9)
         + resnet152 at 512/1024 (fast-engine sweep)
  fig10  ResNet-152 x 256 case study + energy        (paper Fig. 10)
  fig11  multi-model co-scheduling vs baselines      (beyond-paper)
  serving executor: goodput/p95 under load + autoscale drift (beyond-paper)
  search DSE wall-time table                         (paper SSV-B(1))
  kernels micro-bench CSV
  roofline LM-arch dry-run aggregation               (SSRoofline)
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="subset of nets/scales for a fast pass")
    ap.add_argument("--refresh", action="store_true",
                    help="ignore cached results")
    args = ap.parse_args()

    from . import (fig7_throughput, fig8_search_quality, fig9_scalability,
                   fig10_case_study, fig11_multimodel, kernel_bench, roofline,
                   search_time)

    def section(title, lines):
        print(f"\n## {title}")
        for ln in lines:
            print(ln)
        sys.stdout.flush()

    r8 = fig8_search_quality.run(refresh=args.refresh,
                                 samples=10_000 if args.quick else 50_000)
    section("fig8_search_quality", fig8_search_quality.report(r8))

    if args.quick:
        r7 = fig7_throughput.run(refresh=args.refresh,
                                 nets=["alexnet", "resnet18", "resnet50"],
                                 scales=[16, 64])
    else:
        r7 = fig7_throughput.run(refresh=args.refresh)
    section("fig7_throughput", fig7_throughput.report(r7))

    r9 = fig9_scalability.run(refresh=args.refresh)
    section("fig9_scalability", fig9_scalability.report(r9))

    if args.quick:
        r11 = fig11_multimodel.run(refresh=args.refresh,
                                   mixes=fig11_multimodel.MIXES[:1])
    else:
        r11 = fig11_multimodel.run(refresh=args.refresh)
    section("fig11_multimodel", fig11_multimodel.report(r11))

    if not args.quick:
        from . import serving_bench

        rsv = serving_bench.run(refresh=args.refresh)
        section("serving_bench", serving_bench.report(rsv))

        r9l = fig9_scalability.run_large(refresh=args.refresh)
        section("fig9_scalability_large", fig9_scalability.report(r9l))

        r10 = fig10_case_study.run(refresh=args.refresh)
        section("fig10_case_study", fig10_case_study.report(r10))

        rs = search_time.run(refresh=args.refresh)
        section("search_time", search_time.report(rs))

    rk = kernel_bench.run()
    section("kernel_microbench", kernel_bench.report(rk))

    rows = roofline.load_rows("pod16x16")
    section("roofline_pod16x16", roofline.report(rows))
    rows2 = roofline.load_rows("pod2x16x16")
    if rows2:
        section("roofline_pod2x16x16", roofline.report(rows2))


if __name__ == "__main__":
    main()
