"""Jit'd public wrapper for the int8 matmul."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import qmatmul_kernel
from .ref import qmatmul_ref, quantize_cols, quantize_rows  # noqa: F401


@partial(jax.jit, static_argnames=("block_m", "block_n", "block_k", "impl", "interpret"))
def qmatmul(x_q, w_q, x_scale, w_scale, block_m=128, block_n=128, block_k=128,
            impl: str = "pallas", interpret: bool = False):
    if impl == "ref":
        return qmatmul_ref(x_q, w_q, x_scale, w_scale)
    return qmatmul_kernel(x_q, w_q, x_scale, w_scale, block_m=block_m,
                          block_n=block_n, block_k=block_k, interpret=interpret)
