"""Checkpointing: atomic, content-verified, mesh-elastic.

Layout:  <dir>/step_<N>/
    manifest.json      {step, keys, shapes, dtypes, sha256 per leaf, meta}
    <leaf-id>.npy      one file per pytree leaf

Design points for scale:
* leaves are written one at a time (streaming; host never needs 2x model),
* writes go to ``step_N.tmp`` then ``os.replace`` -> crash-atomic,
* restore takes *target shardings*: leaves are ``jax.device_put`` onto the
  current mesh, so a checkpoint written on a 16x16 mesh restores onto 2x16x16
  (or 1 device) unchanged -- this is the elastic-rescale path used by
  ft/runner.py and tested in tests/test_ckpt_ft.py.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil

import jax
import numpy as np


def _resolve_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _leaf_paths(tree):
    try:
        flat, treedef = jax.tree.flatten_with_path(tree)
    except AttributeError:  # jax < 0.5: only the tree_util spelling exists
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(p) for p in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return keys, leaves, treedef


def save_checkpoint(directory: str, step: int, tree, meta: dict | None = None) -> str:
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    keys, leaves, _ = _leaf_paths(tree)
    manifest = {"step": step, "leaves": [], "meta": meta or {}}
    for i, (key, leaf) in enumerate(zip(keys, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"leaf_{i:05d}.npy"
        if arr.dtype.kind == "V" or arr.dtype.name not in np.sctypeDict:
            # exotic dtypes (bfloat16 etc.): store raw bytes; the manifest
            # dtype/shape reconstructs them on load
            np.save(os.path.join(tmp, fn),
                    np.frombuffer(arr.tobytes(), dtype=np.uint8))
        else:
            np.save(os.path.join(tmp, fn), arr)
        with open(os.path.join(tmp, fn), "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest["leaves"].append(
            {"key": key, "file": fn, "shape": list(arr.shape),
             "dtype": str(arr.dtype), "sha256": digest}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, target_tree, shardings=None,
                       verify: bool = True):
    """Restore into ``target_tree``'s structure; device_put per ``shardings``
    (a matching pytree of NamedSharding or None for host arrays)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    keys, leaves, treedef = _leaf_paths(target_tree)
    by_key = {e["key"]: e for e in manifest["leaves"]}
    sh_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(leaves)
    )
    out = []
    for key, ref, sh in zip(keys, leaves, sh_leaves):
        entry = by_key[key]
        fpath = os.path.join(path, entry["file"])
        if verify:
            with open(fpath, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            if digest != entry["sha256"]:
                raise IOError(f"checkpoint corruption at {key}")
        arr = np.load(fpath)
        want_dtype = _resolve_dtype(entry["dtype"])
        if arr.dtype == np.uint8 and want_dtype != np.uint8:
            arr = np.frombuffer(arr.tobytes(), dtype=want_dtype).reshape(entry["shape"])
        assert list(arr.shape) == list(ref.shape), (key, arr.shape, ref.shape)
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return treedef.unflatten(out), manifest


def prune_checkpoints(directory: str, keep: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)
