"""KV-cache sizing: the memory axis of the phase DSE.

Autoregressive decode keeps per-sequence state resident for the lifetime of
the sequence -- KV blocks for attention layers (windowed layers cap at the
window), fixed-size recurrent state for mamba/rwkv blocks.  The formulas
here mirror the halo terms of :mod:`repro.core.workloads.lm` exactly: the
bytes a decode step *streams* per boundary are the bytes a resident
sequence *holds* per layer.

The DSE consumes this through :func:`repro.multimodel.curves.kv_bound_curve`
-- a decode quota's throughput flattens at ``K / service(K)`` once the
quota's KV budget (``HardwareModel.kv_bytes_per_chip`` x chips) holds fewer
than ``m`` concurrent sequences.
"""
from __future__ import annotations

from ...core.hw import HardwareModel
from ...core.workloads.lm import BYTES
from ...models.config import ModelConfig

# Sentinel for "no resident state" (a config with zero stateful layers
# never bounds concurrency).
UNBOUNDED = 10**9


def kv_seq_bytes(cfg: ModelConfig, seq_len: int) -> float:
    """Resident decode state of ONE sequence at context ``seq_len``.

    Per layer: attention holds K and V (``2 * n_kv_heads * head_dim``)
    per cached token -- windowed ("local") layers cap the cache at the
    window; mamba holds its SSM state + conv buffer; rwkv holds the WKV
    state matrix.  Matches the ``halo_bytes`` of the corresponding
    :mod:`~repro.core.workloads.lm` nodes.
    """
    total = 0.0
    for kind in cfg.block_kinds():
        if kind in ("attn", "local"):
            win = cfg.window if kind == "local" else 0
            ctx = min(win, seq_len) if win else seq_len
            total += 2.0 * cfg.n_kv_heads * cfg.head_dim * BYTES * ctx
        elif kind == "mamba":
            di = cfg.mamba_expand * cfg.d_model
            total += di * cfg.mamba_d_state * 4 + cfg.mamba_d_conv * di * BYTES
        elif kind == "rwkv":
            hd = cfg.rwkv_head_dim
            total += (cfg.d_model // hd) * hd * hd * 4
    return total


def kv_capacity_bytes(hw: HardwareModel, chips: int) -> float:
    """KV budget of a ``chips``-chip quota on this package."""
    return hw.kv_bytes_per_chip * chips


def max_concurrent_seqs(hw: HardwareModel, chips: int, cfg: ModelConfig,
                        seq_len: int) -> int:
    """How many sequences at context ``seq_len`` a quota can hold resident."""
    per_seq = kv_seq_bytes(cfg, seq_len)
    if per_seq <= 0:
        return UNBOUNDED
    return int(kv_capacity_bytes(hw, chips) // per_seq)
