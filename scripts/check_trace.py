#!/usr/bin/env python
"""Validate a Chrome trace-event JSON file produced by ``repro.obs``.

Thin CLI over :func:`repro.obs.validate_chrome_trace`: checks the required
event keys, per-lane span nesting (no overlaps within a (pid, tid)),
monotone counter-track timestamps, and -- optionally -- that fault instant
events and expected process groups are present.

Usage::

    python scripts/check_trace.py trace.json
    python scripts/check_trace.py trace.json --expect-faults \
        --expect-groups dse,serving
    python scripts/check_trace.py llm_trace.json --expect-llm \
        --expect-groups dse,serving,llm
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
)

from repro.obs import validate_chrome_trace  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="Chrome trace-event JSON file")
    ap.add_argument("--expect-faults", action="store_true",
                    help="require fault instant events (fault:fail / "
                         "fault:re-solve / ...)")
    ap.add_argument("--expect-groups", default="",
                    help="comma-separated process groups that must appear "
                         "(e.g. dse,serving)")
    ap.add_argument("--expect-llm", action="store_true",
                    help="require token-level serving lanes: prefill/decode "
                         "spans per model, admit_midbatch instants, and "
                         "kv_bytes/<model> counter tracks")
    args = ap.parse_args()

    with open(args.trace) as f:
        payload = json.load(f)
    groups = [g for g in args.expect_groups.split(",") if g]
    problems = validate_chrome_trace(
        payload, expect_fault_events=args.expect_faults, expect_groups=groups,
        expect_llm=args.expect_llm,
    )
    events = payload.get("traceEvents", [])
    if problems:
        for p in problems:
            print(f"check_trace: {p}", file=sys.stderr)
        print(f"check_trace: {args.trace}: {len(problems)} problem(s) in "
              f"{len(events)} events", file=sys.stderr)
        return 1
    print(f"check_trace: {args.trace}: OK ({len(events)} events)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
