"""RWKV-6 ("Finch") block: attention-free token mixing with data-dependent
decay (the v6 contribution), plus the RWKV channel-mix FFN.

Per head, the WKV state S [hd, hd] evolves as
    out_t = r_t . (S_{t-1} + (u * k_t) v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T
with w_t = exp(-exp(w0 + lora(x~_t))) computed *from the input* (data
dependence).  Prefill scans over time with ``lax.scan``; decode is one
update.  Simplification vs the full release (documented): the r/k/v/g
token-shift mixing coefficients are static learned vectors (mu), while the
decay keeps its full data-dependent LoRA -- the defining v6 feature.

State cache for serving: {"S": [B, H, hd, hd], "shift": [B, 1, d],
"shift_ffn": [B, 1, d]}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense


def init_rwkv(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = d // hd
    ff = cfg.d_ff
    lora = 64
    ks = jax.random.split(key, 12)
    s = d ** -0.5
    return {
        "mu": (jax.random.uniform(ks[0], (5, d))).astype(dtype),  # r,k,v,g,w mix
        "wr": (jax.random.normal(ks[1], (d, d)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[2], (d, d)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[3], (d, d)) * s).astype(dtype),
        "wg": (jax.random.normal(ks[4], (d, d)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[5], (d, d)) * s).astype(dtype),
        "w0": jnp.full((d,), -2.0, jnp.float32),
        "w_lora_a": (jax.random.normal(ks[6], (d, lora)) * s).astype(dtype),
        "w_lora_b": (jax.random.normal(ks[7], (lora, d)) * lora ** -0.5).astype(dtype),
        "u": (jax.random.normal(ks[8], (H, hd)) * 0.1).astype(jnp.float32),
        "ln_x": jnp.zeros((d,), jnp.float32),
        # channel mix
        "cm_r": (jax.random.normal(ks[9], (d, d)) * s).astype(dtype),
        "cm_k": (jax.random.normal(ks[10], (d, ff)) * s).astype(dtype),
        "cm_v": (jax.random.normal(ks[11], (ff, d)) * ff ** -0.5).astype(dtype),
    }


def _shift(x, prev):
    """Token shift: x_{t-1} (prev fills t=0).  x [B,S,d], prev [B,1,d]."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu


def wkv_scan(r, k, v, w, u, S0):
    """r,k,v [B,S,H,hd]; w decay in (0,1) [B,S,H,hd]; S0 [B,H,hd,hd].

    Returns (out [B,S,H,hd], S_last).  fp32 throughout.
    """
    def step(S, inp):
        rt, kt, vt, wt = inp              # [B,H,hd]
        kv = kt[..., :, None] * vt[..., None, :]          # [B,H,hd,hd]
        out = jnp.einsum("bhi,bhij->bhj", rt, S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, out

    rs, ks_, vs, ws = (jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    S_last, outs = jax.lax.scan(step, S0, (rs, ks_, vs, ws))
    return jnp.moveaxis(outs, 0, 1), S_last


def rwkv_time_mix(params, x, cfg: ModelConfig, state=None):
    B, S, d = x.shape
    hd = cfg.rwkv_head_dim
    H = d // hd
    prev = state["shift"] if state else jnp.zeros((B, 1, d), x.dtype)
    xs = _shift(x, prev)
    mu = params["mu"]
    xr, xk, xv, xg, xw = (_mix(x, xs, mu[i]) for i in range(5))
    r = dense(xr, params["wr"]).astype(jnp.float32).reshape(B, S, H, hd)
    k = dense(xk, params["wk"]).astype(jnp.float32).reshape(B, S, H, hd)
    v = dense(xv, params["wv"]).astype(jnp.float32).reshape(B, S, H, hd)
    g = dense(xg, params["wg"])
    # data-dependent decay (the RWKV-6 core)
    dw = dense(jnp.tanh(dense(xw, params["w_lora_a"])), params["w_lora_b"])
    w = jnp.exp(-jnp.exp(params["w0"] + dw.astype(jnp.float32)))  # (0,1)
    w = w.reshape(B, S, H, hd)
    S0 = state["S"] if state else jnp.zeros((B, H, hd, hd), jnp.float32)
    out, S_last = wkv_scan(r, k, v, w, params["u"], S0)
    # group norm per head (approximated by rmsnorm over hd)
    var = jnp.mean(out * out, axis=-1, keepdims=True)
    out = out * jax.lax.rsqrt(var + 1e-5) * (1.0 + params["ln_x"].reshape(H, hd))
    out = out.reshape(B, S, d).astype(x.dtype) * jax.nn.silu(g)
    new_state = {"S": S_last, "shift": x[:, -1:]}
    return dense(out, params["wo"]), new_state


def rwkv_channel_mix(params, x, state=None):
    B, S, d = x.shape
    prev = state["shift_ffn"] if state else jnp.zeros((B, 1, d), x.dtype)
    xs = _shift(x, prev)
    xk = _mix(x, xs, 0.5)
    r = jax.nn.sigmoid(dense(xk, params["cm_r"]))
    k = jnp.square(jax.nn.relu(dense(xk, params["cm_k"])))
    out = r * dense(k, params["cm_v"])
    return out, {"shift_ffn": x[:, -1:]}
