"""Multi-device integration tests (8 fake CPU devices via subprocess --
the main pytest process must keep seeing 1 device)."""
import os
import subprocess
import sys

import pytest

SCRIPTS = os.path.join(os.path.dirname(__file__), "md_scripts")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, os.path.join(SCRIPTS, script)],
        capture_output=True, text=True, timeout=540, env=env,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "OK" in out.stdout
    return out.stdout


@pytest.mark.slow
def test_sharded_execution_matches_single_device():
    """ISP / WSP / mixed plans all reproduce the unsharded loss, and WSP
    produces a different (sequence-shard) collective pattern than ISP."""
    _run("check_sharded_equivalence.py")


@pytest.mark.slow
def test_merged_pipeline_matches_plain_forward():
    """The shard_map GPipe pipeline (Scope clusters as stages) reproduces
    the plain forward and reduces loss when training."""
    _run("check_pipeline.py")
