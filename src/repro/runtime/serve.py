"""Serving runtime: batched prefill + KV-cache decode steps under a plan."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import decode_step, forward, init_kv_cache, init_params
from ..models.config import ModelConfig
from .sharding import (
    ShardPlan,
    cache_pspecs,
    make_constrain,
    param_pspecs,
    sanitize_pspecs,
    to_shardings,
)


def _sanitized_param_specs(cfg, plan, mesh):
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    return sanitize_pspecs(param_pspecs(cfg, plan, mesh), shapes, mesh)


def build_prefill_step(cfg: ModelConfig, mesh: Mesh, plan: ShardPlan):
    """Signature depends on the frontend:
    none        -> prefill(params, tokens)
    audio_stub  -> prefill(params, frontend_embeds)
    vision_stub -> prefill(params, tokens, frontend_embeds)
    """
    c1 = make_constrain(mesh, plan, zone=1)
    c2 = make_constrain(mesh, plan, zone=2)

    def core(params, tokens, frontend_embeds):
        logits, _ = forward(
            params, cfg, tokens, frontend_embeds,
            constrain=c1, constrain2=c2,
            transition_repeat=plan.transition_repeat,
            collect_cache=False,
        )
        return logits

    p_specs = _sanitized_param_specs(cfg, plan, mesh)
    dp = plan.dp
    p_sh = to_shardings(mesh, p_specs)
    tok_sh = NamedSharding(mesh, P(dp, None))
    emb_sh = NamedSharding(mesh, P(dp, None, None))
    out_sh = NamedSharding(mesh, P(dp, None, "model"))

    if cfg.frontend == "audio_stub":
        fn = lambda params, fe: core(params, None, fe)
        in_sh = (p_sh, emb_sh)
    elif cfg.frontend == "vision_stub":
        fn = core
        in_sh = (p_sh, tok_sh, emb_sh)
    else:
        fn = lambda params, tokens: core(params, tokens, None)
        in_sh = (p_sh, tok_sh)
    return jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh), p_specs


def build_decode_step(cfg: ModelConfig, mesh: Mesh, plan: ShardPlan,
                      batch: int | None = None, max_len: int | None = None):
    """serve_step: one new token against a resident KV cache (donated).

    ``batch``/``max_len`` (when known) let the cache shardings be checked
    for divisibility against the actual cache shapes."""
    c = make_constrain(mesh, plan, zone=2)   # decode is single-token: ISP zone

    def step(params, token, position, caches):
        return decode_step(params, cfg, token, position, caches, constrain=c)

    p_specs = _sanitized_param_specs(cfg, plan, mesh)
    k_specs = cache_pspecs(cfg, plan)
    if batch is not None and max_len is not None:
        cache_shapes = jax.eval_shape(
            lambda: init_kv_cache(cfg, batch, max_len)
        )
        k_specs = sanitize_pspecs(k_specs, cache_shapes, mesh)
    dp = plan.dp
    in_sh = (
        to_shardings(mesh, p_specs),
        NamedSharding(mesh, P(dp, None)),          # token [B,1]
        NamedSharding(mesh, P(dp)),                # position [B]
        to_shardings(mesh, k_specs),
    )
    out_sh = (
        NamedSharding(mesh, P(dp, None, "model")),  # logits
        to_shardings(mesh, k_specs),
    )
    jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(3,))
    return jitted, {"params": p_specs, "caches": k_specs}


def build_multimodel_steps(
    cfgs,
    mesh: Mesh,
    plans: dict[str, ShardPlan],
    batch: int | None = None,
    max_len: int | None = None,
    with_decode: bool = True,
):
    """Per-model serving steps from a multimodel co-schedule.

    ``plans`` comes from :func:`repro.runtime.planner.plan_for_multimodel`:
    each plan's WSP->ISP transition and ``meta["quota_chips"]`` /
    ``meta["time_share"]`` were chosen jointly by the co-scheduler.  Every
    model gets its own jitted prefill (and decode) step on the *shared*
    mesh, which executes a time-multiplexed co-schedule directly (dispatch
    each model for its ``time_share``).  The request scheduler that drives
    these steps under load -- queueing, batching, quota sub-meshes, slice
    windows -- is :mod:`repro.serving`; its ``measure=True`` path times the
    steps built here to calibrate the simulator's service model
    (:func:`repro.serving.measure_service_models`).

    Returns ``{cfg.name: {"prefill": fn, "param_specs": specs,
    "decode": fn, "cache_specs": specs, "plan": plan}}``.
    """
    fleet = {}
    for cfg in cfgs:
        plan = plans[cfg.name]
        prefill, p_specs = build_prefill_step(cfg, mesh, plan)
        entry = {"prefill": prefill, "param_specs": p_specs, "plan": plan}
        if with_decode:
            decode, specs = build_decode_step(cfg, mesh, plan,
                                              batch=batch, max_len=max_len)
            entry["decode"] = decode
            entry["cache_specs"] = specs["caches"]
        fleet[cfg.name] = entry
    return fleet


def greedy_generate(cfg, params, decode_fn, caches, prompt_last_token, start_pos, steps):
    """Simple batched greedy loop driving the jitted decode step."""
    B = prompt_last_token.shape[0]
    tok = prompt_last_token
    pos = jnp.full((B,), start_pos, jnp.int32)
    out = []
    for _ in range(steps):
        logits, caches = decode_fn(params, tok, pos, caches)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out.append(tok)
        pos = pos + 1
    return jnp.concatenate(out, axis=1), caches
