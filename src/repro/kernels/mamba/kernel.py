"""Pallas TPU chunked selective scan (Mamba-1 SSM core).

Recurrence per channel block (state h [bd, N], fp32):
    h_t = exp(dt_t * A) h_{t-1} + (dt_t * x_t) B_t
    y_t = C_t . h_t + D x_t

TPU mapping: grid = (batch, d_inner/bd, S/chunk) with the chunk axis
sequential; h persists in VMEM scratch, so the state never round-trips HBM.
dt/x tiles are [chunk, bd], B/C tiles [chunk, N]; the per-step update is VPU
elementwise work over [bd, N] -- the kernel's value is state residency +
fused discretization (exp(dt*A)) rather than MXU throughput.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..._compat.pallas import CompilerParams as _CompilerParams


def _mamba_kernel(dt_ref, x_ref, A_ref, B_ref, C_ref, D_ref, y_ref, h_out_ref,
                  h_scr, *, chunk: int, n_chunks: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    dt = dt_ref[0].astype(jnp.float32)      # [T, bd]
    x = x_ref[0].astype(jnp.float32)        # [T, bd]
    A = A_ref[...].astype(jnp.float32)      # [bd, N]
    Bc = B_ref[0].astype(jnp.float32)       # [T, N]
    Cc = C_ref[0].astype(jnp.float32)       # [T, N]
    D = D_ref[...].astype(jnp.float32)      # [bd]

    a = jnp.exp(dt[:, :, None] * A[None, :, :])            # [T, bd, N]
    bx = (dt * x)[:, :, None] * Bc[:, None, :]             # [T, bd, N]

    def step(t, carry):
        h, ys = carry
        h = a[t] * h + bx[t]                               # [bd, N]
        y = jnp.sum(h * Cc[t][None, :], axis=1)            # [bd]
        ys = jax.lax.dynamic_update_index_in_dim(ys, y, t, 0)
        return h, ys

    h0 = h_scr[...]
    ys0 = jnp.zeros((chunk, a.shape[1]), jnp.float32)
    h_last, ys = jax.lax.fori_loop(0, chunk, step, (h0, ys0))
    y_ref[0] = (ys + D[None, :] * x).astype(y_ref.dtype)
    h_scr[...] = h_last

    @pl.when(c == n_chunks - 1)
    def _final():
        h_out_ref[0] = h_last


def mamba_scan_kernel(
    dt: jax.Array,     # [B, S, di] fp32 (post-softplus)
    x: jax.Array,      # [B, S, di]
    A: jax.Array,      # [di, N]  (negative)
    Bc: jax.Array,     # [B, S, N]
    Cc: jax.Array,     # [B, S, N]
    D: jax.Array,      # [di]
    block_d: int = 128,
    chunk: int = 64,
    interpret: bool = False,
):
    """Returns (y [B,S,di] fp32, h_last [B,di,N] fp32)."""
    B, S, di = x.shape
    N = A.shape[1]
    block_d = min(block_d, di)
    chunk = min(chunk, S)
    assert di % block_d == 0 and S % chunk == 0
    n_chunks = S // chunk
    grid = (B, di // block_d, n_chunks)
    kernel = functools.partial(_mamba_kernel, chunk=chunk, n_chunks=n_chunks)
    sd = pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d))
    sn = pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            sd,                                                  # dt
            sd,                                                  # x
            pl.BlockSpec((block_d, N), lambda b, d, c: (d, 0)),  # A
            sn,                                                  # B
            sn,                                                  # C
            pl.BlockSpec((block_d,), lambda b, d, c: (d,)),      # D
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, block_d, N), lambda b, d, c: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, di), jnp.float32),
            jax.ShapeDtypeStruct((B, di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(dt, x, A, Bc, Cc, D)
