"""Jit'd public wrapper for the chunked selective scan."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import mamba_scan_kernel
from .ref import mamba_scan_ref


@partial(jax.jit, static_argnames=("block_d", "chunk", "impl", "interpret"))
def mamba_scan(dt, x, A, Bc, Cc, D, block_d: int = 128, chunk: int = 64,
               impl: str = "pallas", interpret: bool = False):
    if impl == "ref":
        return mamba_scan_ref(dt, x, A, Bc, Cc, D)
    return mamba_scan_kernel(dt, x, A, Bc, Cc, D, block_d=block_d,
                             chunk=chunk, interpret=interpret)
