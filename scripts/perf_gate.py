#!/usr/bin/env python
"""Perf regression gate: fresh fast-engine DSE wall times vs the committed
``BENCH_search_time.json`` baseline.

The observability layer (repro.obs) instruments the solver's hot path; its
disabled cost must stay in the noise.  This gate re-times the two
heavyweight fast-engine rows (resnet50 x 64, resnet152 x 256) through the
same facade the benchmark used -- tracing off, best of ``RUNS`` attempts to
shave scheduler jitter -- and fails when either exceeds the committed
``fast_search_s`` by more than ``CI_PERF_FACTOR`` (default 1.5x: a generous
budget that still catches an accidentally-always-on tracer or a hot-loop
allocation, while tolerating machine-class variance).

Usage::

    PYTHONPATH=src python scripts/perf_gate.py
    CI_PERF_FACTOR=2.0 PYTHONPATH=src python scripts/perf_gate.py
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
)

from repro import scope  # noqa: E402

BASELINE = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_search_time.json",
)
# The committed fast-engine rows worth gating (the alexnet row is
# millisecond-scale: pure timer noise).  All gated rows run the batched
# population evaluator -- its engagement is asserted via the batch
# counters, so a silent fallback to scalar sweeps also fails the gate.
GATED = [("resnet50", 64), ("resnet152", 256), ("resnet152", 1024)]
# Absolute ceilings, independent of the committed baseline: the 1024-chip
# sweep is the "interactive at scale" acceptance row.
HARD_CEILING_S = {("resnet152", 1024): 60.0}
RUNS = 2
M_SAMPLES = 16          # matches benchmarks/common.py


def baseline_rows() -> dict[tuple[str, int], float]:
    with open(BASELINE) as f:
        rows = json.load(f)
    out = {}
    for r in rows:
        if "fast_search_s" in r and "chips" in r:
            out[(r["net"], r["chips"])] = r["fast_search_s"]
    return out


def time_solve(net: str, chips: int) -> float:
    best = float("inf")
    for _ in range(RUNS):
        t0 = time.perf_counter()
        sol = scope.solve(
            scope.problem(net, f"mcm{chips}", m_samples=M_SAMPLES)
        )
        dt = time.perf_counter() - t0
        assert sol.feasible, (net, chips)
        stats = sol.diagnostics.get("engine_stats", {})
        assert stats.get("batch_evals", 0) > 0, (
            "batched population evaluator did not engage", net, chips, stats
        )
        best = min(best, dt)
    return best


def main() -> int:
    factor = float(os.environ.get("CI_PERF_FACTOR", "1.5"))
    base = baseline_rows()
    failures = []
    for net, chips in GATED:
        committed = base.get((net, chips))
        if committed is None:
            print(f"perf gate: no committed baseline for {net} x {chips}; "
                  "run benchmarks/search_time.py first", file=sys.stderr)
            return 2
        fresh = time_solve(net, chips)
        ratio = fresh / committed
        ceiling = HARD_CEILING_S.get((net, chips))
        over_ceiling = ceiling is not None and fresh > ceiling
        verdict = ("ok" if ratio <= factor and not over_ceiling
                   else "REGRESSION")
        print(f"perf gate: {net} x {chips}: {fresh:.3f}s vs committed "
              f"{committed:.3f}s ({ratio:.2f}x, budget {factor:.2f}x"
              f"{f', ceiling {ceiling:.0f}s' if ceiling else ''}) "
              f"[{verdict}]")
        if ratio > factor or over_ceiling:
            failures.append((net, chips, ratio))
    if failures:
        for net, chips, ratio in failures:
            print(f"perf gate FAILED: {net} x {chips} regressed {ratio:.2f}x "
                  f"(> {factor:.2f}x budget)", file=sys.stderr)
        return 1
    print("perf gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
