"""Scope layer graphs for the assigned LM architectures.

Exports a :class:`LayerGraph` per (ModelConfig x shape) so the paper's DSE
schedules the same models the JAX runtime executes.  Costs are per *sample*
(one sequence); the pipeline unit count m = global batch.

Parallelism metadata:
* ``wsp_parallel``  = tokens (sequence split; the CNN row-split analogue),
* ``isp_parallel``  = heads*d_head or d_ff (weight-output split),
* ``halo_bytes``    = WSP boundary exchange: KV block for attention,
  recurrent state for SSM/RWKV (tiny -- which is why WSP loves them).
"""
from __future__ import annotations

from ...models.config import ModelConfig
from ..graph import LayerGraph, LayerNode, chain

BYTES = 2  # bf16


def _attn_node(cfg: ModelConfig, name: str, S: int, window: int = 0) -> LayerNode:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    proj = 2.0 * S * d * hd * (2 * H + 2 * KV)
    ctx = min(window, S) if window else S
    attn = 2.0 * 2.0 * S * ctx * H * hd / 2.0       # causal QK^T + PV
    kv_bytes = S * KV * hd * 2 * BYTES
    return LayerNode(
        name=name, kind="attention",
        flops=proj + attn,
        weight_bytes=d * hd * (H + 2 * KV) * BYTES + H * hd * d * BYTES,
        in_bytes=S * d * BYTES, out_bytes=S * d * BYTES,
        halo_bytes=min(kv_bytes, (min(window, S) if window else S) * KV * hd * 2 * BYTES),
        wsp_parallel=float(S), isp_parallel=float(H * hd),
    )


def _ffn_node(cfg: ModelConfig, name: str, S: int, moe: bool) -> LayerNode:
    d = cfg.d_model
    fmats = 3.0 if cfg.ffn_gated else 2.0
    if moe:
        m = cfg.moe
        ff = m.d_ff or cfg.d_ff
        flops = 2.0 * S * m.top_k * m.capacity_factor * fmats * d * ff \
            + 2.0 * S * d * m.n_experts
        w = fmats * d * ff * m.n_experts * BYTES
        return LayerNode(
            name=name, kind="moe_ffn", flops=flops, weight_bytes=w,
            in_bytes=S * d * BYTES, out_bytes=S * d * BYTES,
            wsp_parallel=float(S), isp_parallel=float(ff),
            n_experts=m.n_experts, active_experts=m.top_k,
        )
    ff = cfg.d_ff
    return LayerNode(
        name=name, kind="ffn", flops=2.0 * S * fmats * d * ff,
        weight_bytes=fmats * d * ff * BYTES,
        in_bytes=S * d * BYTES, out_bytes=S * d * BYTES,
        wsp_parallel=float(S), isp_parallel=float(ff),
    )


def _mamba_node(cfg: ModelConfig, name: str, S: int) -> LayerNode:
    d = cfg.d_model
    di = cfg.mamba_expand * d
    N = cfg.mamba_d_state
    R = max(1, d // 16)
    proj = 2.0 * S * (2 * d * di + di * (R + 2 * N) + R * di + di * d)
    scan = 10.0 * S * di * N                 # discretize + recurrence + output
    w = (2 * d * di + di * (cfg.mamba_d_conv + R + 2 * N + 2) + R * di + di * d) * BYTES
    return LayerNode(
        name=name, kind="mamba", flops=proj + scan, weight_bytes=w,
        in_bytes=S * d * BYTES, out_bytes=S * d * BYTES,
        halo_bytes=float(di * N * 4 + cfg.mamba_d_conv * di * BYTES),  # state handoff
        wsp_parallel=float(S), isp_parallel=float(di),
    )


def _rwkv_node(cfg: ModelConfig, name: str, S: int) -> LayerNode:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = d // hd
    proj = 2.0 * S * 5 * d * d
    wkv = 4.0 * S * H * hd * hd              # state update + readout
    cm = 2.0 * S * (2 * d * cfg.d_ff + d * d)
    w = (5 * d * d + 2 * d * cfg.d_ff + d * d) * BYTES
    return LayerNode(
        name=name, kind="rwkv", flops=proj + wkv + cm, weight_bytes=w,
        in_bytes=S * d * BYTES, out_bytes=S * d * BYTES,
        halo_bytes=float(H * hd * hd * 4),    # WKV state handoff
        wsp_parallel=float(S), isp_parallel=float(d),
    )


def _embed_node(cfg: ModelConfig, name: str, S: int, out: bool) -> LayerNode:
    d, V = cfg.d_model, cfg.vocab
    return LayerNode(
        name=name, kind="embed",
        flops=2.0 * S * d * V if out else 2.0 * S * d,
        weight_bytes=float(V * d * BYTES),
        in_bytes=S * (d if out else 4) * BYTES,
        out_bytes=S * (V if out else d) * BYTES,
        wsp_parallel=float(S), isp_parallel=float(V),
    )


def lm_graph(cfg: ModelConfig, seq_len: int, decode: bool = False) -> LayerGraph:
    """decode=True models one serve_step token (S=1 compute, full-S KV halo)."""
    S = 1 if decode else seq_len
    layers = [_embed_node(cfg, "embed", S, out=False)]
    for i, kind in enumerate(cfg.block_kinds()):
        moe = cfg.is_moe_block(i) and kind != "rwkv"
        if kind in ("attn", "local"):
            win = cfg.window if kind == "local" else 0
            node = _attn_node(cfg, f"l{i}.attn", S, win)
            if decode:
                # one-token attention against the full cache
                import dataclasses

                ctx = min(win, seq_len) if win else seq_len
                node = dataclasses.replace(
                    node,
                    flops=2.0 * cfg.d_model * cfg.head_dim
                    * (2 * cfg.n_heads + 2 * cfg.n_kv_heads)
                    + 4.0 * ctx * cfg.n_heads * cfg.head_dim,
                )
            layers.append(node)
            layers.append(_ffn_node(cfg, f"l{i}.ffn", S, moe))
        elif kind == "mamba":
            layers.append(_mamba_node(cfg, f"l{i}.mamba", S))
            layers.append(_ffn_node(cfg, f"l{i}.ffn", S, moe))
        elif kind == "rwkv":
            layers.append(_rwkv_node(cfg, f"l{i}.rwkv", S))
    layers.append(_embed_node(cfg, "lm_head", S, out=True))
    return chain(f"{cfg.name}@{'decode' if decode else 'prefill'}{seq_len}", layers)
