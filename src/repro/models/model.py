"""Composable decoder model: embed -> scanned blocks -> norm -> logits.

Layers are scanned over pattern *repeats* with stacked params (keeps HLO
small and compile times sane at 48+ layers).  To execute a Scope schedule,
``forward``/``decode_step`` accept:

* ``constrain(x, tag)``   -- sharding-constraint callback (identity default);
  tags: "embed", "resid", "logits", f"blk{i}:attn" etc.
* ``transition_repeat``   -- the paper's WSP->ISP transition point mapped to
  the repeat axis: repeats [0, t) run under ``constrain``, repeats [t, R)
  under ``constrain2``.  Implemented as two scan segments over sliced
  stacked params -- per-layer heterogeneous sharding with scanned layers is
  exactly what the single-transition-point structure makes possible.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .attention import attention_decode, attention_prefill, init_attn
from .config import ModelConfig
from .layers import dense, embed, ffn, init_ffn, rmsnorm, softcap
from .moe import init_moe, moe_ffn
from .rwkv import init_rwkv, rwkv_channel_mix, rwkv_time_mix
from .ssm import init_mamba, mamba_decode, mamba_prefill


def _identity_constrain(x, tag):
    return x


# --------------------------------------------------------------------- init

def _init_block(key, cfg: ModelConfig, kind: str, layer_idx: int, dtype) -> dict:
    ks = jax.random.split(key, 4)
    p = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
         "ln2": jnp.zeros((cfg.d_model,), jnp.float32)}
    if kind in ("attn", "local"):
        p["attn"] = init_attn(ks[0], cfg, dtype)
    elif kind == "mamba":
        p["mamba"] = init_mamba(ks[0], cfg, dtype)
    elif kind == "rwkv":
        p["rwkv"] = init_rwkv(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if kind == "rwkv":
        pass                                  # channel mix lives in p["rwkv"]
    elif cfg.is_moe_block(layer_idx):
        p["moe"] = init_moe(ks[1], cfg, dtype)
    else:
        p["ffn"] = init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn_gated, dtype)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    R = cfg.pattern_repeats
    P = len(cfg.expanded_pattern)
    keys = jax.random.split(key, R * P + 2)
    blocks = []
    for pi, kind in enumerate(cfg.expanded_pattern):
        stacked = [
            _init_block(keys[r * P + pi], cfg, kind, r * P + pi, dtype)
            for r in range(R)
        ]
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *stacked))
    params = {
        "embed": (jax.random.normal(keys[-2], (cfg.padded_vocab, cfg.d_model))
                  * cfg.d_model ** -0.5).astype(dtype),
        "blocks": tuple(blocks),
        "final_ln": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[-1], (cfg.d_model, cfg.padded_vocab)) * cfg.d_model ** -0.5
        ).astype(dtype)
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ------------------------------------------------------------------ forward

def _block_prefill(cfg, kind, layer_idx_in_pattern, bp, x, positions, constrain):
    tag = f"blk{layer_idx_in_pattern}"
    h = rmsnorm(x, bp["ln1"], cfg.norm_eps)
    if kind in ("attn", "local"):
        window = cfg.window if kind == "local" else 0
        a, kv = attention_prefill(bp["attn"], h, cfg, positions, window)
        x = constrain(x + a, f"{tag}:attn")
        h2 = rmsnorm(x, bp["ln2"], cfg.norm_eps)
        if "moe" in bp:
            f = moe_ffn(bp["moe"], h2, cfg, constrain)
        else:
            f = ffn(bp["ffn"], h2, cfg.ffn_gated)
        x = constrain(x + f, f"{tag}:ffn")
        cache = {"k": kv[0], "v": kv[1]}
    elif kind == "mamba":
        a, st = mamba_prefill(bp["mamba"], h, cfg)
        x = constrain(x + a, f"{tag}:mamba")
        h2 = rmsnorm(x, bp["ln2"], cfg.norm_eps)
        if "moe" in bp:
            f = moe_ffn(bp["moe"], h2, cfg, constrain)
        else:
            f = ffn(bp["ffn"], h2, cfg.ffn_gated)
        x = constrain(x + f, f"{tag}:ffn")
        cache = st
    elif kind == "rwkv":
        a, st = rwkv_time_mix(bp["rwkv"], h, cfg)
        x = constrain(x + a, f"{tag}:rwkv")
        h2 = rmsnorm(x, bp["ln2"], cfg.norm_eps)
        f, st2 = rwkv_channel_mix(bp["rwkv"], h2)
        x = constrain(x + f, f"{tag}:ffn")
        cache = {**st, **st2}
    else:
        raise ValueError(kind)
    return x, cache


def _scan_blocks(cfg, blocks, x, positions, constrain, collect_cache=False):
    """One lax.scan over repeats; pattern positions applied inside the body."""

    def body(carry, bps):
        h = carry
        caches = []
        for pi, kind in enumerate(cfg.expanded_pattern):
            h, c = _block_prefill(cfg, kind, pi, bps[pi], h, positions, constrain)
            caches.append(c)
        return h, tuple(caches) if collect_cache else None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    n = jax.tree.leaves(blocks)[0].shape[0]
    x, caches = jax.lax.scan(
        body_fn, x, blocks, unroll=max(1, min(cfg.scan_unroll, n))
    )
    return x, caches


def forward(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array | None,
    frontend_embeds: jax.Array | None = None,
    constrain=_identity_constrain,
    constrain2=None,
    transition_repeat: int | None = None,
    collect_cache: bool = False,
    positions: jax.Array | None = None,
):
    """Returns (logits [B,S,V], caches or None)."""
    if cfg.frontend == "audio_stub":
        x = frontend_embeds.astype(jnp.dtype(cfg.param_dtype))
        B, S = x.shape[:2]
    elif cfg.frontend == "vision_stub":
        t_emb = embed(tokens, params["embed"])
        x = jnp.concatenate(
            [frontend_embeds.astype(t_emb.dtype), t_emb], axis=1
        )
        B, S = x.shape[:2]
    else:
        x = embed(tokens, params["embed"])
        B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = constrain(x, "embed")

    if transition_repeat is None or constrain2 is None:
        blocks = params["blocks"]
        x, caches = _scan_blocks(cfg, blocks, x, positions, constrain, collect_cache)
    else:
        t = transition_repeat
        zone1 = jax.tree.map(lambda a: a[:t], params["blocks"])
        zone2 = jax.tree.map(lambda a: a[t:], params["blocks"])
        caches = []
        if t > 0:
            x, c1 = _scan_blocks(cfg, zone1, x, positions, constrain, collect_cache)
            caches.append(c1)
        if t < cfg.pattern_repeats:
            x = constrain2(x, "transition")
            x, c2 = _scan_blocks(cfg, zone2, x, positions, constrain2, collect_cache)
            caches.append(c2)
        caches = tuple(caches) if collect_cache else None

    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = dense(x, head)
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return constrain(logits, "logits"), caches


# ----------------------------------------------------------------- KV cache

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    R = cfg.pattern_repeats
    caches = []
    for kind in cfg.expanded_pattern:
        if kind in ("attn", "local"):
            kv, hd = cfg.n_kv_heads, cfg.head_dim
            caches.append({
                "k": jnp.zeros((R, batch, max_len, kv, hd), dtype),
                "v": jnp.zeros((R, batch, max_len, kv, hd), dtype),
            })
        elif kind == "mamba":
            di = cfg.mamba_expand * cfg.d_model
            caches.append({
                "h": jnp.zeros((R, batch, di, cfg.mamba_d_state), jnp.float32),
                "conv": jnp.zeros((R, batch, cfg.mamba_d_conv - 1, di), dtype),
            })
        elif kind == "rwkv":
            H = cfg.d_model // cfg.rwkv_head_dim
            caches.append({
                "S": jnp.zeros((R, batch, H, cfg.rwkv_head_dim, cfg.rwkv_head_dim), jnp.float32),
                "shift": jnp.zeros((R, batch, 1, cfg.d_model), dtype),
                "shift_ffn": jnp.zeros((R, batch, 1, cfg.d_model), dtype),
            })
    return tuple(caches)


def _block_decode(cfg, kind, pi, bp, x, position, cache, constrain):
    tag = f"blk{pi}"
    h = rmsnorm(x, bp["ln1"], cfg.norm_eps)
    if kind in ("attn", "local"):
        window = cfg.window if kind == "local" else 0
        a, (ck, cv) = attention_decode(
            bp["attn"], h, cfg, cache["k"], cache["v"], position, window
        )
        new_cache = {"k": ck, "v": cv}
        x = constrain(x + a, f"{tag}:attn")
        h2 = rmsnorm(x, bp["ln2"], cfg.norm_eps)
        f = moe_ffn(bp["moe"], h2, cfg, constrain) if "moe" in bp else ffn(bp["ffn"], h2, cfg.ffn_gated)
        x = constrain(x + f, f"{tag}:ffn")
    elif kind == "mamba":
        a, st = mamba_decode(bp["mamba"], h, cfg, cache)
        new_cache = st
        x = constrain(x + a, f"{tag}:mamba")
        h2 = rmsnorm(x, bp["ln2"], cfg.norm_eps)
        f = moe_ffn(bp["moe"], h2, cfg, constrain) if "moe" in bp else ffn(bp["ffn"], h2, cfg.ffn_gated)
        x = constrain(x + f, f"{tag}:ffn")
    elif kind == "rwkv":
        a, st = rwkv_time_mix(bp["rwkv"], h, cfg, state=cache)
        x = constrain(x + a, f"{tag}:rwkv")
        h2 = rmsnorm(x, bp["ln2"], cfg.norm_eps)
        f, st2 = rwkv_channel_mix(bp["rwkv"], h2, state=cache)
        new_cache = {**st, **st2}
        x = constrain(x + f, f"{tag}:ffn")
    return x, new_cache


def decode_step(
    params: dict,
    cfg: ModelConfig,
    token: jax.Array,            # [B, 1] int32
    position: jax.Array,         # [B] write index
    caches: tuple,
    constrain=_identity_constrain,
):
    """One autoregressive step.  Returns (logits [B,1,V], new caches)."""
    x = embed(token, params["embed"])
    x = constrain(x, "embed")

    def body(carry, scanned):
        h = carry
        bps, layer_caches = scanned
        new_caches = []
        for pi, kind in enumerate(cfg.expanded_pattern):
            h, nc = _block_decode(cfg, kind, pi, bps[pi], h, position,
                                  layer_caches[pi], constrain)
            new_caches.append(nc)
        return h, tuple(new_caches)

    x, new_caches = jax.lax.scan(
        body, x, (params["blocks"], caches),
        unroll=max(1, min(cfg.scan_unroll, cfg.pattern_repeats)),
    )
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = dense(x, head)
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return constrain(logits, "logits"), new_caches


# -------------------------------------------------------------------- loss

def loss_fn(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,
    labels: jax.Array,
    frontend_embeds: jax.Array | None = None,
    constrain=_identity_constrain,
    constrain2=None,
    transition_repeat: int | None = None,
) -> jax.Array:
    logits, _ = forward(
        params, cfg, tokens, frontend_embeds,
        constrain=constrain, constrain2=constrain2,
        transition_repeat=transition_repeat,
    )
    # labels cover the final S_label positions of the sequence (frontend
    # stub positions are unlabeled)
    S_lab = labels.shape[1]
    logits = logits[:, -S_lab:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean()
