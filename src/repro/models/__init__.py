"""Pure-JAX LM model stack (no flax): params are pytrees of jnp arrays."""
from .config import ModelConfig, MoEConfig  # noqa: F401
from .model import (  # noqa: F401
    init_params,
    forward,
    init_kv_cache,
    decode_step,
    loss_fn,
)
