"""Segment division (shared by Scope and the segmented-pipeline baseline).

Per paper SSV-A, Scope "uses an identical segment allocation method as the
segmented pipeline to isolate performance gains" -- so both schedulers call
this module.  A division into S segments is a contiguous split of the layer
chain that (a) is weight-capacity feasible (a segment's parameters must fit
on-package, in the best case fully sharded: sum W / C <= cap/chip) and
(b) balances per-segment compute load (min-max FLOPs, classic linear
partitioning DP).
"""
from __future__ import annotations

from .graph import LayerGraph
from .hw import HardwareModel

Split = tuple[tuple[int, int], ...]


def segment_feasible(graph: LayerGraph, lo: int, hi: int, hw: HardwareModel, chips: int) -> bool:
    """Best-case (fully sharded) weight fit.  Must stay consistent with the
    inlined prefix-sum check in :func:`divide_segments`."""
    w = sum(graph.layers[i].weight_bytes for i in range(lo, hi))
    return w <= hw.weight_capacity_per_chip * chips


def divide_segments(
    graph: LayerGraph, hw: HardwareModel, chips: int, n_segments: int
) -> Split | None:
    """Min-max-FLOPs contiguous split into ``n_segments`` feasible segments."""
    L = len(graph)
    if n_segments > L:
        return None
    flops = [l.flops for l in graph.layers]
    prefix = [0.0]
    for f in flops:
        prefix.append(prefix[-1] + f)
    wpre = [0.0]
    for l in graph.layers:
        wpre.append(wpre[-1] + l.weight_bytes)
    w_cap = hw.weight_capacity_per_chip * chips

    def load(lo, hi):
        return prefix[hi] - prefix[lo]

    INF = float("inf")
    # dp[s][i] = best achievable max-load splitting layers[:i] into s segments
    dp = [[INF] * (L + 1) for _ in range(n_segments + 1)]
    cut = [[-1] * (L + 1) for _ in range(n_segments + 1)]
    dp[0][0] = 0.0
    for s in range(1, n_segments + 1):
        for i in range(s, L + 1):
            for j in range(s - 1, i):
                if dp[s - 1][j] == INF:
                    continue
                if wpre[i] - wpre[j] > w_cap:   # segment_feasible via prefix
                    continue
                cand = max(dp[s - 1][j], load(j, i))
                if cand < dp[s][i]:
                    dp[s][i] = cand
                    cut[s][i] = j
    if dp[n_segments][L] == INF:
        return None
    # reconstruct
    bounds = []
    i = L
    for s in range(n_segments, 0, -1):
        j = cut[s][i]
        bounds.append((j, i))
        i = j
    return tuple(reversed(bounds))


def min_segments(graph: LayerGraph, hw: HardwareModel, chips: int, cap: int = 16) -> int | None:
    for s in range(1, min(cap, len(graph)) + 1):
        if divide_segments(graph, hw, chips, s) is not None:
            return s
    return None


def candidate_segment_counts(
    graph: LayerGraph, hw: HardwareModel, chips: int, extra: int = 4
) -> list[int]:
    """The sweep the DSE explores: minimal feasible count plus a few more."""
    base = min_segments(graph, hw, chips)
    if base is None:
        return []
    return list(range(base, min(base + extra, len(graph)) + 1))
