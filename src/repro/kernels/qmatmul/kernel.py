"""Pallas TPU int8 x int8 -> int32 blocked matmul with row/col dequant.

The paper deploys 8-bit weights/activations with 24-bit accumulation
(Table III); the TPU analogue is int8 MXU issue with int32 accumulation.
Grid = (M/bm, N/bn, K/bk), K sequential with an int32 VMEM accumulator;
dequantization (row scale x col scale) happens once at the last K step.
Blocks are 128-aligned for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..._compat.pallas import CompilerParams as _CompilerParams


def _qmm_kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_scr, *, k_steps: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...]            # [bm, bk] int8
    w = w_ref[...]            # [bk, bn] int8
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )

    @pl.when(kk == k_steps - 1)
    def _final():
        xs = xs_ref[...].astype(jnp.float32)       # [bm]
        ws = ws_ref[...].astype(jnp.float32)       # [bn]
        o_ref[...] = (
            acc_scr[...].astype(jnp.float32) * xs[:, None] * ws[None, :]
        ).astype(o_ref.dtype)


def qmatmul_kernel(
    x: jax.Array,        # [M, K] int8
    w: jax.Array,        # [K, N] int8
    x_scale: jax.Array,  # [M] f32 (per-row)
    w_scale: jax.Array,  # [N] f32 (per-col)
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    M, K = x.shape
    N = w.shape[1]
    block_m, block_n, block_k = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    k_steps = K // block_k
    kernel = functools.partial(_qmm_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=(M // block_m, N // block_n, k_steps),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_m,), lambda i, j, kk: (i,)),
            pl.BlockSpec((block_n,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w, x_scale, w_scale)
