"""Merged interleaving: fuse several models into one shared merged pipeline.

Spatial partitioning wastes chips when a small model cannot use even its
minimal quota efficiently.  The alternative the merged-pipeline dimension
opens up: concatenate the models' LayerGraphs into one chain, scale each
model's layers by a per-model batch weighting (``LayerNode.scaled``), and
run a single Scope DSE over the whole package.  One pipeline beat then
produces ``scale_i`` samples of model ``i``; every region serves exactly one
model's layers (clusters never straddle models more than the CMT merge
allows -- straddling is legal and simply means two small adjacent models
share a region, which is the point of merging).

Boundary semantics: consecutive models exchange no activations -- model
outputs leave via DRAM (out/halo sanitized to 0, like any network output)
and the next model's inputs arrive from DRAM.  Each model-initial layer is
marked ``meta["dram_input"]`` and the cost model's segment-level load term
charges its staging wherever the boundary lands (mid-segment entry layers
included, see ``segment_time``) -- partition-independent, so the DSE cannot
dodge the charge by picking a particular boundary partition pair.
"""
from __future__ import annotations

from dataclasses import replace

from ..core.costmodel import INF, CostModel
from ..core.graph import (
    MM_MERGED,
    MM_PARTITIONED,
    LayerGraph,
    ModelAssignment,
    MultiModelSchedule,
    mix_rate,
)
from ..core.search import search


def batch_scales(specs, max_scale: int = 8) -> list[int]:
    """Integer samples-per-beat per model, approximately proportional to the
    traffic weights (capped at ``max_scale`` to keep merged graphs small).
    The achieved mix rate is computed from the *actual* scales, so the
    integer rounding never over-reports throughput."""
    w_min = min(s.weight for s in specs)
    return [
        max(1, min(max_scale, round(s.weight / w_min))) for s in specs
    ]


def merged_graph(specs, scales=None) -> tuple[LayerGraph, list[int]]:
    """Concatenate the specs' graphs with per-model batch weighting."""
    scales = scales or batch_scales(specs)
    layers = []
    for m, (spec, scale) in enumerate(zip(specs, scales)):
        for i, node in enumerate(spec.graph.layers):
            node = node.scaled(scale)
            if i == len(spec.graph) - 1:
                node = replace(node, out_bytes=0.0, halo_bytes=0.0)
            if i == 0 and m > 0:
                node = replace(
                    node, meta={**node.meta, "dram_input": True}
                )
            layers.append(replace(node, name=f"{spec.name}.{node.name}"))
    name = "+".join(
        f"{s.name}x{k}" if k > 1 else s.name for s, k in zip(specs, scales)
    )
    return LayerGraph(name, tuple(layers)), list(scales)


def _set_partitions(items: list):
    """All partitions of ``items`` into non-empty groups (Bell enumeration;
    callers gate on small N, so the growth is harmless)."""
    if not items:
        yield []
        return
    first, rest = items[0], items[1:]
    for part in _set_partitions(rest):
        for i in range(len(part)):
            yield part[:i] + [[first] + part[i]] + part[i + 1:]
        yield [[first]] + part


def search_merged_groups(
    specs,
    cost: CostModel,
    step: int = 1,
    paper_strict: bool = False,
    curves=None,
    max_models: int = 4,
) -> MultiModelSchedule | None:
    """Partitioned quotas over *merged sub-groups* of the model set.

    The all-merged pipeline (:func:`search_merged`) and fully-partitioned
    quotas (:func:`~.quota.search_partitioned`) are the two extremes of a
    spectrum: any partition of the model set into groups -- each group
    merged into one pipeline, the groups sharing the package through the
    quota search -- is a legal co-schedule.  This enumerates the proper
    partitions (at least two groups, at least one of size >= 2; the
    extremes are already separate ``co_schedule`` candidates) for small
    model sets and returns the best, so the co-scheduler's result is by
    construction at least as good as either extreme.

    A merged group enters the quota search as a pseudo-model whose curve
    points are beat rates; its traffic weight is ``max_i(w_i / scale_i)``
    -- the beats each mix unit demands -- so the quota search's
    ``min(tp / weight)`` objective prices the group exactly.  Group curves
    are cached across partitions (the same pair appears in several), and
    singleton models reuse the caller's curves; everything flows through
    the one shared FastCostModel memo.
    """
    from .curves import throughput_curve
    from .quota import package_flavors, search_partitioned
    from .spec import ModelSpec

    hw = cost.hw
    n = len(specs)
    if n < 3 or n > max_models:
        return None
    flavors = package_flavors(hw)
    group_cache: dict[tuple[int, ...], tuple] = {}
    curve_cache: dict[tuple[str, str | None], object] = {}
    best = None
    for part in _set_partitions(list(range(n))):
        if len(part) < 2 or all(len(g) == 1 for g in part):
            continue
        pseudo, expand = [], []
        for g in part:
            if len(g) == 1:
                spec = specs[g[0]]
                pseudo.append(spec)
                expand.append([(spec, 1.0)])
            else:
                key = tuple(sorted(g))
                ent = group_cache.get(key)
                if ent is None:
                    members = [specs[i] for i in g]
                    mg, scales = merged_graph(members)
                    w_unit = max(
                        m.weight / s for m, s in zip(members, scales)
                    )
                    ent = group_cache[key] = (
                        ModelSpec(mg, w_unit), members, scales
                    )
                pseudo.append(ent[0])
                expand.append(list(zip(ent[1], ent[2])))
        pcurves = {}
        for s in pseudo:
            for ctype, cap in flavors:
                ckey = (s.name, ctype)
                if curves is not None and ckey in curves:
                    pcurves[ckey] = curves[ckey]
                    continue
                c = curve_cache.get(ckey)
                if c is None:
                    c = curve_cache[ckey] = throughput_curve(
                        cost, s.graph, cap, ctype, step, paper_strict
                    )
                pcurves[ckey] = c
        res = search_partitioned(pseudo, cost, step, paper_strict,
                                 curves=pcurves)
        if res is None:
            continue
        assignments = []
        for a, members in zip(res.assignments, expand):
            for m, scale in members:
                assignments.append(ModelAssignment(
                    model=m.name, weight=m.weight, chips=a.chips,
                    schedule=a.schedule, chip_type=a.chip_type,
                    samples_per_beat=float(scale),
                ))
        assignments = tuple(assignments)
        lam = mix_rate(assignments)
        wt = lam * sum(s.weight for s in specs)
        if best is None or wt > best.weighted_throughput:
            best = MultiModelSchedule(
                package=hw.name,
                chips=hw.chips,
                mode=MM_PARTITIONED,
                assignments=assignments,
                mix_rate=lam,
                weighted_throughput=wt,
                meta={
                    "family": "partitioned_merged_groups",
                    "merge_groups": [
                        [specs[i].name for i in g] for g in part
                        if len(g) > 1
                    ],
                },
            )
    return best


def search_merged(
    specs,
    cost: CostModel,
    chip_type: str | None = None,
    chips: int | None = None,
    paper_strict: bool = False,
) -> MultiModelSchedule | None:
    """One Scope DSE over the merged graph on the whole package.

    On a heterogeneous package the merged pipeline must live on a single
    flavor (a Scope schedule is single-typed); callers pick the flavor via
    ``chip_type``/``chips`` -- co_schedule tries each.
    """
    hw = cost.hw
    if chips is None:
        chips = hw.chips if not hw.region_types else hw.chip_type(chip_type).chips
    graph, scales = merged_graph(specs)
    sched = search(graph, cost, chips, chip_type=chip_type,
                   paper_strict=paper_strict)
    if sched is None or sched.latency == INF:
        return None
    sched.meta["m_samples"] = cost.m
    sched.meta["batch_scales"] = list(scales)
    assignments = tuple(
        ModelAssignment(
            model=spec.name,
            weight=spec.weight,
            chips=chips,
            schedule=sched,
            chip_type=chip_type,
            samples_per_beat=float(scale),
        )
        for spec, scale in zip(specs, scales)
    )
    lam = mix_rate(assignments)
    return MultiModelSchedule(
        package=hw.name,
        chips=hw.chips,
        mode=MM_MERGED,
        assignments=assignments,
        mix_rate=lam,
        weighted_throughput=lam * sum(s.weight for s in specs),
        meta={"merged_graph": graph.name, "batch_scales": list(scales)},
    )
