"""SSV-B(1) search-cost table: DSE wall time per (net x chips) + space size.

Paper reference point: ResNet-152 x 256 chiplets searched in ~1 hour on a
laptop CPU over an O(10^164) space.  The fast engine (FastCostModel,
fastcost.py) sweeps the same space in seconds; every sweep goes through the
solver facade (``repro.scope.solve``, strategy ``scope``) and records

* ``fast_search_s``   -- wall time with FastCostModel (the default engine),
* ``ref_search_s``    -- wall time of the reference CostModel driving the
                         *same* search code (skipped when projected > budget),
* ``seed_search_s``   -- the pre-PR-1 seed implementation's measured wall
                         time (recorded constants; the seed rebalance
                         explored strictly less: no INF-seed repair, no
                         donor retry),
* engine memo counters and the best-schedule latency, which must be
  identical between engines (asserted here and in tests/test_fastcost.py).

The ``resnet152 x 512`` row is the larger sweep the seed code was too slow
to run routinely (projected >= 5 minutes; the fast engine does it in a few
seconds).  The curve rows time the quota-curve sampling
(multimodel/curves.py): 1D exhaustive vs coarse-to-fine, and the 2D
mixed-flavor analogue (``mixed_throughput_curve(refine=True)``) on a
heterogeneous package.

Results land in ``benchmarks/results/search_time.json`` and are mirrored to
``BENCH_search_time.json`` at the repo root for before/after tracking.
"""
from __future__ import annotations

import json
import math
import os
import time

from repro import scope
from repro.core.fastcost import FastCostModel
from repro.core.hw import get_hw, mcm_table_iii
from repro.core.workloads import get_cnn
from repro.multimodel.quota import package_flavors

from .common import M_SAMPLES, cached

CASES = [("alexnet", 16), ("resnet50", 64), ("resnet152", 256)]
# New larger sweeps enabled by the fast engine (reference/seed too slow).
# The 1024-chip row rides on the batched population evaluator: the whole
# sweep must land under 60s (gated by scripts/perf_gate.py).
LARGE_CASES = [("resnet152", 512), ("resnet152", 1024)]
# Quota-curve sampling (multimodel/curves.py): exhaustive step=1 sweep vs
# the coarse-to-fine schedule (coarse grid + step-1 refinement around the
# argmax) on large packages -- the ROADMAP's ~10x curve-time item.
CURVE_CASES = [("resnet18", 256, 16), ("resnet18", 512, 16)]
# 2D analogue on a heterogeneous package: mixed-flavor budget-pair curves,
# exhaustive vs coarse grid vs coarse + 2D refine pass.
MIXED_CURVE_CASES = [("resnet18", "mcm16_hetero", 4)]
# Measured on the seed commit (d44433a) with the same driver and machine
# class; see CHANGES.md.  Kept as constants so speedup-vs-seed survives the
# seed implementation no longer being in the tree.
SEED_SEARCH_S = {("alexnet", 16): 0.004, ("resnet50", 64): 1.67, ("resnet152", 256): 62.6}
REF_BUDGET_S = 120.0          # skip the reference engine beyond this estimate
ROOT_BENCH = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                          "BENCH_search_time.json")


def q_total(L: int, C: int) -> float:
    """Eq. 9 (log10): 2^L * sum_i C(L-1, i-1) C(C-1, i-1)."""
    total = 0.0
    for i in range(1, min(L, C) + 1):
        total += math.comb(L - 1, i - 1) * math.comb(C - 1, i - 1)
    return L * math.log10(2) + math.log10(total)


def _sweep(net: str, chips: int, engine: str = "fast",
           batched_seed_fill: bool = True):
    """One full Scope DSE through the facade on a chosen engine."""
    opts = scope.SearchOptions(strategy="scope", m_samples=M_SAMPLES,
                               engine=engine)
    cost = opts.make_cost(get_hw(f"mcm{chips}"))
    if hasattr(cost, "batched_seed_fill"):
        cost.batched_seed_fill = batched_seed_fill
    sol = scope.solve(workload=net, package=f"mcm{chips}",
                      options=scope.SearchOptions(
                          strategy="scope", m_samples=M_SAMPLES, cost=cost))
    return sol.diagnostics["dse_s"], sol.schedule, cost


def run(refresh: bool = False):
    def _go():
        rows = []
        for net, chips in CASES:
            fast_s, sched, fast = _sweep(net, chips)
            # Same engine without the 2D (k x layer) seed-phase batch fill:
            # isolates that satellite's constant-factor effect.
            nobatch_s, nb_sched, _ = _sweep(net, chips,
                                            batched_seed_fill=False)
            assert nb_sched.latency == sched.latency, (net, chips)
            row = {
                "net": net, "chips": chips, "layers": len(get_cnn(net)),
                "fast_search_s": fast_s,
                "no_batched_fill_search_s": nobatch_s,
                "latency_s": sched.latency,
                "log10_Q_total": q_total(len(get_cnn(net)), chips),
                "engine_stats": fast.stats,
                "seed_search_s": SEED_SEARCH_S.get((net, chips)),
            }
            if row["seed_search_s"]:
                row["speedup_vs_seed"] = row["seed_search_s"] / fast_s
            # Reference engine on the same search code, if affordable: the
            # seed timing scaled by the repaired rebalance's extra work.
            # Unknown seed timing -> assume unaffordable, skip.
            seed_s = row["seed_search_s"]
            if seed_s is not None and seed_s * 5 <= REF_BUDGET_S:
                ref_s, ref_sched, _ = _sweep(net, chips, engine="reference")
                # Engine contract is 1e-9 rtol (bit-identical in practice).
                assert math.isclose(
                    ref_sched.latency, sched.latency, rel_tol=1e-9
                ), (
                    "engine parity violated", net, chips,
                    ref_sched.latency, sched.latency,
                )
                row["ref_search_s"] = ref_s
                row["engine_speedup"] = ref_s / fast_s
            rows.append(row)
        for net, chips in LARGE_CASES:
            fast_s, sched, fast = _sweep(net, chips)
            nobatch_s, nb_sched, _ = _sweep(net, chips,
                                            batched_seed_fill=False)
            assert nb_sched.latency == sched.latency, (net, chips)
            rows.append({
                "net": net, "chips": chips, "layers": len(get_cnn(net)),
                "fast_search_s": fast_s,
                "no_batched_fill_search_s": nobatch_s,
                "latency_s": sched.latency,
                "log10_Q_total": q_total(len(get_cnn(net)), chips),
                "engine_stats": fast.stats,
                "seed_search_s": None,
                "note": "new sweep unlocked by the fast engine",
            })
        for net, chips, step in CURVE_CASES:
            from repro.multimodel.curves import throughput_curve

            g = get_cnn(net)
            cost = FastCostModel(mcm_table_iii(chips), m_samples=M_SAMPLES)
            t0 = time.time()
            exact = throughput_curve(cost, g, chips, step=1)
            exact_s = time.time() - t0
            cost = FastCostModel(mcm_table_iii(chips), m_samples=M_SAMPLES)
            t0 = time.time()
            refined = throughput_curve(cost, g, chips, step=step, refine=True)
            refined_s = time.time() - t0
            peak = lambda c: max(p.throughput for p in c.points.values())
            rows.append({
                "net": net, "chips": chips, "layers": len(g),
                "curve_step": step,
                "curve_exhaustive_s": exact_s,
                "curve_exhaustive_points": len(exact.points),
                "curve_refined_s": refined_s,
                "curve_refined_points": len(refined.points),
                "curve_speedup": exact_s / refined_s,
                "curve_peak_match": peak(exact) == peak(refined),
                "note": "quota-curve sampling: exhaustive vs coarse-to-fine",
            })
        for net, hw_name, step in MIXED_CURVE_CASES:
            from repro.multimodel.curves import mixed_throughput_curve

            g = get_cnn(net)
            hw = get_hw(hw_name)
            flavors = package_flavors(hw)
            peak = lambda c: max(
                (p.throughput for p in c.points.values()), default=0.0
            )

            def timed(**kw):
                cost = FastCostModel(hw, m_samples=M_SAMPLES)
                t0 = time.time()
                curve = mixed_throughput_curve(cost, g, flavors, **kw)
                return time.time() - t0, curve

            exact_s, exact = timed(step=1)
            coarse_s, coarse = timed(step=step)
            refined_s, refined = timed(step=step, refine=True)
            rows.append({
                "net": net, "hw": hw_name, "layers": len(g),
                "mixed_curve_step": step,
                "mixed_curve_exhaustive_s": exact_s,
                "mixed_curve_exhaustive_points": len(exact.points),
                "mixed_curve_coarse_s": coarse_s,
                "mixed_curve_coarse_points": len(coarse.points),
                "mixed_curve_refined_s": refined_s,
                "mixed_curve_refined_points": len(refined.points),
                "mixed_curve_peak_coarse": peak(coarse),
                "mixed_curve_peak_refined": peak(refined),
                "mixed_curve_peak_exhaustive": peak(exact),
                "mixed_curve_peak_match": peak(refined) == peak(exact),
                "note": "2D mixed-flavor budget curves: exhaustive vs "
                        "coarse vs coarse + 2D refine pass",
            })
        return rows

    rows = cached("search_time", _go, refresh)
    if rows and (
        "no_batched_fill_search_s" not in rows[0]
        or not any("curve_speedup" in r for r in rows)
        or not any("mixed_curve_step" in r for r in rows)
    ):
        # Stale cache from an older schema (pre-fastcost "search_s"-only
        # rows, pre-batched-fill rows, pre-curve or pre-mixed-curve rows):
        # redo.
        rows = cached("search_time", _go, refresh=True)
    with open(ROOT_BENCH, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def report(rows) -> list[str]:
    lines = ["net,chips,layers,log10_space,fast_s,ref_s,seed_s,speedup_vs_seed,engine_speedup"]
    for r in rows:
        if "curve_speedup" in r or "mixed_curve_step" in r:
            continue
        lines.append(
            f"{r['net']},{r['chips']},{r['layers']},"
            f"{r['log10_Q_total']:.0f},{r['fast_search_s']:.3f},"
            f"{r.get('ref_search_s', float('nan')):.3f},"
            f"{r.get('seed_search_s') or float('nan')},"
            f"{r.get('speedup_vs_seed', float('nan')):.1f},"
            f"{r.get('engine_speedup', float('nan')):.1f}"
        )
    for r in rows:
        if "curve_speedup" not in r:
            continue
        lines.append(
            f"# curve {r['net']}x{r['chips']}: exhaustive "
            f"{r['curve_exhaustive_s']:.2f}s ({r['curve_exhaustive_points']} pts) "
            f"vs coarse-to-fine {r['curve_refined_s']:.2f}s "
            f"({r['curve_refined_points']} pts), {r['curve_speedup']:.1f}x, "
            f"peak match {r['curve_peak_match']}"
        )
    for r in rows:
        if "mixed_curve_step" not in r:
            continue
        lines.append(
            f"# mixed curve {r['net']}x{r['hw']}: exhaustive "
            f"{r['mixed_curve_exhaustive_s']:.2f}s "
            f"({r['mixed_curve_exhaustive_points']} pts) vs coarse "
            f"{r['mixed_curve_coarse_s']:.2f}s "
            f"({r['mixed_curve_coarse_points']} pts) vs 2D-refined "
            f"{r['mixed_curve_refined_s']:.2f}s "
            f"({r['mixed_curve_refined_points']} pts), peak match "
            f"{r['mixed_curve_peak_match']}"
        )
    lines.append("# paper: resnet152x256 space O(10^164), search ~1h on i7")
    lines.append("# seed_s measured on the seed commit; the current search "
                 "additionally repairs INF seeds and retries tied donors")
    return lines
