"""CLI: co-schedule a model mix onto an MCM package.

    PYTHONPATH=src python -m repro.multimodel.cli \
        --mix resnet50:1,alexnet:1 --hw mcm16 [--step 1] [--baselines]

``--hw`` accepts any preset from repro.core.hw (including ``mcm64_hetero``).
"""
from __future__ import annotations

import argparse

from ..core.fastcost import FastCostModel
from ..core.hw import get_hw
from .baselines import equal_split, time_multiplexed
from .coschedule import co_schedule, describe
from .spec import parse_mix


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mix", required=True,
                    help="comma list of net[:weight], e.g. resnet50:2,alexnet:1")
    ap.add_argument("--hw", default="mcm64", help="hardware preset name")
    ap.add_argument("--m-samples", type=int, default=16)
    ap.add_argument("--step", type=int, default=1,
                    help="quota grid step (1 = exhaustive)")
    ap.add_argument("--refine", action="store_true",
                    help="coarse-to-fine curves: re-sample at step 1 around "
                         "each coarse argmax")
    ap.add_argument("--no-mixed", action="store_true",
                    help="disable mixed-flavor (spanning) quotas on "
                         "heterogeneous packages")
    ap.add_argument("--mixed-step", type=int, default=None,
                    help="budget grid step of the mixed-flavor curves "
                         "(default: quarter of the smaller flavor)")
    ap.add_argument("--switch-cost", action="store_true",
                    help="charge time-mux slices for per-slice weight "
                         "re-deployment")
    ap.add_argument("--baselines", action="store_true",
                    help="also report equal-split and time-mux baselines")
    args = ap.parse_args(argv)

    specs = parse_mix(args.mix)
    hw = get_hw(args.hw)
    cost = FastCostModel(hw, m_samples=args.m_samples)
    sched = co_schedule(specs, hw, m_samples=args.m_samples, step=args.step,
                        cost=cost, include_mixed=not args.no_mixed,
                        curve_refine=args.refine, mixed_step=args.mixed_step,
                        switch_cost=args.switch_cost)
    if sched is None:
        raise SystemExit(f"no feasible co-schedule for {args.mix} on {args.hw}")
    for line in describe(sched):
        print(line)
    print(f"  searched in {sched.meta['dse_s']:.2f}s; "
          f"engine {sched.meta['engine_stats']}")
    if args.baselines:
        for name, fn in (("equal_split", equal_split),
                         ("time_multiplexed", time_multiplexed)):
            b = fn(specs, cost)
            if b is None:
                print(f"{name}: infeasible")
                continue
            print(f"{name}: weighted throughput "
                  f"{b.weighted_throughput:.1f} samples/s "
                  f"({sched.weighted_throughput / b.weighted_throughput:.2f}x "
                  "vs co-schedule)")


if __name__ == "__main__":
    main()
