"""Pallas TPU chunked WKV scan for RWKV-6 (data-dependent decay).

Recurrence per head (state S [hd, hd], fp32):
    out_t = r_t . (S_{t-1} + (u * k_t) v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T

TPU mapping: the sequence is processed in chunks of T tokens; the state S
lives in VMEM scratch across the (sequential) chunk grid axis, so HBM traffic
is one read of r/k/v/logw and one write of out per token -- the recurrence
itself never touches HBM.  Within a chunk the scan is refactored into three
MXU matmuls (chunk form):

    lw      = cumsum(log w)                       # [T, hd] per-channel decays
    rt      = r * exp(lw - logw)  (exclusive)     # decayed receptance
    kt      = k * exp(-lw)                        # inverse-decayed keys
    intra   = tril_strict(rt @ kt^T) @ v + ((r*u*k) @ 1) v_t   (diagonal term)
    cross   = rt @ S
    S_new   = diag(exp(lw_T)) S + (k * exp(lw_T - lw))^T @ v

Numerics: per-channel cumulative decays are re-based inside each chunk, so
the exp() magnitudes are bounded by the *chunk* decay range; chunk=32..64
keeps fp32 well in range for w >= ~0.6 (production RWKV clamps decay).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..._compat.pallas import CompilerParams as _CompilerParams


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_out_ref, s_scr, *,
                chunk: int, n_chunks: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, 0].astype(jnp.float32)       # [T, hd]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)     # log decay, <= 0
    u = u_ref[0].astype(jnp.float32)          # [hd]

    clw = jnp.cumsum(lw, axis=0)              # inclusive per-channel cum-decay
    clw_excl = clw - lw                       # exclusive
    rt = r * jnp.exp(clw_excl)                # decayed receptance
    kt = k * jnp.exp(-clw)                    # inverse-decayed keys

    # intra-chunk attention-like term (strictly causal) + u-bonus diagonal
    a = jax.lax.dot_general(rt, kt, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [T, T]
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    a = jnp.where(tj < ti, a, 0.0)
    intra = jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    diag = jnp.sum(r * u[None, :] * k, axis=1, keepdims=True) * v

    cross = jax.lax.dot_general(rt, s_scr[...], (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[0, 0] = (cross + intra + diag).astype(o_ref.dtype)

    # state update
    total = clw[-1]                            # [hd]
    kdec = k * jnp.exp(total[None, :] - clw)   # keys decayed to chunk end
    s_new = jnp.exp(total)[:, None] * s_scr[...] + jax.lax.dot_general(
        kdec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    s_scr[...] = s_new

    @pl.when(c == n_chunks - 1)
    def _final():
        s_out_ref[0, 0] = s_new


def wkv6_kernel(
    r: jax.Array,       # [B, H, S, hd]
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,    # [B, H, S, hd], log of decay in (0,1)
    u: jax.Array,       # [H, hd]
    chunk: int = 32,
    interpret: bool = False,
):
    """Returns (out [B,H,S,hd] fp32, S_last [B,H,hd,hd] fp32)."""
    B, H, S, hd = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    n_chunks = S // chunk
    grid = (B, H, n_chunks)
    kernel = functools.partial(_wkv_kernel, chunk=chunk, n_chunks=n_chunks)
    tile = pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[tile, tile, tile, tile,
                  pl.BlockSpec((1, hd), lambda b, h, c: (h, 0))],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, logw, u)
