"""Multi-model co-scheduling walkthrough: mixed traffic on one MCM package.

Schedules a 3-model mix (weighted traffic) onto a 64-chiplet package with
the co-scheduler, compares it against the two static baselines, then shows
the same subsystem on a heterogeneous big/little package -- including
mixed-flavor quotas, where one model's pipeline spans both flavors -- and
finally drives a mixed-flavor plan end to end through the runtime bridge
(``plan_for_multimodel`` -> ``build_multimodel_steps``) on a host-device
mesh.

    PYTHONPATH=src python examples/multimodel_serve.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from repro.core.fastcost import FastCostModel
from repro.core.hw import mcm_hetero, mcm_table_iii
from repro.multimodel import (
    co_schedule,
    describe,
    equal_split,
    parse_mix,
    time_multiplexed,
)

# Traffic mix: resnet50 gets 2x the request rate of the small models.
MIX = "resnet50:2,resnet18:1,alexnet:1"

specs = parse_mix(MIX)
hw = mcm_table_iii(64)
cost = FastCostModel(hw, m_samples=16)   # one shared memo for everything

print(f"mix {MIX} on {hw.name}\n")
co = co_schedule(specs, hw, cost=cost)
for line in describe(co):
    print(line)
print(f"  modes searched: { {k: round(v) for k, v in co.meta['mode_rates'].items()} }")
print(f"  engine stats:   {co.meta['engine_stats']}")

print("\nstatic baselines:")
for name, fn in (("equal_split", equal_split), ("time_mux", time_multiplexed)):
    b = fn(specs, cost)
    print(f"  {name:12s} {b.weighted_throughput:9.1f} samples/s "
          f"({co.weighted_throughput / b.weighted_throughput:.2f}x behind)")

# --- heterogeneous package: quotas are drawn per chip flavor -------------
# Mixed-flavor quotas are searched too: a model's pipeline may start on big
# chips and finish on little ones, crossing the flavor seam
# (hw.seam_link_bw) exactly once -- look for `quota=AxBig+BxLittle` below.
hw2 = mcm_hetero(64)    # 32 big + 32 little (half the FLOPs, 3/4 the NoP)
specs2 = parse_mix("resnet50:4,resnet18:1")
print(f"\nmix resnet50:4,resnet18:1 on {hw2.name} "
      f"({', '.join(f'{t.chips}x{t.name}' for t in hw2.region_types)})")
co2 = co_schedule(specs2, hw2)
for line in describe(co2):
    print(line)
print(f"  modes searched: { {k: round(v) for k, v in co2.meta['mode_rates'].items()} }")

# --- runtime bridge: a mixed-flavor plan end to end ----------------------
# Co-schedule two tiny LM configs onto a heterogeneous 8-chip model axis,
# then build their jitted serving steps on a shared host-device mesh.  Each
# plan records which chip flavor serves which pipeline stage
# (plan.stage_chip_types); a mixed-flavor assignment itemizes its
# per-flavor chips in meta["chip_quota"].
from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.hw import ChipType, tpu_v5e
from repro.launch.mesh import make_mesh
from repro.models import init_params
from repro.runtime.planner import plan_for_multimodel
from repro.runtime.serve import build_multimodel_steps

MODEL_AXIS = 8
hw3 = replace(
    tpu_v5e(MODEL_AXIS, (1, MODEL_AXIS)),
    name=f"tpu_v5e_{MODEL_AXIS}_hetero",
    region_types=(
        ChipType("big", 4),
        ChipType("little", 4, flops_scale=0.5, nop_bw_scale=0.75),
    ),
)
cfgs = [get_smoke_config("granite-3-8b"), get_smoke_config("granite-20b")]
mm, plans = plan_for_multimodel(
    cfgs, seq_len=64, global_batch=8, mesh_axes=("data", "model"),
    model_axis=MODEL_AXIS, weights=[2.0, 1.0], hw=hw3,
)
print(f"\nruntime bridge on {hw3.name} (4xbig + 4xlittle):")
for line in describe(mm):
    print(line)
for name, plan in plans.items():
    print(f"  {name}: p1={plan.p1} p2={plan.p2} "
          f"stages={[(lo, hi, t, c) for lo, hi, t, c in plan.stage_chip_types]}")

mesh = make_mesh((1, len(jax.devices())), ("data", "model"))
fleet = build_multimodel_steps(cfgs, mesh, plans, with_decode=False)
for cfg in cfgs:
    prefill = fleet[cfg.name]["prefill"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.ones((2, 16), jnp.int32)
    logits = prefill(params, toks)
    print(f"  {cfg.name}: prefill logits {logits.shape} on {mesh.shape}")
