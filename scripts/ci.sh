#!/usr/bin/env bash
# CI entry point: tier-1 tests (minus slow markers) + DSE perf smoke budgets.
#
#   ./scripts/ci.sh            # full run
#   CI_SKIP_PERF=1 ./scripts/ci.sh   # tests only
#
# Every smoke goes through the solver facade (repro.scope.solve) -- and the
# mixed-flavor smoke through the actual `python -m repro solve` CLI -- so
# the one front door the benchmarks/examples use is itself exercised on
# every run.  Budgets fail loudly on evaluation-engine regressions instead
# of silently re-inflating every benchmark.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests (-m 'not slow') =="
python -m pytest -q -m "not slow"

if [ "${CI_SKIP_PERF:-0}" != "1" ]; then
  echo "== multi-model co-scheduling smoke budget =="
  python - <<'PY'
import os

from repro import scope

budget = float(os.environ.get("CI_MULTIMODEL_BUDGET_S", "20"))
prob = scope.problem("alexnet:1,resnet18:1", "mcm16", m_samples=16)
co = scope.solve(prob)
eq = scope.solve(prob.with_options(strategy="equal-split"))
tm = scope.solve(prob.with_options(strategy="time-mux"))
dt = co.diagnostics["dse_s"]
stats = co.diagnostics["engine_stats"]
assert co.feasible and eq.feasible and tm.feasible, "co-schedule/baseline infeasible"
assert co.strategy == "coschedule", co.strategy   # auto-selected by shape
print(f"2-model x 16 co-schedule: {dt:.2f}s (budget {budget:.0f}s), "
      f"mode={co.multi.mode}, weighted tp {co.weighted_throughput:.0f}/s "
      f"(equal-split {eq.weighted_throughput:.0f}, "
      f"time-mux {tm.weighted_throughput:.0f}), engine {stats}")
assert co.weighted_throughput > 0, "co-schedule infeasible"
assert co.weighted_throughput >= eq.weighted_throughput - 1e-9, "below equal-split"
assert co.weighted_throughput >= tm.weighted_throughput - 1e-9, "below time-mux"
# memo reuse across quota candidates: the joint sweep must answer far more
# segment evaluations than it computes cluster costs for
assert stats["segment_evals"] > 3 * stats["cluster_computes"], stats
assert dt <= budget, f"multi-model DSE regression: {dt:.2f}s > {budget:.0f}s"

# warm-start drift re-solve: the autoscaler's interactive path.  A drifted
# mix re-solved with the incumbent as warm_start (shared engine memo +
# quota windows) must land under 1s wall.
import time as _time
warm_budget = float(os.environ.get("CI_WARM_RESOLVE_BUDGET_S", "1"))
cache = scope.SolutionCache()
inc = cache.solve(prob)
drifted = scope.problem("alexnet:3,resnet18:1", "mcm16", m_samples=16)
t0 = _time.perf_counter()
warm = cache.solve(drifted.with_options(warm_start=inc))
warm_s = _time.perf_counter() - t0
assert warm.feasible and warm.multi.meta.get("warm_start") is True, \
    "drift re-solve did not take the warm path"
print(f"warm-start drift re-solve: {warm_s:.3f}s (budget {warm_budget:.1f}s)")
assert warm_s <= warm_budget, \
    f"warm re-solve not interactive: {warm_s:.3f}s > {warm_budget:.1f}s"

# full 2-model x 64 mix (the acceptance-scale sweep; exhaustive quota grid)
budget64 = float(os.environ.get("CI_MULTIMODEL64_BUDGET_S", "60"))
co64 = scope.solve(scope.problem("resnet50:1,resnet18:1", "mcm64", m_samples=16))
dt64 = co64.diagnostics["dse_s"]
s64 = co64.diagnostics["engine_stats"]
print(f"2-model x 64 co-schedule: {dt64:.2f}s (budget {budget64:.0f}s), "
      f"mode={co64.multi.mode}, weighted tp {co64.weighted_throughput:.0f}/s, "
      f"engine {s64}")
assert co64.weighted_throughput > 0
assert s64["segment_evals"] > 3 * s64["cluster_computes"], s64
assert dt64 <= budget64, f"x64 multi-model DSE: {dt64:.2f}s > {budget64:.0f}s"
PY

  echo "== mixed-flavor DSE smoke budget (via the python -m repro solve CLI) =="
  python - <<'PY'
import json
import os
import subprocess
import sys
import time

from repro import scope

budget = float(os.environ.get("CI_MIXED_BUDGET_S", "30"))
args = ["--mix", "resnet50", "--hw", "mcm64_hetero", "--m-samples", "16"]
t0 = time.time()
out = subprocess.run(
    [sys.executable, "-m", "repro", "solve", *args, "--json"],
    capture_output=True, text=True, check=True,
    env={**os.environ, "PYTHONPATH": "src"},
)
dt = time.time() - t0
cli = json.loads(out.stdout)
assert cli["strategy"] == "scope-mixed", cli["strategy"]  # auto-selected
assert cli["feasible"], "mixed DSE infeasible via CLI"

# Facade parity: the in-process solve must reproduce the CLI bit-exactly.
# (one shared engine memo across the mixed and single-flavor solves)
hw = scope.PackageSpec.of("mcm64_hetero").resolve()
shared = scope.SearchOptions(m_samples=16).make_cost(hw)
prob = scope.problem("resnet50", "mcm64_hetero", m_samples=16, cost=shared)
sol = scope.solve(prob)
assert sol.strategy == "scope-mixed", sol.strategy
assert sol.latency == cli["latency_s"], (sol.latency, cli["latency_s"])
# the per-cluster flavor dimension strictly generalizes single-flavor search
single = scope.solve(prob.with_options(strategy="scope"))
best_single = min(single.diagnostics["per_flavor"].values())
# fast/reference parity on the mixed-flavor winner
sol.verify_reference()
flavors = sorted({cl.chip_type for seg in sol.schedule.segments
                  for cl in seg.clusters})
print(f"resnet50 x mcm64_hetero mixed DSE via CLI: {dt:.2f}s "
      f"(budget {budget:.0f}s), mixed latency {sol.latency:.6g} vs best "
      f"single-flavor {best_single:.6g} ({best_single / sol.latency:.2f}x), "
      f"flavors used {flavors}, seams {sol.diagnostics['seam_crossings']}, "
      f"engine {sol.diagnostics['engine_stats']}")
assert sol.latency <= best_single + 1e-12, "mixed lost to single-flavor"
assert dt <= budget, f"mixed DSE regression: {dt:.2f}s > {budget:.0f}s"
PY

  echo "== serving executor smoke (via the python -m repro serve CLI) =="
  python - <<'PY'
import json
import os
import subprocess
import sys
import time

budget = float(os.environ.get("CI_SERVE_BUDGET_S", "60"))
args = ["--mix", "alexnet:1,resnet18:1", "--hw", "mcm16",
        "--requests", "1000", "--rate-scale", "0.95", "--seed", "0",
        "--baselines", "--json"]
t0 = time.time()
out = subprocess.run(
    [sys.executable, "-m", "repro", "serve", *args],
    capture_output=True, text=True, check=True,
    env={**os.environ, "PYTHONPATH": "src"},
)
dt = time.time() - t0
payload = json.loads(out.stdout)
co = payload["serving"]
eq = payload["baselines"]["equal-split"]
tm = payload["baselines"]["time-mux"]
# request conservation on every replay of the same trace
for name, rep in (("co", co), ("equal-split", eq), ("time-mux", tm)):
    assert rep["conserved"], f"{name}: requests not conserved"
    assert rep["total_arrived"] == co["total_arrived"], f"{name}: trace mismatch"
    # latency-waterfall conservation: per-request components fold back to
    # end-to-end latency exactly, aggregated per model and overall
    assert rep["explain"]["conserved"], f"{name}: waterfalls not conserved"
print(f"serving smoke: {dt:.2f}s (budget {budget:.0f}s), "
      f"{co['total_completed']}/{co['total_arrived']} requests conserved; "
      f"goodput co {co['goodput']:.0f}/s vs equal-split {eq['goodput']:.0f} "
      f"vs time-mux {tm['goodput']:.0f}; "
      f"p95 co {co['latency_p95_s']*1e3:.2f}ms vs "
      f"equal-split {eq['latency_p95_s']*1e3:.2f}ms")
# the DSE winner must also win under simulated load
assert co["latency_p95_s"] <= eq["latency_p95_s"] + 1e-12, \
    "co-schedule p95 worse than equal-split"
assert co["goodput"] >= eq["goodput"] - 1e-9, "co-schedule below equal-split"
assert co["goodput"] >= tm["goodput"] - 1e-9, "co-schedule below time-mux"
assert dt <= budget, f"serving smoke regression: {dt:.2f}s > {budget:.0f}s"
PY

  echo "== LLM token-level serving smoke (via python -m repro serve --llm) =="
  python - <<'PY'
import json
import os
import subprocess
import sys
import time

budget = float(os.environ.get("CI_LLM_SERVE_BUDGET_S", "60"))
args = ["--llm", "gemma2-9b:2,granite-3-8b:1", "--llm-smoke", "--hw", "mcm16",
        "--seq-len", "128", "--output-tokens", "64",
        "--requests", "800", "--rate-scale", "0.9", "--seed", "0",
        "--ttft-slo-ms", "50", "--tpot-slo-ms", "2",
        "--trace", "/tmp/repro_llm_trace.json",
        "--baselines", "--json"]
t0 = time.time()
out = subprocess.run(
    [sys.executable, "-m", "repro", "serve", *args],
    capture_output=True, text=True, check=True,
    env={**os.environ, "PYTHONPATH": "src"},
)
dt = time.time() - t0
payload = json.loads(out.stdout)
sol = payload["solution"]
rep = payload["serving"]
assert sol["strategy"] == "llm-phase" and sol["feasible"], sol["strategy"]
# strict request conservation with attributed drops, on every replay
for name, r in [("chosen", rep)] + list(payload["baselines"].items()):
    assert r is not None and r["conserved"], f"{name}: not conserved"
    assert r["total_arrived"] == rep["total_arrived"], f"{name}: trace mismatch"
# continuous batching must actually admit into running decode batches
assert rep["admitted_midbatch"] > 0, "no mid-batch admissions"
# token waterfalls (queue/prefill/hand-off/admission/decode) conserve TTFT
# + decode latency exactly for every completed request
assert rep["explain"]["conserved"], "LLM waterfalls not conserved"
for m, mm in rep["per_model"].items():
    assert mm["kv_peak_bytes"] <= mm["kv_capacity_bytes"] + 1e-6, \
        f"{m}: KV occupancy exceeded the searched bound"
# TTFT SLO gate: the chosen deployment must meet its p95 TTFT target
ttft_p95 = rep["ttft_p95_s"]
assert ttft_p95 <= 0.05, f"TTFT p95 {ttft_p95*1e3:.2f}ms > 50ms SLO"
# and win SLO-gated token goodput vs the best whole-request static replay
best = max(r["token_goodput"] for r in payload["baselines"].values() if r)
assert rep["token_goodput"] >= best - 1e-9, "chosen plan lost to a baseline"
print(f"llm smoke: {dt:.2f}s (budget {budget:.0f}s), mode={rep['mode']}, "
      f"{rep['total_completed']}/{rep['total_arrived']} requests, "
      f"token goodput {rep['token_goodput']:.0f}/s "
      f"(best static {best:.0f}), TTFT p95 {ttft_p95*1e3:.2f}ms, "
      f"TPOT p95 {rep['tpot_p95_s']*1e3:.3f}ms, "
      f"midbatch {rep['admitted_midbatch']}")
assert dt <= budget, f"llm serve smoke regression: {dt:.2f}s > {budget:.0f}s"
PY

  echo "== chaos smoke: zone failure + degraded re-solve (serve --faults) =="
  python - <<'PY'
import json
import os
import subprocess
import sys
import time

budget = float(os.environ.get("CI_CHAOS_BUDGET_S", "90"))
args = ["--mix", "alexnet:1:500,resnet18:1:500", "--hw", "mcm16_hetero",
        "--requests", "8000", "--rate-scale", "0.75", "--seed", "0",
        "--faults", "zone:little@35%:65%",
        "--trace", "/tmp/repro_trace.json",
        "--dashboard", "/tmp/repro_dash.html", "--json"]
t0 = time.time()
out = subprocess.run(
    [sys.executable, "-m", "repro", "serve", *args],
    capture_output=True, text=True, check=True,
    env={**os.environ, "PYTHONPATH": "src"},
)
dt = time.time() - t0
rep = json.loads(out.stdout)["serving"]
f = rep["faults"]
# strict conservation: arrived == completed + dropped(by cause) + queued
assert rep["conserved"], "requests not conserved through the failure"
# waterfall conservation must hold through kills, spills and redeploys,
# with the fault dead time attributed to its cause
assert rep["explain"]["conserved"], "chaos waterfalls not conserved"
assert rep["explain"]["dead_time_s"]["fault"] > 0, \
    "zone failure charged no fault dead time"
for m, mm in rep["per_model"].items():
    by_cause = sum(s for _, s in mm["drop_causes"].values())
    assert by_cause == mm["dropped_samples"], f"{m}: unattributed drops"
# the failure must actually kill a server and be recovered by a re-solve
kills = [e for e in f["log"] if e["kind"] == "fail" and e["killed"]]
assert kills, "zone failure killed no server"
assert f["recoveries"] and all(r["resolved"] for r in f["recoveries"]), \
    "no recorded degraded-re-solve recovery"
assert f["unrecovered"] == 0
pre, post = f["goodput_pre_fault"], f["goodput_post_recovery"]
assert post >= 0.9 * pre, \
    f"post-recovery goodput {post:.0f}/s < 90% of pre-failure {pre:.0f}/s"
print(f"chaos smoke: {dt:.2f}s (budget {budget:.0f}s), "
      f"{len(kills)} kill(s) -> {len(f['recoveries'])} recovery(ies), "
      f"mean TTR {f['mean_ttr_s']*1e3:.2f}ms, "
      f"availability {f['availability']:.4f}, goodput pre {pre:.0f}/s -> "
      f"post {post:.0f}/s, in-window {f['goodput_in_failure'] or 0:.0f}/s")
assert dt <= budget, f"chaos smoke regression: {dt:.2f}s > {budget:.0f}s"
PY

  echo "== trace schema check (repro.obs Chrome trace from the chaos smoke) =="
  python scripts/check_trace.py /tmp/repro_trace.json \
    --expect-faults --expect-groups dse,serving
  python scripts/check_trace.py /tmp/repro_llm_trace.json \
    --expect-llm --expect-groups dse,serving,llm

  echo "== dashboard sanity (Scope Lens HTML from the chaos smoke) =="
  python - <<'PY'
html = open("/tmp/repro_dash.html").read()
assert len(html) > 10_000, f"dashboard suspiciously small: {len(html)} bytes"
assert "fault-window" in html, "no fault/recovery windows rendered"
assert "latency waterfalls" in html, "no waterfall tables rendered"
assert "DSE cost attribution" in html, "no cost attribution tables rendered"
assert "<script" not in html, "dashboard must stay dependency-free"
print(f"dashboard sanity: {len(html)} bytes, fault windows + waterfall "
      f"+ attribution tables present")
PY

  echo "== trace_diff self-diff (must report zero deltas) =="
  python scripts/trace_diff.py /tmp/repro_trace.json /tmp/repro_trace.json \
    --fail-on-delta
  python scripts/trace_diff.py /tmp/repro_llm_trace.json \
    /tmp/repro_llm_trace.json --fail-on-delta

  echo "== perf regression gate (tracing-off DSE vs committed baseline) =="
  python scripts/perf_gate.py

  echo "== DSE search-time smoke budget =="
  python - <<'PY'
import os

from repro import scope

budget = float(os.environ.get("CI_DSE_BUDGET_S", "10"))
sol = scope.solve(scope.problem("resnet50", "mcm64", m_samples=16))
dt = sol.diagnostics["dse_s"]
print(f"resnet50 x 64 full DSE: {dt:.2f}s (budget {budget:.0f}s), "
      f"latency {sol.latency:.6g}, stats {sol.diagnostics['engine_stats']}")
assert sol.feasible, "DSE found no schedule"
assert dt <= budget, f"DSE perf regression: {dt:.2f}s > {budget:.0f}s budget"
PY
fi

echo "CI OK"
