"""Jit'd public wrapper for the chunked WKV-6 scan."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import wkv6_kernel
from .ref import wkv6_ref


@partial(jax.jit, static_argnames=("chunk", "impl", "interpret"))
def wkv6(r, k, v, logw, u, chunk: int = 32, impl: str = "pallas", interpret: bool = False):
    if impl == "ref":
        return wkv6_ref(r, k, v, logw, u)
    return wkv6_kernel(r, k, v, logw, u, chunk=chunk, interpret=interpret)
