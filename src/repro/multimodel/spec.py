"""Model specs for co-scheduling: a LayerGraph plus its traffic weight."""
from __future__ import annotations

from dataclasses import dataclass

from ..core.graph import LayerGraph
from ..core.workloads import get_cnn


@dataclass(frozen=True)
class ModelSpec:
    """One tenant of a co-scheduled package.

    ``weight`` is the relative request rate of this model in the traffic
    mix (weights only matter relative to each other): the co-scheduler
    maximizes the sustainable rate of the weighted mix unit.

    ``slo_s`` (optional) is the model's serving latency objective: the DSE
    ignores it, but the serving executor reports per-model SLO attainment
    and counts only SLO-satisfying samples toward goodput.
    """
    graph: LayerGraph
    weight: float = 1.0
    slo_s: float | None = None

    @property
    def name(self) -> str:
        return self.graph.name

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"{self.graph.name}: weight must be > 0")
        if self.slo_s is not None and self.slo_s <= 0:
            raise ValueError(f"{self.graph.name}: slo_s must be > 0")


def parse_mix(mix: str) -> list[ModelSpec]:
    """``"resnet50:2,alexnet:1"`` -> ModelSpecs (weight defaults to 1).

    A third ``:``-field is the model's serving SLO in milliseconds
    (``"resnet50:2:50"`` = weight 2, 50 ms latency objective).  Names
    resolve through the CNN workload registry; duplicate names get a
    ``#k`` suffix so per-model results stay distinguishable.
    """
    specs: list[ModelSpec] = []
    seen: dict[str, int] = {}
    for part in mix.split(","):
        part = part.strip()
        if not part:
            continue
        fields = part.split(":")
        if len(fields) > 3:
            raise ValueError(f"mix entry {part!r}: name[:weight[:slo_ms]]")
        name = fields[0]
        weight = float(fields[1]) if len(fields) > 1 and fields[1] else 1.0
        slo_s = (float(fields[2]) / 1e3
                 if len(fields) > 2 and fields[2] else None)
        graph = get_cnn(name)
        count = seen.get(name, 0)
        seen[name] = count + 1
        if count:
            graph = LayerGraph(f"{name}#{count + 1}", graph.layers)
        specs.append(ModelSpec(graph, weight, slo_s=slo_s))
    if not specs:
        raise ValueError(f"empty mix: {mix!r}")
    return specs
