#!/usr/bin/env bash
# CI entry point: tier-1 tests (minus slow markers) + DSE perf smoke budget.
#
#   ./scripts/ci.sh            # full run
#   CI_SKIP_PERF=1 ./scripts/ci.sh   # tests only
#
# The perf smoke asserts a full Scope DSE on resnet50 x 64 finishes under
# CI_DSE_BUDGET_S seconds (default 10; the fast engine needs ~0.5s, the
# pre-PR seed needed ~1.7s and the reference engine ~7s) so an evaluation-
# engine regression fails loudly instead of silently re-inflating every
# benchmark.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests (-m 'not slow') =="
python -m pytest -q -m "not slow"

if [ "${CI_SKIP_PERF:-0}" != "1" ]; then
  echo "== DSE search-time smoke budget =="
  python - <<'PY'
import os
import time

from repro.core.fastcost import FastCostModel
from repro.core.baselines import schedule_scope
from repro.core.hw import mcm_table_iii
from repro.core.workloads import get_cnn

budget = float(os.environ.get("CI_DSE_BUDGET_S", "10"))
g = get_cnn("resnet50")
cost = FastCostModel(mcm_table_iii(64), m_samples=16)
t0 = time.time()
sched = schedule_scope(g, cost, 64)
dt = time.time() - t0
print(f"resnet50 x 64 full DSE: {dt:.2f}s (budget {budget:.0f}s), "
      f"latency {sched.latency:.6g}, stats {cost.stats}")
assert sched is not None and sched.latency < float("inf"), "DSE found no schedule"
assert dt <= budget, f"DSE perf regression: {dt:.2f}s > {budget:.0f}s budget"
PY
fi

echo "CI OK"
