"""Region allocation: proportional seed + iterative rebalance + ZigZag placement.

Paper SSIV-B: chiplets are first allocated across regions proportionally to
cluster computational load; the heuristic then repeatedly moves one chiplet
from the fastest region to the slowest until overall latency stops improving.
Regions are laid out on the 2D mesh in a ZigZag (boustrophedon) pattern.

``RegionMode.UNIFORM`` is the TPU/SPMD constraint (DESIGN.md SS3): all regions
must have equal chip counts, so only ``chips % n_regions == 0`` allocations
are legal and the rebalance loop is disabled -- balance must come from the
cluster-merge dimension instead.
"""
from __future__ import annotations

import enum


class RegionMode(enum.Enum):
    FREE = "free"          # paper: arbitrary per-region chip counts
    UNIFORM = "uniform"    # TPU SPMD: equal-size regions only


def proportional_allocate(loads: list[float], chips: int) -> list[int]:
    """Seed allocation: >=1 chip each, proportional to load, sum == chips."""
    n = len(loads)
    if n > chips:
        raise ValueError(f"{n} clusters > {chips} chips")
    total = sum(loads) or 1.0
    alloc = [max(1, int(chips * l / total)) for l in loads]
    # repair the sum: remove from the most over-provisioned, add to the most
    # under; pressure(i) = alloc[i] / load[i], chips per unit load.  The
    # running-sum / explicit-scan form keeps the exact division and
    # first-argmax tie-breaks of the original max(key=...) loops.
    lds = [max(l, 1e-30) for l in loads]
    s = sum(alloc)
    while s > chips:
        cand, cp = -1, -1.0
        for i in range(n):
            if alloc[i] > 1:
                p = alloc[i] / lds[i]
                if p > cp:
                    cand, cp = i, p
        if cand < 0:
            raise ValueError("cannot satisfy >=1 chip per region")
        alloc[cand] -= 1
        s -= 1
    while s < chips:
        cand, cp = 0, alloc[0] / lds[0]
        for i in range(1, n):
            p = alloc[i] / lds[i]
            if p < cp:
                cand, cp = i, p
        alloc[cand] += 1
        s += 1
    return alloc


def uniform_allocate(n_regions: int, chips: int) -> list[int] | None:
    if chips % n_regions != 0:
        return None
    return [chips // n_regions] * n_regions


def zigzag_order(mesh_shape: tuple[int, int]) -> list[tuple[int, int]]:
    """The boustrophedon walk of the mesh: the 1D chip order every placement
    (and every flavor zone of a heterogeneous package) is carved from."""
    rows, cols = mesh_shape
    order = []
    for r in range(rows):
        rng = range(cols) if r % 2 == 0 else range(cols - 1, -1, -1)
        order.extend((r, c) for c in rng)
    return order


def flavor_zones(
    flavor_counts: list[tuple[str | None, int]],
    mesh_shape: tuple[int, int],
    dead: frozenset[tuple[int, int]] | set | tuple = frozenset(),
) -> dict[str | None, list[tuple[int, int]]]:
    """Physical home of each chip flavor: consecutive slices of the zigzag
    walk, in ``flavor_counts`` (= ``HardwareModel.region_types``) order.

    Adjacent zones share the package's physical flavor seam -- the boundary
    the cost model prices via ``HardwareModel.seam_link_bw``.

    ``dead`` (a degraded package's ``HardwareModel.dead_chips``) removes
    failed coordinates from the walk before slicing.  Because pristine
    zones are consecutive slices and ``flavor_counts`` then carries the
    *surviving* count per flavor, slicing the filtered walk reproduces
    exactly each pristine zone minus its holes.
    """
    order = zigzag_order(mesh_shape)
    if dead:
        dead = set(dead)
        order = [c for c in order if c not in dead]
    if sum(c for _, c in flavor_counts) > len(order):
        raise ValueError("flavor zones exceed mesh capacity")
    zones, cursor = {}, 0
    for flavor, c in flavor_counts:
        if flavor in zones:
            raise ValueError(f"duplicate flavor {flavor!r}")
        zones[flavor] = order[cursor : cursor + c]
        cursor += c
    return zones


def zigzag_placement(
    region_sizes: list[int],
    mesh_shape: tuple[int, int],
    region_flavors: list[str | None] | None = None,
    flavor_counts: list[tuple[str | None, int]] | None = None,
    dead: frozenset[tuple[int, int]] | set | tuple = frozenset(),
) -> list[list[tuple[int, int]]]:
    """Assign chip coordinates to regions walking the mesh boustrophedon.

    Keeps each region spatially contiguous, as validated by prior work
    ([17] Tangram) -- consecutive regions share a seam, which is what the
    cost model's cross-region boundary term assumes.

    ``region_flavors`` (mixed-flavor pipelines) makes the placement
    flavor-aware: each region is pinned inside its flavor's physical zone
    (:func:`flavor_zones` over ``flavor_counts``), and each flavor *run* is
    aligned against the zone edge facing the neighboring run's zone, so the
    pipeline's cross-flavor hand-off happens across the physical seam the
    cost model charges.  Region flavors must form contiguous runs -- a
    placement like ``big, little, big`` would tear the big zone apart and
    straddle the seam twice; it raises ``ValueError``.

    ``dead`` coordinates (failed chips of a degraded package) are skipped
    by the walk, so regions place around the holes while staying contiguous
    in the surviving chip order.
    """
    if region_flavors is None:
        order = zigzag_order(mesh_shape)
        if dead:
            order = [c for c in order if c not in set(dead)]
        if sum(region_sizes) > len(order):
            raise ValueError("regions exceed mesh capacity")
        out, cursor = [], 0
        for size in region_sizes:
            out.append(order[cursor : cursor + size])
            cursor += size
        return out

    if flavor_counts is None:
        raise ValueError("region_flavors requires flavor_counts")
    if len(region_flavors) != len(region_sizes):
        raise ValueError(
            f"{len(region_flavors)} flavors for {len(region_sizes)} regions"
        )
    zone_index = {f: k for k, (f, _) in enumerate(flavor_counts)}
    for f in region_flavors:
        if f not in zone_index:
            raise ValueError(f"region flavor {f!r} not in {list(zone_index)}")
    # Group regions into contiguous same-flavor runs.
    runs: list[tuple[str | None, list[int]]] = []
    for i, f in enumerate(region_flavors):
        if runs and runs[-1][0] == f:
            runs[-1][1].append(i)
        else:
            runs.append((f, [i]))
    seen = [f for f, _ in runs]
    if len(set(seen)) != len(seen):
        raise ValueError(
            f"non-contiguous flavor runs {seen}: a flavor's regions must "
            "occupy one contiguous stretch of the pipeline (the placement "
            "would straddle the physical seam)"
        )
    zones = flavor_zones(flavor_counts, mesh_shape, dead=dead)
    out: list[list[tuple[int, int]] | None] = [None] * len(region_sizes)
    for k, (f, idxs) in enumerate(runs):
        need = sum(region_sizes[i] for i in idxs)
        zone = zones[f]
        if need > len(zone):
            raise ValueError(
                f"flavor {f!r} regions need {need} > {len(zone)} chips"
            )
        # Pin the run against the seam shared with its neighboring run
        # (successor preferred: that is where the activations hand off).
        neighbor = (runs[k + 1][0] if k + 1 < len(runs)
                    else runs[k - 1][0] if k > 0 else None)
        start = (len(zone) - need
                 if neighbor is not None and zone_index[neighbor] > zone_index[f]
                 else 0)
        cursor = start
        for i in idxs:
            out[i] = zone[cursor : cursor + region_sizes[i]]
            cursor += region_sizes[i]
    return out  # type: ignore[return-value]


def check_schedule_placement(
    schedule,
    mesh_shape: tuple[int, int],
    flavor_counts: list[tuple[str | None, int]],
    dead: frozenset[tuple[int, int]] | set | tuple = frozenset(),
) -> list[list[list[tuple[int, int]]]]:
    """Flavor-aware placement of every segment of a ``ScopeSchedule``.

    Segments run sequentially, so each places independently; within a
    segment the clusters' flavors must form contiguous runs inside their
    zones (:func:`zigzag_placement` raises otherwise).  This is the one
    placement validator behind both the runtime planner and the serving
    executor; returns per-segment region coordinates.
    """
    return [
        zigzag_placement(
            [cl.region_chips for cl in seg.clusters],
            mesh_shape,
            region_flavors=[cl.chip_type for cl in seg.clusters],
            flavor_counts=flavor_counts,
            dead=dead,
        )
        for seg in schedule.segments
    ]


def check_assignments_placement(
    assignments,
    mesh_shape: tuple[int, int],
    flavor_counts: list[tuple[str | None, int]],
    dead: frozenset[tuple[int, int]] | set | tuple = frozenset(),
) -> None:
    """Run :func:`check_schedule_placement` over a co-schedule's
    assignments, deduplicating shared schedules (merged mode carries one
    schedule on every assignment) -- the one wrapper behind both the
    runtime planner's and the serving executor's placement enforcement."""
    seen: set[int] = set()
    for a in assignments:
        if id(a.schedule) in seen:
            continue
        seen.add(id(a.schedule))
        check_schedule_placement(a.schedule, mesh_shape, flavor_counts,
                                 dead=dead)


def rebalance(
    alloc: list[int],
    eval_fn,
    max_iters: int = 256,
    donor_tries: int = 2,
    paper_strict: bool = False,
    groups: list[int] | None = None,
    times0: tuple[float, list[float]] | None = None,
) -> tuple[list[int], float, list[float]]:
    """Paper's heuristic: move 1 chip from the fastest to the slowest region.

    ``eval_fn(alloc) -> (latency, per_cluster_times)``.  Continues while a
    move strictly improves latency (Alg. 1's inner while-loop), with two
    repairs over the literal pseudocode:

    * an INF seed (some cluster's weights overflow its region) is repaired by
      feeding the first infeasible region one chip at a time from the fastest
      feasible donor, instead of giving up immediately;
    * when the fastest donor's move ties or regresses, the next-fastest
      donor is tried (``donor_tries`` donors in total) before terminating --
      a tie through one donor does not prove no donor can improve.

    ``groups`` (mixed-flavor pipelines) gives each region a pool id: chips
    only move between regions of the same pool, because a chip physically
    belongs to one flavor of the package.  A bottleneck region whose pool
    has no improving donor terminates the walk, exactly as in the ungrouped
    case -- cross-pool moves could never lower a bottleneck outside their
    pool.  ``None`` is a single shared pool (homogeneous behavior).

    ``paper_strict=True`` disables both repairs and replicates Algorithm 1's
    pseudocode exactly: an infeasible seed terminates immediately, and only
    the single fastest region is ever tried as donor.  Use it for
    literal-pseudocode comparison tables; the default explores strictly more.

    ``times0=(latency, per_cluster_times)`` supplies the seed allocation's
    evaluation when the caller already has it -- the batched transition
    sweep (``fastcost._SegmentSweep.sweep_transitions``) scores every
    candidate's seed in one array pass, so re-evaluating it here would undo
    the batching.  The values must equal ``eval_fn(alloc)`` exactly; the
    walk (and therefore the result) is then bit-identical to the unseeded
    call.
    """
    INF = float("inf")
    if paper_strict:
        donor_tries = 1
    best = list(alloc)
    if times0 is not None:
        best_lat, best_times = times0[0], list(times0[1])
    else:
        best_lat, best_times = eval_fn(best)
    if paper_strict and best_lat == INF:
        return best, best_lat, best_times
    # Incremental protocol (fastcost.py): ``move(alloc, times, dst, src, k)``
    # re-evaluates only the clusters a chip transfer actually changes.
    mv = getattr(eval_fn, "move", None)
    if mv is None:
        def mv(base_alloc, base_times, dst, src, k=1):
            trial = list(base_alloc)
            trial[dst] += k
            trial[src] -= k
            lat, times = eval_fn(trial)
            return lat, trial, times

    step = 1        # repair transfer size (doubles while the target stays INF)
    for _ in range(max_iters):
        if not best_times:
            break
        n = len(best_times)
        if best_lat == INF:
            # Repair phase: grow the first infeasible region.  A region goes
            # INF only when weights overflow capacity, and more chips shard
            # weights further, so feeding it is the only move that can help.
            # Transfers grow geometrically so a region that is hundreds of
            # chips short is repaired in O(log) evaluations.
            bad = [j for j, t in enumerate(best_times) if t == INF]
            if not bad:
                break
            # Repair an infeasible region whose pool still has donors
            # (pool-less infeasible regions stay INF and the walk ends).
            if groups is None:
                # Without pools donor availability is receiver-independent,
                # so the scan below always lands on the first bad region.
                target = bad[0]
            else:
                target = next(
                    (
                        j for j in bad
                        if _fastest_donors(best_times, best, bad, 1, groups, j)
                    ),
                    bad[0],
                )
            donors = _fastest_donors(best_times, best, bad, donor_tries,
                                     groups, target)
            moved = False
            for donor in donors:
                # donors all have > 1 chip, so k >= 1
                k = min(step, best[donor] - 1)
                lat, trial, times = mv(best, best_times, target, donor, k)
                # The donor must stay feasible (otherwise chips ping-pong
                # between regions); the target's allocation then grows
                # monotonically while it stays infeasible, so this terminates.
                if times[donor] != INF and sum(1 for t in times if t == INF) <= len(bad):
                    best, best_lat, best_times = trial, lat, times
                    moved = True
                    step = step * 2 if times[target] == INF else 1
                    break
            if not moved:
                if step > 1:    # retry the conservative single-chip transfer
                    step = 1
                    continue
                break
            continue
        if groups is None and donor_tries <= 2:
            # Fused scan (the hot path): one pass finds the bottleneck
            # (first max, matching the plain max scan) and the three
            # fastest donor-eligible regions; dropping the bottleneck from
            # those three leaves the two fastest donors excluding it --
            # exactly ``_fastest_donors(..., (slow,), donor_tries)``.
            slow = 0
            ts = best_times[0]
            t1 = t2 = t3 = 0.0
            j1 = j2 = j3 = -1
            for j, t in enumerate(best_times):
                if t > ts:
                    slow, ts = j, t
                if best[j] > 1:
                    if j1 < 0 or t < t1:
                        t3, j3 = t2, j2
                        t2, j2 = t1, j1
                        t1, j1 = t, j
                    elif j2 < 0 or t < t2:
                        t3, j3 = t2, j2
                        t2, j2 = t, j
                    elif j3 < 0 or t < t3:
                        t3, j3 = t, j
            donors = [d for d in (j1, j2, j3)
                      if d >= 0 and d != slow][:donor_tries]
        else:
            slow = 0
            for j in range(1, n):
                if best_times[j] > best_times[slow]:
                    slow = j
            donors = _fastest_donors(best_times, best, (slow,), donor_tries,
                                     groups, slow)
        improved = False
        for fast in donors:
            lat, trial, times = mv(best, best_times, slow, fast, 1)
            if lat < best_lat:
                best, best_lat, best_times = trial, lat, times
                improved = True
                break
        if not improved:
            break
    return best, best_lat, best_times


def _fastest_donors(times, alloc, exclude, k, groups=None, receiver=None):
    """Indices of the ``k`` fastest regions that can give up a chip.

    With ``groups``, only regions in the receiver's pool may donate (chips
    never cross a flavor boundary).
    """
    pool = None if groups is None or receiver is None else groups[receiver]
    if k <= 2:
        # Hot path (donor_tries <= 2): a two-min scan instead of building
        # and sorting the full (t, j) list.  Strict ``<`` with ascending j
        # reproduces the sort's lexicographic tie-break (smallest t, then
        # smallest j) exactly.
        t1 = t2 = 0.0
        j1 = j2 = -1
        for j, t in enumerate(times):
            if alloc[j] > 1 and j not in exclude:
                if pool is not None and groups[j] != pool:
                    continue
                if j1 < 0 or t < t1:
                    t2, j2 = t1, j1
                    t1, j1 = t, j
                elif j2 < 0 or t < t2:
                    t2, j2 = t, j
        if j1 < 0:
            return []
        if k == 1 or j2 < 0:
            return [j1]
        return [j1, j2]
    out = []
    for j, t in enumerate(times):
        if alloc[j] > 1 and j not in exclude:
            if pool is not None and groups[j] != pool:
                continue
            out.append((t, j))
    out.sort()
    return [j for _, j in out[:k]]
