"""Span-based tracer with dual clocks and Chrome trace-event export.

Two usage modes share one event buffer:

* **Wall-clock spans** (the DSE path): ``with tracer.span("search", ...):``
  measures elapsed ``time.perf_counter`` seconds, relative to the tracer's
  epoch.  Spans nest; late arguments attach via ``span.set(best=...)``.
* **Simulated-time events** (the serving executor): the caller owns the
  clock and reports explicit times through :meth:`Tracer.complete`,
  :meth:`Tracer.instant`, and :meth:`Tracer.counter`.  Sim events never
  read the wall clock, so same-seed runs export bytewise-identical traces.

Events group into Chrome trace *processes* (``group``: e.g. ``dse`` vs
``serving``) and *threads* (``lane``: e.g. one lane per model server) so
Perfetto / ``chrome://tracing`` renders a Gantt: solver spans, per-server
batch lanes, queue-depth counter tracks, and fault/recovery instants on a
shared timeline.  :meth:`Tracer.write` emits Chrome JSON (``*.json``) or
one event per line (``*.jsonl``); :meth:`Tracer.summary` prints top spans
by self-time plus the metrics table.

Disabled path: :data:`NULL_TRACER` is a falsy no-op singleton.  Hot code
uses the ambient-tracer stack (:func:`current_tracer` / :func:`use_tracer`)
and pays roughly a dict-free method call per span when tracing is off —
``tests/test_obs.py`` micro-benches the bound.
"""
from __future__ import annotations

import functools
import json
import time

from .metrics import MetricsRegistry, NULL_METRICS

__all__ = [
    "NULL_TRACER",
    "NullTracer",
    "Tracer",
    "current_tracer",
    "traced",
    "use_tracer",
    "validate_chrome_trace",
]


# ---------------------------------------------------------------------------
# Disabled path
# ---------------------------------------------------------------------------

class _NullSpan:
    """Shared no-op span (context manager)."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False

    def set(self, **args):
        return self


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Falsy do-nothing tracer; every method is a cheap no-op."""
    enabled = False
    metrics = NULL_METRICS
    events: list = []

    def __bool__(self) -> bool:
        return False

    def now(self) -> float:
        return 0.0

    def span(self, name, group="dse", lane="solver", **args):
        return _NULL_SPAN

    def complete(self, name, t0, t1, group="sim", lane="", **args):
        pass

    def instant(self, name, t=None, group="dse", lane="solver", **args):
        pass

    def counter(self, name, t, value, group="sim"):
        pass

    def summary(self, top: int = 10) -> str:
        return "(tracing disabled)"


NULL_TRACER = NullTracer()


# ---------------------------------------------------------------------------
# Ambient tracer stack
# ---------------------------------------------------------------------------

_STACK: list = [NULL_TRACER]


def current_tracer():
    """The innermost active tracer (the no-op singleton by default)."""
    return _STACK[-1]


class use_tracer:
    """Install ``tracer`` as the ambient tracer for a ``with`` block."""
    __slots__ = ("tracer",)

    def __init__(self, tracer):
        self.tracer = tracer if tracer is not None else NULL_TRACER

    def __enter__(self):
        _STACK.append(self.tracer)
        return self.tracer

    def __exit__(self, exc_type, exc, tb):
        _STACK.pop()
        return False


def traced(name: str | None = None, group: str = "dse", lane: str = "solver"):
    """Decorator: run the function inside a span on the ambient tracer."""
    def deco(fn):
        label = name or fn.__name__

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            with current_tracer().span(label, group=group, lane=lane):
                return fn(*a, **kw)

        return wrapper

    return deco


# ---------------------------------------------------------------------------
# Live tracer
# ---------------------------------------------------------------------------

class _Span:
    """Wall-clock span; records on ``__exit__``."""
    __slots__ = ("tr", "name", "group", "lane", "args", "t0")

    def __init__(self, tr, name, group, lane, args):
        self.tr = tr
        self.name = name
        self.group = group
        self.lane = lane
        self.args = args
        self.t0 = 0.0

    def __enter__(self):
        self.t0 = self.tr.now()
        return self

    def set(self, **args):
        self.args.update(args)
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            self.args.setdefault("error", exc_type.__name__)
        self.tr._record("X", self.name, self.group, self.lane,
                        self.t0, self.tr.now(), self.args)
        return False


class Tracer:
    """Collects span/instant/counter events; owns a :class:`MetricsRegistry`."""
    enabled = True

    def __init__(self, clock=None):
        self._clock = clock if clock is not None else time.perf_counter
        self._epoch = self._clock()
        # event: (ph, name, group, lane, t0, t1_or_value, args)
        self.events: list[tuple] = []
        self.metrics = MetricsRegistry()

    def __bool__(self) -> bool:
        return True

    def now(self) -> float:
        """Seconds since this tracer's epoch (wall clock by default)."""
        return self._clock() - self._epoch

    # -- recording ----------------------------------------------------------

    def span(self, name: str, group: str = "dse", lane: str = "solver", **args):
        """Context-manager span on this tracer's own clock."""
        return _Span(self, name, group, lane, args)

    def complete(self, name: str, t0: float, t1: float,
                 group: str = "sim", lane: str = "", **args) -> None:
        """A finished span with caller-supplied times (simulated seconds)."""
        self._record("X", name, group, lane, t0, t1, args)

    def instant(self, name: str, t: float | None = None,
                group: str = "dse", lane: str = "solver", **args) -> None:
        """A point event; ``t=None`` stamps the tracer's own clock."""
        tt = self.now() if t is None else t
        self._record("i", name, group, lane, tt, tt, args)

    def counter(self, name: str, t: float, value, group: str = "sim") -> None:
        """One sample of a counter track (rendered as a filled series)."""
        self._record("C", name, group, "", t, t, {"value": value})

    def _record(self, ph, name, group, lane, t0, t1, args) -> None:
        self.events.append((ph, name, group, lane, t0, t1, args))

    # -- export -------------------------------------------------------------

    def to_chrome(self) -> dict:
        """Chrome trace-event JSON object (load in Perfetto / chrome://tracing).

        ``group`` -> pid, ``(group, lane)`` -> tid, both assigned in first-use
        order so same-event-stream exports are identical.
        """
        pids: dict[str, int] = {}
        tids: dict[tuple[str, str], int] = {}
        meta: list[dict] = []
        body: list[dict] = []

        def pid_of(group: str) -> int:
            pid = pids.get(group)
            if pid is None:
                pid = pids[group] = len(pids) + 1
                meta.append({"ph": "M", "name": "process_name", "pid": pid,
                             "tid": 0, "ts": 0, "args": {"name": group}})
            return pid

        def tid_of(group: str, lane: str) -> int:
            key = (group, lane)
            tid = tids.get(key)
            if tid is None:
                tid = tids[key] = len(tids) + 1
                meta.append({"ph": "M", "name": "thread_name",
                             "pid": pid_of(group), "tid": tid, "ts": 0,
                             "args": {"name": lane or group}})
            return tid

        def us(t: float) -> float:
            v = round(t * 1e6, 3)
            return int(v) if v == int(v) else v

        for ph, name, group, lane, t0, t1, args in self.events:
            ev = {"ph": ph, "name": name, "pid": pid_of(group),
                  "tid": tid_of(group, lane), "ts": us(t0)}
            if ph == "X":
                ev["dur"] = us(max(0.0, t1 - t0))
            elif ph == "i":
                ev["s"] = "t"
            if args:
                ev["args"] = args
            body.append(ev)

        return {"traceEvents": meta + body, "displayTimeUnit": "ms"}

    def write(self, path: str) -> str:
        """Write the trace: ``*.jsonl`` -> one event per line, else Chrome JSON."""
        payload = self.to_chrome()
        with open(path, "w") as fh:
            if path.endswith(".jsonl"):
                for ev in payload["traceEvents"]:
                    fh.write(json.dumps(ev, sort_keys=True) + "\n")
            else:
                json.dump(payload, fh, sort_keys=True)
                fh.write("\n")
        return path

    # -- reporting ----------------------------------------------------------

    def _span_aggregate(self) -> dict:
        """(group, name) -> [count, total_s, self_s] with child time removed."""
        agg: dict[tuple[str, str], list] = {}
        lanes: dict[tuple[str, str], list] = {}
        for ev in self.events:
            if ev[0] == "X":
                lanes.setdefault((ev[2], ev[3]), []).append(ev)
        for evs in lanes.values():
            evs.sort(key=lambda e: (e[4], -(e[5])))
            stack: list = []
            for ev in evs:
                _, name, group, _, t0, t1, _ = ev
                while stack and t0 >= stack[-1][5] - 1e-12:
                    stack.pop()
                a = agg.setdefault((group, name), [0, 0.0, 0.0])
                dur = t1 - t0
                a[0] += 1
                a[1] += dur
                a[2] += dur
                if stack:
                    parent = agg[(stack[-1][2], stack[-1][1])]
                    parent[2] -= dur
                stack.append(ev)
        return agg

    def summary(self, top: int = 10) -> str:
        """Text report: top spans by self-time, then the metrics table."""
        agg = self._span_aggregate()
        n_spans = sum(a[0] for a in agg.values())
        lines = [f"trace: {n_spans} spans, {len(self.events)} events"]
        if agg:
            lines.append(f"{'self_s':>10} {'total_s':>10} {'count':>7}  span")
            ranked = sorted(agg.items(), key=lambda kv: -kv[1][2])[:top]
            for (group, name), (count, total, self_s) in ranked:
                lines.append(
                    f"{self_s:>10.4f} {total:>10.4f} {count:>7}  {group}/{name}"
                )
        snap = self.metrics.snapshot()
        for kind in ("counters", "gauges"):
            table = snap.get(kind)
            if table:
                lines.append(f"{kind}:")
                for k, v in table.items():
                    vv = f"{v:.6g}" if isinstance(v, float) else str(v)
                    lines.append(f"  {k:<32} {vv}")
        series = snap.get("series")
        if series:
            lines.append("series (time-weighted):")
            for k, st in series.items():
                lines.append(
                    f"  {k:<32} mean={st['mean']:.3f} p95={st['p95']:.3f} "
                    f"max={st['max']:.3f}"
                )
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Chrome-trace validation (shared by scripts/check_trace.py and tests)
# ---------------------------------------------------------------------------

def validate_chrome_trace(payload, expect_fault_events: bool = False,
                          expect_groups=(),
                          expect_llm: bool = False) -> list[str]:
    """Schema-check a Chrome trace-event JSON object; returns problem strings.

    Checks: required keys per event phase, non-negative times, proper span
    nesting per (pid, tid) lane, monotone per-counter timestamps, requested
    process groups present, and (optionally) fault instant events.

    ``expect_llm`` additionally requires the token-level serving signature:
    ``prefill``/``decode`` spans on per-model ``<model>/<phase>`` lanes in
    the ``llm`` group, at least one ``admit_midbatch`` instant, and
    ``kv_bytes/<model>`` counter tracks.
    """
    problems: list[str] = []
    if not isinstance(payload, dict) or not isinstance(
            payload.get("traceEvents"), list):
        return ["payload is not an object with a traceEvents list"]
    events = payload["traceEvents"]
    if not events:
        problems.append("traceEvents is empty")

    groups: set[str] = set()
    lanes: dict[tuple, list] = {}
    counter_last: dict[tuple, float] = {}
    saw_fault = False
    pid_group: dict = {}            # pid -> process (group) name
    lane_name: dict = {}            # (pid, tid) -> thread (lane) name
    span_lanes: dict = {}           # span-name prefix evidence, per group
    counter_names: set[str] = set()
    saw_admit = False

    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        name = ev.get("name")
        for key in ("ph", "name", "pid", "tid", "ts"):
            if key not in ev:
                problems.append(f"event {i} ({ph}/{name}): missing key {key!r}")
        ts = ev.get("ts", 0)
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {i} ({ph}/{name}): bad ts {ts!r}")
            continue
        if ph == "M":
            if name == "process_name":
                groups.add(ev.get("args", {}).get("name", ""))
                pid_group[ev.get("pid")] = ev.get("args", {}).get("name", "")
            elif name == "thread_name":
                lane_name[(ev.get("pid"), ev.get("tid"))] = \
                    ev.get("args", {}).get("name", "")
        elif ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i} (X/{name}): bad dur {dur!r}")
            else:
                key = (ev.get("pid"), ev.get("tid"))
                lanes.setdefault(key, []).append((ts, ts + dur, name))
                if isinstance(name, str):
                    span_lanes.setdefault(
                        pid_group.get(ev.get("pid"), ""), set()).add(
                        (name.split(" ")[0], lane_name.get(key, "")))
        elif ph == "i":
            if ev.get("s") not in ("t", "p", "g"):
                problems.append(f"event {i} (i/{name}): missing scope 's'")
            if isinstance(name, str) and name.startswith("fault"):
                saw_fault = True
            if name == "admit_midbatch":
                saw_admit = True
        elif ph == "C":
            if "value" not in ev.get("args", {}):
                problems.append(f"event {i} (C/{name}): missing args.value")
            key = (ev.get("pid"), name)
            if counter_last.get(key, -1.0) > ts:
                problems.append(
                    f"event {i} (C/{name}): non-monotone counter ts {ts}")
            counter_last[key] = ts
            if isinstance(name, str):
                counter_names.add(name)
        else:
            problems.append(f"event {i}: unknown phase {ph!r}")

    # spans must nest per lane: sort by (start, -end); each span must close
    # inside its enclosing span
    eps = 5e-3          # µs; export rounds to 1e-3
    for (pid, tid), spans in lanes.items():
        spans.sort(key=lambda s: (s[0], -s[1]))
        stack: list = []
        for t0, t1, name in spans:
            while stack and t0 >= stack[-1][1] - eps:
                stack.pop()
            if stack and t1 > stack[-1][1] + eps:
                problems.append(
                    f"lane pid={pid} tid={tid}: span {name!r} "
                    f"[{t0},{t1}] overlaps {stack[-1][2]!r} "
                    f"[{stack[-1][0]},{stack[-1][1]}]")
            stack.append((t0, t1, name))

    for g in expect_groups:
        if g not in groups:
            problems.append(f"missing process group {g!r} "
                            f"(have {sorted(groups)})")
    if expect_fault_events and not saw_fault:
        problems.append("no fault instant events found")
    if expect_llm:
        llm_spans = span_lanes.get("llm", set())
        for phase in ("prefill", "decode"):
            if not any(n == phase and lane.endswith(f"/{phase}")
                       for n, lane in llm_spans):
                problems.append(
                    f"no {phase} spans on a '<model>/{phase}' lane in "
                    f"group 'llm'")
        if not saw_admit:
            problems.append("no admit_midbatch instant events found")
        if not any(n.startswith("kv_bytes/") for n in counter_names):
            problems.append("no kv_bytes/<model> counter tracks found")
    return problems
