"""Mamba-1 selective-state-space block (jamba's SSM layer).

Prefill uses a chunked scan: ``lax.scan`` over sequence chunks carrying the
state h [B, d_inner, N]; within a chunk the recurrence materializes
[B, chunk, d_inner, N] and is evaluated by an associative scan.  Decode is a
single state update.  The Pallas kernel (``repro.kernels.mamba``) implements
the same chunked schedule with VMEM tiling.

State cache for serving: {"h": [B, d_inner, N], "conv": [B, d_conv-1, d_inner]}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense


def init_mamba(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    di = cfg.mamba_expand * d
    N = cfg.mamba_d_state
    R = max(1, d // 16)                      # dt_rank
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.mamba_d_conv, di)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": (jax.random.normal(ks[2], (di, R + 2 * N)) * di ** -0.5).astype(dtype),
        "dt_proj": (jax.random.normal(ks[3], (R, di)) * R ** -0.5).astype(dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),     # softplus^-1(0.01)
        "A_log": jnp.log(
            jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (di, 1))
        ),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[5], (di, d)) * di ** -0.5).astype(dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, init_state=None):
    """Depthwise causal conv along S.  x [B,S,di], w [d_conv, di]."""
    d_conv = w.shape[0]
    if init_state is None:
        pad = jnp.zeros((x.shape[0], d_conv - 1, x.shape[2]), x.dtype)
    else:
        pad = init_state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(d_conv))
    new_state = xp[:, -(d_conv - 1):] if d_conv > 1 else pad
    return out + b, new_state


def _ssm_params(params, x, cfg):
    """x [B,S,di] -> (decay a [B,S,di,N], bx [B,S,di,N], C [B,S,N], dt)."""
    N = cfg.mamba_d_state
    R = params["dt_proj"].shape[0]
    dbc = dense(x, params["x_proj"])
    dt_r, Bc, Cc = jnp.split(dbc, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        dense(dt_r, params["dt_proj"]).astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )                                                        # [B,S,di]
    A = -jnp.exp(params["A_log"])                            # [di, N]
    a = jnp.exp(dt[..., None] * A)                           # [B,S,di,N]
    bx = (dt * x.astype(jnp.float32))[..., None] * Bc.astype(jnp.float32)[:, :, None, :]
    return a, bx, Cc.astype(jnp.float32), dt


def mamba_scan_chunked(a, bx, h0, chunk: int):
    """Linear recurrence h_t = a_t h_{t-1} + bx_t, scanned by chunks.

    a, bx: [B, S, di, N]; h0 [B, di, N]; returns (h_all [B,S,di,N], h_last).
    """
    B, S, di, N = a.shape
    n_chunks = S // chunk
    a_c = a.reshape(B, n_chunks, chunk, di, N).swapaxes(0, 1)
    b_c = bx.reshape(B, n_chunks, chunk, di, N).swapaxes(0, 1)

    def body(h, inputs):
        ac, bc = inputs

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        aa, hh = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        hh = hh + aa * h[:, None]
        return hh[:, -1], hh

    h_last, h_all = jax.lax.scan(body, h0, (a_c, b_c))
    h_all = h_all.swapaxes(0, 1).reshape(B, S, di, N)
    return h_all, h_last


def mamba_prefill(params: dict, x: jax.Array, cfg: ModelConfig, chunk: int = 128):
    """x [B,S,d] -> (out [B,S,d], state cache)."""
    B, S, d = x.shape
    di = cfg.mamba_expand * d
    xz = dense(x, params["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)
    xi, conv_state = _causal_conv(xi, params["conv_w"], params["conv_b"])
    xi = jax.nn.silu(xi)
    a, bx, Cc, _ = _ssm_params(params, xi, cfg)
    h0 = jnp.zeros((B, di, cfg.mamba_d_state), jnp.float32)
    c = min(chunk, S)
    while S % c:
        c -= 1
    h_all, h_last = mamba_scan_chunked(a, bx, h0, c)
    y = jnp.einsum("bsdn,bsn->bsd", h_all, Cc)
    y = y + params["D"] * xi.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = dense(y, params["out_proj"])
    return out, {"h": h_last, "conv": conv_state}


def mamba_decode(params: dict, x: jax.Array, cfg: ModelConfig, state: dict):
    """Single-token step.  x [B,1,d]."""
    B = x.shape[0]
    xz = dense(x, params["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)
    xi_s, conv_state = _causal_conv(xi, params["conv_w"], params["conv_b"], state["conv"])
    xi_s = jax.nn.silu(xi_s)
    a, bx, Cc, _ = _ssm_params(params, xi_s, cfg)
    h = a[:, 0] * state["h"] + bx[:, 0]
    y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0])[:, None]
    y = y + params["D"] * xi_s.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = dense(y, params["out_proj"])
    return out, {"h": h, "conv": conv_state}


def mamba_ref_sequential(params: dict, x: jax.Array, cfg: ModelConfig):
    """Step-by-step oracle for tests (slow, exact)."""
    B, S, d = x.shape
    di = cfg.mamba_expand * d
    xz = dense(x, params["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)
    xi, _ = _causal_conv(xi, params["conv_w"], params["conv_b"])
    xi = jax.nn.silu(xi)
    a, bx, Cc, _ = _ssm_params(params, xi, cfg)
    h = jnp.zeros((B, di, cfg.mamba_d_state), jnp.float32)
    ys = []
    for t in range(S):
        h = a[:, t] * h + bx[:, t]
        ys.append(jnp.einsum("bdn,bn->bd", h, Cc[:, t]))
    y = jnp.stack(ys, axis=1)
    y = y + params["D"] * xi.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return dense(y, params["out_proj"])
