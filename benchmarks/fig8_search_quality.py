"""Fig. 8 + SSV-B(1): search-quality validation on AlexNet x 16 chiplets.

The paper compares Algorithm 1's result against the full design space
(exhaustive at the smallest scale) and reports a top-0.05% rank.  We build
the processing-time histogram from uniform random samples of the space
(facade strategy ``random``) and rank Algorithm 1's schedule
(strategy ``scope``, pinned to one segment like the paper's single-segment
study) in it; a small exact exhaustive case (strategy ``exhaustive``)
checks near-optimality directly.
"""
from __future__ import annotations

from repro import scope
from repro.core.graph import chain
from repro.core.hw import mcm_table_iii
from repro.core.workloads import get_cnn

from .common import M_SAMPLES, cached


def run(refresh: bool = False, samples: int = 50_000):
    def _go():
        g = get_cnn("alexnet")
        # One shared engine: the random sweep reuses the DSE's memo.
        cost = scope.SearchOptions(m_samples=M_SAMPLES).make_cost(
            mcm_table_iii(16)
        )
        alg1 = scope.solve(
            workload="alexnet", package="mcm16",
            options=scope.SearchOptions(
                strategy="scope", m_samples=M_SAMPLES, cost=cost,
                segment_counts=(1,),
            ),
        )
        rand = scope.solve(
            workload="alexnet", package="mcm16",
            options=scope.SearchOptions(
                strategy="random", m_samples=M_SAMPLES, cost=cost,
                samples=samples, seed=0,
            ),
        )
        pop = rand.diagnostics["population"]
        beaten = sum(1 for s in pop if s < alg1.latency)
        # exact exhaustive check on a reduced case
        sub = chain("alexnet[:4]", g.layers[:4])
        sub_opts = dict(m_samples=M_SAMPLES, segment_counts=(1,))
        best = scope.solve(
            workload=scope.WorkloadSpec.graphs([sub]),
            package=mcm_table_iii(16).with_chips(6),
            options=scope.SearchOptions(strategy="exhaustive", **sub_opts),
        )
        res_sub = scope.solve(
            workload=scope.WorkloadSpec.graphs([sub]),
            package=mcm_table_iii(16).with_chips(6),
            options=scope.SearchOptions(strategy="scope", **sub_opts),
        )
        # histogram (20 bins) of the sampled space
        lo, hi = min(pop), max(pop)
        bins = [0] * 20
        for s in pop:
            bins[min(19, int((s - lo) / (hi - lo + 1e-30) * 20))] += 1
        return {
            "alg1_latency_s": alg1.latency,
            "alg1_search_s": alg1.diagnostics["dse_s"],
            "samples": samples,
            "sample_s": rand.diagnostics["dse_s"],
            "rank_fraction": beaten / samples,
            "histogram": {"lo": lo, "hi": hi, "bins": bins},
            "exhaustive_small": {
                "optimum_s": best.latency,
                "alg1_s": res_sub.latency,
                "ratio": res_sub.latency / best.latency,
            },
        }

    return cached("fig8_search_quality", _go, refresh)


def report(r) -> list[str]:
    return [
        "metric,value",
        f"alg1_rank_in_space,{r['rank_fraction']:.5f}",
        f"paper_claim_top_fraction,0.0005",
        f"small_exhaustive_ratio,{r['exhaustive_small']['ratio']:.4f}",
        f"alg1_search_seconds,{r['alg1_search_s']:.3f}",
        f"# alg1 ranks in top {100 * r['rank_fraction']:.3f}% of {r['samples']} uniform samples"
        f" (paper: top 0.05%)",
    ]
