"""DSE -> runtime bridge: pick the Scope plan for an (arch x shape x mesh).

For the non-pipelined production meshes the ``model`` axis is one Scope
*region*; the searched knob is the paper's WSP->ISP transition point, which
maps onto the scanned layer stack as ``transition_repeat`` (two scan zones).
The search evaluates the paper's cost model (Eq. 1-7, Table II volumes) with
TPU v5e constants over the arch's exported layer graph.
"""
from __future__ import annotations

import time

from ..core.costmodel import INF
from ..core.fastcost import FastCostModel
from ..core.graph import PARTITION_ISP, PARTITION_WSP
from ..core.hw import tpu_v5e
from ..core.workloads.lm import lm_graph
from ..models.config import ModelConfig
from .sharding import ShardPlan


def plan_for_cell(
    cfg: ModelConfig,
    seq_len: int,
    global_batch: int,
    mesh_axes: tuple[str, ...],
    model_axis: int = 16,
    kind: str = "train",
    use_dse: bool = True,
) -> ShardPlan:
    if kind == "decode":
        # single-token steps have no sequence to split: pure ISP
        return ShardPlan(mesh_axes=mesh_axes, p1="ISP", p2="ISP",
                         transition_repeat=None,
                         meta={"kind": kind, "dse": False})
    if not use_dse:
        return ShardPlan(mesh_axes=mesh_axes, p1="ISP", p2="ISP",
                         transition_repeat=None, meta={"kind": kind, "dse": False})

    graph = lm_graph(cfg, seq_len, decode=False)
    L = len(graph)
    hw = tpu_v5e(model_axis, (1, model_axis))
    cost = FastCostModel(hw, m_samples=max(2, global_batch), distributed_weights=True)
    clustering = ((0, L),)          # the model axis is one region
    best = (INF, L)                 # default: all ISP
    t0 = time.time()
    sweeper = cost.segment_sweeper(graph, 0, clustering)
    for idx in range(L + 1):
        partitions = tuple(
            [PARTITION_WSP] * idx + [PARTITION_ISP] * (L - idx)
        )
        eval_fn = sweeper(partitions, transition=(idx, False))
        lat, _ = eval_fn([model_axis])
        if lat < best[0]:
            best = (lat, idx)
    dse_s = time.time() - t0
    t_layers = best[1]
    meta = {"kind": kind, "dse": True, "t_layers": t_layers,
            "latency": best[0], "dse_s": dse_s,
            "dse_engine": cost.stats}
    return _plan_from_transition(cfg, mesh_axes, t_layers, L, meta)


def _plan_from_transition(
    cfg: ModelConfig,
    mesh_axes: tuple[str, ...],
    t_layers: int,
    L: int,
    meta: dict,
    stage_chip_types: tuple = (),
) -> ShardPlan:
    """Map a WSP->ISP layer transition index onto the scanned layer stack.

    Graph layout: [embed] + per-block nodes + [lm_head]; the transition maps
    onto the repeat axis of the stack as ``transition_repeat`` (two zones).
    ``stage_chip_types`` carries the schedule's per-stage chip flavors into
    the plan (mixed-flavor packages).
    """
    per_block = (L - 2) / max(1, cfg.n_layers)
    layers_per_repeat = per_block * len(cfg.expanded_pattern)
    t_rep = round(max(0.0, (t_layers - 1)) / max(1e-9, layers_per_repeat))
    t_rep = min(max(t_rep, 0), cfg.pattern_repeats)
    if t_rep == 0:
        return ShardPlan(mesh_axes=mesh_axes, p1="ISP", p2="ISP",
                         transition_repeat=None,
                         stage_chip_types=stage_chip_types, meta=meta)
    if t_rep == cfg.pattern_repeats:
        return ShardPlan(mesh_axes=mesh_axes, p1="WSP", p2="WSP",
                         transition_repeat=None,
                         stage_chip_types=stage_chip_types, meta=meta)
    return ShardPlan(
        mesh_axes=mesh_axes, p1="WSP", p2="ISP", transition_repeat=t_rep,
        stage_chip_types=stage_chip_types, meta=meta,
    )


def schedule_stages(schedule) -> tuple[tuple[int, int, str | None, int], ...]:
    """Flatten a ScopeSchedule into per-stage ``(layer_lo, layer_hi,
    chip_type, region_chips)`` tuples -- the runtime's view of which chip
    flavor serves which layer range."""
    return tuple(
        (cl.layer_lo, cl.layer_hi, cl.chip_type, cl.region_chips)
        for seg in schedule.segments
        for cl in seg.clusters
    )


def check_stage_placement(
    stage_chip_types: tuple[tuple[int, int, str | None, int], ...],
    hw,
) -> list[list[tuple[int, int]]]:
    """Tie a plan's per-stage chip flavors to mesh coordinates.

    Places each stage's region inside its flavor's physical zone of the
    package mesh (flavor-aware :func:`~repro.core.regions.zigzag_placement`)
    and returns the per-stage coordinate lists.  Raises ``ValueError`` when
    the plan's flavor runs straddle the seam non-contiguously (a flavor
    appearing in two separate runs would tear its zone apart) or overflow a
    flavor's chips -- the placement-level completion of the
    ``validate_schedule`` seam accounting.
    """
    from ..core.regions import zigzag_placement
    from ..multimodel.quota import package_flavors

    if not stage_chip_types:
        return []
    return zigzag_placement(
        [chips for _, _, _, chips in stage_chip_types],
        hw.mesh_shape,
        region_flavors=[ctype for _, _, ctype, _ in stage_chip_types],
        flavor_counts=package_flavors(hw),
        dead=getattr(hw, "dead_chips", ()),
    )


def plan_for_multimodel(
    cfgs: list[ModelConfig],
    seq_len: int,
    global_batch: int,
    mesh_axes: tuple[str, ...],
    model_axis: int = 16,
    weights: list[float] | None = None,
    step: int = 1,
    hw=None,
    switch_cost: bool = False,
    mm=None,
):
    """Co-schedule several LM configs onto one model axis.

    Runs the multimodel quota search (``repro.multimodel.co_schedule``) over
    the configs' exported layer graphs on a ``model_axis``-chip package, then
    derives each model's ShardPlan from its Scope schedule: the plan's
    WSP->ISP transition is the schedule's first transition point,
    ``meta["quota_chips"]`` is the model-axis share the co-schedule assigned
    (the serving path runs each model on that sub-axis, or time-multiplexes
    when the co-schedule says so), and ``plan.stage_chip_types`` records
    which chip flavor serves each pipeline stage -- on a heterogeneous
    package (pass ``hw``) one model's stages may span flavors, and
    ``meta["chip_quota"]`` itemizes the per-flavor chips.

    ``mm`` skips the search and derives the plans from an already-solved
    :class:`~repro.core.graph.MultiModelSchedule` (the facade's
    ``Solution.deploy`` passes its own result through, so solve-then-deploy
    never searches twice); it must cover every config by name.

    Returns ``(MultiModelSchedule, {cfg.name: ShardPlan})``.
    """
    from ..multimodel import ModelSpec, co_schedule

    names = [cfg.name for cfg in cfgs]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate config names in co-schedule: {names}")
    weights = weights or [1.0] * len(cfgs)
    if len(weights) != len(cfgs):
        raise ValueError(
            f"{len(weights)} weights for {len(cfgs)} configs"
        )
    graphs = [lm_graph(cfg, seq_len, decode=False) for cfg in cfgs]
    # LayerGraph names default to the arch name; keep them aligned to cfgs.
    specs = [ModelSpec(g, w) for g, w in zip(graphs, weights)]
    if hw is None:
        hw = tpu_v5e(model_axis, (1, model_axis))
    elif hw.chips != model_axis:
        raise ValueError(f"hw has {hw.chips} chips != model_axis {model_axis}")
    if mm is None:
        cost = FastCostModel(hw, m_samples=max(2, global_batch),
                             distributed_weights=True)
        # Merged interleaving has no GSPMD execution path (one jitted fn
        # serves one config), so the runtime bridge searches partitioned +
        # time-mux.
        mm = co_schedule(specs, hw, m_samples=max(2, global_batch), step=step,
                         include_merged=False, cost=cost,
                         switch_cost=switch_cost)
    if mm is None:
        return None, {}
    if hw.region_types:
        # Placement-level seam check: every assignment's stage flavors must
        # map onto contiguous zone coordinates (per segment).
        from ..core.regions import check_assignments_placement
        from ..multimodel.quota import package_flavors

        check_assignments_placement(mm.assignments, hw.mesh_shape,
                                    package_flavors(hw),
                                    dead=hw.dead_chips)
    plans: dict[str, ShardPlan] = {}
    for cfg, graph, spec in zip(cfgs, graphs, specs):
        a = mm.assignment(spec.name)
        flat = a.schedule.layer_partition()
        L = len(graph)
        t_layers = next(
            (i for i, (_, p, _) in enumerate(flat) if p != PARTITION_WSP), L
        )
        meta = {
            "kind": "serve", "dse": True, "t_layers": t_layers,
            "latency": a.schedule.latency,
            "quota_chips": a.chips,
            "co_mode": mm.mode,
            "time_share": a.time_share,
        }
        if a.chip_type:
            meta["chip_type"] = a.chip_type
        if a.chip_quota:
            meta["chip_quota"] = [[t, c] for t, c in a.chip_quota]
        plans[cfg.name] = _plan_from_transition(
            cfg, mesh_axes, t_layers, L, meta,
            stage_chip_types=schedule_stages(a.schedule),
        )
    return mm, plans
