"""Compiled-HLO analysis: collective byte counts + roofline terms.

``cost_analysis()`` lacks collective traffic, so we parse the (optimized)
HLO text: every ``all-gather``/``all-reduce``/``reduce-scatter``/
``all-to-all``/``collective-permute`` op contributes its operand bytes.
Shapes are parsed from the HLO result/operand types (e.g.
``bf16[2,4096,128]{...}``).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[^=(]+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.MULTILINE,
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))

    def to_dict(self):
        return {"bytes_by_kind": self.bytes_by_kind,
                "count_by_kind": self.count_by_kind,
                "total_bytes": self.total_bytes}


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective op ('-start' counted,
    '-done' skipped to avoid double counting async pairs)."""
    stats = CollectiveStats()
    for m in _OP_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        line = hlo_text[m.start():hlo_text.index("\n", m.start())]
        if f"{kind}-done" in line:
            continue
        b = _shape_bytes(type_str)
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + b
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


# ------------------------------------------------------------------ roofline

@dataclass(frozen=True)
class HwConstants:
    peak_flops: float = 197e12       # bf16 / chip
    hbm_bw: float = 819e9            # bytes/s / chip
    link_bw: float = 50e9            # bytes/s / ICI link


def roofline_terms(
    hlo_flops: float,
    hlo_bytes: float,
    collective_bytes: float,
    chips: int,
    hw: HwConstants = HwConstants(),
) -> dict:
    """The three roofline terms in seconds (per step, whole mesh).

    cost_analysis reports whole-program numbers for the SPMD module, which
    XLA gives *per partition*; we treat flops/bytes as per-chip and
    collectives as per-chip wire bytes over one link.
    """
    compute = hlo_flops / hw.peak_flops
    memory = hlo_bytes / hw.hbm_bw
    collective = collective_bytes / hw.link_bw
    dominant = max(
        ("compute", compute), ("memory", memory), ("collective", collective),
        key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
        "bound_s": max(compute, memory, collective),
    }
