"""Scope reproduction: merged-pipeline DSE for multi-chip-module accelerators.

The one front door is :mod:`repro.api`, exported as ``repro.scope``::

    from repro import scope

    solution = scope.solve(scope.problem("resnet50", "mcm64"))

Heavy subpackages (kernels, runtime, models -- which import jax) are NOT
imported here; everything is loaded lazily so ``import repro`` stays cheap
and dependency-light.
"""
from importlib import import_module

__all__ = ["scope", "api", "serving", "solve", "problem", "Problem", "Solution"]

_API_NAMES = {
    "solve", "problem", "Problem", "Solution", "Deployment",
    "WorkloadSpec", "PackageSpec", "SearchOptions", "SolutionCache",
    "register_strategy", "available_strategies", "solve_many",
}


def __getattr__(name):
    if name in ("scope", "api"):
        mod = import_module(".api", __name__)
        globals()["scope"] = globals()["api"] = mod
        return mod
    if name == "serving":
        mod = import_module(".serving", __name__)
        globals()["serving"] = mod
        return mod
    if name in _API_NAMES:
        value = getattr(import_module(".api", __name__), name)
        globals()[name] = value
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | _API_NAMES | {"scope", "api", "serving"})
