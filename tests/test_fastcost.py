"""Parity suite: FastCostModel vs the reference CostModel.

The fast engine's contract (fastcost.py) is *exact parity*: identical
cluster/segment/system times within 1e-9 rtol (bit-identical in practice)
and the same argmin schedules out of the DSE, across RegionModes,
``ep_for_moe``, ``literal_pre``, ``distributed_weights`` and ``overlap``
settings, for CNN and LM graphs.
"""
import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.costmodel import INF, CostModel
from repro.core.fastcost import FastCostModel
from repro.core.graph import ClusterAssignment, LayerNode, chain, validate_schedule
from repro.core.hw import mcm_table_iii
from repro.core.baselines import schedule_scope, schedule_segmented
from repro.core.regions import RegionMode
from repro.core.search import evaluate_segment, search_segment
from repro.core.workloads import get_cnn
from repro.core.workloads.lm import lm_graph
from repro.configs import get_smoke_config

RTOL = 1e-9


def close(a: float, b: float) -> bool:
    if a == b:
        return True
    if a == INF or b == INF:
        return False
    return abs(a - b) <= RTOL * max(abs(a), abs(b))


def make_models(chips: int, **kw):
    hw = mcm_table_iii(chips)
    return CostModel(hw, m_samples=16, **kw), FastCostModel(hw, m_samples=16, **kw)


def random_segment_configs(graph, chips: int, samples: int, seed: int = 0):
    """Random (clustering, partitions, regions) over a whole graph."""
    rng = random.Random(seed)
    L = len(graph)
    for _ in range(samples):
        n_cluster = rng.randint(1, min(L, chips))
        cuts = sorted(rng.sample(range(1, L), n_cluster - 1)) if n_cluster > 1 else []
        bounds, cursor = [], 0
        for c in cuts + [L]:
            bounds.append((cursor, c))
            cursor = c
        rcuts = sorted(rng.sample(range(1, chips), n_cluster - 1)) if n_cluster > 1 else []
        regions, prev = [], 0
        for c in rcuts + [chips]:
            regions.append(c - prev)
            prev = c
        choices = ("WSP", "ISP")
        partitions = tuple(rng.choice(choices) for _ in range(L))
        yield tuple(bounds), partitions, regions


class TestClusterParity:
    @pytest.mark.parametrize("net,chips", [("alexnet", 16), ("resnet18", 32)])
    def test_random_segment_configs_match(self, net, chips):
        g = get_cnn(net)
        ref, fast = make_models(chips)
        n_inf = n_fin = 0
        for clustering, partitions, regions in random_segment_configs(g, chips, 120):
            lr, tr = evaluate_segment(ref, g, 0, clustering, partitions, regions)
            lf, tf = evaluate_segment(fast, g, 0, clustering, partitions, regions)
            assert close(lr, lf), (clustering, partitions, regions, lr, lf)
            for a, b in zip(tr, tf):
                assert close(a, b)
            n_inf += lr == INF
            n_fin += lr < INF
        assert n_fin > 5   # the sample must actually exercise finite configs

    def test_large_cluster_vectorized_path(self):
        """Clusters > _SCALAR_MAX_LAYERS route through the NumPy body; pin
        its parity explicitly (the small-graph tests only hit the scalar
        path)."""
        from repro.core.fastcost import _SCALAR_MAX_LAYERS

        g = get_cnn("resnet50")
        L = len(g)
        assert L > _SCALAR_MAX_LAYERS
        ref, fast = make_models(64)
        for idx in (0, L // 3, L // 2, L):          # whole graph = one cluster
            partitions = tuple(["WSP"] * idx + ["ISP"] * (L - idx))
            for n in (8, 33, 64):
                lr, _ = evaluate_segment(ref, g, 0, ((0, L),), partitions, [n])
                lf, _ = evaluate_segment(fast, g, 0, ((0, L),), partitions, [n])
                assert close(lr, lf), (idx, n, lr, lf)
        # two big clusters: exercises the Case 2 boundary with big statics
        cut = L // 2
        parts = tuple(["WSP"] * cut + ["ISP"] * (L - cut))
        lr, tr = evaluate_segment(ref, g, 0, ((0, cut), (cut, L)), parts, [31, 33])
        lf, tf = evaluate_segment(fast, g, 0, ((0, cut), (cut, L)), parts, [31, 33])
        assert close(lr, lf)
        for a, b in zip(tr, tf):
            assert close(a, b)

    def test_resnet152_flagship_graph_parity(self):
        """Per-candidate parity on the paper's flagship 151-layer graph
        (running the full reference DSE here would take minutes; random
        configs cover the same evaluation paths per candidate)."""
        g = get_cnn("resnet152")
        ref, fast = make_models(256)
        n_fin = 0
        for clustering, partitions, regions in random_segment_configs(g, 256, 40, seed=17):
            lr, _ = evaluate_segment(ref, g, 0, clustering, partitions, regions)
            lf, _ = evaluate_segment(fast, g, 0, clustering, partitions, regions)
            assert close(lr, lf), (len(clustering), lr, lf)
            n_fin += lr < INF
        assert n_fin > 0

    @pytest.mark.parametrize("literal_pre", [False, True])
    @pytest.mark.parametrize("distributed_weights", [False, True])
    @pytest.mark.parametrize("overlap", [False, True])
    def test_flags_parity(self, literal_pre, distributed_weights, overlap):
        g = get_cnn("alexnet")
        ref, fast = make_models(
            16, literal_pre=literal_pre,
            distributed_weights=distributed_weights, overlap=overlap,
        )
        for clustering, partitions, regions in random_segment_configs(g, 16, 60, seed=3):
            lr, _ = evaluate_segment(ref, g, 0, clustering, partitions, regions)
            lf, _ = evaluate_segment(fast, g, 0, clustering, partitions, regions)
            assert close(lr, lf), (clustering, partitions, regions, lr, lf)

    def test_cluster_time_api_parity(self):
        g = get_cnn("alexnet")
        ref, fast = make_models(16)
        cl = ClusterAssignment(0, 3, 8, ("WSP", "WSP", "ISP"))
        nxt = ClusterAssignment(3, 5, 8, ("ISP", "ISP"))
        assert close(
            ref.cluster_time(g, cl, nxt, True, False),
            fast.cluster_time(g, cl, nxt, True, False),
        )
        assert close(
            ref.cluster_time(g, cl, None, True, True),
            fast.cluster_time(g, cl, None, True, True),
        )


class TestLMGraphParity:
    @pytest.mark.parametrize("arch", ["granite-3-8b", "granite-moe-1b-a400m"])
    def test_lm_random_configs(self, arch):
        cfg = get_smoke_config(arch)
        g = lm_graph(cfg, seq_len=256)
        ref, fast = make_models(16)
        for clustering, partitions, regions in random_segment_configs(g, 16, 50, seed=11):
            lr, _ = evaluate_segment(ref, g, 0, clustering, partitions, regions)
            lf, _ = evaluate_segment(fast, g, 0, clustering, partitions, regions)
            assert close(lr, lf)

    def test_moe_ep_partitions(self):
        """EP partitions (expert parallelism) agree between engines."""
        cfg = get_smoke_config("granite-moe-1b-a400m")
        g = lm_graph(cfg, seq_len=256)
        L = len(g)
        ref, fast = make_models(16)
        ep = tuple(
            "EP" if l.n_experts > 1 else ("WSP" if i < L // 2 else "ISP")
            for i, l in enumerate(g.layers)
        )
        clustering = ((0, L // 2), (L // 2, L))
        lr, _ = evaluate_segment(ref, g, 0, clustering, ep, [8, 8])
        lf, _ = evaluate_segment(fast, g, 0, clustering, ep, [8, 8])
        assert close(lr, lf)


class TestSearchParity:
    """Same argmin out of Algorithm 1, not just close values."""

    @pytest.mark.parametrize("mode", [RegionMode.FREE, RegionMode.UNIFORM])
    def test_search_segment_same_result(self, mode):
        g = get_cnn("alexnet")
        ref, fast = make_models(16)
        rr = search_segment(ref, g, 0, len(g), 16, mode=mode)
        rf = search_segment(fast, g, 0, len(g), 16, mode=mode)
        assert close(rr.latency, rf.latency)
        assert rr.clusters == rf.clusters

    def test_search_segment_ep_for_moe(self):
        cfg = get_smoke_config("granite-moe-1b-a400m")
        g = lm_graph(cfg, seq_len=256)
        ref, fast = make_models(16)
        rr = search_segment(ref, g, 0, len(g), 16, ep_for_moe=True)
        rf = search_segment(fast, g, 0, len(g), 16, ep_for_moe=True)
        assert close(rr.latency, rf.latency)
        assert rr.clusters == rf.clusters

    def test_full_dse_same_schedule(self):
        g = get_cnn("resnet18")
        ref, fast = make_models(64)
        sr = schedule_scope(g, ref, 64)
        sf = schedule_scope(g, fast, 64)
        assert close(sr.latency, sf.latency)
        assert [s.clusters for s in sr.segments] == [s.clusters for s in sf.segments]
        validate_schedule(g, sf, 64)

    def test_segmented_baseline_same_schedule(self):
        g = get_cnn("alexnet")
        ref, fast = make_models(16)
        sr = schedule_segmented(g, ref, 16)
        sf = schedule_segmented(g, fast, 16)
        assert close(sr.latency, sf.latency)


class TestMemoSoundness:
    def test_memoized_matches_fresh(self):
        """The same model instance answers identically before/after warmup."""
        g = get_cnn("resnet18")
        _, fast = make_models(32)
        cfgs = list(random_segment_configs(g, 32, 40, seed=5))
        first = [evaluate_segment(fast, g, 0, c, p, r)[0] for c, p, r in cfgs]
        second = [evaluate_segment(fast, g, 0, c, p, r)[0] for c, p, r in cfgs]
        assert first == second
        fresh = FastCostModel(mcm_table_iii(32), m_samples=16)
        third = [evaluate_segment(fresh, g, 0, c, p, r)[0] for c, p, r in cfgs]
        assert first == third

    @given(
        flops=st.lists(st.floats(min_value=1e6, max_value=1e12), min_size=2, max_size=12),
        chips=st.integers(min_value=2, max_value=32),
        split=st.integers(min_value=1, max_value=11),
        trans=st.integers(min_value=0, max_value=12),
    )
    @settings(max_examples=60, deadline=None)
    def test_property_parity_synthetic(self, flops, chips, split, trans):
        """Memoized fast evaluations == fresh reference, any synthetic graph."""
        L = len(flops)
        layers = [
            LayerNode(
                name=f"l{i}", kind="conv", flops=float(f),
                weight_bytes=64e3 * (1 + i % 3), in_bytes=32e3, out_bytes=32e3,
                halo_bytes=512.0, wsp_parallel=28.0 + i, isp_parallel=128.0,
            )
            for i, f in enumerate(flops)
        ]
        g = chain("synthetic", layers)
        cut = min(split, L - 1) if L > 1 else 0
        clustering = ((0, L),) if cut == 0 else ((0, cut), (cut, L))
        n_cl = len(clustering)
        if n_cl > chips:
            return
        regions = [chips // n_cl] * n_cl
        regions[0] += chips - sum(regions)
        t = min(trans, L)
        partitions = tuple(["WSP"] * t + ["ISP"] * (L - t))
        ref, fast = make_models(chips)
        lr, tr = evaluate_segment(ref, g, 0, clustering, partitions, regions)
        # evaluate twice: cold then memoized
        lf1, _ = evaluate_segment(fast, g, 0, clustering, partitions, regions)
        lf2, tf = evaluate_segment(fast, g, 0, clustering, partitions, regions)
        assert lf1 == lf2
        assert close(lr, lf1)
        for a, b in zip(tr, tf):
            assert close(a, b)
