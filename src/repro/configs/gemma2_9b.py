"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000 -- local/global alternating attention (window 4096) and logit
softcapping (50 attn / 30 final) [arXiv:2408.00118; hf].

The alternating pattern makes per-layer cost heterogeneous -- a natural
showcase for Scope's cluster merging (DESIGN.md SS5).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_head=256,
    d_ff=14336,
    vocab=256000,
    block_pattern=("local", "attn"),
    window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    tie_embeddings=True,
    ffn_gated=True,
    rope_theta=10_000.0,
)
