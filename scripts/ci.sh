#!/usr/bin/env bash
# CI entry point: tier-1 tests (minus slow markers) + DSE perf smoke budget.
#
#   ./scripts/ci.sh            # full run
#   CI_SKIP_PERF=1 ./scripts/ci.sh   # tests only
#
# The perf smoke asserts a full Scope DSE on resnet50 x 64 finishes under
# CI_DSE_BUDGET_S seconds (default 10; the fast engine needs ~0.5s, the
# pre-PR seed needed ~1.7s and the reference engine ~7s) so an evaluation-
# engine regression fails loudly instead of silently re-inflating every
# benchmark.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests (-m 'not slow') =="
python -m pytest -q -m "not slow"

if [ "${CI_SKIP_PERF:-0}" != "1" ]; then
  echo "== multi-model co-scheduling smoke budget =="
  python - <<'PY'
import os
import time

from repro.core.fastcost import FastCostModel
from repro.core.hw import mcm_table_iii
from repro.multimodel import co_schedule, equal_split, parse_mix, time_multiplexed

budget = float(os.environ.get("CI_MULTIMODEL_BUDGET_S", "20"))
specs = parse_mix("alexnet:1,resnet18:1")
hw = mcm_table_iii(16)
cost = FastCostModel(hw, m_samples=16)
t0 = time.time()
co = co_schedule(specs, hw, m_samples=16, cost=cost)
dt = time.time() - t0
eq = equal_split(specs, cost)
tm = time_multiplexed(specs, cost)
stats = cost.stats
assert None not in (co, eq, tm), "co-schedule/baseline infeasible"
print(f"2-model x 16 co-schedule: {dt:.2f}s (budget {budget:.0f}s), "
      f"mode={co.mode}, weighted tp {co.weighted_throughput:.0f}/s "
      f"(equal-split {eq.weighted_throughput:.0f}, "
      f"time-mux {tm.weighted_throughput:.0f}), engine {stats}")
assert co.weighted_throughput > 0, "co-schedule infeasible"
assert co.weighted_throughput >= eq.weighted_throughput - 1e-9, "below equal-split"
assert co.weighted_throughput >= tm.weighted_throughput - 1e-9, "below time-mux"
# memo reuse across quota candidates: the joint sweep must answer far more
# segment evaluations than it computes cluster costs for
assert stats["segment_evals"] > 3 * stats["cluster_computes"], stats
assert dt <= budget, f"multi-model DSE regression: {dt:.2f}s > {budget:.0f}s"

# full 2-model x 64 mix (the acceptance-scale sweep; exhaustive quota grid)
budget64 = float(os.environ.get("CI_MULTIMODEL64_BUDGET_S", "60"))
specs64 = parse_mix("resnet50:1,resnet18:1")
hw64 = mcm_table_iii(64)
cost64 = FastCostModel(hw64, m_samples=16)
t0 = time.time()
co64 = co_schedule(specs64, hw64, m_samples=16, cost=cost64)
dt64 = time.time() - t0
s64 = cost64.stats
print(f"2-model x 64 co-schedule: {dt64:.2f}s (budget {budget64:.0f}s), "
      f"mode={co64.mode}, weighted tp {co64.weighted_throughput:.0f}/s, "
      f"engine {s64}")
assert co64.weighted_throughput > 0
assert s64["segment_evals"] > 3 * s64["cluster_computes"], s64
assert dt64 <= budget64, f"x64 multi-model DSE: {dt64:.2f}s > {budget64:.0f}s"
PY

  echo "== mixed-flavor DSE smoke budget =="
  python - <<'PY'
import os
import time

from repro.core.costmodel import CostModel
from repro.core.fastcost import FastCostModel
from repro.core.hw import mcm_hetero
from repro.core.search import search, search_mixed
from repro.core.workloads import get_cnn

budget = float(os.environ.get("CI_MIXED_BUDGET_S", "30"))
g = get_cnn("resnet50")
hw = mcm_hetero(64)
cost = FastCostModel(hw, m_samples=16)
t0 = time.time()
singles = {
    t.name: search(g, cost, t.chips, chip_type=t.name)
    for t in hw.region_types
}
mixed = search_mixed(g, cost)
dt = time.time() - t0
assert mixed is not None and mixed.latency < float("inf"), "mixed DSE infeasible"
finite = [s.latency for s in singles.values() if s is not None]
assert finite, "both single-flavor searches infeasible"
best_single = min(finite)
flavors = sorted({cl.chip_type for seg in mixed.segments for cl in seg.clusters})
print(f"resnet50 x {hw.name} mixed DSE: {dt:.2f}s (budget {budget:.0f}s), "
      f"mixed latency {mixed.latency:.6g} vs best single-flavor "
      f"{best_single:.6g} ({best_single / mixed.latency:.2f}x), "
      f"flavors used {flavors}, stats {cost.stats}")
# the per-cluster flavor dimension strictly generalizes single-flavor search
assert mixed.latency <= best_single + 1e-12, "mixed lost to single-flavor"
# fast/reference parity on the mixed-flavor winner
ref = CostModel(hw, m_samples=16)
ref_lat = sum(ref.segment_time(g, seg.clusters)[0] for seg in mixed.segments)
assert abs(ref_lat - mixed.latency) <= 1e-9 * ref_lat, (
    f"mixed-flavor parity violated: ref {ref_lat} vs fast {mixed.latency}")
assert dt <= budget, f"mixed DSE regression: {dt:.2f}s > {budget:.0f}s"
PY

  echo "== DSE search-time smoke budget =="
  python - <<'PY'
import os
import time

from repro.core.fastcost import FastCostModel
from repro.core.baselines import schedule_scope
from repro.core.hw import mcm_table_iii
from repro.core.workloads import get_cnn

budget = float(os.environ.get("CI_DSE_BUDGET_S", "10"))
g = get_cnn("resnet50")
cost = FastCostModel(mcm_table_iii(64), m_samples=16)
t0 = time.time()
sched = schedule_scope(g, cost, 64)
dt = time.time() - t0
print(f"resnet50 x 64 full DSE: {dt:.2f}s (budget {budget:.0f}s), "
      f"latency {sched.latency:.6g}, stats {cost.stats}")
assert sched is not None and sched.latency < float("inf"), "DSE found no schedule"
assert dt <= budget, f"DSE perf regression: {dt:.2f}s > {budget:.0f}s budget"
PY
fi

echo "CI OK"
