"""Typed metrics registry: counters / gauges / histograms / time-weighted series.

One interface subsumes the ad-hoc stat dicts the repo grew (the engines'
``stats`` counters, the serving executor's queue traces): a
:class:`MetricsRegistry` hands out named instruments, and
:meth:`MetricsRegistry.snapshot` renders them back into one plain dict for
reports and benches.

Disabled-path contract: :data:`NULL_METRICS` is a no-op singleton whose
instruments are shared do-nothing objects -- code may call
``registry.counter("x").inc()`` unconditionally and pay only an attribute
lookup plus an empty method call when metrics are off
(``tests/test_obs.py`` micro-benches the bound).

:class:`TimeSeries` is the time-weighted step series used for queue depths:
``record(t, v)`` means the series holds value ``v`` from ``t`` until the
next record (and 0 before its first record), so ``mean`` / ``percentile``
integrate over the whole run exactly like the serving report's
time-weighted queue mean.
"""
from __future__ import annotations

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_METRICS",
    "NullRegistry",
    "TimeSeries",
]


class Counter:
    """Monotone (or snapshot-``set``) integer counter."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def set(self, v) -> None:
        """Absolute snapshot (engine stats are cumulative at the source)."""
        self.value = v


class Gauge:
    """Last-value-wins scalar."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v) -> None:
        self.value = v


class Histogram:
    """Exact-sample histogram with nearest-rank percentiles."""
    __slots__ = ("values",)

    def __init__(self):
        self.values: list[float] = []

    def observe(self, v: float) -> None:
        self.values.append(v)

    @property
    def count(self) -> int:
        return len(self.values)

    @property
    def total(self) -> float:
        return sum(self.values)

    def percentile(self, q: float) -> float:
        vals = sorted(self.values)
        if not vals:
            return 0.0
        k = max(1, int(-(-q * len(vals) // 100)))       # ceil without floats
        return vals[min(k, len(vals)) - 1]

    def snapshot(self) -> dict:
        vals = self.values
        return {
            "count": len(vals),
            "sum": sum(vals),
            "min": min(vals) if vals else 0.0,
            "max": max(vals) if vals else 0.0,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }


class TimeSeries:
    """Right-continuous step series ``[(t, value), ...]`` with time-weighted
    statistics over ``[0, t_end]`` (value 0 before the first record)."""
    __slots__ = ("points",)

    def __init__(self):
        self.points: list[tuple[float, float]] = []

    def record(self, t: float, v) -> None:
        pts = self.points
        if pts and pts[-1][0] == t:
            pts[-1] = (t, v)
        else:
            pts.append((t, v))

    def extend(self, pairs) -> None:
        for t, v in pairs:
            self.record(t, v)

    @property
    def max(self):
        """Peak recorded value (matches a step trace's recorded peak)."""
        return max((v for _, v in self.points), default=0)

    def _segments(self, t_end: float) -> list[tuple[float, float]]:
        """``(value, duration)`` pieces covering ``[0, t_end]``."""
        pts = self.points
        if not pts:
            return [(0.0, max(0.0, t_end))]
        segs: list[tuple[float, float]] = []
        first_t = pts[0][0]
        if first_t > 0:
            segs.append((0.0, min(first_t, t_end)))
        for (t, v), (t_next, _) in zip(pts, pts[1:] + [(t_end, None)]):
            if t >= t_end:
                break
            segs.append((v, max(0.0, min(t_next, t_end) - t)))
        return segs

    def mean(self, t_end: float) -> float:
        area = sum(v * d for v, d in self._segments(t_end))
        return area / max(1e-12, t_end)

    def percentile(self, q: float, t_end: float):
        """Time-weighted percentile: the smallest value whose cumulative
        holding time reaches ``q``% of ``t_end``."""
        segs = [(v, d) for v, d in self._segments(t_end) if d > 0]
        total = sum(d for _, d in segs)
        if total <= 0:
            return 0.0
        segs.sort(key=lambda s: s[0])
        need = (q / 100.0) * total
        acc = 0.0
        for v, d in segs:
            acc += d
            if acc >= need - 1e-12:
                return v
        return segs[-1][0]

    def stats(self, t_end: float) -> dict:
        return {
            "mean": self.mean(t_end),
            "max": self.max,
            "p95": self.percentile(95, t_end),
            "points": len(self.points),
        }


class MetricsRegistry:
    """Named instruments, created on first use."""
    enabled = True

    def __init__(self):
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, Histogram] = {}
        self.series: dict[str, TimeSeries] = {}

    def __bool__(self) -> bool:
        return True

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge()
        return g

    def histogram(self, name: str) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram()
        return h

    def timeseries(self, name: str) -> TimeSeries:
        s = self.series.get(name)
        if s is None:
            s = self.series[name] = TimeSeries()
        return s

    def update_counters(self, mapping: dict, prefix: str = "") -> None:
        """Snapshot a plain counter dict (e.g. an engine's ``stats``)."""
        for k, v in mapping.items():
            if isinstance(v, (int, float)):
                self.counter(prefix + k).set(v)

    def snapshot(self, t_end: float | None = None) -> dict:
        out: dict = {}
        if self.counters:
            out["counters"] = {k: c.value for k, c in sorted(self.counters.items())}
        if self.gauges:
            out["gauges"] = {k: g.value for k, g in sorted(self.gauges.items())}
        if self.histograms:
            out["histograms"] = {
                k: h.snapshot() for k, h in sorted(self.histograms.items())
            }
        if self.series:
            end = t_end if t_end is not None else max(
                (pts.points[-1][0] for pts in self.series.values() if pts.points),
                default=0.0,
            )
            out["series"] = {
                k: s.stats(end) for k, s in sorted(self.series.items())
            }
        return out


# ---------------------------------------------------------------------------
# Disabled path: shared no-op instruments
# ---------------------------------------------------------------------------

class _NullCounter:
    __slots__ = ()
    value = 0

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, v) -> None:
        pass


class _NullGauge:
    __slots__ = ()
    value = 0.0

    def set(self, v) -> None:
        pass


class _NullHistogram:
    __slots__ = ()
    values: list = []
    count = 0
    total = 0.0

    def observe(self, v) -> None:
        pass

    def percentile(self, q) -> float:
        return 0.0

    def snapshot(self) -> dict:
        return {}


class _NullSeries:
    __slots__ = ()
    points: list = []
    max = 0

    def record(self, t, v) -> None:
        pass

    def extend(self, pairs) -> None:
        pass

    def mean(self, t_end) -> float:
        return 0.0

    def percentile(self, q, t_end) -> float:
        return 0.0

    def stats(self, t_end) -> dict:
        return {}


_NULL_COUNTER = _NullCounter()
_NULL_GAUGE = _NullGauge()
_NULL_HISTOGRAM = _NullHistogram()
_NULL_SERIES = _NullSeries()


class NullRegistry:
    """Do-nothing registry: every accessor returns a shared no-op object."""
    enabled = False
    counters: dict = {}
    gauges: dict = {}
    histograms: dict = {}
    series: dict = {}

    def __bool__(self) -> bool:
        return False

    def counter(self, name: str) -> _NullCounter:
        return _NULL_COUNTER

    def gauge(self, name: str) -> _NullGauge:
        return _NULL_GAUGE

    def histogram(self, name: str) -> _NullHistogram:
        return _NULL_HISTOGRAM

    def timeseries(self, name: str) -> _NullSeries:
        return _NULL_SERIES

    def update_counters(self, mapping: dict, prefix: str = "") -> None:
        pass

    def snapshot(self, t_end=None) -> dict:
        return {}


NULL_METRICS = NullRegistry()
