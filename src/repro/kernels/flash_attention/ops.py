"""Jit'd public wrapper: picks the Pallas kernel or the jnp reference."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import flash_attention_kernel
from .ref import attention_ref


@partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k", "impl", "interpret"),
)
def flash_attention(
    q, k, v,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 128,
    block_k: int = 128,
    impl: str = "pallas",
    interpret: bool = False,
):
    """q [B,H,Sq,hd], k/v [B,KV,Skv,hd] -> [B,H,Sq,hd]."""
    if impl == "ref":
        return attention_ref(q, k, v, causal, window, softcap)
    return flash_attention_kernel(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
