"""Batched + memoized DSE evaluation engine (drop-in for :class:`CostModel`).

The reference :class:`~repro.core.costmodel.CostModel` walks Python objects
layer by layer for every candidate the DSE proposes.  Algorithm 1 proposes
millions of candidates for the paper's larger cases (resnet152 x 256), and
nearly all of them share cluster sub-problems with candidates evaluated
moments earlier: the transition-point sweep changes a few layers' partitions,
the CMT sweep re-splits the same layer ranges, and ``rebalance`` moves one
chip between two regions while every other region is untouched.

:class:`FastCostModel` exploits this twice over:

1. **Vectorized cluster evaluation.**  Per graph it precomputes NumPy arrays
   of ``flops``, ``weight_bytes``, ``in/out_bytes``, ``halo_bytes``,
   ``wsp/isp_parallel`` and expert counts (plus a weight-bytes prefix sum for
   segment load terms).  A cluster's computation time (Eq. 5), intra-region
   communication (Table II Case 1), and the greedy weight-placement plan
   (paper SSIII-B) are then array expressions over ``layers[lo:hi]`` instead
   of per-layer Python loops.  The array expressions replicate the reference
   model's arithmetic *operation by operation* so results agree to the last
   few ulps (the parity suite in ``tests/test_fastcost.py`` asserts 1e-9
   rtol; in practice values are almost always bit-identical).

2. **Cross-candidate memoization.**  The steady-state beat time of a cluster
   (Eq. 3 body) depends only on

   ``(graph, layer_lo, layer_hi, partitions, region_chips, chip_type,
      next_first_partition, next_chips, next_chip_type)``

   which is exactly the memo key.  Why this is sound: every term of the
   reference ``cluster_time`` reads only (a) the layer records in
   ``[layer_lo, layer_hi)`` -- fixed by the graph and the bounds, (b) the
   per-layer partition choices, the region size ``n`` and the region's chip
   flavor -- in the key, and (c) for the *last* layer's Table II Case 2
   hand-off, the next cluster's first-layer partition, region size and chip
   flavor (the hand-off crosses the flavor seam, whose bandwidth depends on
   both endpoints' flavors) -- also in the key.  Nothing else
   (segment membership, position within the segment, the allocation of other
   regions) enters the formula, so two candidates that agree on the key have
   equal cluster cost by construction.  The memo is shared across the
   transition-point sweep, the CMT sweep, the rebalance walk, the
   segment-count sweep, and the baselines, because they all funnel through
   :meth:`FastCostModel.cluster_time` / :meth:`segment_evaluator`.

The memo is also what makes ``rebalance`` *incremental*: moving one chip
from region ``f`` to region ``s`` changes the keys of clusters ``f`` and
``s`` (their ``region_chips``) and of their left boundary neighbors
``f-1`` / ``s-1`` (their ``next_chips``); ``_SegmentSweep.move`` re-probes
exactly those slots and every other cluster of the segment keeps its cached
time, so a rebalance step costs O(changed clusters), not O(all clusters).
``FastCostModel.stats`` (segment_evals / cluster_computes / memo sizes)
exposes this in benchmarks.
"""
from __future__ import annotations

import math
from bisect import bisect_left, bisect_right
from dataclasses import dataclass

import numpy as np

from .costmodel import INF, SAME_FLAVOR, CostModel, _flavor_tuple
from .graph import ClusterAssignment, LayerGraph
from .hw import eff

_WSP, _ISP, _EP = 0, 1, 2
_CODE = {"WSP": _WSP, "ISP": _ISP, "EP": _EP}
_PSTR = {_WSP: "WSP", _ISP: "ISP", _EP: "EP"}


@dataclass(frozen=True)
class _GraphData:
    """Per-graph NumPy precomputation (held alive for id() stability)."""
    graph: LayerGraph
    flops: np.ndarray
    weight_bytes: np.ndarray
    in_bytes: np.ndarray
    out_bytes: np.ndarray
    halo_bytes: np.ndarray
    wsp: np.ndarray
    isp: np.ndarray
    n_experts: np.ndarray
    active_experts: np.ndarray
    is_expert: np.ndarray          # n_experts > 1 (apply_ep's flip condition)
    expert_prefix: np.ndarray      # prefix sum of is_expert, len L+1
    wprefix: np.ndarray            # prefix sum of weight_bytes, len L+1
    dram_idx: tuple[int, ...]      # meta["dram_input"] layers (merged graphs)


def _graph_data(graph: LayerGraph) -> _GraphData:
    ls = graph.layers
    arr = lambda f: np.array([f(l) for l in ls], dtype=np.float64)
    w = arr(lambda l: l.weight_bytes)
    nexp = arr(lambda l: float(l.n_experts))
    is_expert = nexp > 1
    return _GraphData(
        graph=graph,
        flops=arr(lambda l: l.flops),
        weight_bytes=w,
        in_bytes=arr(lambda l: l.in_bytes),
        out_bytes=arr(lambda l: l.out_bytes),
        halo_bytes=arr(lambda l: l.halo_bytes),
        wsp=arr(lambda l: l.wsp_parallel),
        isp=arr(lambda l: l.isp_parallel),
        n_experts=nexp,
        active_experts=arr(lambda l: float(l.active_experts)),
        is_expert=is_expert,
        expert_prefix=np.concatenate(([0], np.cumsum(is_expert))),
        wprefix=np.concatenate(([0.0], np.cumsum(w))),
        dram_idx=tuple(
            i for i, l in enumerate(ls) if l.meta.get("dram_input")
        ),
    )


def _veff(dim: np.ndarray, granule: int) -> np.ndarray:
    """Vectorized :func:`repro.core.hw.eff` (same expression order).

    ``np.maximum(tiles, 1.0)`` only guards the ``dim <= 0`` lanes (whose
    result is overwritten with 1e-9 anyway); for dim > 0, tiles >= 1 and the
    quotient is bit-identical to the scalar ``eff``.
    """
    tiles = np.ceil(dim / granule)
    e = dim / (granule * np.maximum(tiles, 1.0))
    return np.where(dim <= 0, 1e-9, e)


def _seqsum(a) -> float:
    """Left-to-right Python summation, matching the reference model's ``sum``/
    ``+=`` accumulation bit-for-bit (NumPy's pairwise sum would not)."""
    return sum(a.tolist(), 0.0)


_STATIC = None      # sentinel key holding a cell's _ClusterStatic
_BODY = "body"      # sentinel key holding a cell's per-n body cache
_INF_BODY = (INF,)  # marker: placement infeasible at this n
# Below this cluster size a tight scalar loop beats NumPy dispatch overhead;
# the scalar path reuses the reference model's exact scalar arithmetic.
_SCALAR_MAX_LAYERS = 32
# Below this cluster size the 2D (k x layer) seed-phase batch fill is not
# worth its NumPy dispatch either; the lazy per-k paths handle it.
_BATCH_MIN_LAYERS = 8
# Region-size window (+- chips around the seed) pre-filled per slot by
# prefill_seed: covers the one-chip-at-a-time rebalance walk's body misses.
_PREFILL_N_WINDOW = 1
# Batched-first-rebalance-iteration group floor: a (bottleneck, donor) pair
# shared by fewer candidates than this runs the scalar walk instead -- the
# move-table costs span + 2 memo consults, so tiny groups would compute more
# speculative entries than their walks save.
_FIRST_MOVE_MIN_GROUP = 8
# engine="jit": below this (rows x layers) population size the XLA dispatch
# overhead loses to NumPy; above it the compiled fill kernel takes over.
_JIT_MIN_ELEMS = 2048


class _ClusterStatic:
    """Allocation-independent precomputation for one (lo, hi, partitions).

    Everything here depends only on the memo cell's identity, so it is built
    once and reused for every region size ``n`` the DSE probes against this
    cluster -- the per-``n`` cost below is a handful of array expressions.
    """

    __slots__ = (
        "lo", "hi", "last_layer", "last_p", "fl", "w", "wsp",
        "isp", "is_wsp", "is_isp", "is_ep", "any_ep", "m_base", "men",
        "flip_order", "flip_w", "out_i", "halo_i", "ep_edge", "ww_edge",
        "iw_edge", "rows", "codes_l", "flip_l", "w_l",
    )

    def __init__(self, gd: _GraphData, lo: int, hi: int, codes: np.ndarray):
        self.lo, self.hi = lo, hi
        self.last_layer = gd.graph.layers[hi - 1]
        self.last_p = _PSTR[int(codes[-1])]
        self.fl = gd.flops[lo:hi]
        self.w = gd.weight_bytes[lo:hi]
        self.wsp = gd.wsp[lo:hi]
        self.isp = gd.isp[lo:hi]
        is_wsp, is_isp, is_ep = codes == _WSP, codes == _ISP, codes == _EP
        self.is_wsp, self.is_isp, self.is_ep = is_wsp, is_isp, is_ep
        self.any_ep = bool(is_ep.any())
        # EP activation dim is n-independent (Eq. 5 EP branch); others get
        # the plain wsp dim here and are divided by n per allocation.
        self.m_base = np.where(
            is_ep,
            self.wsp * (gd.active_experts[lo:hi] / np.maximum(1.0, gd.n_experts[lo:hi])),
            self.wsp,
        )
        self.men = np.maximum(1.0, gd.n_experts[lo:hi])
        # Distributed-weight flip order: replicated WSP layers, largest
        # first; stable sort matches the reference ``sorted(key=-w)``.
        wsp_idx = np.nonzero(is_wsp)[0]
        self.flip_order = wsp_idx[np.argsort(-self.w[wsp_idx], kind="stable")]
        self.flip_w = self.w[self.flip_order]
        # Table II Case 1 edge classification for intra-cluster hand-offs.
        if hi - lo > 1:
            p, q = codes[:-1], codes[1:]
            self.out_i = gd.out_bytes[lo : hi - 1]
            self.halo_i = gd.halo_bytes[lo : hi - 1]
            self.ep_edge = (p == _EP) | (q == _EP)
            self.ww_edge = (p == _WSP) & (q == _WSP)
            self.iw_edge = (p == _ISP) & (q == _WSP)
        else:
            self.out_i = self.halo_i = self.ep_edge = self.ww_edge = self.iw_edge = None
        # Scalar fast path (small clusters): per-layer tuples in plain
        # Python floats, so a body evaluation is one tight loop with the
        # reference model's exact arithmetic and no NumPy dispatch overhead.
        if hi - lo <= _SCALAR_MAX_LAYERS:
            self.codes_l = codes.tolist()
            self.w_l = self.w.tolist()
            self.rows = list(zip(
                self.fl.tolist(), self.w_l, self.wsp.tolist(),
                self.isp.tolist(), self.codes_l, gd.out_bytes[lo:hi].tolist(),
                gd.halo_bytes[lo:hi].tolist(), self.m_base.tolist(),
                self.men.tolist(),
            ))
            self.flip_l = self.flip_order.tolist()
        else:
            self.rows = None
            self.codes_l = self.flip_l = self.w_l = None


class FastCostModel(CostModel):
    """CostModel-compatible engine with vectorized + memoized evaluation.

    Exact-parity contract: for any (graph, schedule) the reference model can
    evaluate, ``cluster_time`` / ``segment_time`` / ``system_time`` return
    the same values within 1e-9 rtol, and the DSE driven through
    :meth:`segment_evaluator` picks the same argmin schedules.
    """

    def __init__(self, *args, use_jit: bool = False, **kwargs):
        super().__init__(*args, **kwargs)
        self._graphs: dict[int, _GraphData] = {}
        # Two-level memo: (graph, lo, hi, partitions) -> {(n, next_p0,
        # next_n) -> time}.  The outer lookup (hashing the partition tuple)
        # happens once per candidate; the per-allocation probes in the
        # rebalance inner loop only hash small int tuples.
        self._memo: dict[tuple, dict] = {}
        self._codes_cache: dict[tuple[str, ...], np.ndarray] = {}
        # _evals/_misses/_probes/_batched_bodies/_batch_evals/_batch_rows
        # inherited from CostModel
        self.batched_seed_fill = True   # 2D (k x layer) seed-phase fill
        # Batched transition sweep: _SegmentSweep.sweep_transitions scores
        # every (transition index, ep) candidate of a clustering as one
        # gather over per-slot value tables instead of an incremental walk.
        self.batched_sweep = True
        # engine="jit": route large (rows x layer) body-fill matrix programs
        # through jax.jit (rtol parity, opt-in; see core/jit_batch.py).
        self.use_jit = bool(use_jit)
        self._jit = None               # resolved lazily on first large fill

    # ------------------------------------------------------------- plumbing
    def graph_data(self, graph: LayerGraph) -> _GraphData:
        gd = self._graphs.get(id(graph))
        if gd is None or gd.graph is not graph:
            gd = _graph_data(graph)
            self._graphs[id(graph)] = gd
        return gd

    def clear_memo(self) -> None:
        self._graphs.clear()
        self._memo.clear()
        self._evals = self._misses = self._probes = self._batched_bodies = 0
        self._batch_evals = self._batch_rows = 0

    @property
    def stats(self) -> dict:
        """Counters proving the memo/incrementality claims in benchmarks.

        Same schema as the reference :class:`CostModel.stats`;
        ``memo_hits = cluster_probes - cluster_computes`` is what the
        cross-candidate memo saved, and ``batch_evals``/``batch_rows`` count
        batched population calls (sweep_transitions / cluster_population)
        and the candidate rows they scored.
        """
        return {
            "segment_evals": self._evals,
            "cluster_computes": self._misses,
            "cluster_probes": self._probes,
            "memo_hits": self._probes - self._misses,
            "memo_cells": len(self._memo),
            "memo_entries": sum(len(c) - 2 for c in self._memo.values()),
            "batched_bodies": self._batched_bodies,
            "batch_evals": self._batch_evals,
            "batch_rows": self._batch_rows,
        }

    def _cluster_cell(
        self, gd: _GraphData, lo: int, hi: int, partitions: tuple[str, ...],
        ctype: str | None = None,
    ) -> dict:
        """Memo cell for an explicit partition tuple (generic API path)."""
        key = (id(gd.graph), lo, hi, partitions, ctype)
        cell = self._memo.get(key)
        if cell is None:
            cell = self._memo[key] = {
                _STATIC: _ClusterStatic(gd, lo, hi, self._codes(partitions)),
                _BODY: {},
            }
        return cell

    def _cluster_cell_hint(
        self, gd: _GraphData, lo: int, hi: int, k: int, ep: bool,
        ctype: str | None = None,
    ) -> dict:
        """Memo cell for a WSP^k ISP^(len-k) transition slice (DSE path).

        Algorithm 1's partition dimension only ever produces transition
        slices (optionally with MoE layers flipped to EP), so the DSE keys
        cells by the small ``(lo, hi, k, ep)`` tuple instead of hashing a
        partition tuple per probe -- and slices that coincide across
        different segment-level transition points share one cell.  ``ctype``
        (the hetero chip flavor) completes the key: cached times are only
        valid for the flavor whose scaled hardware computed them, so flavors
        never share cells (asserted in tests/test_multimodel.py).
        """
        key = (id(gd.graph), lo, hi, k, ep, ctype)
        cell = self._memo.get(key)
        if cell is None:
            codes = np.full(hi - lo, _ISP, dtype=np.int8)
            codes[:k] = _WSP
            if ep:
                codes[gd.is_expert[lo:hi]] = _EP
            cell = self._memo[key] = {
                _STATIC: _ClusterStatic(gd, lo, hi, codes),
                _BODY: {},
            }
        return cell

    def _codes(self, partitions: tuple[str, ...]) -> np.ndarray:
        c = self._codes_cache.get(partitions)
        if c is None:
            c = np.array([_CODE[p] for p in partitions], dtype=np.int8)
            self._codes_cache[partitions] = c
        return c

    # ------------------------------------------------- vectorized evaluation
    def _cluster_cost(self, st: _ClusterStatic, n: int,
                      next_p0: str | None, next_n: int | None,
                      body_cache: dict | None = None,
                      ctype: str | None = None,
                      next_ctype: str | None = SAME_FLAVOR) -> float:
        """Vectorized reference ``cluster_time`` for one memoized static.

        The last layer's Table II Case 2 boundary term is the only part that
        depends on the *next* cluster (its first partition, region size, and
        -- across a flavor seam -- its chip flavor), so the expensive array
        work -- the ``body`` -- is keyed by ``n`` alone in ``body_cache``
        and the final assembly is three scalar operations.  During
        rebalance, a donor's left neighbor changes only ``next_n``: its
        re-evaluation is a body cache hit plus scalar math, no NumPy at all.
        """
        body = body_cache.get(n) if body_cache is not None else None
        if body is None:
            body = self._cluster_body(st, n, self.hw_for(ctype))
            if body_cache is not None:
                body_cache[n] = body
        if body is _INF_BODY:
            return INF
        head, pre_last, comp_last = body
        comm_last = self.comm_time(
            st.last_layer, st.last_p, n, next_p0, next_n, False, ctype,
            next_ctype,
        )
        if self.overlap:
            t_last = pre_last + (comm_last if comm_last >= comp_last else comp_last)
        else:
            t_last = (pre_last + comm_last) + comp_last
        return head + t_last

    def _cluster_body(self, st: _ClusterStatic, n: int, hw=None):
        """Per-(cluster, n) array work: placement + Eq. 5/7 for all layers,
        minus the last layer's next-dependent comm.  Returns ``(head_sum,
        pre_last, comp_last)`` or ``_INF_BODY`` when weights don't fit.
        ``hw`` is the (possibly chip-type-scaled) hardware of the region."""
        if hw is None:
            hw = self.hw
        if st.rows is not None:
            return self._cluster_body_scalar(st, n, hw)
        w = st.w
        # --- greedy weight placement (reference place_weights, SSIII-B)
        if st.any_ep:
            div = np.where(st.is_ep, np.minimum(float(n), st.men), float(n))
            resident = np.where(st.is_wsp, w, w / div)
        else:
            resident = np.where(st.is_wsp, w, w / n)
        cap = hw.weight_capacity_per_chip
        s = _seqsum(resident)
        gather = None
        transient = 0.0
        if self.distributed_weights and s > cap and len(st.flip_order):
            # Reference semantics: flip the largest replicated WSP layers to
            # distributed storage one at a time while the (sequentially
            # re-summed) residency exceeds capacity.  Guess the flip count
            # from a running delta, then verify with the reference's exact
            # left-to-right sums so the boundary decision is bit-identical.
            def exact_after(t: int) -> float:
                r = resident.copy()
                idx = st.flip_order[:t]
                r[idx] = w[idx] / n
                return _seqsum(r)

            deltas = st.flip_w - st.flip_w / n      # residency drop per flip
            run = s - np.cumsum(deltas)
            t = int(np.searchsorted(-run, -cap))    # first t with run[t-1] <= cap
            t = min(t + 1, len(st.flip_order))
            while t > 0 and exact_after(t - 1) <= cap:
                t -= 1
            while t < len(st.flip_order) and exact_after(t) > cap:
                t += 1
            flips = st.flip_order[:t]
            resident[flips] = w[flips] / n
            gather = np.zeros_like(w)
            gather[flips] = w[flips] * (n - 1) / n
            s = _seqsum(resident)
            transient = max(
                ((2.0 * w[k]) / n for k in np.nonzero(gather > 0)[0]),
                default=0.0,
            )
        if (s + transient) > cap:
            return _INF_BODY

        # --- Eq. 5 computation (vectorized CostModel._util / comp_time)
        m_local = np.where(st.is_wsp, st.wsp / n, st.m_base)
        n_local = np.where(st.is_isp, st.isp / n, st.isp)
        util = _veff(m_local, hw.m_granule) * _veff(n_local, hw.n_granule)
        comp = st.fl / ((n * hw.flops_per_chip) * util)

        # --- Table II Case 1 comm for intra-cluster hand-offs (vectorized)
        pre = None
        if gather is not None:
            pre = gather / hw.nop_bw_per_chip
        if self.literal_pre:
            lit = w / hw.dram_bw_total
            pre = lit if pre is None else pre + lit
        if st.out_i is not None:
            vo = (n - 1) * st.out_i
            ha = st.halo_i * max(0, n - 1)
            vol = np.where(
                st.ep_edge, 2.0 * st.out_i,
                np.where(st.ww_edge, ha, np.where(st.iw_edge, vo + ha, vo)),
            )
            comm_i = np.where(vol <= 0, 0.0, vol / (n * hw.nop_bw_per_chip))
            # Eq. 7 per layer for layers [0, L-1), summed in reference order
            if self.overlap:
                head_arr = np.maximum(comm_i, comp[:-1])
            else:
                head_arr = comm_i + comp[:-1]
            if pre is not None:
                head_arr = (
                    pre[:-1] + head_arr if self.overlap
                    else (pre[:-1] + comm_i) + comp[:-1]
                )
            head = _seqsum(head_arr)
        else:
            head = 0.0
        pre_last = float(pre[-1]) if pre is not None else 0.0
        comp_last = float(comp[-1])
        return (head, pre_last, comp_last)

    def _cluster_body_scalar(self, st: _ClusterStatic, n: int, hw=None):
        """Small-cluster body: one tight loop of the reference model's exact
        scalar arithmetic (no NumPy dispatch), bit-identical by construction."""
        if hw is None:
            hw = self.hw
        cap = hw.weight_capacity_per_chip
        rows = st.rows
        L = len(rows)
        # --- greedy weight placement (reference place_weights, SSIII-B)
        resident = []
        append = resident.append
        for fl, w, wsp, isp, code, out, halo, m_base, men in rows:
            if code == _WSP:
                append(w)
            elif code == _EP:
                append(w / min(n, men))
            else:
                append(w / n)
        s = sum(resident)
        gather = None
        transient = 0.0
        if self.distributed_weights and s > cap and st.flip_l:
            gather = [0.0] * L
            w_l = st.w_l
            for k in st.flip_l:
                if s <= cap:
                    break
                wk = w_l[k]
                resident[k] = wk / n
                gather[k] = wk * (n - 1) / n
                s = sum(resident)
            transient = max(
                (2.0 * w_l[k] / n for k in range(L) if gather[k] > 0),
                default=0.0,
            )
        if (s + transient) > cap:
            return _INF_BODY
        # --- Eq. 5 / Table II Case 1 / Eq. 7 per layer (reference order)
        mg, ng = hw.m_granule, hw.n_granule
        peak, nop = hw.flops_per_chip, hw.nop_bw_per_chip
        dram = hw.dram_bw_total
        literal, overlap = self.literal_pre, self.overlap
        head = 0.0
        pre_last = comp_last = 0.0
        nm1 = n - 1
        last = L - 1
        for i, (fl, w, wsp, isp, code, out, halo, m_base, men) in enumerate(rows):
            if code == _WSP:
                m_l, n_l = wsp / n, isp
            elif code == _ISP:
                m_l, n_l = wsp, isp / n
            else:
                m_l, n_l = m_base, isp
            util = eff(m_l, mg) * eff(n_l, ng)
            comp = fl / (n * peak * util)
            pre = 0.0
            if literal:
                pre += w / dram
            if gather is not None and gather[i] > 0:
                pre += gather[i] / nop
            if i == last:
                pre_last, comp_last = pre, comp
                break
            ncode = rows[i + 1][4]
            if code == _EP or ncode == _EP:
                vol = 2.0 * out
            elif code == _WSP:
                vol = halo * nm1 if ncode == _WSP else nm1 * out
            elif ncode == _WSP:
                vol = nm1 * out + halo * nm1
            else:
                vol = nm1 * out
            comm = 0.0 if vol <= 0 else vol / (n * nop)
            if overlap:
                head += pre + (comm if comm >= comp else comp)
            else:
                head += pre + comm + comp
        return (head, pre_last, comp_last)

    # ------------------------------------------------- 2D seed-phase fill
    def _batch_seed_fill(self, gd: _GraphData, lo: int, hi: int, ns,
                         ctype: str | None = None,
                         eager_ns=None) -> None:
        """Batched (row x layer) bodies for the transition slices of one span.

        Algorithm 1's seed phase probes the same cluster span at the same
        region size ``n`` under every transition index ``k`` (WSP for the
        first ``k`` layers, ISP for the rest).  Filling those ``L + 1``
        bodies one row at a time repeats the identical array setup per row;
        this computes them as one matrix pass over ``(k, n)`` rows and
        writes the results into the per-k memo cells the sweep will probe.
        ``ns`` is one region size or a sequence of them (the mixed-flavor
        run-cut enumeration re-seeds the same spans at several sizes; those
        fills share this one pass too).  ``eager_ns`` restricts which sizes'
        over-capacity rows are worth the scalar greedy-flip fallback here:
        speculative window sizes (prefill_seed's +- window) are left for the
        lazy path to fill only if a probe actually lands on them.

        Exactness: every elementwise expression mirrors ``_cluster_body``
        operation by operation, and row reductions use ``np.cumsum`` (a
        strictly left-to-right accumulation, like ``_seqsum`` and the scalar
        path's ``+=``), so the stored bodies are bit-identical to what the
        lazy per-k evaluation would produce.  Rows whose weight placement
        overflows capacity (they need the greedy distributed-weight flip
        walk, or are infeasible) fall back to the per-k path, as do EP
        variants (never batched).  With ``use_jit`` the matrix pass runs
        under jax.jit instead (rtol parity; see core/jit_batch.py).
        """
        L = hi - lo
        hw = self.hw_for(ctype)
        cells = [
            self._cluster_cell_hint(gd, lo, hi, k, False, ctype)
            for k in range(L + 1)
        ]
        if isinstance(ns, int):
            ns = (ns,)
        need = [
            (k, n) for n in ns for k in range(L + 1)
            if n not in cells[k][_BODY]
        ]
        if not need:
            return
        w = gd.weight_bytes[lo:hi]
        fl = gd.flops[lo:hi]
        wsp = gd.wsp[lo:hi]
        isp = gd.isp[lo:hi]
        ks = np.array([k for k, _ in need], dtype=np.int64)
        nr = np.array([n for _, n in need], dtype=np.int64)[:, None]
        lidx = np.arange(L)

        jit = self._jit_backend() if L > 1 else None
        if jit is not None and len(need) * L >= _JIT_MIN_ELEMS:
            lit = (w / hw.dram_bw_total) if self.literal_pre else None
            s, head, comp_last = jit.slice_bodies(
                w, fl, wsp, isp,
                gd.out_bytes[lo : hi - 1], gd.halo_bytes[lo : hi - 1],
                lit, ks, nr[:, 0], hw,
                self.overlap, self.literal_pre,
            )
            cap = hw.weight_capacity_per_chip
            over = s > cap
        else:
            jit = None
            is_wsp = lidx[None, :] < ks[:, None]                # rows x L

            # --- residency (replicated WSP / sharded ISP), row-wise sums
            resident = np.where(is_wsp, w, w / nr)
            s = np.cumsum(resident, axis=1)[:, -1]
            cap = hw.weight_capacity_per_chip
            over = s > cap
        if over.any():
            # These rows need the greedy flip walk (or are INF): per-k path.
            for row in np.nonzero(over)[0]:
                k, n = need[row]
                if eager_ns is not None and n not in eager_ns:
                    continue
                cell = cells[k]
                cell[_BODY][n] = self._cluster_body(cell[_STATIC], n, hw)
        good = np.nonzero(~over)[0]
        if not len(good):
            return
        ks_g = ks[good]
        nr_g = nr[good]

        if jit is None:
            is_wsp = is_wsp[good]
            # --- Eq. 5 computation (rows of _cluster_body's vectorized path)
            m_local = np.where(is_wsp, wsp / nr_g, wsp)
            n_local = np.where(is_wsp, isp, isp / nr_g)
            util = _veff(m_local, hw.m_granule) * _veff(n_local, hw.n_granule)
            comp = fl / ((nr_g * hw.flops_per_chip) * util)

            lit = (w / hw.dram_bw_total) if self.literal_pre else None
            if L > 1:
                # Transition-slice edge (l, l+1): WSP->WSP iff l <= k-2,
                # WSP->ISP iff l == k-1, ISP->ISP otherwise (ISP->WSP and EP
                # edges cannot occur in a WSP^k ISP^(L-k) row).
                out_i = gd.out_bytes[lo : hi - 1]
                halo_i = gd.halo_bytes[lo : hi - 1]
                vo = (nr_g - 1) * out_i
                ha = halo_i * np.maximum(0, nr_g - 1)
                ww = lidx[None, : L - 1] <= (ks_g[:, None] - 2)
                vol = np.where(ww, ha, vo)
                comm_i = np.where(vol <= 0, 0.0, vol / (nr_g * hw.nop_bw_per_chip))
                comph = comp[:, :-1]
                if self.overlap:
                    head_arr = np.maximum(comm_i, comph)
                else:
                    head_arr = comm_i + comph
                if lit is not None:
                    head_arr = (
                        lit[None, :-1] + head_arr if self.overlap
                        else (lit[None, :-1] + comm_i) + comph
                    )
                head = np.cumsum(head_arr, axis=1)[:, -1]
            else:
                head = np.zeros(len(good))
            comp_last = comp[:, -1]
        else:
            head = head[good]
            comp_last = comp_last[good]
        pre_last = float(lit[-1]) if lit is not None else 0.0
        for row, g in enumerate(good.tolist()):
            k, n = need[g]
            cells[k][_BODY][n] = (
                float(head[row]), pre_last, float(comp_last[row])
            )
        self._batched_bodies += len(good)

    def prefill_spans(self, graph: LayerGraph, spans) -> None:
        """Batch-fill transition-slice bodies for many spans in one go.

        ``spans`` is an iterable of ``(lo, hi, ns, ctype)`` with global layer
        bounds and one-or-more region sizes per span.  The mixed-flavor
        run-cut enumeration uses this to score a whole flavor assignment's
        cut candidates as one population: every cut re-seeds the same
        cluster spans at different sizes, and this fills all those bodies
        as one matrix pass per span before the per-cut sweeps probe them.
        """
        if not self.batched_seed_fill:
            return
        gd = self.graph_data(graph)
        for lo, hi, ns, ctype in spans:
            if hi - lo >= _BATCH_MIN_LAYERS:
                self._batch_seed_fill(gd, lo, hi, ns, ctype)

    def _jit_backend(self):
        """Resolve the jax.jit fill backend once (None when disabled or jax
        is unavailable -- the NumPy path is always a correct fallback)."""
        if not self.use_jit:
            return None
        if self._jit is None:
            from . import jit_batch
            self._jit = jit_batch if jit_batch.available() else False
        return self._jit or None

    # ---------------------------------------------------------- populations
    def _fill_bodies(self, cell: dict, ns, hw) -> None:
        """Fill a memo cell's bodies for several region sizes in one pass.

        The multi-``n`` analogue of the seed fill: one cluster static, a
        vector of region sizes (the population evaluator's grouped misses).
        Small clusters and EP statics keep the scalar/lazy paths (parity is
        trivially guaranteed there); large non-EP statics run the body as a
        ``(len(ns) x layers)`` matrix program mirroring ``_cluster_body``
        operation by operation, with over-capacity rows falling back to the
        exact greedy flip walk.
        """
        st = cell[_STATIC]
        body = cell[_BODY]
        ns = [n for n in ns if n not in body]
        if not ns:
            return
        if st.rows is not None or st.any_ep or len(ns) == 1:
            for n in ns:
                body[n] = self._cluster_body(st, n, hw)
            return
        nr = np.array(ns, dtype=np.int64)[:, None]              # R x 1
        w = st.w
        resident = np.where(st.is_wsp, w, w / nr)
        s = np.cumsum(resident, axis=1)[:, -1]
        cap = hw.weight_capacity_per_chip
        over = s > cap
        for row in np.nonzero(over)[0]:
            body[ns[row]] = self._cluster_body(st, ns[row], hw)
        good = np.nonzero(~over)[0]
        if not len(good):
            return
        nr = nr[good]
        m_local = np.where(st.is_wsp, st.wsp / nr, st.m_base)
        n_local = np.where(st.is_isp, st.isp / nr, st.isp)
        util = _veff(m_local, hw.m_granule) * _veff(n_local, hw.n_granule)
        comp = st.fl / ((nr * hw.flops_per_chip) * util)
        lit = (w / hw.dram_bw_total) if self.literal_pre else None
        if st.out_i is not None:
            vo = (nr - 1) * st.out_i
            ha = st.halo_i * np.maximum(0, nr - 1)
            vol = np.where(
                st.ep_edge, 2.0 * st.out_i,
                np.where(st.ww_edge, ha, np.where(st.iw_edge, vo + ha, vo)),
            )
            comm_i = np.where(vol <= 0, 0.0, vol / (nr * hw.nop_bw_per_chip))
            comph = comp[:, :-1]
            if self.overlap:
                head_arr = np.maximum(comm_i, comph)
            else:
                head_arr = comm_i + comph
            if lit is not None:
                head_arr = (
                    lit[None, :-1] + head_arr if self.overlap
                    else (lit[None, :-1] + comm_i) + comph
                )
            head = np.cumsum(head_arr, axis=1)[:, -1]
        else:
            head = np.zeros(len(good))
        pre_last = float(lit[-1]) if lit is not None else 0.0
        comp_last = comp[:, -1]
        for row, g in enumerate(good.tolist()):
            body[ns[g]] = (float(head[row]), pre_last, float(comp_last[row]))
        self._batched_bodies += len(good)

    def cluster_population(self, graph: LayerGraph, rows) -> np.ndarray:
        """Batched population evaluator (see :meth:`CostModel.cluster_population`
        for the row format).

        Memo semantics are unchanged: every row is consulted against the
        same two-level memo the scalar paths use and misses are written
        back, so a population call warms the cache for later scalar probes
        and vice versa.  What *is* batched is the body arithmetic: all
        missing bodies that share a cluster cell are filled as one
        ``(rows x layers)`` matrix program (:meth:`_fill_bodies`), and the
        per-row remainder is scalar memo assembly.
        """
        gd = self.graph_data(graph)
        out = np.empty(len(rows), dtype=np.float64)
        self._batch_evals += 1
        self._batch_rows += len(rows)
        resolved = []
        pending: dict[int, tuple[dict, str | None, set]] = {}
        for lo, hi, spec, n, next_p0, next_n, ctype, next_ctype in rows:
            if spec and isinstance(spec[0], str):
                cell = self._cluster_cell(gd, lo, hi, tuple(spec), ctype)
            else:
                k, ep = spec
                cell = self._cluster_cell_hint(gd, lo, hi, int(k), bool(ep), ctype)
            nct = ctype if next_ctype is SAME_FLAVOR else next_ctype
            resolved.append((cell, n, next_p0, next_n, ctype, nct))
            if n not in cell[_BODY]:
                ent = pending.get(id(cell))
                if ent is None:
                    pending[id(cell)] = (cell, ctype, {n})
                else:
                    ent[2].add(n)
        for cell, ctype, ns in pending.values():
            self._fill_bodies(cell, sorted(ns), self.hw_for(ctype))
        for i, (cell, n, next_p0, next_n, ctype, nct) in enumerate(resolved):
            self._probes += 1
            key = (n, next_p0, next_n, nct)
            t = cell.get(key)
            if t is None:
                self._misses += 1
                t = cell[key] = self._cluster_cost(
                    cell[_STATIC], n, next_p0, next_n, cell[_BODY], ctype, nct,
                )
            out[i] = t
        return out

    # -------------------------------------------------------------- memoized
    def _cluster_time_fast(
        self,
        gd: _GraphData,
        lo: int,
        hi: int,
        partitions: tuple[str, ...],
        n: int,
        next_p0: str | None,
        next_n: int | None,
        ctype: str | None = None,
        next_ctype: str | None = None,
    ) -> float:
        cell = self._cluster_cell(gd, lo, hi, partitions, ctype)
        # The entry key carries the *neighbor's* flavor too: the last
        # layer's boundary term crosses the seam, so a cached time is only
        # valid against a next cluster of the same flavor.
        self._probes += 1
        k = (n, next_p0, next_n, next_ctype)
        t = cell.get(k)
        if t is None:
            self._misses += 1
            t = cell[k] = self._cluster_cost(
                cell[_STATIC], n, next_p0, next_n, cell[_BODY], ctype,
                next_ctype,
            )
        return t

    # --------------------------------------------- CostModel-compatible API
    def cluster_time(
        self,
        graph: LayerGraph,
        cluster: ClusterAssignment,
        next_cluster: ClusterAssignment | None,
        first_in_segment: bool,
        last_in_segment: bool,
    ) -> float:
        next_p0 = next_cluster.partitions[0] if next_cluster is not None else None
        next_n = next_cluster.region_chips if next_cluster is not None else None
        next_ct = next_cluster.chip_type if next_cluster is not None else None
        return self._cluster_time_fast(
            self.graph_data(graph),
            cluster.layer_lo,
            cluster.layer_hi,
            cluster.partitions,
            cluster.region_chips,
            next_p0,
            next_n,
            cluster.chip_type,
            next_ct,
        )

    def segment_time(
        self, graph: LayerGraph, clusters: tuple[ClusterAssignment, ...]
    ) -> tuple[float, list[float]]:
        gd = self.graph_data(graph)
        times = []
        for j, cl in enumerate(clusters):
            nxt = clusters[j + 1] if j + 1 < len(clusters) else None
            next_p0 = nxt.partitions[0] if nxt is not None else None
            next_n = nxt.region_chips if nxt is not None else None
            next_ct = nxt.chip_type if nxt is not None else None
            times.append(
                self._cluster_time_fast(
                    gd, cl.layer_lo, cl.layer_hi, cl.partitions,
                    cl.region_chips, next_p0, next_n, cl.chip_type, next_ct,
                )
            )
        bottleneck = max(times)
        if bottleneck == INF:
            return INF, times
        load = 0.0
        if not self.literal_pre:
            seg_weights = sum(
                float(gd.wprefix[cl.layer_hi] - gd.wprefix[cl.layer_lo])
                for cl in clusters
            )
            load += seg_weights / self.hw.dram_bw_total
        first_lo = clusters[0].layer_lo
        load += self.m * graph.layers[first_lo].in_bytes / self.hw.dram_bw_total
        if gd.dram_idx:
            # Mid-segment DRAM-staged entry layers (merged model boundaries);
            # mirrors the reference segment_time loop in index order.
            for i in gd.dram_idx:
                if i != first_lo and any(
                    cl.layer_lo <= i < cl.layer_hi for cl in clusters
                ):
                    load += self.m * graph.layers[i].in_bytes / self.hw.dram_bw_total
        n_cl = len(clusters)
        return load + (self.m + n_cl - 1) * bottleneck, times

    # --------------------------------------------------------- DSE hot path
    def segment_sweeper(self, graph, seg_lo, clustering, chip_type=None):
        """Per-clustering factory for Algorithm 1's partition sweep.

        Returns ``sweeper(partitions, transition=None) -> eval_fn`` where
        ``eval_fn(alloc) -> (latency, times)`` and ``eval_fn.move`` is the
        incremental rebalance path.  The allocation-independent precomputation
        (layer spans, Eq. 2 load terms, per-slot memo cells) lives in one
        reusable :class:`_SegmentSweep`; advancing the transition index by one
        only touches the single cluster whose partition slice changed.
        ``sweeper.prefill(seed)`` batch-fills the seed-phase bodies (2D
        ``k x layer`` vectorization) for every transition slice at once.
        ``chip_type`` is one flavor name (whole segment) or a per-cluster
        flavor sequence (mixed pipeline, seam-aware boundary terms).
        """
        sweep = _SegmentSweep(self, graph, seg_lo, clustering, chip_type)

        def configure(partitions, transition=None):
            sweep.set_partitions(partitions, transition)
            return sweep

        configure.prefill = sweep.prefill_seed
        if self.batched_sweep:
            # search_segment scores all transition candidates of the
            # clustering as one batch before the per-candidate rebalance.
            configure.sweep_transitions = sweep.sweep_transitions
        return configure

    def segment_evaluator(self, graph, seg_lo, clustering, partitions,
                          transition=None, chip_type=None):
        """One-shot evaluator (CostModel-compatible); see segment_sweeper."""
        return self.segment_sweeper(graph, seg_lo, clustering, chip_type)(
            partitions, transition
        )


class _SegmentSweep:
    """Reusable segment evaluator: one clustering, many partition sets.

    ``set_partitions`` swaps in the memo cells for the given partition
    choice; Algorithm 1's linear transition sweep changes the slice of only
    one cluster per step, so consecutive calls re-probe a single slot.
    Calling the object evaluates a region allocation; :meth:`move`
    re-evaluates a one-chip transfer by recomputing only the donor/receiver
    clusters and their boundary-comm neighbors (the clusters whose memo keys
    contain the changed region sizes).
    """

    __slots__ = (
        "model", "gd", "spans", "rel", "n_cl", "load_const", "m",
        "fill_factor", "has_expert", "first_expert", "cells", "statics",
        "next_p0s", "cur_k", "cur_ep", "ctypes", "next_ctypes", "slot_cells",
        "_rlos", "_rhis", "_last_t",
    )

    def __init__(self, model: FastCostModel, graph: LayerGraph, seg_lo: int,
                 clustering, chip_type=None) -> None:
        self.model = model
        # One flavor name applies to every cluster; a sequence gives each
        # cluster its own flavor (mixed pipelines).  next_ctypes[j] feeds the
        # seam-aware boundary term of slot j's memo entry key.
        self.ctypes = list(_flavor_tuple(chip_type, len(clustering)))
        self.next_ctypes = self.ctypes[1:] + [None]
        gd = model.graph_data(graph)
        self.gd = gd
        self.rel = tuple(clustering)
        self.spans = [(seg_lo + lo, seg_lo + hi) for lo, hi in clustering]
        n_cl = len(self.spans)
        self.n_cl = n_cl
        epre = gd.expert_prefix
        self.has_expert = [bool(epre[hi] > epre[lo]) for lo, hi in self.spans]
        self.first_expert = [bool(gd.is_expert[lo]) for lo, _ in self.spans]
        load_const = 0.0
        if not model.literal_pre:
            seg_weights = sum(
                float(gd.wprefix[hi] - gd.wprefix[lo]) for lo, hi in self.spans
            )
            load_const += seg_weights / model.hw.dram_bw_total
        first_lo = self.spans[0][0]
        load_const += (
            model.m * graph.layers[first_lo].in_bytes / model.hw.dram_bw_total
        )
        for i in gd.dram_idx:
            # mid-segment DRAM-staged entry layers (merged model boundaries)
            if i != first_lo and any(lo <= i < hi for lo, hi in self.spans):
                load_const += (
                    model.m * graph.layers[i].in_bytes / model.hw.dram_bw_total
                )
        self.load_const = load_const
        self.m = model.m
        self.fill_factor = model.m + n_cl - 1
        self.cells = [None] * n_cl
        self.statics = [None] * n_cl
        self.next_p0s = [None] * n_cl          # next_p0s[j] = slot j+1's first p
        self.cur_k = [None] * n_cl
        self.cur_ep = [None] * n_cl
        # (j, ep) -> [memo cell per k]: the transition sweep touches every k
        # of every slot, so cells are resolved once per slot here and looked
        # up by list index afterwards instead of re-hashing hint tuples.
        self.slot_cells: dict = {}
        self._rlos = [lo for lo, _ in self.rel]
        self._rhis = [hi for _, hi in self.rel]
        self._last_t = None          # last applied (idx, ep_variant)

    def set_partitions(self, partitions, transition=None) -> None:
        model, gd = self.model, self.gd
        if transition is None:
            # Generic path (arbitrary partition tuples): tuple-keyed cells.
            for j, (lo, hi) in enumerate(self.rel):
                p = partitions[lo:hi]
                cell = model._cluster_cell(gd, *self.spans[j], p, self.ctypes[j])
                self.cells[j] = cell
                self.statics[j] = cell[_STATIC]
                self.cur_k[j] = self.cur_ep[j] = None
                if j > 0:
                    self.next_p0s[j - 1] = p[0]
            self._last_t = None
            return
        idx, ep_variant = transition
        last = self._last_t
        self._last_t = transition
        rel = self.rel
        if last is not None and last[1] == ep_variant:
            # Same ep variant: slot j's clipped k changes between transition
            # indices p and idx only if (lo_j, hi_j] meets (min, max] -- a
            # contiguous j range since clusterings tile the segment.  The
            # usual sweep step is |idx - p| = 1, touching one or two slots.
            p = last[0]
            if p == idx:
                return
            mn, mx = (p, idx) if p < idx else (idx, p)
            js = range(bisect_right(self._rhis, mn),
                       bisect_left(self._rlos, mx))
        else:
            js = range(self.n_cl)
        cur_k, cur_ep = self.cur_k, self.cur_ep
        has_expert, first_expert = self.has_expert, self.first_expert
        cells, statics, next_p0s = self.cells, self.statics, self.next_p0s
        for j in js:
            lo, hi = rel[j]
            k = idx - lo
            if k < 0:
                k = 0
            elif k > hi - lo:
                k = hi - lo
            ep_j = ep_variant and has_expert[j]
            if k == cur_k[j] and ep_j == cur_ep[j]:
                continue
            cell = self._slot_cell_list(j, ep_j)[k]
            cells[j] = cell
            statics[j] = cell[_STATIC]
            cur_k[j] = k
            cur_ep[j] = ep_j
            if j > 0:
                next_p0s[j - 1] = (
                    "EP" if (ep_j and first_expert[j])
                    else ("WSP" if k > 0 else "ISP")
                )

    def _slot_cell_list(self, j: int, ep_j: bool) -> list:
        """Slot ``j``'s memo cells for every transition slice k (cached)."""
        key = (j, ep_j)
        lst = self.slot_cells.get(key)
        if lst is None:
            lo, hi = self.spans[j]
            model, gd, ctype = self.model, self.gd, self.ctypes[j]
            hint = model._cluster_cell_hint
            lst = self.slot_cells[key] = [
                hint(gd, lo, hi, k, ep_j, ctype)
                for k in range(hi - lo + 1)
            ]
        return lst

    def _probe(self, j: int, n: int, next_n: int | None) -> float:
        next_p0 = self.next_p0s[j]
        next_ct = self.next_ctypes[j]
        self.model._probes += 1
        k = (n, next_p0, next_n, next_ct)
        cell = self.cells[j]
        t = cell.get(k)
        if t is None:
            self.model._misses += 1
            t = cell[k] = self.model._cluster_cost(
                self.statics[j], n, next_p0, next_n, cell[_BODY],
                self.ctypes[j], next_ct,
            )
        return t

    def __call__(self, alloc):
        model = self.model
        model._evals += 1
        model._probes += self.n_cl
        n_cl = self.n_cl
        cells = self.cells
        statics = self.statics
        next_p0s = self.next_p0s
        cost = model._cluster_cost
        ctypes = self.ctypes
        next_ctypes = self.next_ctypes
        times = []
        append = times.append
        bottleneck = 0.0
        for j in range(n_cl):
            next_n = alloc[j + 1] if j + 1 < n_cl else None
            k = (alloc[j], next_p0s[j], next_n, next_ctypes[j])
            cell = cells[j]
            t = cell.get(k)
            if t is None:
                model._misses += 1
                t = cell[k] = cost(
                    statics[j], alloc[j], next_p0s[j], next_n, cell[_BODY],
                    ctypes[j], next_ctypes[j],
                )
            if t > bottleneck:
                bottleneck = t
            append(t)
        if bottleneck == INF:
            return INF, times
        return self.load_const + self.fill_factor * bottleneck, times

    def prefill_seed(self, alloc) -> None:
        """Batch-fill the seed-phase bodies of every transition slice.

        Called once per (clustering, seed allocation) by search_segment
        before the transition sweep; spans below _BATCH_MIN_LAYERS stay on
        the lazy per-k paths (scalar loops beat NumPy dispatch there).
        Besides the seed size itself, a +-_PREFILL_N_WINDOW window of region
        sizes rides along in the same matrix pass: the rebalance walks that
        follow move one chip at a time, so almost all their body misses land
        within a few chips of the seed -- pre-filling them swaps scalar
        per-(k, n) fills during the walk for a few extra vectorized rows
        here.  Extra rows only add bodies to the memo; probe results are
        unchanged.
        """
        model = self.model
        if not model.batched_seed_fill:
            return
        d = _PREFILL_N_WINDOW
        for j, (lo, hi) in enumerate(self.spans):
            if hi - lo >= _BATCH_MIN_LAYERS:
                a = alloc[j]
                ns = range(max(1, a - d), a + d + 1)
                model._batch_seed_fill(self.gd, lo, hi, ns, self.ctypes[j],
                                       eager_ns=(a,))

    def move(self, base_alloc, base_times, dst, src, k=1):
        """Incremental re-eval after moving ``k`` chips src -> dst."""
        model = self.model
        model._evals += 1
        n_cl = self.n_cl
        alloc = list(base_alloc)
        alloc[dst] += k
        alloc[src] -= k
        times = list(base_times)
        # Inlined _probe for the four affected slots (the rebalance walk's
        # innermost loop): donor, receiver, and their left neighbors.
        j2 = dst - 1
        j3 = src - 1
        slots = (dst, src) + (
            () if j2 < 0 or j2 == src else (j2,)
        ) + (
            () if j3 < 0 or j3 == dst else (j3,)
        )
        cells = self.cells
        next_p0s = self.next_p0s
        next_ctypes = self.next_ctypes
        model._probes += len(slots)
        for j in slots:
            key = (alloc[j], next_p0s[j],
                   alloc[j + 1] if j + 1 < n_cl else None, next_ctypes[j])
            cell = cells[j]
            t = cell.get(key)
            if t is None:
                model._misses += 1
                t = cell[key] = model._cluster_cost(
                    self.statics[j], key[0], key[1], key[2], cell[_BODY],
                    self.ctypes[j], key[3],
                )
            times[j] = t
        bottleneck = max(times)
        if bottleneck == INF:
            return INF, alloc, times
        return self.load_const + self.fill_factor * bottleneck, alloc, times

    # ----------------------------------------------- batched transition sweep
    def _slot_vals(self, j: int, n: int, next_n: int | None, ep_variant: bool,
                   out: list) -> None:
        """Append slot ``j``'s transition-index value table to ``out``.

        For a transition index ``idx``, slot ``j`` (relative span
        ``[lo, hi)``) evaluates the WSP^k ISP^(span-k) slice with
        ``k = clip(idx - lo, 0, span)``, against a next cluster starting
        ISP while ``idx <= hi`` and WSP once ``idx > hi`` (EP-pinned when
        the ep variant makes the next slot start on an expert layer; absent
        for the last slot).  The table therefore has one entry per k plus --
        when the next-start can flip to WSP -- one trailing ``(k=span,
        next=WSP)`` entry, and a candidate's value sits at
        ``clip(idx - lo, 0, len-1)``.  Entries are memo consults with the
        exact keys the scalar probes use, so the sweep and the incremental
        rebalance walk share every cached time.
        """
        model = self.model
        lo, hi = self.rel[j]
        span = hi - lo
        ep_j = ep_variant and self.has_expert[j]
        ctype = self.ctypes[j]
        next_ct = self.next_ctypes[j]
        last = j == self.n_cl - 1
        ep_next = (not last) and ep_variant and self.first_expert[j + 1]
        cost = model._cluster_cost
        if last:
            p0, nn = None, None
        elif ep_next:
            p0, nn = "EP", next_n
        else:
            p0, nn = "ISP", next_n
        cell = None
        slot_cells = self._slot_cell_list(j, ep_j)
        model._probes += span + 1
        key = (n, p0, nn, next_ct)
        append = out.append
        for k in range(span + 1):
            cell = slot_cells[k]
            t = cell.get(key)
            if t is None:
                model._misses += 1
                t = cell[key] = cost(
                    cell[_STATIC], n, p0, nn, cell[_BODY], ctype, next_ct,
                )
            append(t)
        if not last and not ep_next:
            # idx past this slot: k stays at span, the next slot starts WSP.
            model._probes += 1
            key = (n, "WSP", next_n, next_ct)
            t = cell.get(key)
            if t is None:
                model._misses += 1
                t = cell[key] = cost(
                    cell[_STATIC], n, "WSP", next_n, cell[_BODY], ctype,
                    next_ct,
                )
            out.append(t)

    def sweep_transitions(self, alloc, hints, first_moves=False):
        """Score every transition candidate of this clustering as one batch.

        ``hints`` is the list of ``(transition_idx, ep_variant)`` pairs from
        ``_partition_sets``; the return is ``(lats, times)`` -- a float64
        array of segment latencies and the per-candidate cluster-time lists
        -- exactly what evaluating each candidate's ``eval_fn(alloc)`` one
        at a time would produce, bit for bit.  Instead of ``K x n_cl``
        scalar probes, each slot's distinct values are materialized once
        (``span + 2`` memo consults per slot) and all K candidates are
        assembled with a single clipped fancy-index gather + row max.

        With ``first_moves=True`` the return gains a third element: a
        per-candidate head-of-walk decision from batching the *first
        rebalance iteration* as well (see :meth:`_first_moves`).  ``None``
        means "run the scalar walk from the seed" (infeasible seeds take
        the repair phase; small candidate groups are not worth batching),
        ``("done",)`` means the walk provably terminates at the seed, and
        ``("cont", alloc2, lat2, times2)`` is the state after the one
        accepted move, from which the scalar walk continues.
        """
        model = self.model
        n_cl = self.n_cl
        K = len(hints)
        model._evals += K
        model._batch_evals += 1
        model._batch_rows += K
        lats = np.empty(K, dtype=np.float64)
        times: list[list[float] | None] = [None] * K
        heads: list[tuple | None] | None = [None] * K if first_moves else None
        move_tables: dict = {}
        for ep_variant in (False, True):
            rows = [r for r, (_i, ep) in enumerate(hints) if bool(ep) == ep_variant]
            if not rows:
                continue
            idxs = np.array([hints[r][0] for r in rows], dtype=np.int64)
            vals: list[float] = []
            offs = np.empty(n_cl, dtype=np.int64)
            caps = np.empty(n_cl, dtype=np.int64)
            rlos = np.empty(n_cl, dtype=np.int64)
            for j in range(n_cl):
                offs[j] = len(vals)
                self._slot_vals(
                    j, alloc[j],
                    alloc[j + 1] if j + 1 < n_cl else None,
                    ep_variant, vals,
                )
                caps[j] = len(vals) - offs[j] - 1
                rlos[j] = self.rel[j][0]
            flat = np.array(vals, dtype=np.float64)
            pos = np.clip(idxs[None, :] - rlos[:, None], 0, caps[:, None])
            tmat = flat[offs[:, None] + pos]               # n_cl x K_variant
            bn = tmat.max(axis=0)
            lat_v = np.where(np.isinf(bn), INF, self.load_const + self.fill_factor * bn)
            for c, r in enumerate(rows):
                lats[r] = lat_v[c]
                times[r] = tmat[:, c].tolist()
            if first_moves and n_cl > 1:
                self._first_moves(alloc, rows, tmat, pos, lat_v, times, heads,
                                  ep_variant, move_tables)
        if first_moves:
            return lats, times, heads
        return lats, times

    def _first_moves(self, alloc, rows, tmat, pos, lat_v, times, heads,
                     ep_variant, tables) -> None:
        """Batch the first rebalance iteration of every finite-seed candidate.

        Most rebalance walks end immediately: the two fastest donors both
        fail to lower the bottleneck.  This replicates iteration 1 of
        :func:`repro.core.regions.rebalance`'s hot path (``groups=None``,
        ``donor_tries=2``) exactly -- bottleneck = first argmax, donors = the
        two fastest regions with more than one chip excluding the bottleneck
        (first-argmin tie-breaks, like the scalar scans), acceptance =
        strictly lower latency -- but for whole candidate groups at once.
        Candidates are grouped by their (bottleneck, donor) pair; a group's
        post-move cluster times are one fancy-index gather from a value
        table at the moved allocation (``tables`` caches them, keyed
        ``(slot, n, next_n, ep)``).  Groups smaller than
        ``_FIRST_MOVE_MIN_GROUP`` keep ``heads[r] = None`` and take the
        scalar walk -- a table costs ``span + 2`` memo consults, so tiny
        groups would compute more speculative entries than the walk itself.
        """
        model = self.model
        n_cl = self.n_cl
        fin = np.nonzero(np.isfinite(lat_v))[0]
        if not len(fin):
            return
        eligible = np.array([a > 1 for a in alloc], dtype=bool)
        slow = tmat[:, fin].argmax(axis=0)
        M = np.where(eligible[:, None], tmat[:, fin], np.inf)
        ar = np.arange(len(fin))
        M[slow, ar] = np.inf
        d1 = M.argmin(axis=0)
        ok1 = M[d1, ar] < np.inf

        def eval_move(cols, s, d):
            # Post-move state for candidates `cols` (fin-relative) moving
            # one chip from donor d to bottleneck s: exactly what
            # _SegmentSweep.move would compute, gathered per slot.
            a2 = list(alloc)
            a2[s] += 1
            a2[d] -= 1
            aff = [s, d]
            if s - 1 >= 0 and s - 1 != d:
                aff.append(s - 1)
            if d - 1 >= 0 and d - 1 != s:
                aff.append(d - 1)
            gcols = fin[cols]
            newvals = np.empty((len(aff), len(cols)))
            for i, j in enumerate(aff):
                key = (j, a2[j], a2[j + 1] if j + 1 < n_cl else None,
                       ep_variant)
                tab = tables.get(key)
                if tab is None:
                    out: list[float] = []
                    self._slot_vals(j, key[1], key[2], ep_variant, out)
                    tab = tables[key] = np.array(out, dtype=np.float64)
                newvals[i] = tab[pos[j, gcols]]
            model._evals += len(cols)
            rest = np.ones(n_cl, dtype=bool)
            rest[aff] = False
            bn2 = newvals.max(axis=0)
            if rest.any():
                bn2 = np.maximum(bn2, tmat[rest][:, gcols].max(axis=0))
            lat2 = np.where(np.isinf(bn2), INF,
                            self.load_const + self.fill_factor * bn2)
            return a2, aff, newvals, lat2

        def apply_round(pairs, failed):
            for (s, d), cols in pairs.items():
                if len(cols) < _FIRST_MOVE_MIN_GROUP:
                    continue                     # scalar walk (heads stay None)
                cols = np.array(cols)
                a2, aff, newvals, lat2 = eval_move(cols, s, d)
                imp = lat2 < lat_v[fin[cols]]
                for i, c in enumerate(cols):
                    r = rows[fin[c]]
                    if imp[i]:
                        t2 = list(times[r])
                        for ai, j in enumerate(aff):
                            t2[j] = float(newvals[ai, i])
                        heads[r] = ("cont", a2, float(lat2[i]), t2)
                    elif failed is None:
                        heads[r] = ("done",)
                    else:
                        failed.append(int(c))

        pairs1: dict[tuple[int, int], list[int]] = {}
        for c in ar[ok1]:
            pairs1.setdefault((int(slow[c]), int(d1[c])), []).append(int(c))
        for c in ar[~ok1]:
            heads[rows[fin[c]]] = ("done",)      # no donor: walk ends at seed
        fail1: list[int] = []
        apply_round(pairs1, fail1)
        if fail1:
            f1 = np.array(fail1)
            M[d1[f1], f1] = np.inf
            d2 = M[:, f1].argmin(axis=0)
            ok2 = M[d2, f1] < np.inf
            pairs2: dict[tuple[int, int], list[int]] = {}
            for i, c in enumerate(fail1):
                if ok2[i]:
                    pairs2.setdefault((int(slow[c]), int(d2[i])), []).append(c)
                else:
                    heads[rows[fin[c]]] = ("done",)
            apply_round(pairs2, None)
