"""Fig. 7: normalized throughput of the four methods, 8 nets x 3 MCM scales.

Paper claim reproduced: Scope achieves the best throughput everywhere, with
the largest gain on the deepest network at scale (up to 1.73x over the
segmented-pipeline SOTA).
"""
from __future__ import annotations

from .common import cached, run_method

NETS = ["alexnet", "vgg16", "darknet19", "resnet18", "resnet34", "resnet50",
        "resnet101", "resnet152"]
SCALES = [16, 64, 256]
METHODS = ["sequential", "full_pipeline", "segmented", "scope"]


def run(refresh: bool = False, nets=None, scales=None):
    nets = nets or NETS
    scales = scales or SCALES
    rows = []
    for net in nets:
        for chips in scales:
            def _one(net=net, chips=chips):
                return [run_method(net, chips, m) for m in METHODS]
            rows.extend(cached(f"fig7_{net}_{chips}", _one, refresh))
    return rows


def report(rows) -> list[str]:
    lines = ["net,chips,sequential,full_pipeline,segmented,scope,scope_vs_segmented"]
    by_key = {}
    for r in rows:
        by_key.setdefault((r["net"], r["chips"]), {})[r["method"]] = r
    best_gain, best_key = 0.0, None
    for (net, chips), d in sorted(by_key.items()):
        tp = {m: (d[m]["throughput"] if d.get(m, {}).get("valid") else 0.0)
              for m in METHODS}
        gain = tp["scope"] / tp["segmented"] if tp.get("segmented") else float("nan")
        if gain == gain and gain > best_gain:
            best_gain, best_key = gain, (net, chips)
        lines.append(
            f"{net},{chips},{tp['sequential']:.0f},{tp['full_pipeline']:.0f},"
            f"{tp['segmented']:.0f},{tp['scope']:.0f},{gain:.3f}"
        )
    lines.append(f"# max scope/segmented gain: {best_gain:.2f}x at {best_key} "
                 f"(paper: up to 1.73x, deepest net at scale)")
    return lines
