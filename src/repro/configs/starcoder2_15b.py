"""starcoder2-15b [dense]: 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152, GQA + RoPE [arXiv:2402.19173; hf]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    ffn_gated=False,            # starcoder2 uses a classic 4x MLP
    rope_theta=100_000.0,
)
