"""Scope analytical cost model: paper Eqs. 1-7 + Table II.

Phase decomposition per layer (paper SSIII-A):

* preparation  (Eq. 4): weight delivery.  Segment-level DRAM loads are charged
  once per segment deployment; the distributed-weight-buffering exchange
  (paper SSIII-B) is charged per pipeline beat.
* computation  (Eq. 5): FLOPs / (chips x peak x util), where ``util`` models
  tiling quantization: ISP shrinks the weight-output dim per chip, WSP shrinks
  the activation dim per chip (this reproduces the paper's observation that
  ISP "reduces the parallelizable weight dimension").
* communication (Eq. 6 / Table II): activation redistribution to the next
  layer, which depends on both layers' partitions and whether the next layer
  lives in the same region (Case1) or the next region (Case2).

Eq. 7 overlaps computation and NoP communication:
``T_layer = T_pre + max(T_comm, T_comp)``.

Deviation from the literal equations (documented in DESIGN.md): Eq. 3 as
printed charges T_pre per sample.  With weight-stationary regions, DRAM weight
loads happen once per segment *deployment*; we charge them once and keep only
the per-beat distributed-buffer exchange inside the steady-state beat time.
Set ``literal_pre=True`` to reproduce the literal reading.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from .graph import (
    PARTITION_EP,
    PARTITION_ISP,
    PARTITION_WSP,
    ClusterAssignment,
    LayerGraph,
    LayerNode,
    ScopeSchedule,
    SegmentSchedule,
)
from .hw import HardwareModel, eff

INF = float("inf")

# Sentinel for ``next_chip_type``: "the consuming cluster has the same flavor
# as the producer" -- the homogeneous-pipeline default, which keeps every
# pre-mixed-flavor call site's behavior (and results) unchanged.  ``None`` is
# a real flavor (the package's base type), so it cannot double as the default.
SAME_FLAVOR = "<same>"


def _flavor_tuple(chip_type, n_clusters: int) -> tuple:
    """Normalize a schedule-level or per-cluster flavor argument.

    ``chip_type`` may be ``None``/a flavor name (every cluster on that
    flavor, the pre-mixed-flavor calling convention) or a sequence of
    per-cluster flavors (mixed pipelines).
    """
    if chip_type is None or isinstance(chip_type, str):
        return (chip_type,) * n_clusters
    types = tuple(chip_type)
    if len(types) != n_clusters:
        raise ValueError(
            f"{len(types)} chip types for {n_clusters} clusters"
        )
    return types


# --------------------------------------------------------------- attribution
# Fixed component order: the conservation fold sums in exactly this order, so
# "components sum to the scalar" is a bit-exact statement, not an approximate
# one (see conserve_components).
BREAKDOWN_COMPONENTS = ("compute", "nop_comm", "seam", "dram", "staging")

# Component -> bottleneck label ("what is this stage bound by").
BOUND_LABELS = {"compute": "compute", "nop_comm": "link", "seam": "seam",
                "dram": "dram", "staging": "staging"}


def fold_components(components: dict, order=BREAKDOWN_COMPONENTS) -> float:
    """Left-to-right sum in a fixed component order."""
    total = 0.0
    for name in order:
        total += components.get(name, 0.0)
    return total


def conserve_components(components: dict, total: float,
                        order=BREAKDOWN_COMPONENTS) -> dict:
    """Adjust ``components`` so :func:`fold_components` equals ``total``
    *bit-identically*.

    The per-component charges are recomputed with the same arithmetic the
    scalar used, but accumulated per category rather than per layer -- a
    different floating-point summation order, so the fold can differ from
    the optimized scalar by a few ulps.  The residual is folded into the
    dominant bucket until exact; if rounding refuses to converge (or the
    scalar is non-finite: an infeasible placement), the degenerate-but-exact
    fallback parks the whole scalar in one bucket (``x + 0.0 == x``).

    The serving layer reuses this with its own ``order`` (latency-waterfall
    components); the same bit-exactness argument applies.
    """
    out = {k: float(components.get(k, 0.0)) for k in order}
    if not math.isfinite(total):
        # Infeasible cluster: place_weights ran out of per-chip DRAM/SRAM
        # residency, so the infinity is a memory fact.
        out = dict.fromkeys(order, 0.0)
        out["dram" if "dram" in out else order[0]] = total
        return out
    for _ in range(64):
        residual = total - fold_components(out, order)
        if residual == 0.0:
            return out
        out[max(out, key=lambda k: abs(out[k]))] += residual
    top = max(out, key=lambda k: abs(out[k]))
    out = dict.fromkeys(order, 0.0)
    out[top] = total
    return out


@dataclass(frozen=True)
class CostBreakdown:
    """A scalar cost split into additive components that *conserve* it.

    ``components`` maps every name in :data:`BREAKDOWN_COMPONENTS` to
    seconds; folding them in that fixed order reproduces ``total``
    bit-identically (the invariant ``conserved`` checks).  ``bottleneck``
    names the largest component, ``bound`` its human label
    (compute/link/seam/dram/staging).
    """
    total: float
    components: dict

    @property
    def conserved(self) -> bool:
        return fold_components(self.components) == self.total

    @property
    def bottleneck(self) -> str:
        return max(self.components, key=lambda k: self.components[k])

    @property
    def bound(self) -> str:
        return BOUND_LABELS[self.bottleneck]

    def to_json(self) -> dict:
        return {"total": self.total, "bound": self.bound,
                "components": dict(self.components)}

    @classmethod
    def build(cls, components: dict, total: float) -> "CostBreakdown":
        return cls(total=total,
                   components=conserve_components(components, total))

    @classmethod
    def merge(cls, parts, total: float) -> "CostBreakdown":
        """Sum breakdowns (e.g. per-segment -> whole schedule), re-conserved
        against the combined scalar."""
        buckets = dict.fromkeys(BREAKDOWN_COMPONENTS, 0.0)
        for p in parts:
            for k, v in p.components.items():
                buckets[k] += v
        return cls.build(buckets, total)


@dataclass(frozen=True)
class LayerTime:
    pre: float
    comp: float
    comm: float

    @property
    def total(self) -> float:          # Eq. 7
        return self.pre + max(self.comm, self.comp)

    @property
    def unoverlapped(self) -> float:
        return self.pre + self.comm + self.comp


@dataclass(frozen=True)
class WeightPlacement:
    """How a cluster's weights sit in the region (paper SSIII-B)."""
    resident_bytes_per_chip: float
    transient_bytes_per_chip: float      # peak scratch for the active gather
    gather_bytes: tuple[float, ...]      # per-layer per-beat NoP receive / chip
    feasible: bool


class CostModel:
    def __init__(
        self,
        hw: HardwareModel,
        m_samples: int = 16,
        overlap: bool = True,
        distributed_weights: bool = True,
        literal_pre: bool = False,
    ):
        self.hw = hw
        self.m = m_samples
        self.overlap = overlap
        self.distributed_weights = distributed_weights
        self.literal_pre = literal_pre
        self._typed_hw: dict[str | None, HardwareModel] = {}
        self._seam_bw: dict[tuple[str | None, str | None], float] = {}
        # engine counters (same schema as FastCostModel.stats; the reference
        # model has no memo, so every cluster probe is a compute)
        self._evals = 0
        self._misses = 0
        self._probes = 0
        self._batched_bodies = 0
        self._batch_evals = 0
        self._batch_rows = 0

    @property
    def stats(self) -> dict:
        """Engine work counters (schema shared with :class:`FastCostModel`)."""
        return {
            "segment_evals": self._evals,
            "cluster_computes": self._misses,
            "cluster_probes": self._probes,
            "memo_hits": self._probes - self._misses,
            "memo_cells": 0,
            "memo_entries": 0,
            "batched_bodies": self._batched_bodies,
            "batch_evals": self._batch_evals,
            "batch_rows": self._batch_rows,
        }

    def hw_for(self, chip_type: str | None) -> HardwareModel:
        """The hardware a region of ``chip_type`` chips sees (hetero packages;
        ``None``/base type returns ``self.hw`` unchanged)."""
        if not chip_type:
            return self.hw
        hw = self._typed_hw.get(chip_type)
        if hw is None:
            hw = self._typed_hw[chip_type] = self.hw.typed(chip_type)
        return hw

    def seam_bw(self, a: str | None, b: str | None) -> float:
        """Cached :meth:`HardwareModel.seam_link_bw` for a flavor pair."""
        bw = self._seam_bw.get((a, b))
        if bw is None:
            bw = self._seam_bw[(a, b)] = self.hw.seam_link_bw(a, b)
        return bw

    # ------------------------------------------------------------------ utils
    def _util(self, layer: LayerNode, p: str, n: int,
              hw: HardwareModel | None = None) -> float:
        hw = hw or self.hw
        if p == PARTITION_WSP:
            m_local = layer.wsp_parallel / n
            n_local = layer.isp_parallel
        elif p == PARTITION_ISP:
            m_local = layer.wsp_parallel
            n_local = layer.isp_parallel / n
        else:  # EP: experts spread over chips; within an expert both dims intact
            m_local = layer.wsp_parallel * (layer.active_experts / max(1, layer.n_experts))
            n_local = layer.isp_parallel
        return eff(m_local, hw.m_granule) * eff(n_local, hw.n_granule)

    def comp_time(self, layer: LayerNode, p: str, n: int,
                  chip_type: str | None = None) -> float:
        """Eq. 5 (Timeloop regression replaced by peak x tiling-efficiency)."""
        hw = self.hw_for(chip_type)
        util = self._util(layer, p, n, hw)
        return layer.flops / (n * hw.flops_per_chip * util)

    # -------------------------------------------------------------- Table II
    def comm_volume(
        self,
        layer: LayerNode,
        p: str,
        n: int,
        next_p: str | None,
        next_n: int | None,
        same_region: bool,
    ) -> float:
        """NoP bytes produced by ``layer``'s output redistribution (Table II)."""
        if next_p is None:            # network output: leaves via DRAM, no NoP
            return 0.0
        out = layer.out_bytes
        # ``halo_bytes`` is per split boundary; an n-way WSP split has n-1 seams.
        halo = layer.halo_bytes * max(0, n - 1)
        if p == PARTITION_EP or next_p == PARTITION_EP:
            # Beyond-paper: expert dispatch/combine is an all-to-all of the
            # token activations, volume ~ out each way.
            return 2.0 * out
        if same_region:               # Case 1
            if p == PARTITION_WSP and next_p == PARTITION_WSP:
                return halo
            if p == PARTITION_WSP and next_p == PARTITION_ISP:
                return (n - 1) * out
            if p == PARTITION_ISP and next_p == PARTITION_WSP:
                return (n - 1) * out + halo
            return (n - 1) * out      # ISP -> ISP
        # Case 2: hand-off to the next cluster's region
        if next_p == PARTITION_WSP:
            return out
        return (next_n or 1) * out    # replicate into every chip of next region

    def comm_time(
        self,
        layer: LayerNode,
        p: str,
        n: int,
        next_p: str | None,
        next_n: int | None,
        same_region: bool,
        chip_type: str | None = None,
        next_chip_type: str | None = SAME_FLAVOR,
    ) -> float:
        vol = self.comm_volume(layer, p, n, next_p, next_n, same_region)
        if vol <= 0:
            return 0.0
        # The producing region's flavor bounds its injection bandwidth; the
        # boundary links are shared with the consuming region, so a flavor
        # seam runs at the weaker flavor's link rate (hw.seam_link_bw).
        hw = self.hw_for(chip_type)
        if same_region:
            # Collectives inside the region: aggregate injection bandwidth.
            return vol / (n * hw.nop_bw_per_chip)
        # Region boundary: limited by the links crossing the ZigZag seam
        # (stand-in for the paper's BookSim regression, see DESIGN.md SS3).
        if next_chip_type is SAME_FLAVOR or next_chip_type == chip_type:
            link_bw = hw.link_bw
        else:
            link_bw = self.seam_bw(chip_type, next_chip_type)
        links = max(1, round(math.sqrt(min(n, next_n or n))))
        boundary = vol / (links * link_bw)
        injection = vol / (n * hw.nop_bw_per_chip)
        return max(boundary, injection)

    # ------------------------------------------------------ weight placement
    def place_weights(
        self, graph: LayerGraph, cluster: ClusterAssignment
    ) -> WeightPlacement:
        """Greedy residency plan for a cluster (paper SSIII-B).

        ISP/EP layers are sharded by construction.  WSP layers start
        replicated; while over capacity, the largest replicated WSP layer
        flips to distributed storage (tile resident, full copy gathered
        per beat).
        """
        n = cluster.region_chips
        layers = graph.layers[cluster.layer_lo : cluster.layer_hi]
        resident = []
        wsp_idx = []
        for k, (layer, p) in enumerate(zip(layers, cluster.partitions)):
            if p == PARTITION_WSP:
                resident.append(layer.weight_bytes)      # replicated
                wsp_idx.append(k)
            elif p == PARTITION_EP:
                resident.append(layer.weight_bytes / min(n, max(1, layer.n_experts)))
            else:
                resident.append(layer.weight_bytes / n)  # ISP shard
        gather = [0.0] * len(layers)
        cap = self.hw.weight_capacity_per_chip
        if self.distributed_weights:
            order = sorted(wsp_idx, key=lambda k: -layers[k].weight_bytes)
            ptr = 0
            while sum(resident) > cap and ptr < len(order):
                k = order[ptr]
                w = layers[k].weight_bytes
                resident[k] = w / n
                gather[k] = w * (n - 1) / n      # received per chip per beat
                ptr += 1
        # Distributed WSP compute proceeds ring-style: compute with tile t
        # while receiving tile t+1 ("chiplets exchange their weight tiles",
        # paper SSIII-B) => transient scratch is two tiles, not the full matrix.
        transient = max(
            (2.0 * layers[k].weight_bytes / n for k in range(len(layers)) if gather[k] > 0),
            default=0.0,
        )
        feasible = (sum(resident) + transient) <= cap
        return WeightPlacement(sum(resident), transient, tuple(gather), feasible)

    # --------------------------------------------------------------- layers
    def layer_time(
        self,
        layer: LayerNode,
        p: str,
        n: int,
        next_p: str | None,
        next_n: int | None,
        same_region: bool,
        gather_bytes: float = 0.0,
        extra_pre: float = 0.0,
        chip_type: str | None = None,
        next_chip_type: str | None = SAME_FLAVOR,
    ) -> LayerTime:
        pre = extra_pre
        if gather_bytes > 0:
            pre += gather_bytes / self.hw_for(chip_type).nop_bw_per_chip
        comp = self.comp_time(layer, p, n, chip_type)
        comm = self.comm_time(layer, p, n, next_p, next_n, same_region,
                              chip_type, next_chip_type)
        return LayerTime(pre=pre, comp=comp, comm=comm)

    # -------------------------------------------------------------- clusters
    def cluster_time(
        self,
        graph: LayerGraph,
        cluster: ClusterAssignment,
        next_cluster: ClusterAssignment | None,
        first_in_segment: bool,
        last_in_segment: bool,
    ) -> float:
        """Steady-state beat time of one cluster (Eq. 3 with Eq. 7 per layer)."""
        self._probes += 1
        self._misses += 1
        placement = self.place_weights(graph, cluster)
        if not placement.feasible:
            return INF
        n = cluster.region_chips
        layers = graph.layers[cluster.layer_lo : cluster.layer_hi]
        total = 0.0
        for k, (layer, p) in enumerate(zip(layers, cluster.partitions)):
            last_layer = k == len(layers) - 1
            nxt_t = SAME_FLAVOR
            if not last_layer:
                nxt_p, nxt_n, same = cluster.partitions[k + 1], n, True
            elif next_cluster is not None:
                nxt_p, nxt_n, same = next_cluster.partitions[0], next_cluster.region_chips, False
                nxt_t = next_cluster.chip_type
            else:
                nxt_p, nxt_n, same = None, None, False
            extra_pre = 0.0
            if self.literal_pre:
                extra_pre += layer.weight_bytes / self.hw.dram_bw_total
            t = self.layer_time(
                layer, p, n, nxt_p, nxt_n, same,
                gather_bytes=placement.gather_bytes[k],
                extra_pre=extra_pre,
                chip_type=cluster.chip_type,
                next_chip_type=nxt_t,
            )
            total += t.total if self.overlap else t.unoverlapped
        return total

    # ------------------------------------------------------------ populations
    def cluster_population(self, graph: LayerGraph, rows) -> "np.ndarray":
        """Batched population evaluator: score a ``(K, ...)`` batch of cluster
        configurations in one call.

        Each row is ``(lo, hi, spec, n, next_p0, next_n, ctype, next_ctype)``
        with global layer bounds ``[lo, hi)``.  ``spec`` is either an explicit
        partition tuple (first element a partition string) or an Algorithm 1
        transition hint ``(k, ep)``: WSP for the first ``k`` layers, ISP for
        the rest, MoE layers flipped to EP when ``ep``.  ``next_p0`` is the
        consuming cluster's first partition (``None`` = network output) and
        ``next_ctype`` its flavor (:data:`SAME_FLAVOR` = producer's flavor).

        Returns a float64 array of the K steady-state cluster beat times.
        The reference implementation scores rows one at a time through
        :meth:`cluster_time`; :class:`repro.core.fastcost.FastCostModel`
        overrides it with per-row memo consults plus grouped vectorized body
        fills, so cache semantics are unchanged while the arithmetic runs as
        one array program per distinct cluster cell.
        """
        out = np.empty(len(rows), dtype=np.float64)
        self._batch_evals += 1
        self._batch_rows += len(rows)
        for i, (lo, hi, spec, n, next_p0, next_n, ctype, next_ctype) in enumerate(rows):
            if spec and isinstance(spec[0], str):
                partitions = tuple(spec)
            else:
                k, ep = spec
                parts = [PARTITION_WSP] * k + [PARTITION_ISP] * (hi - lo - k)
                if ep:
                    for d, layer in enumerate(graph.layers[lo:hi]):
                        if layer.n_experts > 1:
                            parts[d] = PARTITION_EP
                partitions = tuple(parts)
            cluster = ClusterAssignment(
                layer_lo=lo, layer_hi=hi, region_chips=n,
                partitions=partitions, chip_type=ctype,
            )
            nxt = None
            if next_p0 is not None:
                nxt_t = ctype if next_ctype is SAME_FLAVOR else next_ctype
                nxt = ClusterAssignment(
                    layer_lo=hi, layer_hi=hi + 1, region_chips=next_n or 1,
                    partitions=(next_p0,), chip_type=nxt_t,
                )
            out[i] = self.cluster_time(
                graph, cluster, nxt,
                first_in_segment=False, last_in_segment=nxt is None,
            )
        return out

    # -------------------------------------------------------------- segments
    def segment_time(
        self, graph: LayerGraph, clusters: tuple[ClusterAssignment, ...]
    ) -> tuple[float, list[float]]:
        """Eq. 2: (m + Nc - 1) * max_j T_cluster + one-time weight load."""
        self._evals += 1
        times = []
        for j, cl in enumerate(clusters):
            nxt = clusters[j + 1] if j + 1 < len(clusters) else None
            times.append(
                self.cluster_time(
                    graph, cl, nxt,
                    first_in_segment=(j == 0),
                    last_in_segment=(nxt is None),
                )
            )
        bottleneck = max(times)
        if bottleneck == INF:
            return INF, times
        # Sequential-deployment overheads (the anti-segment force of Fig. 1b):
        # before the pipeline wave can run, the segment's weights and the
        # batch's input activations must be staged through shared DRAM.  The
        # output-side spill overlaps with the pipeline drain and is not
        # serialized.
        load = 0.0
        if not self.literal_pre:
            seg_weights = sum(
                graph.layers[i].weight_bytes
                for cl in clusters
                for i in range(cl.layer_lo, cl.layer_hi)
            )
            load += seg_weights / self.hw.dram_bw_total
        first_lo = clusters[0].layer_lo
        load += self.m * graph.layers[first_lo].in_bytes / self.hw.dram_bw_total
        # Mid-segment DRAM-staged entry layers (merged multi-model graphs
        # mark model boundaries with meta["dram_input"]): their inputs are
        # staged like a segment start's, wherever the boundary lands.
        for cl in clusters:
            for i in range(cl.layer_lo, cl.layer_hi):
                if i != first_lo and graph.layers[i].meta.get("dram_input"):
                    load += self.m * graph.layers[i].in_bytes / self.hw.dram_bw_total
        n_cl = len(clusters)
        return load + (self.m + n_cl - 1) * bottleneck, times

    # ------------------------------------------------------------ attribution
    def comm_kind(
        self,
        layer: LayerNode,
        p: str,
        n: int,
        next_p: str | None,
        next_n: int | None,
        same_region: bool,
        chip_type: str | None = None,
        next_chip_type: str | None = SAME_FLAVOR,
    ) -> str:
        """Which component a :meth:`comm_time` charge belongs to.

        Intra-region collectives ride the NoP injection links
        (``nop_comm``); a region hand-off is ``seam`` when the boundary
        links bind (the ZigZag cut, flavor seam or not) and ``nop_comm``
        when the producer's injection bandwidth does.
        """
        if same_region:
            return "nop_comm"
        vol = self.comm_volume(layer, p, n, next_p, next_n, same_region)
        if vol <= 0:
            return "nop_comm"
        hw = self.hw_for(chip_type)
        if next_chip_type is SAME_FLAVOR or next_chip_type == chip_type:
            link_bw = hw.link_bw
        else:
            link_bw = self.seam_bw(chip_type, next_chip_type)
        links = max(1, round(math.sqrt(min(n, next_n or n))))
        boundary = vol / (links * link_bw)
        injection = vol / (n * hw.nop_bw_per_chip)
        return "seam" if boundary >= injection else "nop_comm"

    def cluster_breakdown(
        self,
        graph: LayerGraph,
        cluster: ClusterAssignment,
        next_cluster: ClusterAssignment | None,
        first_in_segment: bool,
        last_in_segment: bool,
    ) -> CostBreakdown:
        """Decompose :meth:`cluster_time` into BREAKDOWN_COMPONENTS.

        The scalar is obtained through ``self.cluster_time`` -- i.e. the
        *engine's own* entry point (memoized on FastCostModel) -- and the
        per-layer charges are re-derived with the reference arithmetic this
        class defines (FastCostModel inherits it unchanged), so the
        conserved breakdown sums bit-identically to the number the solver
        optimized on either engine.
        """
        total = self.cluster_time(graph, cluster, next_cluster,
                                  first_in_segment, last_in_segment)
        buckets = dict.fromkeys(BREAKDOWN_COMPONENTS, 0.0)
        if total == INF:
            return CostBreakdown.build(buckets, total)
        placement = self.place_weights(graph, cluster)
        n = cluster.region_chips
        layers = graph.layers[cluster.layer_lo : cluster.layer_hi]
        for k, (layer, p) in enumerate(zip(layers, cluster.partitions)):
            last_layer = k == len(layers) - 1
            nxt_t = SAME_FLAVOR
            if not last_layer:
                nxt_p, nxt_n, same = cluster.partitions[k + 1], n, True
            elif next_cluster is not None:
                nxt_p, nxt_n, same = (next_cluster.partitions[0],
                                      next_cluster.region_chips, False)
                nxt_t = next_cluster.chip_type
            else:
                nxt_p, nxt_n, same = None, None, False
            if self.literal_pre:
                buckets["dram"] += layer.weight_bytes / self.hw.dram_bw_total
            gather = placement.gather_bytes[k]
            if gather > 0:
                buckets["nop_comm"] += (
                    gather / self.hw_for(cluster.chip_type).nop_bw_per_chip)
            comp = self.comp_time(layer, p, n, cluster.chip_type)
            comm = self.comm_time(layer, p, n, nxt_p, nxt_n, same,
                                  cluster.chip_type, nxt_t)
            kind = self.comm_kind(layer, p, n, nxt_p, nxt_n, same,
                                  cluster.chip_type, nxt_t)
            if self.overlap:
                # Eq. 7 keeps only the winner of the overlap race; ties go
                # to comm, matching max(comm, comp) and the vectorized
                # engine's select.
                if comm >= comp:
                    buckets[kind] += comm
                else:
                    buckets["compute"] += comp
            else:
                buckets["compute"] += comp
                buckets[kind] += comm
        return CostBreakdown.build(buckets, total)

    def segment_breakdown(
        self, graph: LayerGraph, clusters: tuple[ClusterAssignment, ...]
    ) -> tuple[CostBreakdown, list[CostBreakdown]]:
        """Decompose :meth:`segment_time`: ``(segment, per-cluster)``.

        The pipeline wave repeats the bottleneck cluster's beat
        ``m + Nc - 1`` times, so the segment inherits that cluster's
        components at scale; the one-time deployment load splits into
        ``dram`` (segment weights) and ``staging`` (batch input staging,
        incl. mid-segment ``dram_input`` entries).
        """
        total, times = self.segment_time(graph, clusters)
        per_cluster = []
        for j, cl in enumerate(clusters):
            nxt = clusters[j + 1] if j + 1 < len(clusters) else None
            per_cluster.append(
                self.cluster_breakdown(graph, cl, nxt, j == 0, nxt is None))
        buckets = dict.fromkeys(BREAKDOWN_COMPONENTS, 0.0)
        if total == INF:
            return CostBreakdown.build(buckets, total), per_cluster
        if not self.literal_pre:
            seg_weights = sum(
                graph.layers[i].weight_bytes
                for cl in clusters
                for i in range(cl.layer_lo, cl.layer_hi)
            )
            buckets["dram"] += seg_weights / self.hw.dram_bw_total
        first_lo = clusters[0].layer_lo
        stage_bytes = self.m * graph.layers[first_lo].in_bytes
        for cl in clusters:
            for i in range(cl.layer_lo, cl.layer_hi):
                if i != first_lo and graph.layers[i].meta.get("dram_input"):
                    stage_bytes += self.m * graph.layers[i].in_bytes
        buckets["staging"] += stage_bytes / self.hw.dram_bw_total
        beats = self.m + len(clusters) - 1
        b = max(range(len(times)), key=lambda j: times[j])
        for name, v in per_cluster[b].components.items():
            buckets[name] += beats * v
        return CostBreakdown.build(buckets, total), per_cluster

    # --------------------------------------------------------- DSE interface
    def segment_evaluator(self, graph, seg_lo, clustering, partitions,
                          transition=None, chip_type=None):
        """Return ``eval_fn(alloc) -> (latency, per_cluster_times)``.

        ``transition`` is an optional Algorithm 1 sweep hint (ignored here;
        see :meth:`repro.core.fastcost.FastCostModel.segment_evaluator`).
        ``chip_type`` evaluates the segment on a heterogeneous package: one
        flavor name runs every cluster on that flavor, a per-cluster
        sequence evaluates a mixed-flavor pipeline (boundary comm between
        differently-flavored neighbors is charged through the seam model).

        The DSE (search.py) funnels every candidate region allocation of a
        fixed (clustering, partitions) choice through this closure.  The
        reference implementation rebuilds ClusterAssignments and re-derives
        every cluster from scratch; :class:`repro.core.fastcost.FastCostModel`
        overrides it with a vectorized, memoized evaluator.
        """
        types = _flavor_tuple(chip_type, len(clustering))

        def eval_fn(alloc):
            clusters = tuple(
                ClusterAssignment(
                    layer_lo=seg_lo + lo,
                    layer_hi=seg_lo + hi,
                    region_chips=chips,
                    partitions=partitions[lo:hi],
                    chip_type=ctype,
                )
                for (lo, hi), chips, ctype in zip(clustering, alloc, types)
            )
            return self.segment_time(graph, clusters)

        return eval_fn

    def segment_sweeper(self, graph, seg_lo, clustering, chip_type=None):
        """Factory used by Algorithm 1: ``sweeper(partitions, transition) ->
        eval_fn`` for one clustering.  FastCostModel overrides this with a
        reusable evaluator that updates incrementally along the sweep."""
        def configure(partitions, transition=None):
            return self.segment_evaluator(
                graph, seg_lo, clustering, partitions, transition, chip_type
            )

        return configure

    # ---------------------------------------------------------------- system
    def system_time(self, graph: LayerGraph, segments) -> float:
        """Eq. 1."""
        total = 0.0
        for seg in segments:
            t, _ = self.segment_time(graph, seg if isinstance(seg, tuple) else seg.clusters)
            if t == INF:
                return INF
            total += t
        return total

    def evaluate(self, graph: LayerGraph, sched: ScopeSchedule) -> float:
        return self.system_time(graph, sched.segments)

    def throughput(self, graph: LayerGraph, sched_or_latency) -> float:
        lat = (
            sched_or_latency
            if isinstance(sched_or_latency, float)
            else self.evaluate(graph, sched_or_latency)
        )
        if lat == INF or lat <= 0:
            return 0.0
        return self.m / lat
