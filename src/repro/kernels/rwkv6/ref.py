"""Sequential jnp oracle for the WKV-6 recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, logw, u):
    """r,k,v,logw [B,H,S,hd]; u [H,hd] -> (out [B,H,S,hd], S_last [B,H,hd,hd])."""
    B, H, S, hd = r.shape
    w = jnp.exp(logw.astype(jnp.float32))

    def step(S_state, t):
        rt, kt, vt, wt = (x[:, :, t].astype(jnp.float32) for x in (r, k, v, w))
        kv = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhi,bhij->bhj", rt, S_state + u[None, :, :, None] * kv)
        S_state = wt[..., :, None] * S_state + kv
        return S_state, out

    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    S_last, outs = jax.lax.scan(step, S0, jnp.arange(S))
    return jnp.moveaxis(outs, 0, 2), S_last
