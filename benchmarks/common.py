"""Shared benchmark utilities: scheduling runs with a JSON result cache."""
from __future__ import annotations

import json
import os
import time

from repro.core.costmodel import INF
from repro.core.fastcost import FastCostModel
from repro.core.baselines import ALL_METHODS
from repro.core.hw import mcm_table_iii
from repro.core.workloads import get_cnn

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
M_SAMPLES = 16          # inference batch streamed through the pipeline


def _cache_path(name: str) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, name + ".json")


def cached(name: str, fn, refresh: bool = False):
    path = _cache_path(name)
    if not refresh and os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    out = fn()
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    return out


def run_method(net: str, chips: int, method: str) -> dict:
    g = get_cnn(net)
    hw = mcm_table_iii(chips)
    # The vectorized + memoized engine (exact parity with CostModel).
    cost = FastCostModel(hw, m_samples=M_SAMPLES)
    t0 = time.time()
    sched = ALL_METHODS[method](g, cost, chips)
    dt = time.time() - t0
    if sched is None or sched.latency == INF:
        return {"net": net, "chips": chips, "method": method, "valid": False,
                "search_s": dt}
    return {
        "net": net, "chips": chips, "method": method, "valid": True,
        "latency_s": sched.latency,
        "throughput": cost.throughput(g, sched.latency),
        "n_segments": len(sched.segments) or None,
        "clusters_per_segment": [s.n_clusters for s in sched.segments],
        "search_s": dt,
    }
