"""Region allocation: proportional seed + iterative rebalance + ZigZag placement.

Paper SSIV-B: chiplets are first allocated across regions proportionally to
cluster computational load; the heuristic then repeatedly moves one chiplet
from the fastest region to the slowest until overall latency stops improving.
Regions are laid out on the 2D mesh in a ZigZag (boustrophedon) pattern.

``RegionMode.UNIFORM`` is the TPU/SPMD constraint (DESIGN.md SS3): all regions
must have equal chip counts, so only ``chips % n_regions == 0`` allocations
are legal and the rebalance loop is disabled -- balance must come from the
cluster-merge dimension instead.
"""
from __future__ import annotations

import enum


class RegionMode(enum.Enum):
    FREE = "free"          # paper: arbitrary per-region chip counts
    UNIFORM = "uniform"    # TPU SPMD: equal-size regions only


def proportional_allocate(loads: list[float], chips: int) -> list[int]:
    """Seed allocation: >=1 chip each, proportional to load, sum == chips."""
    n = len(loads)
    if n > chips:
        raise ValueError(f"{n} clusters > {chips} chips")
    total = sum(loads) or 1.0
    alloc = [max(1, int(chips * l / total)) for l in loads]
    # repair the sum: remove from the most over-provisioned, add to the most under
    def pressure(i):  # chips per unit load (higher -> over-provisioned)
        return alloc[i] / max(loads[i], 1e-30)
    while sum(alloc) > chips:
        cand = max((i for i in range(n) if alloc[i] > 1), key=pressure, default=None)
        if cand is None:
            raise ValueError("cannot satisfy >=1 chip per region")
        alloc[cand] -= 1
    while sum(alloc) < chips:
        cand = min(range(n), key=pressure)
        alloc[cand] += 1
    return alloc


def uniform_allocate(n_regions: int, chips: int) -> list[int] | None:
    if chips % n_regions != 0:
        return None
    return [chips // n_regions] * n_regions


def zigzag_placement(region_sizes: list[int], mesh_shape: tuple[int, int]) -> list[list[tuple[int, int]]]:
    """Assign chip coordinates to regions walking the mesh boustrophedon.

    Keeps each region spatially contiguous, as validated by prior work
    ([17] Tangram) -- consecutive regions share a seam, which is what the
    cost model's cross-region boundary term assumes.
    """
    rows, cols = mesh_shape
    order = []
    for r in range(rows):
        rng = range(cols) if r % 2 == 0 else range(cols - 1, -1, -1)
        order.extend((r, c) for c in rng)
    if sum(region_sizes) > len(order):
        raise ValueError("regions exceed mesh capacity")
    out, cursor = [], 0
    for size in region_sizes:
        out.append(order[cursor : cursor + size])
        cursor += size
    return out


def rebalance(
    alloc: list[int],
    eval_fn,
    max_iters: int = 256,
) -> tuple[list[int], float, list[float]]:
    """Paper's heuristic: move 1 chip from the fastest to the slowest region.

    ``eval_fn(alloc) -> (latency, per_cluster_times)``.  Continues while the
    move strictly improves latency (Alg. 1's inner while-loop).
    """
    best = list(alloc)
    best_lat, best_times = eval_fn(best)
    for _ in range(max_iters):
        if not best_times or best_lat == float("inf"):
            # Infeasible seed: still try to feed the bottleneck if we know it.
            break
        slow = max(range(len(best_times)), key=lambda j: best_times[j])
        fast = min(
            (j for j in range(len(best_times)) if best[j] > 1 and j != slow),
            key=lambda j: best_times[j],
            default=None,
        )
        if fast is None:
            break
        trial = list(best)
        trial[slow] += 1
        trial[fast] -= 1
        lat, times = eval_fn(trial)
        if lat < best_lat:
            best, best_lat, best_times = trial, lat, times
        else:
            break
    return best, best_lat, best_times
