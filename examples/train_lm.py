"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Builds a granite-family model scaled to ~100M params, lets the Scope DSE
pick the WSP/ISP plan, and runs the fault-tolerant training loop (with a
mid-run injected failure to demonstrate checkpoint restart) on the local
mesh.  Loss drops from ~uniform (ln V ~ 6.2) toward the Markov-chain floor.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import make_batch_iterator
from repro.ft import ResilientTrainer
from repro.launch.mesh import single_device_mesh
from repro.models import init_params
from repro.models.model import param_count
from repro.optim import make_optimizer
from repro.runtime.planner import plan_for_cell
from repro.runtime.train import build_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=16)
ap.add_argument("--seq", type=int, default=64)
args = ap.parse_args()

# granite-3-8b family scaled to ~100M params
cfg = dataclasses.replace(
    get_config("granite-3-8b"),
    name="granite-100m", n_layers=4, d_model=512, n_heads=8, n_kv_heads=2,
    d_head=64, d_ff=1536, vocab=4096, param_dtype="float32", accum_steps=1,
)
params = init_params(cfg, jax.random.PRNGKey(0))
print(f"model: {cfg.name}, {param_count(params) / 1e6:.1f}M params")

mesh = single_device_mesh()
plan = plan_for_cell(cfg, args.seq, args.batch, ("data", "model"), 1,
                     kind="train", use_dse=False)
step, _ = build_train_step(cfg, mesh, plan, base_lr=3e-3, warmup=20,
                           total_steps=args.steps)
init_fn, _u = make_optimizer(cfg.optimizer)
opt = init_fn(params)

it = make_batch_iterator(cfg, batch=args.batch, seq=args.seq)
store = {}


def batch_fn(s):
    while s not in store:
        i, b = next(it)
        store[i] = {k: jnp.asarray(v) for k, v in b.items()}
    return store[s]


def injector(s):
    if s == args.steps // 2 and not getattr(injector, "fired", False):
        injector.fired = True
        print(f"  !! injecting node failure at step {s} "
              "(recovery via checkpoint restart)")
        raise RuntimeError("injected failure")


with tempfile.TemporaryDirectory() as ckpt_dir:
    trainer = ResilientTrainer(train_step=step, batch_fn=batch_fn,
                               ckpt_dir=ckpt_dir, ckpt_every=25)
    params, opt, hist = trainer.run(params, opt, n_steps=args.steps,
                                    failure_injector=injector)

for h in hist:
    if h["step"] % 25 == 0 or h["step"] == 1:
        print(f"step {h['step']:4d}  loss {h['loss']:.4f}")
print(f"\nloss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
      f"(uniform = {jnp.log(cfg.vocab):.3f})")
assert hist[-1]["loss"] < hist[0]["loss"] - 1.0, "expected a clear loss drop"
