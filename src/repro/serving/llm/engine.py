"""Token-level serving executor: continuous batching over prefill/decode.

Runs an :class:`~repro.serving.llm.phases.LLMPlan` against a request trace
whose requests carry seeded prompt/output token lengths
(:class:`~repro.serving.traffic.TokenLengths`).  Per model:

* a **prefill server** batches queued prompts (FIFO or EDF order) and runs
  one pipeline pass per batch -- the batch's first tokens are produced at
  batch completion (TTFT);
* a **decode server** holds a pool of active sequences and runs *steps*: a
  step over ``b`` active sequences emits one token each and takes
  ``(stages - 1 + b) * beat`` under the decode schedule's own service law,
  so a pool saturated at the DSE batch reproduces the solved decode
  throughput exactly.

**Continuous batching** admits prefilled sequences into the running pool at
step boundaries whenever KV capacity allows (counted by the
``llm.admitted_midbatch`` counter); **static batching** (``static=True``,
the whole-request baseline) admits only into an empty pool and reserves the
full batch width until every member finishes -- the classic drain waste
that continuous batching exists to remove.  Admission enforces the
searched KV bound in *bytes* (``sum of per-sequence state <= quota
capacity``), so the occupancy series can never exceed the bound the DSE
assumed.

Deployment modes follow the plan: **disaggregated** runs the two servers
concurrently with a per-request KV hand-off delay
(``kv_prompt_bytes / handoff_bw``) between prefill completion and decode
eligibility; **colocated** serializes both phases on one server --
arbitration between a ready prefill batch and pending decode steps is
prefill-first under ``queue_policy="fifo"`` and deadline-driven (TTFT
deadline vs next-token TPOT deadline) under ``"edf"``.  Batch-delay timers
are deduplicated per ``(model, phase)``, the PR 5 one-timer-per-model fix
extended to phases.

Wall-clock-free and deterministic under the trace seed, like the
whole-request executor.
"""
from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass, field

from ...core.hw import HardwareModel
from ...multimodel.curves import service_law
from ...obs import current_tracer
from ..executor import BatchingPolicy
from ..metrics import conserve_waterfall
from ..traffic import Request
from .kv import kv_seq_bytes
from .metrics import LLM_WATERFALL_COMPONENTS, LLMReport, summarize_llm
from .phases import LLMPlan, PhaseAssignment

INF = float("inf")
_EPS = 1e-12

# event kinds (heap order at equal times: arrivals before timers before
# completions, completions before hand-off wakes)
_ARRIVE, _TIMER, _PDONE, _DDONE, _HAND = 0, 1, 2, 3, 4

__all__ = ["TokenExecutor", "simulate_tokens"]


@dataclass
class _Seq:
    """One sequence resident in (or bound for) a decode pool."""
    req: Request
    kv: float                  # resident state bytes at full context
    t_first: float             # first-token time (prefill completion)
    remaining: int             # decode tokens still to emit
    acct: dict = field(default_factory=dict)   # waterfall accumulators


@dataclass
class _MState:
    a: PhaseAssignment
    stages_p: int
    beat_p: float
    stages_d: int
    beat_d: float
    coloc: bool
    p_max: int                 # prefill batch cap
    d_max: int                 # decode pool cap (DSE batch ^ KV bound)
    queue: deque = field(default_factory=deque)
    waiting: deque = field(default_factory=deque)   # admission-eligible seqs
    pool: list = field(default_factory=list)
    pool_kv: float = 0.0
    busy_p: bool = False
    busy_d: bool = False
    static_slots: int = 0      # reserved batch width (static mode)
    inflight_hand: int = 0     # seqs between prefill and decode eligibility
    step_t0: float = 0.0       # start of the current decode busy run
    run_steps: int = 0         # steps in the current decode busy run
    t_last_step: float = 0.0
    prefill_batches: int = 0
    decode_steps: int = 0
    admitted_midbatch: int = 0
    busy_chip_s: float = 0.0
    kv_trace: list = field(default_factory=list)
    q_trace: list = field(default_factory=list)   # (t, queue depth)


class TokenExecutor:
    """Discrete-event token-level engine over a solved :class:`LLMPlan`."""

    def __init__(
        self,
        plan: LLMPlan,
        hw: HardwareModel,
        batching: BatchingPolicy | None = None,
        slos: dict[str, tuple[float | None, float | None]] | None = None,
        static: bool = False,
        seed: int = 0,
        tracer=None,
    ):
        self.plan = plan
        self.hw = hw
        self.batching = batching or BatchingPolicy()
        self.slos = slos or {}
        self.static = static
        self.seed = seed
        self.tracer = tracer if tracer else None
        self.states: dict[str, _MState] = {}
        for a in plan.assignments:
            sp, bp = service_law(a.prefill_schedule)
            if a.decode_schedule is not None:
                sd, bd = service_law(a.decode_schedule)
                m_d = a.decode_schedule.meta.get("m_samples", 1)
            else:
                sd, bd, m_d = 1, 0.0, 1
            self.states[a.model] = _MState(
                a=a, stages_p=sp, beat_p=bp, stages_d=sd, beat_d=bd,
                coloc=plan.mode == "colocated",
                p_max=max(1, self.batching.max_batch),
                d_max=max(1, min(m_d, a.max_seqs)),
            )
        self._heap: list = []
        self._seq = 0
        self._timer_at: dict[tuple[str, str], float] = {}
        self._arrived: dict[str, int] = {m: 0 for m in self.states}
        self._dropped: dict[str, dict[str, int]] = {m: {} for m in self.states}
        self._completions: dict[str, list] = {m: [] for m in self.states}
        self.waterfalls: dict[str, list[dict]] = {m: [] for m in self.states}
        self._makespan = 0.0

    # ----------------------------------------------------------- plumbing
    def _push(self, t: float, kind: int, payload) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (t, kind, self._seq, payload))

    def _deadline(self, r: Request, ms: _MState) -> float:
        ttft_slo = self.slos.get(r.model, (None, None))[0]
        return r.t_arrive + (ttft_slo if ttft_slo is not None
                             else self.batching.max_delay_s)

    def _drop(self, r: Request, cause: str) -> None:
        by = self._dropped[r.model]
        by[cause] = by.get(cause, 0) + 1

    def _complete(self, r: Request, ttft: float, tpot: float | None,
                  t: float) -> None:
        self._completions[r.model].append(
            (ttft, tpot, r.prompt_tokens, r.output_tokens))
        self._makespan = max(self._makespan, t)

    def _finish_waterfall(self, r: Request, comps: dict, t_done: float) -> None:
        """Close a per-request waterfall, conserved against end-to-end latency."""
        total = t_done - r.t_arrive
        wf = conserve_waterfall(comps, total, order=LLM_WATERFALL_COMPONENTS)
        wf["total"] = total
        self.waterfalls[r.model].append(wf)

    def _note_queue(self, model: str, ms: _MState, t: float) -> None:
        depth = len(ms.queue)
        ms.q_trace.append((t, depth))
        if self.tracer is not None:
            self.tracer.counter(f"queue:{model}", t, depth, group="serving")

    def _note_kv(self, model: str, ms: _MState, t: float) -> None:
        ms.kv_trace.append((t, max(0.0, ms.pool_kv)))
        if self.tracer is not None:
            self.tracer.counter(f"kv_bytes/{model}", t,
                                max(0.0, ms.pool_kv), group="llm")

    # ------------------------------------------------------------ arrival
    def _arrive(self, r: Request, t: float) -> None:
        ms = self.states.get(r.model)
        if ms is None:
            raise KeyError(f"trace names unknown model {r.model!r}")
        self._arrived[r.model] += 1
        kv = kv_seq_bytes(ms.a.cfg, r.prompt_tokens + r.output_tokens)
        if (r.output_tokens > 1 and ms.a.kv_capacity_bytes
                and kv > ms.a.kv_capacity_bytes):
            self._drop(r, "kv_overflow")
            return
        cap = self.batching.max_queue_samples
        if cap is not None and len(ms.queue) >= cap:
            self._drop(r, "queue_full")
            return
        ms.queue.append(r)
        self._note_queue(r.model, ms, t)
        self._schedule(r.model, t)

    # --------------------------------------------------------- scheduling
    def _prefill_ready(self, ms: _MState, t: float) -> bool:
        if not ms.queue:
            return False
        if len(ms.queue) >= ms.p_max:
            return True
        oldest = min(r.t_arrive for r in ms.queue)
        return t >= oldest + self.batching.max_delay_s - _EPS

    def _set_timer(self, model: str, ms: _MState, t: float) -> None:
        if not ms.queue:
            return
        oldest = min(r.t_arrive for r in ms.queue)
        deadline = oldest + self.batching.max_delay_s
        key = (model, "prefill")
        if self._timer_at.get(key, INF) > deadline + _EPS:
            self._timer_at[key] = deadline
            self._push(deadline, _TIMER, key)

    def _decode_pending(self, ms: _MState) -> bool:
        if ms.pool:
            return True
        if not ms.waiting:
            return False
        if self.static:
            return not ms.pool          # admits only into an empty pool
        w = ms.waiting[0]
        return (len(ms.pool) < ms.d_max
                and ms.pool_kv + w.kv <= ms.a.kv_capacity_bytes + _EPS)

    def _schedule(self, model: str, t: float) -> None:
        ms = self.states[model]
        if ms.coloc:
            if ms.busy_p or ms.busy_d:
                return
            p_ready = self._prefill_ready(ms, t)
            d_ready = self._decode_pending(ms)
            if p_ready and d_ready and self.batching.queue_policy == "edf":
                # deadline arbitration: the queue head's TTFT deadline vs
                # the pool's next-token TPOT deadline
                p_dl = min(self._deadline(r, ms) for r in ms.queue)
                tpot_slo = self.slos.get(model, (None, None))[1]
                d_dl = (ms.t_last_step + tpot_slo
                        if (ms.pool and tpot_slo is not None) else INF)
                if d_dl < p_dl:
                    self._start_decode(model, ms, t)
                else:
                    self._start_prefill(model, ms, t)
            elif p_ready:
                self._start_prefill(model, ms, t)
            elif d_ready:
                self._start_decode(model, ms, t)
            else:
                self._set_timer(model, ms, t)
            return
        if not ms.busy_p:
            if self._prefill_ready(ms, t):
                self._start_prefill(model, ms, t)
            else:
                self._set_timer(model, ms, t)
        if not ms.busy_d and self._decode_pending(ms):
            self._start_decode(model, ms, t)

    # ------------------------------------------------------------ prefill
    def _start_prefill(self, model: str, ms: _MState, t: float) -> None:
        if self.batching.queue_policy == "edf":
            batch = sorted(ms.queue, key=lambda r: (self._deadline(r, ms),
                                                    r.seq))[:ms.p_max]
            picked = set(id(r) for r in batch)
            ms.queue = deque(r for r in ms.queue if id(r) not in picked)
        else:
            batch = [ms.queue.popleft() for _ in range(
                min(ms.p_max, len(ms.queue)))]
        self._note_queue(model, ms, t)
        eff = sum(max(1, r.prompt_tokens) for r in batch) / max(
            1, self.plan.seq_len)
        dur = (ms.stages_p - 1 + eff) * ms.beat_p
        ms.busy_p = True
        ms.busy_chip_s += dur * ms.a.prefill_chips
        self._push(t + dur, _PDONE, (model, batch, t))

    def _prefill_done(self, model: str, batch: list[Request], t0: float,
                      t: float) -> None:
        ms = self.states[model]
        ms.busy_p = False
        ms.prefill_batches += 1
        if self.tracer is not None:
            self.tracer.complete(f"prefill x{len(batch)}", t0, t,
                                 group="llm", lane=f"{model}/prefill",
                                 reqs=len(batch))
        for r in batch:
            ttft = t - r.t_arrive
            queue_wait = t0 - r.t_arrive
            prefill = t - t0
            if r.output_tokens <= 1:
                self._complete(r, ttft, None, t)
                self._finish_waterfall(
                    r, {"queue_wait": queue_wait, "prefill": prefill,
                        "kv_handoff": 0.0, "admission_wait": 0.0,
                        "decode": 0.0}, t)
                continue
            seq = _Seq(req=r,
                       kv=kv_seq_bytes(ms.a.cfg,
                                       r.prompt_tokens + r.output_tokens),
                       t_first=t, remaining=r.output_tokens - 1)
            seq.acct = {"queue_wait": queue_wait, "prefill": prefill,
                        "kv_handoff": 0.0, "ready": t}
            if ms.coloc or self.plan.handoff_bw <= 0:
                ms.waiting.append(seq)
            else:
                delay = kv_seq_bytes(ms.a.cfg, r.prompt_tokens) \
                    / self.plan.handoff_bw
                seq.acct["kv_handoff"] = delay
                seq.acct["ready"] = t + delay
                ms.inflight_hand += 1
                self._push(t + delay, _HAND, (model, seq))
        self._makespan = max(self._makespan, t)
        self._schedule(model, t)

    def _handoff(self, model: str, seq: _Seq, t: float) -> None:
        ms = self.states[model]
        ms.inflight_hand -= 1
        ms.waiting.append(seq)
        self._schedule(model, t)

    # ------------------------------------------------------------- decode
    def _admit(self, ms: _MState, t: float) -> None:
        was = len(ms.pool)
        admitted = 0
        if self.static:
            if ms.pool:
                return
            while ms.waiting and len(ms.pool) < ms.d_max and (
                    ms.pool_kv + ms.waiting[0].kv
                    <= ms.a.kv_capacity_bytes + _EPS):
                s = ms.waiting.popleft()
                s.acct["admit"] = t
                ms.pool.append(s)
                ms.pool_kv += s.kv
                admitted += 1
            ms.static_slots = len(ms.pool)
        else:
            while ms.waiting and len(ms.pool) < ms.d_max and (
                    ms.pool_kv + ms.waiting[0].kv
                    <= ms.a.kv_capacity_bytes + _EPS):
                s = ms.waiting.popleft()
                s.acct["admit"] = t
                ms.pool.append(s)
                ms.pool_kv += s.kv
                admitted += 1
            if was > 0 and admitted:
                ms.admitted_midbatch += admitted
                if self.tracer is not None:
                    self.tracer.instant("admit_midbatch", t=t,
                                        group="llm",
                                        lane=f"{ms.a.model}/decode",
                                        n=admitted)
        if admitted:
            self._note_kv(ms.a.model, ms, t)

    def _start_decode(self, model: str, ms: _MState, t: float) -> None:
        if not ms.pool:
            ms.step_t0 = t
            ms.run_steps = 0
        self._admit(ms, t)
        if not ms.pool:
            return
        b = ms.static_slots if self.static else len(ms.pool)
        dur = (ms.stages_d - 1 + b) * ms.beat_d
        ms.busy_d = True
        ms.busy_chip_s += dur * ms.a.decode_chips
        self._push(t + dur, _DDONE, model)

    def _decode_done(self, model: str, t: float) -> None:
        ms = self.states[model]
        ms.busy_d = False
        ms.decode_steps += 1
        ms.run_steps += 1
        ms.t_last_step = t
        finished = [s for s in ms.pool if s.remaining <= 1]
        ms.pool = [s for s in ms.pool if s.remaining > 1]
        for s in ms.pool:
            s.remaining -= 1
        for s in finished:
            ms.pool_kv -= s.kv
            r = s.req
            tpot = (t - s.t_first) / max(1, r.output_tokens - 1)
            self._complete(r, s.t_first - r.t_arrive, tpot, t)
            a = s.acct
            admit = a.get("admit", a.get("ready", t))
            self._finish_waterfall(
                r, {"queue_wait": a.get("queue_wait", 0.0),
                    "prefill": a.get("prefill", 0.0),
                    "kv_handoff": a.get("kv_handoff", 0.0),
                    "admission_wait": admit - a.get("ready", admit),
                    "decode": t - admit}, t)
        if finished:
            self._note_kv(model, ms, t)
        if not ms.pool:
            ms.static_slots = 0
            if self.tracer is not None and ms.run_steps:
                self.tracer.complete(f"decode x{ms.run_steps}", ms.step_t0,
                                     t, group="llm", lane=f"{model}/decode",
                                     steps=ms.run_steps)
        self._makespan = max(self._makespan, t)
        self._schedule(model, t)

    # ---------------------------------------------------------------- run
    def run(self, trace: list[Request],
            horizon_s: float | None = None) -> LLMReport:
        for r in trace:
            self._push(r.t_arrive, _ARRIVE, r)
        if horizon_s is None:
            horizon_s = max((r.t_arrive for r in trace), default=0.0)
        while self._heap:
            t, kind, _, payload = heapq.heappop(self._heap)
            if kind == _ARRIVE:
                self._arrive(payload, t)
            elif kind == _TIMER:
                if self._timer_at.pop(payload, None) is not None:
                    self._schedule(payload[0], t)
            elif kind == _PDONE:
                model, batch, t0 = payload
                self._prefill_done(model, batch, t0, t)
            elif kind == _DDONE:
                self._decode_done(payload, t)
            elif kind == _HAND:
                self._handoff(payload[0], payload[1], t)
        return self._report(horizon_s)

    def _report(self, horizon_s: float) -> LLMReport:
        queued_end = {}
        for m, ms in self.states.items():
            queued_end[m] = (len(ms.queue) + len(ms.waiting) + len(ms.pool)
                             + ms.inflight_hand)
        chips = {}
        for m, ms in self.states.items():
            a = ms.a
            chips[m] = (a.prefill_chips if ms.coloc
                        else a.prefill_chips + a.decode_chips)
        rep = summarize_llm(
            mode=self.plan.mode,
            batching="static" if self.static else "continuous",
            package=self.plan.package,
            chips=self.plan.chips,
            seed=self.seed,
            horizon_s=horizon_s,
            makespan_s=self._makespan,
            arrived=self._arrived,
            dropped=self._dropped,
            queued_end=queued_end,
            completions=self._completions,
            slos={m: self.slos.get(m, (None, None)) for m in self.states},
            model_chips=chips,
            prefill_batches={m: ms.prefill_batches
                             for m, ms in self.states.items()},
            decode_steps={m: ms.decode_steps
                          for m, ms in self.states.items()},
            admitted_midbatch={m: ms.admitted_midbatch
                               for m, ms in self.states.items()},
            kv_traces={m: ms.kv_trace for m, ms in self.states.items()},
            queue_traces={m: ms.q_trace for m, ms in self.states.items()},
            waterfalls=self.waterfalls,
            kv_capacity={m: ms.a.kv_capacity_bytes
                         for m, ms in self.states.items()},
            busy_chip_s={m: ms.busy_chip_s for m, ms in self.states.items()},
            meta={"mix_rate": self.plan.mix_rate,
                  "queue_policy": self.batching.queue_policy,
                  "plan_token_rate": self.plan.token_rate},
        )
        rep.tracer = self.tracer
        return rep


def simulate_tokens(
    plan: LLMPlan,
    hw: HardwareModel,
    trace: list[Request],
    batching: BatchingPolicy | None = None,
    slos: dict[str, tuple[float | None, float | None]] | None = None,
    static: bool = False,
    horizon_s: float | None = None,
    seed: int = 0,
    tracer=None,
) -> LLMReport:
    """One-call wrapper mirroring :func:`repro.serving.executor.simulate`."""
    if tracer is None:
        tracer = current_tracer()
    ex = TokenExecutor(plan, hw, batching=batching, slos=slos, static=static,
                       seed=seed, tracer=tracer)
    return ex.run(trace, horizon_s=horizon_s)
