"""Merged-pipeline execution with shard_map (Scope clusters as stages).

The mesh gains a leading ``stage`` axis; the scanned layer stack [R, ...] is
reshaped to [n_stages, R/n_stages, ...] and sharded over it, so stage ``s``
owns the Scope *cluster* of R/S merged repeats -- uniform regions whose
loads the cluster-merge made equal (DESIGN.md SS3: the SPMD adaptation).

GPipe schedule over ``n_micro`` microbatches: beat t lets stage s work on
microbatch t - s; activations hop stages via ``ppermute`` (double-buffered:
the edge transfer of beat t overlaps the compute of beat t+1 at the HLO
level since the permute result is only consumed next iteration).  Total
beats = n_micro + n_stages - 1, i.e. paper Eq. 2's (m + N_cluster - 1).

Embedding + logits are computed outside the pipelined block stack (tables
replicated over ``stage``); DP runs on the ``data`` axis inside the same
shard_map (grads all-reduced with ``psum``, optionally int8-compressed with
error feedback).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..models.config import ModelConfig
from ..models.layers import dense, embed, rmsnorm, softcap
from ..models.model import _block_prefill


def _stage_params_pspec(params_blocks):
    """blocks pytree [R, ...] -> spec sharding dim0 over 'stage'."""
    return jax.tree.map(lambda _: P("stage"), params_blocks)


def _stack_for_stages(blocks, n_stages: int):
    """[R, ...] -> [n_stages, R/S, ...] so dim0 shards over 'stage'."""
    def resh(a):
        R = a.shape[0]
        assert R % n_stages == 0, (R, n_stages)
        return a.reshape(n_stages, R // n_stages, *a.shape[1:])
    return jax.tree.map(resh, blocks)


def pipeline_forward(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,            # [n_micro, mb, S]
    mesh: Mesh,
    n_stages: int,
):
    """Pipelined forward producing logits [n_micro, mb, S, vocab]."""
    n_micro, mb, S = tokens.shape
    stacked = _stack_for_stages(params["blocks"], n_stages)

    def run(blocks_local, x_micro):
        # blocks_local: [1, R/S, ...] (this stage's cluster);  x_micro:
        # [n_micro, mb_local, S, d] -- every stage sees the full embedded
        # microbatch stack (produced outside; only stage 0 reads it).
        blocks_local = jax.tree.map(lambda a: a[0], blocks_local)
        sidx = jax.lax.axis_index("stage")
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (x_micro.shape[1], S))

        def stage_compute(x):
            def body(h, bps):
                for pi, kind in enumerate(cfg.expanded_pattern):
                    h, _ = _block_prefill(cfg, kind, pi, bps[pi], h, positions,
                                          lambda a, tag: a)
                return h, None
            out, _ = jax.lax.scan(body, x, blocks_local)
            return out

        d = x_micro.shape[-1]
        beats = n_micro + n_stages - 1
        carry = jnp.zeros_like(x_micro[0])
        outputs = jnp.zeros_like(x_micro)

        def beat(t, state):
            carry, outputs = state
            # stage 0 ingests microbatch t; others take the permuted edge
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            fresh = jax.lax.dynamic_index_in_dim(x_micro, mb_idx, 0, keepdims=False)
            x_in = jnp.where(sidx == 0, fresh, carry)
            y = stage_compute(x_in)
            # last stage banks its result for microbatch t - (n_stages - 1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            bank = jnp.logical_and(sidx == n_stages - 1, t >= n_stages - 1)
            outputs = jax.lax.cond(
                bank,
                lambda o: jax.lax.dynamic_update_index_in_dim(o, y, out_idx, 0),
                lambda o: o,
                outputs,
            )
            # forward edge: stage s -> s+1 (ring; the wraparound is ignored)
            nxt = jax.lax.ppermute(
                y, "stage", [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            return (nxt, outputs)

        _, outputs = jax.lax.fori_loop(0, beats, beat, (carry, outputs))
        # results live on the last stage; broadcast over the stage axis
        outputs = jax.lax.psum(
            jnp.where(sidx == n_stages - 1, outputs, jnp.zeros_like(outputs)),
            "stage",
        )
        return outputs

    x = embed(tokens, params["embed"])          # outside the pipeline
    run_sharded = shard_map(
        run,
        mesh=mesh,
        in_specs=(_stage_params_pspec(stacked), P(None, "data", None, None)),
        out_specs=P(None, "data", None, None),
        check_rep=False,
    )
    h = run_sharded(stacked, x)
    h = rmsnorm(h, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = dense(h, head)
    return softcap(logits.astype(jnp.float32), cfg.logit_softcap)


def build_pipeline_train_step(cfg: ModelConfig, mesh: Mesh, n_stages: int,
                              n_micro: int, lr: float = 1e-3):
    """SGD pipeline trainer (demonstrates the merged-pipeline path end to
    end; the pjit path in runtime/train.py is the production trainer)."""

    def loss_fn(params, tokens, labels):
        logits = pipeline_forward(params, cfg, tokens, mesh, n_stages)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        return nll.mean()

    @jax.jit
    def step(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(
            params, batch["tokens"], batch["labels"]
        )
        params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads,
        )
        return params, loss

    return step
