"""Kernel micro-benchmarks (CPU wall time of the *reference* path + the
interpret-mode kernel run for correctness-parity; real-TPU timing is not
available in this container, so `derived` reports the model FLOPs of the
call -- the roofline table covers per-chip performance).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.mamba.ops import mamba_scan
from repro.kernels.qmatmul.ops import qmatmul
from repro.kernels.rwkv6.ops import wkv6


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    key = jax.random.PRNGKey(0)
    rows = []

    B, H, KV, S, hd = 1, 8, 2, 1024, 64
    q = jax.random.normal(key, (B, H, S, hd))
    k = jax.random.normal(key, (B, KV, S, hd))
    v = jax.random.normal(key, (B, KV, S, hd))
    us = _time(flash_attention, q, k, v, impl="ref")
    rows.append(("flash_attention_ref_1k", us, 4.0 * B * H * S * S * hd / 2))

    r = jax.random.normal(key, (1, 4, 512, 64))
    w = jnp.log(jax.random.uniform(key, (1, 4, 512, 64), minval=0.8, maxval=0.99))
    u = jax.random.normal(key, (4, 64))
    us = _time(wkv6, r, r, r, w, u, impl="ref")
    rows.append(("wkv6_ref_512", us, 4.0 * 4 * 512 * 64 * 64))

    dt = jax.nn.softplus(jax.random.normal(key, (1, 512, 256)))
    x = jax.random.normal(key, (1, 512, 256))
    A = -jnp.exp(jax.random.normal(key, (256, 16)) * 0.5)
    Bc = jax.random.normal(key, (1, 512, 16))
    D = jnp.ones((256,))
    us = _time(mamba_scan, dt, x, A, Bc, Bc, D, impl="ref")
    rows.append(("mamba_scan_ref_512", us, 10.0 * 512 * 256 * 16))

    xq = jax.random.randint(key, (256, 512), -127, 128, jnp.int8)
    wq = jax.random.randint(key, (512, 256), -127, 128, jnp.int8)
    s1, s2 = jnp.ones((256,)), jnp.ones((256,))
    us = _time(qmatmul, xq, wq, s1, s2, impl="ref")
    rows.append(("qmatmul_ref_256x512x256", us, 2.0 * 256 * 512 * 256))

    return rows


def report(rows):
    lines = ["name,us_per_call,derived_flops"]
    for name, us, fl in rows:
        lines.append(f"{name},{us:.1f},{fl:.3e}")
    return lines
