"""Legacy CLI shim: forwards to the general solver front door.

    PYTHONPATH=src python -m repro.multimodel.cli \
        --mix resnet50:1,alexnet:1 --hw mcm16 [--step 1] [--baselines]

is now exactly

    PYTHONPATH=src python -m repro solve --strategy coschedule \
        --mix resnet50:1,alexnet:1 --hw mcm16 [--step 1] [--baselines]

(every historical flag is accepted by ``repro solve`` under the same name;
the pinned strategy preserves this CLI's historical behavior of always
running ``co_schedule``, even for single-entry mixes where ``repro
solve``'s auto-selection would pick the single-model DSE).  Kept so
existing invocations keep working; new code should call ``python -m repro
solve`` or :func:`repro.api.solve` directly.
"""
from __future__ import annotations

import sys


def main(argv=None) -> None:
    from ..__main__ import main as repro_main

    argv = list(sys.argv[1:] if argv is None else argv)
    # First so an explicit user --strategy (argparse last-wins) overrides.
    repro_main(["solve", "--strategy", "coschedule", *argv])


if __name__ == "__main__":
    main()
