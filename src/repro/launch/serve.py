"""Serving launcher: batched prefill + greedy decode.

``python -m repro.launch.serve --arch granite-3-8b --smoke --tokens 32``
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.launch.mesh import make_mesh
from repro.models import forward, init_kv_cache, init_params
from repro.runtime.planner import plan_for_cell
from repro.runtime.serve import build_decode_step, greedy_generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    dims = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_mesh(dims, ("data", "model"))
    max_len = args.prompt_len + args.tokens
    plan = plan_for_cell(cfg, max_len, args.batch, ("data", "model"),
                         model_axis=dims[1], kind="decode")
    params = init_params(cfg, jax.random.PRNGKey(0))

    # prefill the prompt token-by-token through the decode path (exercises
    # exactly the serve_step the dry-run lowers)
    dstep, _ = build_decode_step(cfg, mesh, plan, batch=args.batch, max_len=max_len)
    caches = init_kv_cache(cfg, args.batch, max_len,
                           jnp.float32 if args.smoke else jnp.bfloat16)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    tok = prompt[:, :1]
    for t in range(args.prompt_len):
        pos = jnp.full((args.batch,), t, jnp.int32)
        logits, caches = dstep(params, prompt[:, t:t + 1], pos, caches)
    t0 = time.time()
    out, _ = greedy_generate(cfg, params, dstep, caches,
                             prompt_last_token=jnp.argmax(logits[:, -1], -1)
                             .astype(jnp.int32)[:, None],
                             start_pos=args.prompt_len, steps=args.tokens)
    dt = time.time() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s)")
    print("sample:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
